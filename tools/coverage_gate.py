#!/usr/bin/env python3
"""Line-coverage gate: fresh `cargo llvm-cov` totals vs the committed floor.

Usage:
    python3 tools/coverage_gate.py --summary /tmp/coverage.json \
        --floor tools/coverage_floor.txt

The floor file holds one number: the line-coverage percentage the suite is
committed to (authored conservatively, ratcheted up by hand when coverage
grows). The gate reads the ``--summary-only --json`` export of
``cargo llvm-cov`` and fails when the measured line percentage falls below
the floor — a regression in test coverage blocks, growth never does.
"""

import argparse
import json
import sys


def line_percent(doc):
    """Total line-coverage percentage from an llvm-cov JSON summary."""
    try:
        return float(doc["data"][0]["totals"]["lines"]["percent"])
    except (KeyError, IndexError, TypeError, ValueError) as e:
        sys.exit(f"coverage gate: malformed llvm-cov summary ({e!r})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--summary", required=True, help="cargo llvm-cov --json output")
    ap.add_argument("--floor", required=True, help="file holding the committed floor %")
    args = ap.parse_args()

    with open(args.summary) as f:
        got = line_percent(json.load(f))
    with open(args.floor) as f:
        raw = f.read().strip()
    try:
        floor = float(raw)
    except ValueError:
        sys.exit(f"coverage gate: floor file holds {raw!r}, expected a number")
    if not 0.0 <= floor <= 100.0:
        sys.exit(f"coverage gate: floor {floor} out of range [0, 100]")

    if got < floor:
        sys.exit(
            f"coverage gate: line coverage {got:.2f}% fell below the committed "
            f"floor {floor:.2f}% — add tests or (deliberately) lower the floor"
        )
    print(f"coverage gate: line coverage {got:.2f}% >= floor {floor:.2f}%")
    headroom = got - floor
    if headroom > 10.0:
        print(
            f"coverage gate: note — {headroom:.1f} points of headroom; "
            f"consider ratcheting the floor up"
        )


if __name__ == "__main__":
    main()
