#!/usr/bin/env python3
"""Perf-trajectory gate: fresh quick-mode bench points vs committed baselines.

Usage:
    python3 tools/trajectory_gate.py --baseline BENCH_scaling.json \
        --fresh /tmp/fresh_scaling.json [--min-ratio 0.75]

The committed BENCH_*.json files at the repo root are the perf trajectory:
conservative throughput floors authored at quick-mode scale. This gate
re-measures at the same scale and fails if any shared point fell below
``min_ratio`` x its committed floor (default 0.75, i.e. a >25% regression).

Keying is schema-aware:

    nekbone-scaling/1    per point (scenario, decomp, operator, degree,
                         ranks, elements) -> throughput_mdofs
    nekbone-roofline/1   per point (operator, degree) -> gflops
    nekbone-serve/1      the whole report -> throughput_rps

Points present only in the fresh run (a new operator, a wider sweep) are
reported and skipped — the gate never blocks growth, only regression.
Points present only in the baseline are also skipped: quick mode may
legitimately cover a subset of a hand-widened baseline.
"""

import argparse
import json
import sys


def key_points(doc):
    """Return {key: throughput} for a parsed BENCH document."""
    schema = doc.get("schema", "<missing>")
    if schema == "nekbone-scaling/1":
        return {
            (
                p["scenario"],
                p["decomp"],
                doc.get("operator", ""),
                p["degree"],
                p["ranks"],
                p["elements"],
            ): p["throughput_mdofs"]
            for p in doc["points"]
        }
    if schema == "nekbone-roofline/1":
        return {(p["operator"], p["degree"]): p["gflops"] for p in doc["points"]}
    if schema == "nekbone-serve/1":
        return {("serve", "throughput_rps"): doc["throughput_rps"]}
    sys.exit(f"trajectory gate: unknown schema {schema!r}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--fresh", required=True, help="freshly measured BENCH_*.json")
    ap.add_argument(
        "--min-ratio",
        type=float,
        default=0.75,
        help="fail when fresh < ratio * baseline (default 0.75)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        base_doc = json.load(f)
    with open(args.fresh) as f:
        fresh_doc = json.load(f)
    if base_doc.get("schema") != fresh_doc.get("schema"):
        sys.exit(
            f"trajectory gate: schema mismatch — baseline "
            f"{base_doc.get('schema')!r} vs fresh {fresh_doc.get('schema')!r}"
        )

    base = key_points(base_doc)
    fresh = key_points(fresh_doc)

    failures = []
    compared = 0
    for key, floor in sorted(base.items(), key=str):
        if key not in fresh:
            print(f"skip (not in fresh run):    {key}")
            continue
        got = fresh[key]
        compared += 1
        verdict = "ok" if got >= args.min_ratio * floor else "REGRESSION"
        print(f"{verdict:<10} {key}: fresh {got:.3f} vs floor {floor:.3f}")
        if verdict != "ok":
            failures.append((key, got, floor))
    for key in sorted(fresh.keys() - base.keys(), key=str):
        print(f"skip (not in baseline):     {key} = {fresh[key]:.3f}")

    if compared == 0:
        sys.exit("trajectory gate: no shared points — baseline and fresh run are disjoint")
    if failures:
        lines = "\n".join(
            f"  {k}: fresh {g:.3f} < {args.min_ratio} x committed {f:.3f}"
            for k, g, f in failures
        )
        sys.exit(f"trajectory gate: {len(failures)} regression(s):\n{lines}")
    print(f"trajectory gate: {compared} point(s) at or above {args.min_ratio}x their floors")


if __name__ == "__main__":
    main()
