//! Paper Fig. 3: the Kebnekaise sweep (448–3584 elements) with the CPU
//! baseline line added (the paper's 28-core node run with MPI).
//!
//! Adds to Fig. 2's version set: the multi-threaded CPU operator (our
//! analog of the CPU/MPI baseline) and the simulated-rank runtime, which is
//! the same code path the real code's MPI layer takes.
//!
//! Run: `cargo bench --bench fig3_v100_versions`

mod common;

use common::{bench_iters, elems_or, have_artifacts, paper_versions, time_solve};
use nekbone::bench::{Runner, Table};
use nekbone::config::RunConfig;
use nekbone::rank::run_ranked;

fn main() {
    if !have_artifacts() {
        return;
    }
    // The paper matches the CPU strong-scaling interval: 16-128 elements
    // per core on 28 cores -> 448..3584.
    let elems = elems_or(&[448, 896, 1792, 3584]);
    let niter = bench_iters();
    println!("# Fig. 3 analog: versions + CPU baseline, degree 9, {niter} CG iterations");
    println!("# (paper: V100 + 28-core CPU node; columns are GFlop/s)\n");

    let versions = paper_versions();
    let mut header: Vec<&str> = vec!["nelt", "dof"];
    for (name, _) in &versions {
        header.push(name);
    }
    header.push("cpu(threads)");
    header.push("cpu(ranked)");
    let mut table = Table::new(&header);

    for &nelt in &elems {
        let mut cells = vec![nelt.to_string(), (nelt * 1000).to_string()];
        for (_, operator) in &versions {
            let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
            let (_s, gflops, _r) = time_solve(operator, &cfg);
            cells.push(format!("{gflops:.3}"));
        }
        // CPU baseline 1: threaded operator in a serial CG.
        let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
        let (_s, gflops, _r) = time_solve("cpu-threaded", &cfg);
        cells.push(format!("{gflops:.3}"));
        // CPU baseline 2: the full simulated-MPI path (rank count = what
        // the element grid supports, capped at 4).
        let mesh = nekbone::mesh::Mesh::for_nelt(nelt, 10).expect("mesh");
        let ranks = mesh.ez.min(4);
        let cfg = RunConfig { nelt, n: 10, niter, ranks, ..RunConfig::default() };
        let runner = Runner::default();
        let samples = runner.run(|| {
            run_ranked(&cfg).expect("ranked");
        });
        let cm = nekbone::metrics::CostModel::new(10, nelt);
        let gf = (cm.flops_per_iter() * niter as u64) as f64 / samples.median() / 1e9;
        cells.push(format!("{gf:.3}"));
        table.row(&cells);
        eprintln!("  nelt={nelt} done");
    }
    table.print();
    println!(
        "\n# paper (V100): layered +10% vs original, +6% vs shared; the CPU line is\n\
         # flat with problem size while the accelerator lines rise."
    );
}
