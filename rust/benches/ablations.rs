//! Ablations over the design choices DESIGN.md calls out:
//!
//! * `unroll`         — E5: trace-time unroll (CUDA C `#pragma unroll`) vs
//!                      run-time loop manually unrolled x2 (CUDA Fortran);
//!                      the paper measures <1% between its two optimized
//!                      kernels.
//! * `vector-backend` — E6: CG vector algebra in Rust (the paper's OpenACC
//!                      role) vs as XLA executables; the paper: "a few
//!                      percentage points".
//! * `degree-sweep`   — E7: the layered kernel at degrees 7/9/11 (the
//!                      shared-memory version cannot build 11 at all).
//! * `chunk-size`     — launch-batch sweep 64/256/1024 + the fused Ax+pap
//!                      executable (dispatch-overhead amortization).
//! * `cpu-fused`      — the fused Ax+pap CPU hot path (persistent worker
//!                      pool; one fewer glsc3 full-vector sweep per CG
//!                      iteration). Runs without artifacts.
//! * `session`        — SolveSession reuse: one application setup serving
//!                      many right-hand sides vs rebuilding the
//!                      application per solve. Runs without artifacts.
//!
//! Run all: `cargo bench --bench ablations`
//! One:     `cargo bench --bench ablations -- unroll`

mod common;

use common::{bench_iters, build_app, have_artifacts, time_solve};
use nekbone::bench::{Runner, Table};
use nekbone::config::RunConfig;
use nekbone::coordinator::{Nekbone, VectorBackend};

fn ablate_unroll(niter: usize) {
    println!("\n== E5: unroll strategy (paper: CUDA C vs CUDA Fortran < 1%) ==");
    let mut table = Table::new(&["nelt", "layered(GF/s)", "unroll2(GF/s)", "delta"]);
    for nelt in [256usize, 1024] {
        let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
        let (_s, a, _r) = time_solve("xla-layered", &cfg);
        let (_s, b, _r) = time_solve("xla-layered-unroll2", &cfg);
        table.row(&[
            nelt.to_string(),
            format!("{a:.3}"),
            format!("{b:.3}"),
            format!("{:+.1}%", 100.0 * (b / a - 1.0)),
        ]);
    }
    table.print();
}

fn ablate_vector_backend(niter: usize) {
    println!("\n== E6: vector-op backend (paper: OpenACC simple ops cost a few %) ==");
    let mut table = Table::new(&["nelt", "rust-vec(GF/s)", "xla-vec(GF/s)", "delta"]);
    for nelt in [64usize, 256] {
        let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
        let (_s, rust_gf, _r) = time_solve("xla-layered", &cfg);
        // XLA vector path (time one full run; the engine setup is amortized
        // by constructing once).
        let mut app = build_app("xla-layered", &cfg);
        let runner = nekbone::bench::Runner::default();
        let samples = runner.run(|| {
            app.run_vector_backend(VectorBackend::Xla).expect("solve");
        });
        let cm = nekbone::metrics::CostModel::new(10, nelt);
        let xla_gf = (cm.flops_per_iter() * niter as u64) as f64 / samples.median() / 1e9;
        table.row(&[
            nelt.to_string(),
            format!("{rust_gf:.3}"),
            format!("{xla_gf:.3}"),
            format!("{:+.1}%", 100.0 * (xla_gf / rust_gf - 1.0)),
        ]);
    }
    table.print();
}

fn ablate_degree(niter: usize) {
    println!("\n== E7: polynomial-degree portability (shared cannot build degree 11) ==");
    let mut table = Table::new(&["n", "degree", "dof", "layered(GF/s)", "shared"]);
    for n in [8usize, 10, 12] {
        let nelt = 256;
        let cfg = RunConfig { nelt, n, niter, ..RunConfig::default() };
        let (_s, gf, _r) = time_solve("xla-layered", &cfg);
        let shared_cell = if n <= 10 {
            let (_s, sg, _r) = time_solve("xla-shared", &cfg);
            format!("{sg:.3}")
        } else {
            // The capacity wall: no artifact exists (aot.py refuses to
            // build it), matching "does not work for more than 10 GLL
            // points".
            let err = Nekbone::builder(cfg.clone()).operator("xla-shared").build().err();
            assert!(err.is_some(), "shared unexpectedly built at n={n}");
            "CAPACITY-WALL".to_string()
        };
        table.row(&[
            n.to_string(),
            (n - 1).to_string(),
            (nelt * n * n * n).to_string(),
            format!("{gf:.3}"),
            shared_cell,
        ]);
    }
    table.print();
}

fn ablate_chunk(niter: usize) {
    println!("\n== chunk-size / fusion sweep (launch-overhead amortization) ==");
    let mut table = Table::new(&["nelt", "chunk", "backend", "GF/s"]);
    for nelt in [1024usize] {
        for operator in ["xla-layered", "xla-fused-layered"] {
            for chunk in [64usize, 256, 1024] {
                let cfg = RunConfig { nelt, n: 10, niter, chunk, ..RunConfig::default() };
                let (_s, gf, _r) = time_solve(operator, &cfg);
                table.row(&[
                    nelt.to_string(),
                    chunk.to_string(),
                    operator.into(),
                    format!("{gf:.3}"),
                ]);
            }
        }
    }
    table.print();
}

fn ablate_cpu_fused(niter: usize) {
    println!("\n== cpu-fused: Ax+pap fusion on the persistent worker pool ==");
    println!("(fused backends skip one glsc3 full-vector sweep per CG iteration)");
    let mut table = Table::new(&["nelt", "unfused", "GF/s", "fused", "GF/s", "delta"]);
    for nelt in [64usize, 256] {
        for (plain, fused) in
            [("cpu-layered", "cpu-layered-fused"), ("cpu-threaded", "cpu-threaded-fused")]
        {
            let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
            let (_s, a, ra) = time_solve(plain, &cfg);
            let (_s, b, rb) = time_solve(fused, &cfg);
            // Relative agreement with an absolute floor: at large
            // NEKBONE_BENCH_ITERS both solves hit the roundoff floor,
            // where last-bit differences dominate the relative error.
            assert!(
                (ra - rb).abs() < 1e-9 * ra.abs() + 1e-12,
                "{fused} residual diverged from {plain}: {rb} vs {ra}"
            );
            table.row(&[
                nelt.to_string(),
                plain.into(),
                format!("{a:.3}"),
                fused.into(),
                format!("{b:.3}"),
                format!("{:+.1}%", 100.0 * (b / a - 1.0)),
            ]);
        }
    }
    table.print();
}

fn ablate_session(niter: usize) {
    println!("\n== session: one setup serving many right-hand sides ==");
    println!("(SolveSession reuses operator + CG workspace; 'rebuild' constructs the");
    println!(" application — mesh, gather-scatter, operator setup — for every solve)");
    let mut table =
        Table::new(&["nelt", "backend", "rebuild(s)", "session(s)", "delta"]);
    for nelt in [64usize] {
        for name in ["cpu-layered", "cpu-threaded-fused"] {
            let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
            let rhs = nekbone::rng::Rng::new(0xBEEF).normal_vec(cfg.ndof());
            let runner = Runner::default();

            let mut resid_rebuild = 0.0;
            let rebuild = runner.run(|| {
                let mut app = build_app(name, &cfg);
                app.set_rhs(&rhs).expect("rhs");
                resid_rebuild = app.run().expect("solve").final_residual;
            });

            let mut app = build_app(name, &cfg);
            let mut session = app.session();
            let mut resid_session = 0.0;
            let sess = runner.run(|| {
                resid_session = session.solve(&rhs).expect("solve").final_rnorm;
            });

            assert!(
                (resid_rebuild - resid_session).abs()
                    <= 1e-9 * resid_rebuild.abs() + 1e-12,
                "{name}: session residual diverged from rebuild: \
                 {resid_session} vs {resid_rebuild}"
            );
            table.row(&[
                nelt.to_string(),
                name.into(),
                format!("{:.4}", rebuild.median()),
                format!("{:.4}", sess.median()),
                format!("{:+.1}%", 100.0 * (sess.median() / rebuild.median() - 1.0)),
            ]);
        }
    }
    table.print();
}

fn main() {
    let which: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let all = which.is_empty();
    let niter = bench_iters();
    println!("# ablations, degree 9, {niter} CG iterations per run");
    // CPU-only ablations: no artifacts needed.
    if all || which.iter().any(|w| w == "cpu-fused") {
        ablate_cpu_fused(niter);
    }
    if all || which.iter().any(|w| w == "session") {
        ablate_session(niter);
    }
    if !have_artifacts() {
        return;
    }
    if all || which.iter().any(|w| w == "unroll") {
        ablate_unroll(niter);
    }
    if all || which.iter().any(|w| w == "vector-backend") {
        ablate_vector_backend(niter);
    }
    if all || which.iter().any(|w| w == "degree-sweep") {
        ablate_degree(niter);
    }
    if all || which.iter().any(|w| w == "chunk-size") {
        ablate_chunk(niter);
    }
}
