//! Measured-roofline bench: machine ceilings (STREAM-triad bandwidth +
//! peak multiply-add rate), per-operator arithmetic intensity from the
//! `flops()` / `bytes_moved()` hooks, and the `BENCH_roofline.json`
//! trajectory artifact (schema `nekbone-roofline/1`, documented in
//! `ROADMAP.md`).
//!
//! Run:   `cargo bench --bench roofline`
//! Smoke: `cargo bench --bench roofline -- --quick`   (alias: --test)
//! Out:   `cargo bench --bench roofline -- --out path.json`
//!        (default: `<repo root>/BENCH_roofline.json`)
//!
//! The same measurement runs from the binary:
//! `nekbone roofline --bench-json <path> [--quick]`.

use nekbone::bench::roofline::{render_table, run, validate_json, write_json, RooflineConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Cargo passes `--bench` to harness-less bench binaries; ignore it.
    let quick = args.iter().any(|a| a == "--quick" || a == "--test");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| format!("{}/../BENCH_roofline.json", env!("CARGO_MANIFEST_DIR")));

    let cfg = RooflineConfig { quick, ..RooflineConfig::default() };
    println!(
        "# measured roofline: operators {:?} at n in {:?}{}",
        cfg.operators,
        cfg.degrees,
        if quick { " (quick smoke scale)" } else { "" }
    );
    let report = run(&cfg).expect("roofline harness");
    println!(
        "# ceilings: {:.2} GB/s stream bandwidth, {:.2} GF/s peak multiply-add",
        report.roofs.bandwidth_gbs, report.roofs.peak_gflops
    );
    print!("{}", render_table(&report));

    // The paper's claim, restated on this substrate: specialization must
    // not lose to the generic kernel at the paper's degree.
    let gflops_of = |name: &str, n: usize| {
        report.points.iter().find(|p| p.operator == name && p.degree == n).map(|p| p.gflops)
    };
    if let (Some(spec), Some(layered)) = (gflops_of("cpu-spec", 9), gflops_of("cpu-layered", 9))
    {
        println!(
            "# n=9: cpu-spec {spec:.3} GF/s vs cpu-layered {layered:.3} GF/s ({:+.1}%)",
            100.0 * (spec / layered - 1.0)
        );
    }
    // The explicit-SIMD rung on top of specialization: vector kernels vs
    // the autovectorized unrolled ones, and which dispatch arm ran.
    if let (Some(simd), Some(spec)) = (gflops_of("cpu-simd", 9), gflops_of("cpu-spec", 9)) {
        println!(
            "# n=9: cpu-simd ({} arm) {simd:.3} GF/s vs cpu-spec {spec:.3} GF/s ({:+.1}%)",
            nekbone::operators::simd_arm(),
            100.0 * (simd / spec - 1.0)
        );
    }

    // ISSUE 10: whole-solve intensity under the cache-blocked vector
    // pipeline — the cg-iteration family's blocked/unblocked twins share
    // a bitwise-identical trajectory, so any GF/s gap is pure memory
    // traffic saved by `--block-dofs`.
    let intensity_of = |name: &str, n: usize| {
        report.points.iter().find(|p| p.operator == name && p.degree == n).map(|p| p.intensity)
    };
    for (blocked, flat) in
        [("cg-iteration-blocked", "cg-iteration"), ("cg-iteration-fused-blocked", "cg-iteration-fused")]
    {
        if let (Some(bg), Some(fg), Some(bi), Some(fi)) = (
            gflops_of(blocked, 9),
            gflops_of(flat, 9),
            intensity_of(blocked, 9),
            intensity_of(flat, 9),
        ) {
            println!(
                "# n=9: {blocked} {bg:.3} GF/s vs {flat} {fg:.3} GF/s \
                 (intensity {bi:.3} vs {fi:.3} flop/byte)"
            );
        }
    }

    write_json(&report, &out).expect("write BENCH_roofline.json");
    let text = std::fs::read_to_string(&out).expect("re-read emitted json");
    validate_json(&text).expect("emitted json must be schema-valid");
    println!("# wrote {out} ({} points, schema-valid)", report.points.len());
}
