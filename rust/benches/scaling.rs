//! Scaling-scenario bench: strong/weak campaigns over slab, pencil, and
//! box decompositions through the ranked runtime, and the
//! `BENCH_scaling.json` trajectory artifact (schema `nekbone-scaling/1`,
//! documented in `ROADMAP.md`).
//!
//! Run:   `cargo bench --bench scaling`
//! Smoke: `cargo bench --bench scaling -- --quick`   (alias: --test)
//! Out:   `cargo bench --bench scaling -- --out path.json`
//!        (default: `<repo root>/BENCH_scaling.json`)
//!
//! The same campaign runs from the binary:
//! `nekbone scenarios [--quick] [--json <path>]`.

use nekbone::scenario::{render_table, run, validate_json, write_json, ScenarioConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Cargo passes `--bench` to harness-less bench binaries; ignore it.
    let quick = args.iter().any(|a| a == "--quick" || a == "--test");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| format!("{}/../BENCH_scaling.json", env!("CARGO_MANIFEST_DIR")));

    let cfg = if quick {
        ScenarioConfig::quick()
    } else {
        ScenarioConfig {
            ranks: vec![1, 2, 4, 8],
            elements: vec![32, 64],
            degrees: vec![5, 9],
            niter: 30,
            ..ScenarioConfig::quick()
        }
    };
    println!(
        "# scaling campaign: {} at n in {:?}, ranks {:?}, elements {:?}{}",
        cfg.operator,
        cfg.degrees,
        cfg.ranks,
        cfg.elements,
        if quick { " (quick smoke scale)" } else { "" }
    );
    let report = run(&cfg).expect("scaling campaign");
    print!("{}", render_table(&report));
    if report.skipped > 0 {
        println!("# skipped {} infeasible combination(s)", report.skipped);
    }

    // The headline comparison: at the largest strong-scaling rank count,
    // how do the shapes stack up?
    let best_ranks = report.points.iter().map(|p| p.ranks).max().unwrap_or(1);
    for p in &report.points {
        if p.scenario == "strong" && p.ranks == best_ranks {
            println!(
                "# strong n={} r={} {}: {:.3} Mdof/s",
                p.degree, p.ranks, p.decomp, p.throughput_mdofs
            );
        }
    }

    write_json(&report, &out).expect("write BENCH_scaling.json");
    let text = std::fs::read_to_string(&out).expect("re-read emitted json");
    validate_json(&text).expect("emitted json must be schema-valid");
    println!("# wrote {out} ({} points, schema-valid)", report.points.len());
}
