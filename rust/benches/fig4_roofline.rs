//! Paper Fig. 4 + section VI-B: measured roofline vs achieved performance.
//!
//! Methodology (paper section V): replace every load/store of a CG
//! iteration with a plain copy of the same bytes (their `cudaMemcpy`, our
//! `memcpy`), yielding the achievable bandwidth per problem size; the
//! roofline is `I(n) * BW`; the optimized version runs with communication
//! off and is reported as a fraction of that roofline. Paper reference
//! points: 78/87/92% (P100) and 77/84/88% (V100) at 1024/2048/4096.
//!
//! Run: `cargo bench --bench fig4_roofline`

mod common;

use common::{bench_iters, elems_or, have_artifacts, time_solve};
use nekbone::bench::Table;
use nekbone::config::RunConfig;
use nekbone::metrics::CostModel;
use nekbone::roofline::{measure_bandwidth, measure_compute_ceiling};

fn main() {
    if !have_artifacts() {
        return;
    }
    let elems = elems_or(&[64, 256, 512, 1024, 2048, 4096]);
    let niter = bench_iters();
    let n = 10;
    println!("# Fig. 4 analog: measured roofline vs achieved (no-comm), degree 9");
    println!("# I({n}) = {:.4} flop/byte\n", CostModel::new(n, 1).intensity());

    // On this substrate the compute roof can bind (1 CPU core of f64 FMA
    // vs the paper's 4.7 TF/s P100): report both roofs, fraction vs the
    // binding (lower) one — same roofline methodology, honest balance.
    let ceiling = measure_compute_ceiling(n, 200);
    println!("# measured in-cache compute ceiling: {ceiling:.3} GF/s\n");
    let mut table = Table::new(&[
        "nelt",
        "dof",
        "bw(GB/s)",
        "mem-roof(GF/s)",
        "binding-roof",
        "achieved(GF/s)",
        "fraction",
    ]);
    let mut fractions = Vec::new();
    for &nelt in &elems {
        let cm = CostModel::new(n, nelt);
        let bw = measure_bandwidth(cm.dof, 7);
        let mem_roof = cm.roofline_gflops(bw.bandwidth_gbs);
        let roof = mem_roof.min(ceiling);
        let cfg = RunConfig { nelt, n, niter, no_comm: true, ..RunConfig::default() };
        let (_s, achieved, _r) = time_solve("xla-layered", &cfg);
        let frac = achieved / roof;
        fractions.push((nelt, frac));
        table.row(&[
            nelt.to_string(),
            cm.dof.to_string(),
            format!("{:.2}", bw.bandwidth_gbs),
            format!("{mem_roof:.3}"),
            format!("{roof:.3}"),
            format!("{achieved:.3}"),
            format!("{:.1}%", 100.0 * frac),
        ]);
        eprintln!("  nelt={nelt} done");
    }
    table.print();

    println!("\n# paper: fraction rises with problem size (launch overhead amortizes):");
    println!("#   P100: 1024 -> 78%, 2048 -> 87%, 4096 -> 92%");
    println!("#   V100: 1024 -> 77%, 2048 -> 84%, 4096 -> 88%");
    let rising = fractions.windows(2).filter(|w| w[1].1 >= w[0].1).count();
    println!(
        "# this substrate: {}/{} steps rising",
        rising,
        fractions.len().saturating_sub(1)
    );

    // Section VI-B also reports theoretical peaks: at peak GPU bandwidth
    // the model gives 462 GF/s (P100, 720 GB/s) and 577 GF/s (V100,
    // 900 GB/s). The cost model reproduces those exactly:
    let cm = CostModel::new(10, 1024);
    println!(
        "\n# cost-model check (section VI-B): P100 peak -> {:.0} GF/s (paper: 462), \
         V100 peak -> {:.0} GF/s (paper: 577)",
        cm.roofline_gflops(720.0),
        cm.roofline_gflops(900.0)
    );
}
