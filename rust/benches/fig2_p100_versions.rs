//! Paper Fig. 2: performance of every Nekbone version over the P100 sweep
//! (64–4096 elements, polynomial degree 9).
//!
//! Reproduces the figure's *shape* on the CPU-PJRT substrate: GFlop/s per
//! version as the element count grows, the rising curve as launch overhead
//! amortizes, and the relative ordering of the versions. The paper's
//! absolute numbers come from a P100; see EXPERIMENTS.md E1 for the
//! comparison.
//!
//! Run: `cargo bench --bench fig2_p100_versions`
//! Knobs: NEKBONE_BENCH_ITERS (default 30), NEKBONE_BENCH_ELEMS,
//!        NEKBONE_BENCH_SAMPLES.

mod common;

use common::{bench_iters, elems_or, have_artifacts, paper_versions, time_solve};
use nekbone::bench::Table;
use nekbone::config::RunConfig;

fn main() {
    if !have_artifacts() {
        return;
    }
    let elems = elems_or(&[64, 128, 256, 512, 1024, 2048, 4096]);
    let niter = bench_iters();
    println!("# Fig. 2 analog: Nekbone versions, degree 9, {niter} CG iterations");
    println!("# (paper: P100, 64-4096 elements; columns are GFlop/s)\n");

    let versions = paper_versions();
    let mut header: Vec<&str> = vec!["nelt", "dof"];
    for (name, _) in &versions {
        header.push(name);
    }
    let mut table = Table::new(&header);

    let mut last_row: Vec<f64> = Vec::new();
    for &nelt in &elems {
        let mut cells = vec![nelt.to_string(), (nelt * 1000).to_string()];
        last_row.clear();
        for (_, operator) in &versions {
            let cfg = RunConfig { nelt, n: 10, niter, ..RunConfig::default() };
            let (samples, gflops, _res) = time_solve(operator, &cfg);
            cells.push(format!("{gflops:.3}"));
            last_row.push(gflops);
            eprintln!(
                "  nelt={nelt:<5} {operator:<22} median {:.3}s (spread {:.1}%)",
                samples.median(),
                100.0 * samples.rel_spread()
            );
        }
        table.row(&cells);
    }
    table.print();

    // The paper's headline comparisons at the largest size.
    if last_row.len() == 5 {
        let (jnp, orig, shared, layered, _unroll2) =
            (last_row[0], last_row[1], last_row[2], last_row[3], last_row[4]);
        println!("\n# at nelt={} (paper, P100: layered +36% vs original, +10% vs shared):", elems.last().unwrap());
        println!("#   layered vs original : {:+.1}%", 100.0 * (layered / orig - 1.0));
        println!("#   layered vs shared   : {:+.1}%", 100.0 * (layered / shared - 1.0));
        println!("#   layered vs openacc  : {:+.1}%", 100.0 * (layered / jnp - 1.0));
    }
}
