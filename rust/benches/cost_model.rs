//! Paper Eqs. (1)–(2): verify the cost model against instrumented counts
//! and print the intensity table the analysis rests on (experiment E4).
//!
//! Run: `cargo bench --bench cost_model`

use nekbone::bench::Table;
use nekbone::metrics::{CostModel, FlopCounter};

fn main() {
    println!("# Paper Eq. (1): C(D,n) = D(12n+34); Eq. (2): I(n) = (12n+34)/240\n");
    let nelt = 64;
    let mut table = Table::new(&[
        "n",
        "degree",
        "D(dof)",
        "formula flops/iter",
        "counted flops/iter",
        "ratio",
        "I(n) flop/byte",
    ]);
    for n in 4..=13 {
        let cm = CostModel::new(n, nelt);
        let mut fc = FlopCounter::default();
        fc.count_cg_iter(n, nelt);
        table.row(&[
            n.to_string(),
            (n - 1).to_string(),
            cm.dof.to_string(),
            cm.flops_per_iter().to_string(),
            fc.flops.to_string(),
            format!("{:.3}", fc.flops as f64 / cm.flops_per_iter() as f64),
            format!("{:.4}", cm.intensity()),
        ]);
    }
    table.print();

    println!("\n# bandwidth model: 24D reads + 6D writes per iteration (f64)");
    let mut table = Table::new(&["n", "reads/iter", "writes/iter", "bytes/iter"]);
    for n in [8usize, 10, 12] {
        let cm = CostModel::new(n, nelt);
        table.row(&[
            n.to_string(),
            cm.reads_per_iter().to_string(),
            cm.writes_per_iter().to_string(),
            cm.bytes_per_iter().to_string(),
        ]);
    }
    table.print();

    // The section VI-B theoretical peaks.
    let cm = CostModel::new(10, 1024);
    println!("\n# theoretical peaks at degree 9 (paper section VI-B):");
    println!(
        "#   P100 720 GB/s -> {:.1} GF/s (paper: 462)   V100 900 GB/s -> {:.1} GF/s (paper: 577)",
        cm.roofline_gflops(720.0),
        cm.roofline_gflops(900.0)
    );
    let p100 = cm.roofline_gflops(720.0);
    let v100 = cm.roofline_gflops(900.0);
    assert!((p100 - 462.0).abs() < 1.0, "P100 peak drifted: {p100}");
    assert!((v100 - 577.5).abs() < 1.0, "V100 peak drifted: {v100}");
    println!("# cost model matches the paper's arithmetic.");
}
