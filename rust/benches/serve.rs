//! Serve-layer bench: boot an in-process server on a loopback port, drive
//! it with the built-in load generator, and emit the `BENCH_serve.json`
//! trajectory artifact (schema `nekbone-serve/1`, documented in
//! `ROADMAP.md` next to `nekbone-roofline/1`).
//!
//! Run:   `cargo bench --bench serve`
//! Smoke: `cargo bench --bench serve -- --quick`   (alias: --test)
//! Out:   `cargo bench --bench serve -- --out path.json`
//!        (default: `<repo root>/BENCH_serve.json`)
//!
//! The same measurement runs against an external server from the binary:
//! `nekbone serve --addr ... &` then
//! `nekbone loadgen --addr ... --bench-json <path>`.

use std::sync::atomic::Ordering;

use nekbone::cli::Args;
use nekbone::serve::{
    render_summary, run_loadgen, validate_json, write_json, LoadgenConfig, ServeConfig, Server,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Cargo passes `--bench` to harness-less bench binaries; ignore it.
    let quick = args.iter().any(|a| a == "--quick" || a == "--test");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR")));

    // Server on an OS-assigned loopback port, in its own thread.
    let serve_argv: Vec<String> =
        ["serve", "--addr", "127.0.0.1:0"].iter().map(|s| s.to_string()).collect();
    let scfg = ServeConfig::from_args(&Args::parse(&serve_argv).expect("serve args"))
        .expect("serve config");
    let server = Server::bind(&scfg).expect("bind loopback server");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.stop_flag();
    let server_thread = std::thread::spawn(move || server.run().expect("server run"));

    // Loadgen config through the same front door as the CLI.
    let mut argv: Vec<String> =
        ["loadgen", "--addr", &addr].iter().map(|s| s.to_string()).collect();
    if quick {
        argv.push("--quick".into());
    } else {
        // Bench scale: enough traffic to exercise batching and caching
        // without turning the suite into a stress test.
        for tok in ["--clients", "4", "--requests", "12", "--n", "4", "--nelt", "4"] {
            argv.push(tok.into());
        }
    }
    let lcfg = LoadgenConfig::from_args(&Args::parse(&argv).expect("loadgen args"))
        .expect("loadgen config");
    println!(
        "# serve bench: {} clients x {} requests over {} ({}){}",
        lcfg.clients,
        lcfg.requests,
        addr,
        lcfg.operator,
        if quick { " (quick smoke scale)" } else { "" }
    );

    let report = run_loadgen(&lcfg).expect("loadgen run");
    print!("{}", render_summary(&report));
    assert_eq!(report.errors, 0, "serve bench saw failed requests");

    // Wind the server down and make sure it actually drains.
    stop.store(true, Ordering::SeqCst);
    let serve_report = server_thread.join().expect("server thread");
    println!("# server drained after {} connections", serve_report.connections);

    write_json(&report, &out).expect("write BENCH_serve.json");
    let text = std::fs::read_to_string(&out).expect("re-read emitted json");
    validate_json(&text).expect("emitted json must be schema-valid");
    println!(
        "# wrote {out} ({} solves, {:.1} solves/s, schema-valid)",
        report.ok,
        report.throughput_rps()
    );
}
