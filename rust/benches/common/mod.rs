//! Shared scaffolding for the paper-figure benches.
//!
//! All operator construction goes through one place ([`build_app`], backed
//! by the operator registry), so the benches never name a concrete
//! implementation — a newly registered variant benches by adding its name
//! to a list.

#![allow(dead_code)] // each bench includes this module; none uses all of it

use nekbone::bench::{Runner, Samples};
use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;

/// CG iterations per timed sample (env-overridable:
/// `NEKBONE_BENCH_ITERS`). The paper runs 100; the default here keeps a
/// full figure regeneration under a few minutes.
pub fn bench_iters() -> usize {
    std::env::var("NEKBONE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(30)
}

/// Element counts, overridable via `NEKBONE_BENCH_ELEMS=64,128,...`.
pub fn elems_or(default: &[usize]) -> Vec<usize> {
    match std::env::var("NEKBONE_BENCH_ELEMS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

pub fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts").join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing; run `make artifacts` first");
    }
    ok
}

/// Build the application for a registry operator name (the single place
/// benches construct backends).
pub fn build_app(operator: &str, cfg: &RunConfig) -> Nekbone {
    Nekbone::builder(cfg.clone())
        .operator(operator)
        .build()
        .unwrap_or_else(|e| panic!("setup of operator {operator:?} failed: {e}"))
}

/// Median-time one full Nekbone solve for an operator/size; returns
/// (samples, GFlop/s at the median, residual).
pub fn time_solve(operator: &str, cfg: &RunConfig) -> (Samples, f64, f64) {
    let mut app = build_app(operator, cfg);
    let mut residual = 0.0;
    let runner = Runner::default();
    let samples = runner.run(|| {
        let rep = app.run().expect("solve");
        residual = rep.final_residual;
    });
    let cm = nekbone::metrics::CostModel::new(cfg.n, cfg.nelt);
    let flops = cm.flops_per_iter() * cfg.niter as u64;
    let gflops = flops as f64 / samples.median() / 1e9;
    (samples, gflops, residual)
}

/// The paper's five GPU versions in presentation order:
/// (figure label, operator-registry name).
pub fn paper_versions() -> Vec<(&'static str, &'static str)> {
    vec![
        ("openacc(jnp)", "xla-jnp"),
        ("original", "xla-original"),
        ("shared", "xla-shared"),
        ("opt-cuda-c(layered)", "xla-layered"),
        ("opt-cuda-f(unroll2)", "xla-layered-unroll2"),
    ]
}
