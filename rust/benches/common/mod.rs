//! Shared scaffolding for the paper-figure benches.

use nekbone::bench::{Runner, Samples};
use nekbone::config::RunConfig;
use nekbone::coordinator::{Backend, Nekbone};

/// CG iterations per timed sample (env-overridable:
/// `NEKBONE_BENCH_ITERS`). The paper runs 100; the default here keeps a
/// full figure regeneration under a few minutes.
pub fn bench_iters() -> usize {
    std::env::var("NEKBONE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(30)
}

/// Element counts, overridable via `NEKBONE_BENCH_ELEMS=64,128,...`.
pub fn elems_or(default: &[usize]) -> Vec<usize> {
    match std::env::var("NEKBONE_BENCH_ELEMS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

pub fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts").join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing; run `make artifacts` first");
    }
    ok
}

/// Median-time one full Nekbone solve for a backend/size; returns
/// (samples, GFlop/s at the median, residual).
pub fn time_solve(backend: &Backend, cfg: &RunConfig) -> (Samples, f64, f64) {
    let mut app = Nekbone::new(cfg.clone(), backend.clone()).expect("setup");
    let mut residual = 0.0;
    let runner = Runner::default();
    let samples = runner.run(|| {
        let rep = app.run().expect("solve");
        residual = rep.final_residual;
    });
    let cm = nekbone::metrics::CostModel::new(cfg.n, cfg.nelt);
    let flops = cm.flops_per_iter() * cfg.niter as u64;
    let gflops = flops as f64 / samples.median() / 1e9;
    (samples, gflops, residual)
}

/// The paper's five GPU versions in presentation order.
pub fn paper_versions() -> Vec<(&'static str, Backend)> {
    vec![
        ("openacc(jnp)", Backend::Xla("jnp".into())),
        ("original", Backend::Xla("original".into())),
        ("shared", Backend::Xla("shared".into())),
        ("opt-cuda-c(layered)", Backend::Xla("layered".into())),
        ("opt-cuda-f(unroll2)", Backend::Xla("layered_unroll2".into())),
    ]
}
