//! # nekbone-rs
//!
//! Reproduction of *"Optimization of Tensor-product Operations in Nekbone on
//! GPUs"* (Karp, Jansson, Podobas, Schlatter, Markidis — KTH, 2020) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! * **Layer 1** (build-time Python): the paper's tensor-product kernel
//!   variants as Pallas kernels (`python/compile/kernels/`), AOT-lowered to
//!   HLO text.
//! * **Layer 2** (build-time Python): the JAX compute graph around them
//!   (`python/compile/model.py`).
//! * **Layer 3** (this crate): the Nekbone application — spectral-element
//!   mesh, GLL basis, geometric factors, gather–scatter, conjugate-gradient
//!   solver, the PJRT runtime that loads the AOT artifacts, a simulated
//!   multi-rank runtime, and the measurement harness that regenerates every
//!   figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::{Backend, Nekbone};
//!
//! let cfg = RunConfig { nelt: 64, n: 10, niter: 100, ..RunConfig::default() };
//! let mut app = Nekbone::new(cfg, Backend::CpuLayered).unwrap();
//! let report = app.run().unwrap();
//! println!("{:.2} GFlop/s, residual {:e}", report.gflops(), report.final_residual);
//! ```

pub mod error;
pub mod rng;
pub mod json;
pub mod basis;
pub mod mesh;
pub mod geometry;
pub mod gs;
pub mod operators;
pub mod solver;
pub mod metrics;
pub mod roofline;
pub mod runtime;
pub mod coordinator;
pub mod rank;
pub mod bench;
pub mod proputil;
pub mod config;
pub mod cli;

pub use error::{Error, Result};
