//! # nekbone-rs
//!
//! Reproduction of *"Optimization of Tensor-product Operations in Nekbone on
//! GPUs"* (Karp, Jansson, Podobas, Schlatter, Markidis — KTH, 2020) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! * **Layer 1** (build-time Python): the paper's tensor-product kernel
//!   variants as Pallas kernels (`python/compile/kernels/`), AOT-lowered to
//!   HLO text.
//! * **Layer 2** (build-time Python): the JAX compute graph around them
//!   (`python/compile/model.py`).
//! * **Layer 3** (this crate): the Nekbone application — spectral-element
//!   mesh, GLL basis, geometric factors, gather–scatter, conjugate-gradient
//!   solver, the PJRT runtime that loads the AOT artifacts, a simulated
//!   multi-rank runtime, and the measurement harness that regenerates every
//!   figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! ## Quick tour
//!
//! The application is assembled by a builder; the tensor-product operator
//! is picked **by name** from the operator registry (see
//! [`operators::OperatorRegistry`]):
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::Nekbone;
//!
//! let cfg = RunConfig { nelt: 64, n: 10, niter: 100, ..RunConfig::default() };
//! let mut app = Nekbone::builder(cfg)
//!     .operator("cpu-layered") // or "xla-layered", "xla-fused", ...
//!     .build()
//!     .unwrap();
//! let report = app.run().unwrap();
//! println!("{:.2} GFlop/s, residual {:e}", report.gflops(), report.final_residual);
//! ```
//!
//! Repeated solves against one setup (multi-RHS serving) go through a
//! [`coordinator::SolveSession`] — the operator, gather–scatter tables,
//! and CG workspace are built once and reused with zero per-solve
//! allocation:
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::Nekbone;
//!
//! let cfg = RunConfig { nelt: 64, n: 10, ..RunConfig::default() };
//! let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
//! let ndof = app.mesh().ndof_local();
//! let mut session = app.session();
//! for seed in 0..16u64 {
//!     let rhs = nekbone::rng::Rng::new(seed).normal_vec(ndof);
//!     let report = session.solve(&rhs).unwrap();
//!     println!("solve {}: |r| = {:e}", session.solves(), report.final_rnorm);
//! }
//! ```
//!
//! The same sessions can be served over the network: `nekbone serve`
//! exposes a newline-delimited-JSON TCP endpoint backed by a session pool
//! sharded across meshes and operators (see [`serve`]), and `nekbone
//! loadgen` drives it for smoke tests and the `nekbone-serve/1` benchmark.
//!
//! There is exactly **one CG loop** in the crate
//! ([`solver::cg_solve_with`]); it is generic over a
//! [`solver::Communicator`] (collectives) and a [`solver::DomainExchange`]
//! (direct-stiffness assembly), so the serial pipeline, the `--no-comm`
//! roofline mode, and the simulated-MPI rank runtime all run the same
//! solver with different plumbing.
//!
//! The registry is open: implement [`operators::AxOperator`], register a
//! constructor under a new name, and pass the registry to the builder —
//! the CLI, the CG solver, the simulated-rank runtime, and the
//! paper-figure benches all dispatch through the same `Box<dyn
//! AxOperator>`, so the new variant runs everywhere:
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::Nekbone;
//! use nekbone::operators::OperatorRegistry;
//!
//! let mut registry = OperatorRegistry::with_builtins();
//! # struct MyOp;
//! # impl Default for MyOp { fn default() -> Self { MyOp } }
//! # impl nekbone::operators::AxOperator for MyOp {
//! #     fn label(&self) -> String { "my-simd".into() }
//! #     fn setup(&mut self, _ctx: &nekbone::operators::OperatorCtx) -> nekbone::Result<()> { Ok(()) }
//! #     fn apply(&mut self, _u: &[f64], _w: &mut [f64]) -> nekbone::Result<()> { Ok(()) }
//! #     fn flops(&self) -> u64 { 0 }
//! # }
//! registry.register("my-simd", false, || Box::<MyOp>::default()).unwrap();
//! let cfg = RunConfig::default();
//! let mut app = Nekbone::builder(cfg)
//!     .registry(registry)
//!     .operator("my-simd")
//!     .build()
//!     .unwrap();
//! ```

pub mod error;
pub mod rng;
pub mod json;
pub mod basis;
pub mod mesh;
pub mod geometry;
pub mod gs;
pub mod operators;
pub mod solver;
pub mod metrics;
pub mod roofline;
pub mod runtime;
pub mod coordinator;
pub mod rank;
pub mod bench;
pub mod proputil;
pub mod config;
pub mod cli;
pub mod serve;
pub mod scenario;

pub use error::{Error, Result};
