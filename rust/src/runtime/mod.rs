//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and runs
//! them from the Layer-3 hot path.
//!
//! Pattern (see `/opt/xla-example/load_hlo`): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute_b` over persistent device buffers.
//!
//! Buffer discipline on the hot path:
//! * the differentiation matrix `d` and the geometric factors `g` never
//!   change during a solve — they are uploaded **once** per engine and the
//!   per-iteration call uploads only `u` (this is the GPU residency the
//!   paper gets from keeping data on-device between OpenACC and CUDA);
//! * the output tuple is copied back into a caller-provided slice; no
//!   allocation happens per call except inside PJRT itself.

mod manifest;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

use crate::error::{Error, Result};

/// A live PJRT CPU client plus the parsed manifest.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl XlaRuntime {
    /// Connect to the CPU PJRT client and load `<dir>/manifest.json`.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::with_manifest(Manifest::load(artifacts_dir)?)
    }

    /// Connect to the CPU PJRT client with an already-loaded manifest
    /// (avoids re-reading `manifest.json` when the caller has checked it).
    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact into a loaded executable.
    pub fn compile(&self, meta: &ArtifactMeta) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.path_of(meta);
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Upload an f64 host slice as a device buffer.
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Copy a single-array output back to `dst`.
///
/// `tupled` selects the slow path (materialize the Literal, decompose the
/// 1-tuple — an extra allocation + copy) for legacy tuple-rooted
/// artifacts; array-rooted artifacts copy straight out of the output
/// Literal. (The TFRT CPU client does not implement `CopyRawToHost`, so a
/// Literal materialization is unavoidable; see EXPERIMENTS.md §Perf L3.)
fn output_to_slice(buf: &xla::PjRtBuffer, dst: &mut [f64], tupled: bool) -> Result<()> {
    if tupled {
        let lit = buf.to_literal_sync()?.to_tuple1()?;
        lit.copy_raw_to(dst)?;
    } else {
        let lit = buf.to_literal_sync()?;
        lit.copy_raw_to(dst)?;
    }
    Ok(())
}

/// An Ax executable bound to a fixed `(variant, n, chunk)` with `d` and `g`
/// resident on the device.
pub struct AxEngine {
    exe: xla::PjRtLoadedExecutable,
    /// GLL points per dimension.
    pub n: usize,
    /// Elements per launch.
    pub chunk: usize,
    /// Artifact name (diagnostics).
    pub name: String,
    d_buf: xla::PjRtBuffer,
    /// One resident g buffer per chunk of the mesh (last one zero-padded).
    g_bufs: Vec<xla::PjRtBuffer>,
    /// Real (unpadded) element count.
    nelt: usize,
    /// Scratch for padding the final partial chunk of `u`.
    u_pad: Vec<f64>,
    /// Tuple-rooted output? (legacy manifests; new Ax artifacts are bare).
    tupled: bool,
}

impl AxEngine {
    /// Build an engine: compile the artifact and upload `d` and the full
    /// mesh `g` (length `nelt * 6 * n^3`), zero-padding the last chunk.
    /// Zero geometric factors make padded elements inert (w = 0), which the
    /// chunker property tests rely on.
    pub fn new(
        rt: &XlaRuntime,
        variant: &str,
        n: usize,
        chunk: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
    ) -> Result<Self> {
        let meta = rt.manifest().find_ax(variant, n, chunk)?.clone();
        let np = n * n * n;
        if d.len() != n * n {
            return Err(Error::Config("AxEngine: d must be n*n".into()));
        }
        if g.len() != nelt * 6 * np {
            return Err(Error::Config("AxEngine: g must be nelt*6*n^3".into()));
        }
        let exe = rt.compile(&meta)?;
        let d_buf = rt.upload(d, &[n, n])?;
        let nchunks = nelt.div_ceil(chunk);
        let mut g_bufs = Vec::with_capacity(nchunks);
        let g_chunk_len = chunk * 6 * np;
        let mut g_scratch = vec![0.0f64; g_chunk_len];
        for ci in 0..nchunks {
            let e0 = ci * chunk;
            let real = (nelt - e0).min(chunk);
            g_scratch.fill(0.0);
            g_scratch[..real * 6 * np].copy_from_slice(&g[e0 * 6 * np..(e0 + real) * 6 * np]);
            g_bufs.push(rt.upload(&g_scratch, &[chunk, 6, n, n, n])?);
        }
        Ok(AxEngine {
            exe,
            n,
            chunk,
            name: meta.name,
            d_buf,
            g_bufs,
            nelt,
            u_pad: vec![0.0; chunk * np],
            tupled: meta.tupled,
        })
    }

    /// Number of launches per operator application.
    pub fn nchunks(&self) -> usize {
        self.g_bufs.len()
    }

    /// Apply the local operator to the full mesh field `u` (`nelt * n^3`),
    /// writing `w` (same length). Loops over resident-g chunks.
    pub fn apply(&mut self, rt: &XlaRuntime, u: &[f64], w: &mut [f64]) -> Result<()> {
        let np = self.n * self.n * self.n;
        if u.len() != self.nelt * np || w.len() != self.nelt * np {
            return Err(Error::Config("AxEngine::apply: field length mismatch".into()));
        }
        for ci in 0..self.g_bufs.len() {
            let e0 = ci * self.chunk;
            let real = (self.nelt - e0).min(self.chunk);
            let u_slice = &u[e0 * np..(e0 + real) * np];
            let u_buf = if real == self.chunk {
                rt.upload(u_slice, &[self.chunk, self.n, self.n, self.n])?
            } else {
                self.u_pad.fill(0.0);
                self.u_pad[..real * np].copy_from_slice(u_slice);
                rt.upload(&self.u_pad, &[self.chunk, self.n, self.n, self.n])?
            };
            let outputs = self.exe.execute_b(&[&u_buf, &self.d_buf, &self.g_bufs[ci]])?;
            let out = &outputs[0][0];
            if real == self.chunk {
                output_to_slice(out, &mut w[e0 * np..(e0 + real) * np], self.tupled)?;
            } else {
                let mut full = vec![0.0; self.chunk * np];
                output_to_slice(out, &mut full, self.tupled)?;
                w[e0 * np..(e0 + real) * np].copy_from_slice(&full[..real * np]);
            }
        }
        Ok(())
    }
}

/// A chunk-sized vector-op executable (the "OpenACC path" ablation, E6).
pub struct VectorEngine {
    exe: xla::PjRtLoadedExecutable,
    /// Flat vector length per launch.
    pub size: usize,
    /// Op name ("glsc3", "add2s1", "add2s2").
    pub op: String,
    /// Tuple-rooted output? (legacy manifests).
    tupled: bool,
}

impl VectorEngine {
    pub fn new(rt: &XlaRuntime, op: &str, size: usize) -> Result<Self> {
        let name = format!("{op}_s{size}");
        let meta = rt.manifest().find(&name)?.clone();
        Ok(VectorEngine { exe: rt.compile(&meta)?, size, op: op.to_string(), tupled: meta.tupled })
    }

    /// Weighted inner product over one chunk (returns the partial sum).
    pub fn glsc3(&self, rt: &XlaRuntime, a: &[f64], b: &[f64], c: &[f64]) -> Result<f64> {
        let ab = rt.upload(a, &[self.size])?;
        let bb = rt.upload(b, &[self.size])?;
        let cb = rt.upload(c, &[self.size])?;
        let outputs = self.exe.execute_b(&[&ab, &bb, &cb])?;
        let mut out = [0.0f64; 1];
        output_to_slice(&outputs[0][0], &mut out, self.tupled)?;
        Ok(out[0])
    }

    /// `a <- c1 * a + b` (add2s1 engine) or `a <- a + c2 * b` (add2s2
    /// engine) over one chunk, writing back into `a`.
    pub fn axpy(&self, rt: &XlaRuntime, a: &mut [f64], b: &[f64], scalar: f64) -> Result<()> {
        let ab = rt.upload(a, &[self.size])?;
        let bb = rt.upload(b, &[self.size])?;
        let sb = rt.upload(&[scalar], &[1])?;
        let outputs = self.exe.execute_b(&[&ab, &bb, &sb])?;
        output_to_slice(&outputs[0][0], a, self.tupled)?;
        Ok(())
    }
}

/// The fused Ax + partial-pap executable (perf pass).
pub struct CgIterEngine {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub chunk: usize,
    d_buf: xla::PjRtBuffer,
    g_bufs: Vec<xla::PjRtBuffer>,
    c_bufs: Vec<xla::PjRtBuffer>,
    nelt: usize,
}

impl CgIterEngine {
    /// Compile and bind `d`, `g`, and the weight field `c` (all resident).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rt: &XlaRuntime,
        variant: &str,
        n: usize,
        chunk: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
        c: &[f64],
    ) -> Result<Self> {
        let name = format!("cg_iter_{variant}_n{n}_e{chunk}");
        let meta = rt.manifest().find(&name)?.clone();
        let np = n * n * n;
        let exe = rt.compile(&meta)?;
        let d_buf = rt.upload(d, &[n, n])?;
        let nchunks = nelt.div_ceil(chunk);
        let mut g_bufs = Vec::with_capacity(nchunks);
        let mut c_bufs = Vec::with_capacity(nchunks);
        let mut g_scratch = vec![0.0f64; chunk * 6 * np];
        let mut c_scratch = vec![0.0f64; chunk * np];
        for ci in 0..nchunks {
            let e0 = ci * chunk;
            let real = (nelt - e0).min(chunk);
            g_scratch.fill(0.0);
            g_scratch[..real * 6 * np].copy_from_slice(&g[e0 * 6 * np..(e0 + real) * 6 * np]);
            g_bufs.push(rt.upload(&g_scratch, &[chunk, 6, n, n, n])?);
            c_scratch.fill(0.0);
            c_scratch[..real * np].copy_from_slice(&c[e0 * np..(e0 + real) * np]);
            c_bufs.push(rt.upload(&c_scratch, &[chunk, n, n, n])?);
        }
        Ok(CgIterEngine { exe, n, chunk, d_buf, g_bufs, c_bufs, nelt })
    }

    /// `w = Ax(p)` plus the global partial `pap = sum w c p` in one pass.
    pub fn apply(&self, rt: &XlaRuntime, p: &[f64], w: &mut [f64]) -> Result<f64> {
        let np = self.n * self.n * self.n;
        if p.len() != self.nelt * np || w.len() != self.nelt * np {
            return Err(Error::Config("CgIterEngine::apply: length mismatch".into()));
        }
        let mut pap = 0.0;
        let mut pad = vec![0.0f64; self.chunk * np];
        for ci in 0..self.g_bufs.len() {
            let e0 = ci * self.chunk;
            let real = (self.nelt - e0).min(self.chunk);
            let p_slice = &p[e0 * np..(e0 + real) * np];
            let p_buf = if real == self.chunk {
                rt.upload(p_slice, &[self.chunk, self.n, self.n, self.n])?
            } else {
                pad.fill(0.0);
                pad[..real * np].copy_from_slice(p_slice);
                rt.upload(&pad, &[self.chunk, self.n, self.n, self.n])?
            };
            let outputs =
                self.exe.execute_b(&[&p_buf, &self.d_buf, &self.g_bufs[ci], &self.c_bufs[ci]])?;
            let lit = outputs[0][0].to_literal_sync()?;
            let (w_lit, pap_lit) = lit.to_tuple2()?;
            if real == self.chunk {
                w_lit.copy_raw_to(&mut w[e0 * np..(e0 + real) * np])?;
            } else {
                let mut full = vec![0.0; self.chunk * np];
                w_lit.copy_raw_to(&mut full)?;
                w[e0 * np..(e0 + real) * np].copy_from_slice(&full[..real * np]);
            }
            let mut part = [0.0f64; 1];
            pap_lit.copy_raw_to(&mut part)?;
            pap += part[0];
        }
        Ok(pap)
    }
}
