//! The artifact manifest: what `python -m compile.aot` produced and how to
//! call it.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json;

/// Kind of computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Local Poisson operator `(u, d, g) -> (w,)`.
    Ax,
    /// Chunk-sized vector op.
    Vector,
    /// Fused Ax + partial pap.
    CgIter,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "ax" => Ok(ArtifactKind::Ax),
            "vector" => Ok(ArtifactKind::Vector),
            "cg_iter" => Ok(ArtifactKind::CgIter),
            other => Err(Error::Artifact(format!("unknown artifact kind {other:?}"))),
        }
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// Kernel variant ("layered", "shared", ...) or vector-op name.
    pub variant: String,
    /// GLL points per dimension.
    pub n: usize,
    /// Elements per launch.
    pub chunk: usize,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Expected argument shapes (outermost first), for call validation.
    pub arg_shapes: Vec<Vec<usize>>,
    /// Whether the HLO root is a tuple (multi-output) or a bare array
    /// (single-output — downloadable with a raw copy, no Literal).
    pub tupled: bool,
}

impl ArtifactMeta {
    fn from_json(v: &json::Value) -> Result<Self> {
        let field = |k: &str| {
            v.get(k).ok_or_else(|| Error::Artifact(format!("manifest entry missing {k:?}")))
        };
        let name = field("name")?
            .as_str()
            .ok_or_else(|| Error::Artifact("name not a string".into()))?
            .to_string();
        let kind = ArtifactKind::parse(
            field("kind")?.as_str().ok_or_else(|| Error::Artifact("kind not a string".into()))?,
        )?;
        let variant = field("variant")?
            .as_str()
            .ok_or_else(|| Error::Artifact("variant not a string".into()))?
            .to_string();
        let n = field("n")?
            .as_usize()
            .ok_or_else(|| Error::Artifact("n not an integer".into()))?;
        let chunk = field("chunk")?
            .as_usize()
            .ok_or_else(|| Error::Artifact("chunk not an integer".into()))?;
        let file = field("file")?
            .as_str()
            .ok_or_else(|| Error::Artifact("file not a string".into()))?
            .to_string();
        let arg_shapes = field("arg_shapes")?
            .as_array()
            .ok_or_else(|| Error::Artifact("arg_shapes not an array".into()))?
            .iter()
            .map(|shape| {
                shape
                    .as_array()
                    .ok_or_else(|| Error::Artifact("arg shape not an array".into()))?
                    .iter()
                    .map(|d| {
                        d.as_usize().ok_or_else(|| Error::Artifact("dim not an integer".into()))
                    })
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        // Older manifests (before the raw-download optimization) lowered
        // everything with a tuple root.
        let tupled = match v.get("tupled") {
            Some(json::Value::Bool(b)) => *b,
            _ => true,
        };
        Ok(ArtifactMeta { name, kind, variant, n, chunk, file, arg_shapes, tupled })
    }
}

/// The parsed manifest plus its directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let doc = json::parse(text)?;
        let artifacts = doc
            .get("artifacts")
            .and_then(|a| a.as_array())
            .ok_or_else(|| Error::Artifact("manifest has no artifacts array".into()))?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, artifacts })
    }

    /// Entry by exact name.
    pub fn find(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named {name:?} in manifest")))
    }

    /// The Ax artifact for `(variant, n, chunk)` if present.
    pub fn find_ax(&self, variant: &str, n: usize, chunk: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Ax && a.variant == variant && a.n == n && a.chunk == chunk
            })
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no ax artifact for variant={variant} n={n} chunk={chunk}; \
                     run `make artifacts` (available: {})",
                    self.summary()
                ))
            })
    }

    /// Chunk sizes available for an Ax variant at degree `n`, ascending.
    pub fn ax_chunks(&self, variant: &str, n: usize) -> Vec<usize> {
        let mut chunks: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Ax && a.variant == variant && a.n == n)
            .map(|a| a.chunk)
            .collect();
        chunks.sort_unstable();
        chunks
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    fn summary(&self) -> String {
        self.artifacts
            .iter()
            .map(|a| a.name.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "format": 1,
      "artifacts": [
        {"name": "ax_layered_n10_e64", "kind": "ax", "variant": "layered",
         "n": 10, "chunk": 64, "dtype": "float64",
         "file": "ax_layered_n10_e64.hlo.txt", "num_args": 3,
         "arg_shapes": [[64,10,10,10],[10,10],[64,6,10,10,10]]},
        {"name": "ax_layered_n10_e256", "kind": "ax", "variant": "layered",
         "n": 10, "chunk": 256, "dtype": "float64",
         "file": "ax_layered_n10_e256.hlo.txt", "num_args": 3,
         "arg_shapes": [[256,10,10,10],[10,10],[256,6,10,10,10]]},
        {"name": "glsc3_s64000", "kind": "vector", "variant": "glsc3",
         "n": 10, "chunk": 64, "dtype": "float64",
         "file": "glsc3_s64000.hlo.txt", "num_args": 3,
         "arg_shapes": [[64000],[64000],[64000]]}
      ]
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse(DOC, PathBuf::from("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = manifest();
        assert_eq!(m.artifacts.len(), 3);
        let ax = m.find("ax_layered_n10_e64").unwrap();
        assert_eq!(ax.kind, ArtifactKind::Ax);
        assert_eq!(ax.arg_shapes[2], vec![64, 6, 10, 10, 10]);
    }

    #[test]
    fn find_ax_by_config() {
        let m = manifest();
        assert!(m.find_ax("layered", 10, 64).is_ok());
        assert!(m.find_ax("layered", 10, 128).is_err());
        assert!(m.find_ax("shared", 10, 64).is_err());
    }

    #[test]
    fn chunks_sorted() {
        let m = manifest();
        assert_eq!(m.ax_chunks("layered", 10), vec![64, 256]);
        assert!(m.ax_chunks("layered", 12).is_empty());
    }

    #[test]
    fn path_joins_dir() {
        let m = manifest();
        let ax = m.find("ax_layered_n10_e64").unwrap();
        assert_eq!(
            m.path_of(ax),
            PathBuf::from("/tmp/artifacts/ax_layered_n10_e64.hlo.txt")
        );
    }

    #[test]
    fn missing_fields_rejected() {
        let bad = r#"{"artifacts": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must load.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.find_ax("layered", 10, 64).is_ok());
            assert!(m.find_ax("shared", 10, 64).is_ok());
            assert!(m.find_ax("original", 10, 64).is_ok());
            assert!(m.find_ax("jnp", 10, 64).is_ok());
        }
    }
}
