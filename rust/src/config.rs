//! Run configuration (the launcher's knobs, validated in one place).

use crate::error::{Error, Result};

/// Configuration of one Nekbone run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Number of spectral elements (the paper sweeps 64–4096).
    pub nelt: usize,
    /// GLL points per dimension; `n = polynomial degree + 1` (paper: 10).
    pub n: usize,
    /// CG iterations (paper: 100).
    pub niter: usize,
    /// Elements per XLA launch; artifacts exist for the chunks listed in
    /// the manifest (64 by default, 256/1024 for the perf pass).
    pub chunk: usize,
    /// Skip gather–scatter — the paper's roofline methodology
    /// ("without the communication activated").
    pub no_comm: bool,
    /// Skip the Dirichlet mask (for operator-only microbenchmarks).
    pub no_mask: bool,
    /// RNG seed for the right-hand side.
    pub seed: u64,
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Threads for the CPU-threaded backend (0 = all cores).
    pub cpu_threads: usize,
    /// Simulated MPI ranks (1 = single address space).
    pub ranks: usize,
    /// Optional residual tolerance for early exit (`None` mirrors
    /// Nekbone's fixed iteration count). Honored identically by the serial
    /// and ranked paths — both run the same solver.
    pub rtol: Option<f64>,
    /// Record the residual norm every iteration (costs one glsc3 sweep per
    /// iteration when `rtol` is not already paying for it).
    pub record_residuals: bool,
    /// Preconditioner: `"none"` (Nekbone's unpreconditioned CG),
    /// `"jacobi"` (assembled diagonal), or `"cheb"`
    /// (Chebyshev-accelerated Jacobi).
    pub precond: String,
    /// Chebyshev polynomial order (only read when `precond == "cheb"`;
    /// each CG iteration then costs `cheb_order - 1` extra Ax sweeps).
    pub cheb_order: usize,
    /// Rank decomposition shape: `"slab"` (z layers), `"pencil"` (z×y
    /// columns), or `"box"` (z×y×x bricks). Only read on the ranked path.
    pub decomp: String,
    /// Cache-blocked CG iteration pipeline: `"auto"` (a cache-sized
    /// segment, the default — blocked solves are bitwise identical to
    /// unblocked ones), `"off"` (historical whole-vector passes), or a
    /// dof count per segment (rounded down to whole elements).
    pub block_dofs: String,
}

/// Segment size `--block-dofs auto` resolves to, before clamping to the
/// local dof count: 4096 dofs × 6 resident vectors × 8 bytes ≈ 192 KiB,
/// comfortably inside a per-core L2.
pub const AUTO_BLOCK_DOFS: usize = 4096;

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nelt: 64,
            n: 10,
            niter: 100,
            chunk: 64,
            no_comm: false,
            no_mask: false,
            seed: 0x5EED,
            artifacts_dir: "artifacts".into(),
            cpu_threads: 0,
            ranks: 1,
            rtol: None,
            record_residuals: false,
            precond: "none".into(),
            cheb_order: 4,
            decomp: "slab".into(),
            block_dofs: "auto".into(),
        }
    }
}

impl RunConfig {
    /// Local degrees of freedom `D = nelt * n^3`.
    pub fn ndof(&self) -> usize {
        self.nelt * self.n * self.n * self.n
    }

    /// Validate the knobs against each other.
    pub fn validate(&self) -> Result<()> {
        if self.nelt == 0 {
            return Err(Error::Config("nelt must be positive".into()));
        }
        if self.n < 2 {
            return Err(Error::Config(format!("n must be >= 2, got {}", self.n)));
        }
        if self.niter == 0 {
            return Err(Error::Config("niter must be positive".into()));
        }
        if self.chunk == 0 {
            return Err(Error::Config("chunk must be positive".into()));
        }
        if self.ranks == 0 {
            return Err(Error::Config("ranks must be positive".into()));
        }
        if self.ranks > self.nelt {
            return Err(Error::Config(format!(
                "ranks ({}) cannot exceed nelt ({})",
                self.ranks, self.nelt
            )));
        }
        if let Some(t) = self.rtol {
            if t.is_nan() || t <= 0.0 {
                return Err(Error::Config(format!("rtol must be positive, got {t}")));
            }
        }
        match self.precond.as_str() {
            "none" | "jacobi" | "cheb" => {}
            other => {
                return Err(Error::Config(format!(
                    "precond must be none|jacobi|cheb, got {other:?}"
                )));
            }
        }
        if self.precond == "cheb" && self.cheb_order == 0 {
            return Err(Error::Config("cheb-order must be >= 1".into()));
        }
        match self.decomp.as_str() {
            "slab" | "pencil" | "box" => {}
            other => {
                return Err(Error::Config(format!(
                    "decomp must be slab|pencil|box, got {other:?}"
                )));
            }
        }
        self.resolved_block_dofs()?;
        Ok(())
    }

    /// Resolve `block_dofs` into a segment size for
    /// [`CgWorkspace::set_iteration_plan`](crate::solver::CgWorkspace):
    /// `None` for `"off"`, a clamped [`AUTO_BLOCK_DOFS`] for `"auto"`, and
    /// a validated dof count otherwise (zero and values above the global
    /// ndof are structured Config errors; ranked runs clamp further to
    /// each rank's local share at install time).
    pub fn resolved_block_dofs(&self) -> Result<Option<usize>> {
        match self.block_dofs.as_str() {
            "off" => Ok(None),
            "auto" => Ok(Some(AUTO_BLOCK_DOFS.min(self.ndof()).max(1))),
            raw => {
                let n: usize = raw.parse().map_err(|_| {
                    Error::Config(format!("block-dofs must be auto|off|N, got {raw:?}"))
                })?;
                if n == 0 {
                    return Err(Error::Config("block-dofs must be positive".into()));
                }
                if n > self.ndof() {
                    return Err(Error::Config(format!(
                        "block-dofs ({n}) cannot exceed ndof ({})",
                        self.ndof()
                    )));
                }
                Ok(Some(n))
            }
        }
    }
}

/// Parse the `NEKBONE_FUZZ_CASES` override (the differential-fuzz tier's
/// case budget). Garbage or zero is a **loud** [`Error::Config`] naming
/// the variable — a typo must not silently shrink the corpus.
pub fn parse_cases_env(raw: &str) -> Result<usize> {
    match raw.trim().parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        _ => Err(Error::Config(format!(
            "NEKBONE_FUZZ_CASES must be a positive integer, got {raw:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn ndof() {
        let c = RunConfig { nelt: 64, n: 10, ..Default::default() };
        assert_eq!(c.ndof(), 64_000);
    }

    #[test]
    fn rejects_bad_values() {
        for cfg in [
            RunConfig { nelt: 0, ..Default::default() },
            RunConfig { n: 1, ..Default::default() },
            RunConfig { niter: 0, ..Default::default() },
            RunConfig { chunk: 0, ..Default::default() },
            RunConfig { ranks: 0, ..Default::default() },
            RunConfig { ranks: 65, nelt: 64, ..Default::default() },
            RunConfig { rtol: Some(0.0), ..Default::default() },
            RunConfig { rtol: Some(-1e-8), ..Default::default() },
            RunConfig { rtol: Some(f64::NAN), ..Default::default() },
            RunConfig { precond: "ilu".into(), ..Default::default() },
            RunConfig { precond: "cheb".into(), cheb_order: 0, ..Default::default() },
            RunConfig { decomp: "diag".into(), ..Default::default() },
            RunConfig { block_dofs: "0".into(), ..Default::default() },
            RunConfig { block_dofs: "grid".into(), ..Default::default() },
            RunConfig { block_dofs: "64001".into(), ..Default::default() },
        ] {
            assert!(cfg.validate().is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn block_dofs_resolution() {
        let cfg = RunConfig::default(); // ndof = 64_000
        assert_eq!(cfg.resolved_block_dofs().unwrap(), Some(AUTO_BLOCK_DOFS));
        let off = RunConfig { block_dofs: "off".into(), ..Default::default() };
        assert_eq!(off.resolved_block_dofs().unwrap(), None);
        let fixed = RunConfig { block_dofs: "512".into(), ..Default::default() };
        assert_eq!(fixed.resolved_block_dofs().unwrap(), Some(512));
        // auto clamps to tiny problems instead of rejecting them.
        let tiny = RunConfig { nelt: 1, n: 2, ..Default::default() }; // ndof = 8
        assert_eq!(tiny.resolved_block_dofs().unwrap(), Some(8));
    }

    #[test]
    fn fuzz_cases_env_parses_loudly() {
        assert_eq!(parse_cases_env("24").unwrap(), 24);
        assert_eq!(parse_cases_env(" 7 ").unwrap(), 7);
        for bad in ["", "0", "-3", "many", "1e3"] {
            let err = parse_cases_env(bad).unwrap_err();
            assert!(
                err.to_string().contains("NEKBONE_FUZZ_CASES"),
                "error must name the variable: {err}"
            );
        }
    }
}
