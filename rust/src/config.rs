//! Run configuration (the launcher's knobs, validated in one place).

use crate::error::{Error, Result};

/// Configuration of one Nekbone run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Number of spectral elements (the paper sweeps 64–4096).
    pub nelt: usize,
    /// GLL points per dimension; `n = polynomial degree + 1` (paper: 10).
    pub n: usize,
    /// CG iterations (paper: 100).
    pub niter: usize,
    /// Elements per XLA launch; artifacts exist for the chunks listed in
    /// the manifest (64 by default, 256/1024 for the perf pass).
    pub chunk: usize,
    /// Skip gather–scatter — the paper's roofline methodology
    /// ("without the communication activated").
    pub no_comm: bool,
    /// Skip the Dirichlet mask (for operator-only microbenchmarks).
    pub no_mask: bool,
    /// RNG seed for the right-hand side.
    pub seed: u64,
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Threads for the CPU-threaded backend (0 = all cores).
    pub cpu_threads: usize,
    /// Simulated MPI ranks (1 = single address space).
    pub ranks: usize,
    /// Optional residual tolerance for early exit (`None` mirrors
    /// Nekbone's fixed iteration count). Honored identically by the serial
    /// and ranked paths — both run the same solver.
    pub rtol: Option<f64>,
    /// Record the residual norm every iteration (costs one glsc3 sweep per
    /// iteration when `rtol` is not already paying for it).
    pub record_residuals: bool,
    /// Preconditioner: `"none"` (Nekbone's unpreconditioned CG),
    /// `"jacobi"` (assembled diagonal), or `"cheb"`
    /// (Chebyshev-accelerated Jacobi).
    pub precond: String,
    /// Chebyshev polynomial order (only read when `precond == "cheb"`;
    /// each CG iteration then costs `cheb_order - 1` extra Ax sweeps).
    pub cheb_order: usize,
    /// Rank decomposition shape: `"slab"` (z layers), `"pencil"` (z×y
    /// columns), or `"box"` (z×y×x bricks). Only read on the ranked path.
    pub decomp: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nelt: 64,
            n: 10,
            niter: 100,
            chunk: 64,
            no_comm: false,
            no_mask: false,
            seed: 0x5EED,
            artifacts_dir: "artifacts".into(),
            cpu_threads: 0,
            ranks: 1,
            rtol: None,
            record_residuals: false,
            precond: "none".into(),
            cheb_order: 4,
            decomp: "slab".into(),
        }
    }
}

impl RunConfig {
    /// Local degrees of freedom `D = nelt * n^3`.
    pub fn ndof(&self) -> usize {
        self.nelt * self.n * self.n * self.n
    }

    /// Validate the knobs against each other.
    pub fn validate(&self) -> Result<()> {
        if self.nelt == 0 {
            return Err(Error::Config("nelt must be positive".into()));
        }
        if self.n < 2 {
            return Err(Error::Config(format!("n must be >= 2, got {}", self.n)));
        }
        if self.niter == 0 {
            return Err(Error::Config("niter must be positive".into()));
        }
        if self.chunk == 0 {
            return Err(Error::Config("chunk must be positive".into()));
        }
        if self.ranks == 0 {
            return Err(Error::Config("ranks must be positive".into()));
        }
        if self.ranks > self.nelt {
            return Err(Error::Config(format!(
                "ranks ({}) cannot exceed nelt ({})",
                self.ranks, self.nelt
            )));
        }
        if let Some(t) = self.rtol {
            if t.is_nan() || t <= 0.0 {
                return Err(Error::Config(format!("rtol must be positive, got {t}")));
            }
        }
        match self.precond.as_str() {
            "none" | "jacobi" | "cheb" => {}
            other => {
                return Err(Error::Config(format!(
                    "precond must be none|jacobi|cheb, got {other:?}"
                )));
            }
        }
        if self.precond == "cheb" && self.cheb_order == 0 {
            return Err(Error::Config("cheb-order must be >= 1".into()));
        }
        match self.decomp.as_str() {
            "slab" | "pencil" | "box" => {}
            other => {
                return Err(Error::Config(format!(
                    "decomp must be slab|pencil|box, got {other:?}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn ndof() {
        let c = RunConfig { nelt: 64, n: 10, ..Default::default() };
        assert_eq!(c.ndof(), 64_000);
    }

    #[test]
    fn rejects_bad_values() {
        for cfg in [
            RunConfig { nelt: 0, ..Default::default() },
            RunConfig { n: 1, ..Default::default() },
            RunConfig { niter: 0, ..Default::default() },
            RunConfig { chunk: 0, ..Default::default() },
            RunConfig { ranks: 0, ..Default::default() },
            RunConfig { ranks: 65, nelt: 64, ..Default::default() },
            RunConfig { rtol: Some(0.0), ..Default::default() },
            RunConfig { rtol: Some(-1e-8), ..Default::default() },
            RunConfig { rtol: Some(f64::NAN), ..Default::default() },
            RunConfig { precond: "ilu".into(), ..Default::default() },
            RunConfig { precond: "cheb".into(), cheb_order: 0, ..Default::default() },
            RunConfig { decomp: "diag".into(), ..Default::default() },
        ] {
            assert!(cfg.validate().is_err(), "{cfg:?}");
        }
    }
}
