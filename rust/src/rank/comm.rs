//! Message passing between simulated ranks (the MPI substrate): std mpsc
//! channels in a full mesh, with allreduce and pairwise exchange built on
//! top. Every collective is tagged to keep lock-step iterations honest.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::error::{Error, Result};

/// One message on the wire.
#[derive(Debug)]
pub struct Packet {
    pub from: usize,
    pub tag: u64,
    pub data: Vec<f64>,
}

/// Per-rank communicator (full mesh of channels).
pub struct Comm {
    pub rank: usize,
    pub size: usize,
    txs: Vec<Sender<Packet>>,
    rx: Receiver<Packet>,
    /// Out-of-order packets parked until their (from, tag) is requested.
    parked: Vec<Packet>,
}

impl Comm {
    /// Build communicators for `size` ranks.
    pub fn mesh(size: usize) -> Vec<Comm> {
        let mut txs_all = Vec::with_capacity(size);
        let mut rxs = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            txs_all.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| Comm { rank, size, txs: txs_all.clone(), rx, parked: Vec::new() })
            .collect()
    }

    /// Send `data` to `to` with a tag.
    pub fn send(&self, to: usize, tag: u64, data: Vec<f64>) -> Result<()> {
        self.txs[to]
            .send(Packet { from: self.rank, tag, data })
            .map_err(|_| Error::Rank(format!("rank {} -> {to}: channel closed", self.rank)))
    }

    /// Receive the packet with exact `(from, tag)`, parking others.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<f64>> {
        if let Some(pos) = self.parked.iter().position(|p| p.from == from && p.tag == tag) {
            return Ok(self.parked.swap_remove(pos).data);
        }
        loop {
            let pkt = self
                .rx
                .recv()
                .map_err(|_| Error::Rank(format!("rank {}: all senders closed", self.rank)))?;
            if pkt.from == from && pkt.tag == tag {
                return Ok(pkt.data);
            }
            self.parked.push(pkt);
        }
    }

    /// Sum a scalar across all ranks (reduce to rank 0, broadcast back).
    pub fn allreduce_sum(&mut self, value: f64, tag: u64) -> Result<f64> {
        if self.size == 1 {
            return Ok(value);
        }
        if self.rank == 0 {
            let mut acc = value;
            for from in 1..self.size {
                acc += self.recv(from, tag)?[0];
            }
            for to in 1..self.size {
                self.send(to, tag | TAG_BCAST, vec![acc])?;
            }
            Ok(acc)
        } else {
            self.send(0, tag, vec![value])?;
            Ok(self.recv(0, tag | TAG_BCAST)?[0])
        }
    }

    /// Pairwise exchange with `peer`: send `mine`, receive theirs.
    pub fn sendrecv(&mut self, peer: usize, tag: u64, mine: Vec<f64>) -> Result<Vec<f64>> {
        self.send(peer, tag, mine)?;
        self.recv(peer, tag)
    }
}

/// High bit marks broadcast legs of an allreduce.
const TAG_BCAST: u64 = 1 << 63;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sums() {
        let comms = Comm::mesh(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let v = (c.rank + 1) as f64;
                    c.allreduce_sum(v, 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 10.0);
        }
    }

    #[test]
    fn allreduce_single_rank() {
        let mut c = Comm::mesh(1).pop().unwrap();
        assert_eq!(c.allreduce_sum(3.5, 9).unwrap(), 3.5);
    }

    #[test]
    fn sendrecv_pairs() {
        let comms = Comm::mesh(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let peer = 1 - c.rank;
                    let got = c.sendrecv(peer, 7, vec![c.rank as f64]).unwrap();
                    (c.rank, got)
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            assert_eq!(got, vec![(1 - rank) as f64]);
        }
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let mut comms = Comm::mesh(2);
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        // Rank 1 sends tag 2 then tag 1; rank 0 asks for tag 1 first.
        c1.send(0, 2, vec![2.0]).unwrap();
        c1.send(0, 1, vec![1.0]).unwrap();
        assert_eq!(c0.recv(1, 1).unwrap(), vec![1.0]);
        assert_eq!(c0.recv(1, 2).unwrap(), vec![2.0]);
    }

    #[test]
    fn ordered_sequence_of_collectives() {
        // Two back-to-back allreduces must not interfere.
        let comms = Comm::mesh(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let a = c.allreduce_sum(1.0, 10).unwrap();
                    let b = c.allreduce_sum(c.rank as f64, 11).unwrap();
                    (a, b)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (3.0, 3.0));
        }
    }
}
