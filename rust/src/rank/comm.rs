//! Message passing between simulated ranks (the MPI substrate): std mpsc
//! channels in a full mesh, with deterministic allreduce and pairwise
//! exchange built on top, plus [`ThreadComm`] — the adapter that gives
//! these channels the [`Communicator`] face the generic CG solver
//! dispatches through.
//!
//! ## Tag-space layout
//!
//! Every message carries a 64-bit tag so lock-step collectives stay
//! honest even when packets arrive out of order:
//!
//! ```text
//! bit  63      broadcast leg marker (reserved by the allreduces)
//! bit  62      namespace: 0 = ThreadComm collectives, 1 = halo exchange
//! collectives: bits 0..62 hold a per-communicator sequence number
//! exchange:    bits 30..62 hold the exchange round,
//!              bits 0..30 the shared plane's first global id + 1
//! ```
//!
//! Collectives need no negotiated tags at all: every rank's [`ThreadComm`]
//! counts its collectives, and since the solver is SPMD (all ranks issue
//! the same collectives in the same order — see the
//! [`Communicator`](crate::solver::Communicator) contract), the counters
//! agree by construction and never repeat. Halo exchanges live in their
//! own namespace keyed by (round, plane id), so a slow rank's round-`k`
//! plane can never be consumed as round-`k+1` data.
//! [`exchange_tag`] rejects unrepresentable rounds/ids with a `Config`
//! error instead of corrupting the exchange.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender};

use crate::error::{Error, Result};
use crate::solver::Communicator;

/// High bit marks broadcast legs of an allreduce.
const TAG_BCAST: u64 = 1 << 63;

/// Namespace bit separating halo-exchange tags from collective tags.
const TAG_NS_EXCHANGE: u64 = 1 << 62;

/// Bits holding the shared plane's first global id + 1 in exchange tags.
pub(crate) const TAG_PAIR_BITS: u32 = 30;

/// Bits holding the exchange round in exchange tags.
pub(crate) const TAG_ROUND_BITS: u32 = 32;

/// Tag of one halo-plane exchange: both sides derive it from the exchange
/// round and the plane's first global id, so the pair agrees without
/// negotiation. Errors (rather than silently colliding) when the round or
/// id exceeds its field.
pub(crate) fn exchange_tag(round: u64, gid: usize) -> Result<u64> {
    if round >= 1 << TAG_ROUND_BITS {
        return Err(Error::Config(format!(
            "halo exchange round {round} is unrepresentable in the tag space \
             (max {})",
            (1u64 << TAG_ROUND_BITS) - 1
        )));
    }
    if gid as u64 + 1 >= 1 << TAG_PAIR_BITS {
        return Err(Error::Config(format!(
            "halo plane global id {gid} is unrepresentable in the tag space \
             (max {})",
            (1u64 << TAG_PAIR_BITS) - 2
        )));
    }
    Ok(TAG_NS_EXCHANGE | (round << TAG_PAIR_BITS) | (gid as u64 + 1))
}

/// One message on the wire.
#[derive(Debug)]
pub struct Packet {
    pub from: usize,
    pub tag: u64,
    pub data: Vec<f64>,
}

/// Per-rank communicator (full mesh of channels).
pub struct Comm {
    pub rank: usize,
    pub size: usize,
    txs: Vec<Sender<Packet>>,
    rx: Receiver<Packet>,
    /// Out-of-order packets parked until their (from, tag) is requested.
    parked: Vec<Packet>,
}

impl Comm {
    /// Build communicators for `size` ranks.
    pub fn mesh(size: usize) -> Vec<Comm> {
        let mut txs_all = Vec::with_capacity(size);
        let mut rxs = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            txs_all.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| Comm { rank, size, txs: txs_all.clone(), rx, parked: Vec::new() })
            .collect()
    }

    /// Send `data` to `to` with a tag.
    pub fn send(&self, to: usize, tag: u64, data: Vec<f64>) -> Result<()> {
        self.txs[to]
            .send(Packet { from: self.rank, tag, data })
            .map_err(|_| Error::Rank(format!("rank {} -> {to}: channel closed", self.rank)))
    }

    /// Receive the packet with exact `(from, tag)`, parking others.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<f64>> {
        if let Some(pos) = self.parked.iter().position(|p| p.from == from && p.tag == tag) {
            return Ok(self.parked.swap_remove(pos).data);
        }
        loop {
            let pkt = self
                .rx
                .recv()
                .map_err(|_| Error::Rank(format!("rank {}: all senders closed", self.rank)))?;
            if pkt.from == from && pkt.tag == tag {
                return Ok(pkt.data);
            }
            self.parked.push(pkt);
        }
    }

    /// Fold a scalar across all ranks **in ascending rank order** (rank 0
    /// folds its own value, then rank 1's, 2's, ... in sequence) and
    /// broadcast the folded result back. The fold order is fixed and every
    /// rank receives rank 0's accumulator verbatim, so the result is
    /// deterministic run-to-run and **bitwise identical on every rank** —
    /// the determinism the [`Communicator`](crate::solver::Communicator)
    /// contract promises.
    fn allreduce(&mut self, value: f64, tag: u64, fold: impl Fn(f64, f64) -> f64) -> Result<f64> {
        if self.size == 1 {
            return Ok(value);
        }
        if self.rank == 0 {
            let mut acc = value;
            for from in 1..self.size {
                acc = fold(acc, self.recv(from, tag)?[0]);
            }
            for to in 1..self.size {
                self.send(to, tag | TAG_BCAST, vec![acc])?;
            }
            Ok(acc)
        } else {
            self.send(0, tag, vec![value])?;
            Ok(self.recv(0, tag | TAG_BCAST)?[0])
        }
    }

    /// Deterministic rank-order sum of a scalar across all ranks.
    pub fn allreduce_sum(&mut self, value: f64, tag: u64) -> Result<f64> {
        self.allreduce(value, tag, |a, b| a + b)
    }

    /// Deterministic rank-order minimum of a scalar across all ranks.
    pub fn allreduce_min(&mut self, value: f64, tag: u64) -> Result<f64> {
        self.allreduce(value, tag, f64::min)
    }

    /// Pairwise exchange with `peer`: send `mine`, receive theirs.
    pub fn sendrecv(&mut self, peer: usize, tag: u64, mine: Vec<f64>) -> Result<Vec<f64>> {
        self.send(peer, tag, mine)?;
        self.recv(peer, tag)
    }

    /// Keyed ordered sum (the channel realization of
    /// [`Communicator::allreduce_ordered_sum`]): rank 0 gathers every
    /// rank's `(gid, partial)` pairs, sorts them by gid, folds from `0.0`
    /// in ascending-gid order, and broadcasts its accumulator verbatim.
    /// Because each gid is owned by exactly one rank the keys are unique,
    /// so the sort fully determines the fold order — the very expression a
    /// size-1 communicator evaluates over the same gids. That makes the
    /// result bitwise independent of how the gids are distributed across
    /// ranks, which is what pins ranked CG reductions to the serial bits
    /// for every decomposition shape.
    ///
    /// Pairs travel as flat `[gid, partial, gid, partial, ...]` f64 data;
    /// gids are far below 2^53 (the exchange tag space alone caps them at
    /// 2^30), so the f64 round trip is exact.
    pub fn allreduce_ordered_sum(
        &mut self,
        gids: &[u64],
        partials: &[f64],
        tag: u64,
    ) -> Result<f64> {
        debug_assert_eq!(gids.len(), partials.len());
        if self.size == 1 {
            return Ok(partials.iter().fold(0.0, |acc, &p| acc + p));
        }
        if self.rank != 0 {
            let mut flat = Vec::with_capacity(gids.len() * 2);
            for (&g, &p) in gids.iter().zip(partials) {
                flat.push(g as f64);
                flat.push(p);
            }
            self.send(0, tag, flat)?;
            return Ok(self.recv(0, tag | TAG_BCAST)?[0]);
        }
        let mut pairs: Vec<(u64, f64)> =
            gids.iter().copied().zip(partials.iter().copied()).collect();
        for from in 1..self.size {
            let flat = self.recv(from, tag)?;
            for ch in flat.chunks_exact(2) {
                pairs.push((ch[0] as u64, ch[1]));
            }
        }
        pairs.sort_unstable_by_key(|&(g, _)| g);
        let acc = pairs.iter().fold(0.0, |acc, &(_, p)| acc + p);
        for to in 1..self.size {
            self.send(to, tag | TAG_BCAST, vec![acc])?;
        }
        Ok(acc)
    }
}

/// The [`Communicator`] adapter over a rank's channel [`Comm`]: collective
/// tags are generated from a per-communicator sequence counter (see the
/// module docs), so callers — the generic CG solver above all — never
/// handle tags. Shares the underlying `Comm` with the rank's halo exchange
/// through `Rc<RefCell<..>>`; the two tag namespaces are disjoint.
pub struct ThreadComm {
    comm: Rc<RefCell<Comm>>,
    seq: u64,
}

impl ThreadComm {
    /// Wrap a shared channel communicator.
    pub fn new(comm: Rc<RefCell<Comm>>) -> Self {
        ThreadComm { comm, seq: 0 }
    }

    fn next_tag(&mut self) -> Result<u64> {
        if self.seq >= TAG_NS_EXCHANGE {
            return Err(Error::Config(
                "collective sequence number exhausted (2^62 collectives on one \
                 communicator)"
                    .into(),
            ));
        }
        let tag = self.seq;
        self.seq += 1;
        Ok(tag)
    }
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.comm.borrow().rank
    }

    fn size(&self) -> usize {
        self.comm.borrow().size
    }

    fn allreduce_sum(&mut self, value: f64) -> Result<f64> {
        let tag = self.next_tag()?;
        self.comm.borrow_mut().allreduce_sum(value, tag)
    }

    fn allreduce_min(&mut self, value: f64) -> Result<f64> {
        let tag = self.next_tag()?;
        self.comm.borrow_mut().allreduce_min(value, tag)
    }

    fn allreduce_ordered_sum(&mut self, gids: &[u64], partials: &[f64]) -> Result<f64> {
        let tag = self.next_tag()?;
        self.comm.borrow_mut().allreduce_ordered_sum(gids, partials, tag)
    }

    fn barrier(&mut self) -> Result<()> {
        // An allreduce is a barrier: no rank can own the result before
        // every rank has contributed.
        let tag = self.next_tag()?;
        self.comm.borrow_mut().allreduce_sum(0.0, tag).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sums() {
        let comms = Comm::mesh(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let v = (c.rank + 1) as f64;
                    c.allreduce_sum(v, 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 10.0);
        }
    }

    #[test]
    fn allreduce_is_rank_order_deterministic() {
        // Values whose sum depends on association order: the collective
        // must equal the explicit ascending-rank left fold, bitwise, on
        // every rank — this is what lets the rank runtime assert exact
        // (not approximate) cross-rank agreement.
        let vals = [1.0e16, 3.7, -1.0e16, 0.1];
        let want_sum = vals.iter().fold(0.0f64, |a, &b| a + b); // ((v0+v1)+v2)+v3
        let want_min = vals.iter().fold(f64::INFINITY, |a, &b| a.min(b));
        assert_ne!(
            want_sum.to_bits(),
            (vals[3] + vals[2] + vals[1] + vals[0]).to_bits(),
            "test values must be order-sensitive"
        );
        let comms = Comm::mesh(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let s = c.allreduce_sum(vals[c.rank], 5).unwrap();
                    let m = c.allreduce_min(vals[c.rank], 6).unwrap();
                    (s, m)
                })
            })
            .collect();
        for h in handles {
            let (s, m) = h.join().unwrap();
            assert_eq!(s.to_bits(), want_sum.to_bits());
            assert_eq!(m.to_bits(), want_min.to_bits());
        }
    }

    #[test]
    fn ordered_sum_is_distribution_independent() {
        // Order-sensitive values keyed by gid, dealt out to ranks three
        // different ways (contiguous blocks, round-robin, reversed): every
        // layout must reproduce the serial ascending-gid fold bitwise.
        const VALS: [f64; 8] = [1.0e16, 3.7, -1.0e16, 0.1, 2.5e15, -0.3, 7.0, -2.5e15];
        fn deal(layout: usize, rank: usize) -> (Vec<u64>, Vec<f64>) {
            let mine: Vec<u64> = (0..VALS.len() as u64)
                .filter(|&g| match layout {
                    0 => g / 2 == rank as u64,     // contiguous blocks
                    1 => g % 4 == rank as u64,     // round-robin
                    _ => 3 - g / 2 == rank as u64, // reversed blocks
                })
                .collect();
            let parts = mine.iter().map(|&g| VALS[g as usize]).collect();
            (mine, parts)
        }
        let want = VALS.iter().fold(0.0f64, |a, &b| a + b);
        assert_ne!(
            want.to_bits(),
            VALS.iter().rev().fold(0.0f64, |a, &b| a + b).to_bits(),
            "test values must be order-sensitive"
        );
        for layout in 0..3 {
            let comms = Comm::mesh(4);
            let handles: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    std::thread::spawn(move || {
                        let (gids, parts) = deal(layout, c.rank);
                        c.allreduce_ordered_sum(&gids, &parts, 21).unwrap()
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap().to_bits(), want.to_bits(), "layout {layout}");
            }
        }
    }

    #[test]
    fn allreduce_single_rank() {
        let mut c = Comm::mesh(1).pop().unwrap();
        assert_eq!(c.allreduce_sum(3.5, 9).unwrap(), 3.5);
        assert_eq!(c.allreduce_min(3.5, 10).unwrap(), 3.5);
    }

    #[test]
    fn sendrecv_pairs() {
        let comms = Comm::mesh(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let peer = 1 - c.rank;
                    let got = c.sendrecv(peer, 7, vec![c.rank as f64]).unwrap();
                    (c.rank, got)
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            assert_eq!(got, vec![(1 - rank) as f64]);
        }
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let mut comms = Comm::mesh(2);
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        // Rank 1 sends tag 2 then tag 1; rank 0 asks for tag 1 first.
        c1.send(0, 2, vec![2.0]).unwrap();
        c1.send(0, 1, vec![1.0]).unwrap();
        assert_eq!(c0.recv(1, 1).unwrap(), vec![1.0]);
        assert_eq!(c0.recv(1, 2).unwrap(), vec![2.0]);
    }

    #[test]
    fn ordered_sequence_of_collectives() {
        // Two back-to-back allreduces must not interfere.
        let comms = Comm::mesh(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let a = c.allreduce_sum(1.0, 10).unwrap();
                    let b = c.allreduce_sum(c.rank as f64, 11).unwrap();
                    (a, b)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (3.0, 3.0));
        }
    }

    #[test]
    fn thread_comm_collectives_without_explicit_tags() {
        // The Communicator face: sequence-counted collectives, min, and
        // barrier, all without the caller touching a tag.
        let comms = Comm::mesh(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut tc = ThreadComm::new(Rc::new(RefCell::new(comm)));
                    assert_eq!(tc.size(), 3);
                    let rank = tc.rank();
                    let a = tc.allreduce_sum(rank as f64).unwrap();
                    tc.barrier().unwrap();
                    let b = tc.allreduce_min(rank as f64 * -1.0).unwrap();
                    let c = tc.allreduce_sum(1.0).unwrap();
                    (a, b, c)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (3.0, -2.0, 3.0));
        }
    }

    #[test]
    fn tag_namespaces_are_disjoint() {
        // Collective sequence tags, exchange tags, and the broadcast bit
        // can never collide.
        let mut seen = std::collections::BTreeSet::new();
        for seq in [0u64, 1, 2, 1 << 40, TAG_NS_EXCHANGE - 1] {
            assert!(seen.insert(seq), "collective tag collision at {seq}");
            assert_eq!(seq & TAG_NS_EXCHANGE, 0);
        }
        for round in [0u64, 1, 8191, 8192, (1 << TAG_ROUND_BITS) - 1] {
            for gid in [0usize, 1, 4095, (1 << TAG_PAIR_BITS) - 2] {
                let t = exchange_tag(round, gid).unwrap();
                assert!(seen.insert(t), "exchange tag collision at round {round} gid {gid}");
                assert_ne!(t & TAG_NS_EXCHANGE, 0);
            }
        }
        for &t in &seen {
            assert_eq!(t & TAG_BCAST, 0, "tag {t:#x} sets the broadcast bit");
        }
    }

    #[test]
    fn exchange_tag_capacity_is_config_error() {
        assert!(exchange_tag((1 << TAG_ROUND_BITS) - 1, 7).is_ok());
        assert!(matches!(exchange_tag(1 << TAG_ROUND_BITS, 7), Err(Error::Config(_))));
        assert!(exchange_tag(0, (1 << TAG_PAIR_BITS) - 2).is_ok());
        assert!(matches!(
            exchange_tag(0, (1 << TAG_PAIR_BITS) - 1),
            Err(Error::Config(_))
        ));
    }
}
