//! Partitioning the element grid across ranks: slab (z), pencil (z×y),
//! and box (z×y×x) decompositions.
//!
//! A decomposition assigns every rank one contiguous **brick** of
//! elements — per-axis element ranges, the natural generalization of the
//! original z-slab layout (a slab is a brick spanning the full x/y
//! extents). Neighbor topology follows from geometry alone: two bricks
//! are neighbors exactly when their global *point* ranges intersect in
//! all three axes, which covers face, edge, and corner adjacency (up to
//! 26 neighbors for an interior box brick). Each neighbor link carries
//! the ascending list of global point ids in the intersection box; both
//! sides of a link enumerate the identical list, so exchange messages
//! align and tags derive from the link's first gid without negotiation.
//!
//! Shape selection is by feasible factorization: the rank count is
//! factored over the axes the shape may split (slab: z; pencil: z then
//! y; box: all three), subject to each axis factor not exceeding that
//! axis's element count, minimizing the total cut-plane area (the
//! elements-per-face communication proxy). An infeasible request — any
//! axis split finer than its element count — is a structured
//! [`Error::Config`] naming the axes and their limits, never a
//! degenerate empty brick.

use crate::error::{Error, Result};
use crate::mesh::Mesh;

/// Which axes a decomposition may split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecompShape {
    /// z only (the original layout): ranks own whole element layers.
    Slab,
    /// z × y: ranks own full-x columns.
    Pencil,
    /// z × y × x: general 3-D bricks.
    Box,
}

impl DecompShape {
    /// Parse a `--decomp` value.
    pub fn parse(s: &str) -> Result<DecompShape> {
        match s {
            "slab" => Ok(DecompShape::Slab),
            "pencil" => Ok(DecompShape::Pencil),
            "box" => Ok(DecompShape::Box),
            other => Err(Error::Config(format!(
                "unknown decomposition shape '{other}' (expected slab, pencil, or box)"
            ))),
        }
    }

    /// The CLI/report spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            DecompShape::Slab => "slab",
            DecompShape::Pencil => "pencil",
            DecompShape::Box => "box",
        }
    }
}

/// One rank's contiguous element brick: half-open per-axis element
/// ranges into the mesh's `ex × ey × ez` grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Brick {
    pub x0: usize,
    pub x1: usize,
    pub y0: usize,
    pub y1: usize,
    pub z0: usize,
    pub z1: usize,
}

impl Brick {
    /// Elements in this brick.
    pub fn nelt(&self) -> usize {
        (self.x1 - self.x0) * (self.y1 - self.y0) * (self.z1 - self.z0)
    }

    /// Global element ids of this brick in ascending order (k-major —
    /// the mesh numbers elements x-fastest, so lexicographic (k, j, i)
    /// over the ranges *is* ascending global id). The rank runtime
    /// relies on this order: with local elements ascending by global id,
    /// the rank-local gather–scatter folds every purely-local shared
    /// group in exactly the serial fold order.
    pub fn elems(&self, mesh: &Mesh) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nelt());
        for ek in self.z0..self.z1 {
            for ej in self.y0..self.y1 {
                for ei in self.x0..self.x1 {
                    out.push(mesh.elem_id(ei, ej, ek));
                }
            }
        }
        out
    }

    /// Inclusive global *point* range along one axis: elements
    /// `[a0, a1)` of degree-`n` elements cover points
    /// `[a0·(n−1), a1·(n−1)]` (shared faces overlap by one point).
    fn point_range(a0: usize, a1: usize, n: usize) -> (usize, usize) {
        (a0 * (n - 1), a1 * (n - 1))
    }
}

/// Split `len` items over `parts`: contiguous, remainder to low parts.
/// The caller guarantees `parts <= len` (the factorization search only
/// proposes feasible splits), so no range is empty.
fn axis_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut at = 0;
    for p in 0..parts {
        let h = base + usize::from(p < rem);
        out.push((at, at + h));
        at += h;
    }
    out
}

/// A full partition of the mesh: the shape, the chosen per-axis factors
/// (`px · py · pz == ranks`), one [`Brick`] per rank, and the neighbor
/// links (peer rank + ascending shared global point ids) of every rank.
///
/// Rank ordering is x-fastest: `rank = (iz · py + iy) · px + ix`. A slab
/// decomposition (`px = py = 1`) therefore reproduces the original
/// layout exactly — rank r owns z layers `iz = r`.
pub struct Decomposition {
    pub shape: DecompShape,
    pub px: usize,
    pub py: usize,
    pub pz: usize,
    bricks: Vec<Brick>,
    /// Per rank: `(peer, ascending shared point gids)` per neighbor,
    /// peers ascending.
    neighbors: Vec<Vec<(usize, Vec<usize>)>>,
}

impl Decomposition {
    /// Factor `ranks` over the shape's axes and build the bricks and
    /// neighbor links. Infeasible requests (any axis split finer than
    /// its element count) are structured Config errors naming the axes
    /// and limits.
    pub fn new(shape: DecompShape, ranks: usize, mesh: &Mesh) -> Result<Decomposition> {
        if ranks == 0 {
            return Err(Error::Config("decomposition needs at least one rank".into()));
        }
        let (ex, ey, ez) = (mesh.ex, mesh.ey, mesh.ez);
        // Enumerate feasible factorizations, keep the one with the least
        // cut-plane area (elements per internal face, the communication
        // proxy); ties break toward more z splits, then more y splits,
        // so the search is deterministic and slab-like layouts win ties.
        let mut best: Option<(usize, usize, usize, usize)> = None; // (cost, px, py, pz)
        let mut consider = |px: usize, py: usize, pz: usize| {
            let cost = (pz - 1) * ex * ey + (py - 1) * ex * ez + (px - 1) * ey * ez;
            let better = match best {
                None => true,
                Some((c, _, bpy, bpz)) => {
                    (cost, std::cmp::Reverse(pz), std::cmp::Reverse(py))
                        < (c, std::cmp::Reverse(bpz), std::cmp::Reverse(bpy))
                }
            };
            if better {
                best = Some((cost, px, py, pz));
            }
        };
        for pz in 1..=ranks.min(ez) {
            if ranks % pz != 0 {
                continue;
            }
            let rest = ranks / pz;
            match shape {
                DecompShape::Slab => {
                    if rest == 1 {
                        consider(1, 1, pz);
                    }
                }
                DecompShape::Pencil => {
                    if rest <= ey {
                        consider(1, rest, pz);
                    }
                }
                DecompShape::Box => {
                    for py in 1..=rest.min(ey) {
                        if rest % py != 0 {
                            continue;
                        }
                        let px = rest / py;
                        if px <= ex {
                            consider(px, py, pz);
                        }
                    }
                }
            }
        }
        let Some((_, px, py, pz)) = best else {
            let axes = match shape {
                DecompShape::Slab => format!("pz = ranks with pz <= ez ({ez})"),
                DecompShape::Pencil => {
                    format!("py*pz = ranks with py <= ey ({ey}), pz <= ez ({ez})")
                }
                DecompShape::Box => format!(
                    "px*py*pz = ranks with px <= ex ({ex}), py <= ey ({ey}), pz <= ez ({ez})"
                ),
            };
            return Err(Error::Config(format!(
                "{} decomposition of {ranks} ranks is infeasible on the \
                 {ex}x{ey}x{ez} element grid: no factorization {axes}; \
                 use fewer ranks, a roomier shape, or a larger nelt",
                shape.as_str()
            )));
        };

        let zr = axis_ranges(ez, pz);
        let yr = axis_ranges(ey, py);
        let xr = axis_ranges(ex, px);
        let mut bricks = Vec::with_capacity(ranks);
        for &(z0, z1) in &zr {
            for &(y0, y1) in &yr {
                for &(x0, x1) in &xr {
                    bricks.push(Brick { x0, x1, y0, y1, z0, z1 });
                }
            }
        }

        let neighbors = (0..ranks)
            .map(|r| {
                let mut links = Vec::new();
                for (s, other) in bricks.iter().enumerate() {
                    if s == r {
                        continue;
                    }
                    if let Some(gids) = shared_points(&bricks[r], other, mesh) {
                        links.push((s, gids));
                    }
                }
                links
            })
            .collect();

        Ok(Decomposition { shape, px, py, pz, bricks, neighbors })
    }

    /// One brick per rank, indexed by rank.
    pub fn bricks(&self) -> &[Brick] {
        &self.bricks
    }

    /// `rank`'s neighbor links: `(peer, ascending shared point gids)`,
    /// peers ascending. Both endpoints of a link hold the identical gid
    /// list (the intersection box is symmetric).
    pub fn neighbors(&self, rank: usize) -> &[(usize, Vec<usize>)] {
        &self.neighbors[rank]
    }

    /// Ranks in this decomposition.
    pub fn ranks(&self) -> usize {
        self.bricks.len()
    }
}

/// The global point ids two bricks share, ascending — `None` when the
/// bricks are not adjacent. Bricks share points exactly when their
/// inclusive point ranges intersect in all three axes; the shared set is
/// then the (degenerate or not) intersection box, enumerated z-major /
/// x-fastest, which is ascending in `gid = (z·gy + y)·gx + x`.
fn shared_points(a: &Brick, b: &Brick, mesh: &Mesh) -> Option<Vec<usize>> {
    let n = mesh.n;
    let axis = |a0, a1, b0, b1| {
        let (alo, ahi) = Brick::point_range(a0, a1, n);
        let (blo, bhi) = Brick::point_range(b0, b1, n);
        let lo = alo.max(blo);
        let hi = ahi.min(bhi);
        (lo <= hi).then_some((lo, hi))
    };
    let (xlo, xhi) = axis(a.x0, a.x1, b.x0, b.x1)?;
    let (ylo, yhi) = axis(a.y0, a.y1, b.y0, b.y1)?;
    let (zlo, zhi) = axis(a.z0, a.z1, b.z0, b.z1)?;
    let mut gids =
        Vec::with_capacity((zhi - zlo + 1) * (yhi - ylo + 1) * (xhi - xlo + 1));
    for z in zlo..=zhi {
        for y in ylo..=yhi {
            for x in xlo..=xhi {
                gids.push((z * mesh.gy + y) * mesh.gx + x);
            }
        }
    }
    Some(gids)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh(ex: usize, ey: usize, ez: usize, n: usize) -> Mesh {
        Mesh::new(ex, ey, ez, n).unwrap()
    }

    #[test]
    fn slab_reproduces_the_original_layout() {
        let m = mesh(2, 2, 4, 3);
        let d = Decomposition::new(DecompShape::Slab, 4, &m).unwrap();
        assert_eq!((d.px, d.py, d.pz), (1, 1, 4));
        for (r, b) in d.bricks().iter().enumerate() {
            assert_eq!((b.x0, b.x1, b.y0, b.y1), (0, 2, 0, 2));
            assert_eq!((b.z0, b.z1), (r, r + 1));
        }
        // Adjacent slabs share one full xy plane of points; slab 0 and
        // slab 2 are not adjacent (their point ranges never touch).
        let links = d.neighbors(0);
        assert_eq!(links.len(), 1);
        assert_eq!(links[0].0, 1);
        assert_eq!(links[0].1.len(), m.gx * m.gy);
        assert!(d.neighbors(1).iter().any(|(p, _)| *p == 2));
        assert!(!d.neighbors(0).iter().any(|(p, _)| *p == 2));
    }

    #[test]
    fn bricks_partition_every_element_exactly_once() {
        for (shape, ranks) in [
            (DecompShape::Slab, 4),
            (DecompShape::Pencil, 4),
            (DecompShape::Pencil, 6),
            (DecompShape::Box, 8),
            (DecompShape::Box, 12),
        ] {
            let m = mesh(3, 4, 4, 3);
            let d = Decomposition::new(shape, ranks, &m).unwrap();
            assert_eq!(d.ranks(), ranks);
            let mut seen = vec![false; m.nelt()];
            for b in d.bricks() {
                assert!(b.nelt() > 0, "{shape:?}/{ranks}: empty brick");
                for e in b.elems(&m) {
                    assert!(!seen[e], "{shape:?}/{ranks}: element {e} owned twice");
                    seen[e] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{shape:?}/{ranks}: elements unowned");
        }
    }

    #[test]
    fn elems_ascend_within_every_brick() {
        let m = mesh(3, 4, 4, 3);
        let d = Decomposition::new(DecompShape::Box, 12, &m).unwrap();
        for b in d.bricks() {
            let es = b.elems(&m);
            assert!(es.windows(2).all(|w| w[0] < w[1]), "brick {b:?}: {es:?}");
        }
    }

    #[test]
    fn factorization_prefers_fewest_cut_faces() {
        // 4 ranks on a 2x4x4 grid: splitting z into 4 layers cuts
        // 3 planes of 8 elements (24 faces); 2x2 over (y, z) cuts
        // 1 plane of 8 + 1 plane of 8 (16). Pencil must pick 2x2.
        let m = mesh(2, 4, 4, 3);
        let d = Decomposition::new(DecompShape::Pencil, 4, &m).unwrap();
        assert_eq!((d.px, d.py, d.pz), (1, 2, 2));
        // Box on 8 ranks over 2x4x4 prefers 1x2x4 (z-heavy tie-break
        // never splits x while y/z can absorb the factor more cheaply).
        let d8 = Decomposition::new(DecompShape::Box, 8, &m).unwrap();
        assert_eq!(d8.px * d8.py * d8.pz, 8);
        let split = (d8.px, d8.py, d8.pz);
        assert!(d8.px == 1, "x split is the most expensive axis here: {split:?}");
    }

    #[test]
    fn pencil_and_box_links_are_symmetric() {
        let m = mesh(3, 4, 4, 4);
        for (shape, ranks) in [(DecompShape::Pencil, 4), (DecompShape::Box, 12)] {
            let d = Decomposition::new(shape, ranks, &m).unwrap();
            for r in 0..ranks {
                for (peer, gids) in d.neighbors(r) {
                    let back = d
                        .neighbors(*peer)
                        .iter()
                        .find(|(p, _)| *p == r)
                        .unwrap_or_else(|| panic!("{shape:?}: link {r}->{peer} not mirrored"));
                    assert_eq!(&back.1, gids, "{shape:?}: {r}<->{peer} gid lists differ");
                    assert!(gids.windows(2).all(|w| w[0] < w[1]), "gids must ascend");
                }
            }
        }
    }

    #[test]
    fn box_interior_rank_sees_corner_and_edge_neighbors() {
        // 27 ranks on a 3x3x3 grid: the center brick touches all 26
        // others — 6 faces, 12 edges, 8 corners.
        let m = mesh(3, 3, 3, 3);
        let d = Decomposition::new(DecompShape::Box, 27, &m).unwrap();
        assert_eq!((d.px, d.py, d.pz), (3, 3, 3));
        let center = (3 + 1) * 3 + 1; // (iz=1, iy=1, ix=1) under x-fastest ordering
        let links = d.neighbors(center);
        assert_eq!(links.len(), 26);
        let sizes: Vec<usize> = links.iter().map(|(_, g)| g.len()).collect();
        let corners = sizes.iter().filter(|&&s| s == 1).count();
        assert_eq!(corners, 8, "corner links share exactly one point: {sizes:?}");
    }

    #[test]
    fn infeasible_splits_name_the_axis_limits() {
        let m = mesh(2, 4, 4, 3); // ez = 4
        let err = Decomposition::new(DecompShape::Slab, 5, &m).unwrap_err().to_string();
        assert!(err.contains("slab") && err.contains("ez (4)"), "{err}");
        // Pencil: 7 is prime and exceeds both splittable axes' limits...
        let err = Decomposition::new(DecompShape::Pencil, 7, &m).unwrap_err().to_string();
        assert!(err.contains("pencil") && err.contains("ey (4)"), "{err}");
        assert!(err.contains("ez (4)"), "{err}");
        // ...and box names all three axes (32 > 2*4*4 has no fit).
        let err = Decomposition::new(DecompShape::Box, 64, &m).unwrap_err().to_string();
        assert!(err.contains("box") && err.contains("ex (2)"), "{err}");
        // Feasible cousins of the failures above succeed.
        assert!(Decomposition::new(DecompShape::Pencil, 8, &m).is_ok());
        assert!(Decomposition::new(DecompShape::Box, 32, &m).is_ok());
    }

    #[test]
    fn shape_parse_round_trips() {
        for s in ["slab", "pencil", "box"] {
            assert_eq!(DecompShape::parse(s).unwrap().as_str(), s);
        }
        let err = DecompShape::parse("diag").unwrap_err().to_string();
        assert!(err.contains("diag") && err.contains("slab"), "{err}");
    }
}
