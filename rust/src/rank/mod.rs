//! Simulated multi-rank runtime: the MPI layer of Nekbone as threads +
//! channels (experiment E8, strong scaling).
//!
//! The element grid is partitioned into contiguous **z slabs** (ranks own
//! `ez/R` element layers each, remainder to the low ranks). Adjacent slabs
//! share one plane of global points, so the distributed `dssum` is a local
//! gather–scatter followed by one pairwise halo exchange per neighbor —
//! exactly the communication structure of the real code, with
//! `std::sync::mpsc` standing in for MPI.
//!
//! The per-rank compute dispatches through a `Box<dyn AxOperator>` built by
//! name from the [`OperatorRegistry`], so any registered operator (default:
//! the paper's layered CPU schedule, the CPU/MPI baseline) runs inside the
//! rank loop without this module knowing about it.

mod comm;

pub use comm::{Comm, Packet};

use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::RunReport;
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::{OperatorCtx, OperatorRegistry};
use crate::solver::{add2s1, add2s2, glsc3, mask_apply, PapCorrection};

/// The operator each rank runs when the caller does not pick one.
pub const DEFAULT_RANK_OPERATOR: &str = "cpu-layered";

// ---------------------------------------------------------------------------
// Collective tags
// ---------------------------------------------------------------------------
//
// Layout of the 64-bit tag space:
//
// ```text
// bits  0..3   collective id within an iteration
//              (0 = rtz1 allreduce, 1 = dssum halo, 2 = pap allreduce)
// bits  3..32  halo pair id (shared plane's first global id + 1);
//              zero for non-halo collectives
// bits 32..63  iteration + 1 (zero only for TAG_FINAL)
// bit  63      reserved by `Comm::allreduce_sum` for broadcast legs
// ```
//
// The previous layout packed the iteration into the same bits as the halo
// pair id, so `niter >= 8192` silently collided iteration tags with halo
// tags in release builds (the overflow was only a `debug_assert`) and
// ranks exchanged wrong plane data. Iterations now own their own high bit
// range, and [`check_tag_capacity`] rejects genuinely unrepresentable
// runs with a `Config` error instead of corrupting the exchange.

const TAG_COLLECTIVE_BITS: u32 = 3;
const TAG_PAIR_BITS: u32 = 29;
const TAG_ITER_SHIFT: u32 = TAG_COLLECTIVE_BITS + TAG_PAIR_BITS;

/// Tag of the single post-loop residual allreduce. Never produced by
/// [`iter_tag`] / [`halo_pair_tag`]: their iteration field is always >= 1.
const TAG_FINAL: u64 = 3;

/// Tag of one per-iteration collective.
fn iter_tag(iter: usize, collective: u64) -> u64 {
    debug_assert!(collective < (1 << TAG_COLLECTIVE_BITS));
    ((iter as u64 + 1) << TAG_ITER_SHIFT) | collective
}

/// Tag of one halo pair exchange within a dssum (both sides derive it from
/// the plane's first global id, so the pair agrees without negotiation).
fn halo_pair_tag(base: u64, gid: usize) -> u64 {
    base | ((gid as u64 + 1) << TAG_COLLECTIVE_BITS)
}

/// Reject runs whose collective tags cannot be represented: the iteration
/// field holds 31 bits (bit 63 stays clear for the broadcast marker), the
/// halo pair field [`TAG_PAIR_BITS`] bits of global id.
fn check_tag_capacity(niter: usize, ndof_global: usize) -> Result<()> {
    if niter as u64 >= 1u64 << 31 {
        return Err(Error::Config(format!(
            "niter = {niter} is unrepresentable in the collective tag space \
             (max {})",
            (1u64 << 31) - 1
        )));
    }
    if ndof_global as u64 >= 1u64 << TAG_PAIR_BITS {
        return Err(Error::Config(format!(
            "global dof count {ndof_global} is unrepresentable in the \
             halo-pair tag space (max {})",
            (1u64 << TAG_PAIR_BITS) - 1
        )));
    }
    Ok(())
}

/// How one rank sees the mesh.
struct RankSlab {
    rank: usize,
    /// Global element range [e0, e1).
    e0: usize,
    e1: usize,
    /// Rank-local gather–scatter over the slab's own elements.
    gs: GatherScatter,
    /// Sorted global ids of the plane shared with the previous / next rank,
    /// and for each, the rank-local dof indices holding copies.
    lo_plane: Vec<(usize, Vec<usize>)>,
    hi_plane: Vec<(usize, Vec<usize>)>,
    /// Rank-local fields.
    mask: Vec<f64>,
    c: Vec<f64>,
    f: Vec<f64>,
    g: Vec<f64>,
}

/// Partition `ez` layers over `ranks`: contiguous, remainder to low ranks.
fn slab_ranges(ez: usize, ranks: usize) -> Vec<(usize, usize)> {
    let base = ez / ranks;
    let rem = ez % ranks;
    let mut out = Vec::with_capacity(ranks);
    let mut z = 0;
    for r in 0..ranks {
        let h = base + usize::from(r < rem);
        out.push((z, z + h));
        z += h;
    }
    out
}

/// Build the per-rank slabs (global ids, shared planes, local fields).
fn build_slabs(mesh: &Mesh, basis: &Basis, cfg: &RunConfig) -> Result<Vec<RankSlab>> {
    let ranks = cfg.ranks;
    if mesh.ez < ranks {
        return Err(Error::Config(format!(
            "ranks ({ranks}) exceed element layers ez ({}); pick nelt with more z layers",
            mesh.ez
        )));
    }
    let n = mesh.n;
    let np = n * n * n;
    let geom = GeomFactors::affine(mesh, basis);
    let mask_full = mesh.boundary_mask();
    let c_full = mesh.inv_multiplicity();
    let mut rng = crate::rng::Rng::new(cfg.seed);
    let mut f_full = rng.normal_vec(mesh.ndof_local());
    // Make f dssum-consistent + masked globally (same as single-rank setup).
    let mut gs_full = GatherScatter::new(mesh);
    gs_full.dssum(&mut f_full);
    mask_apply(&mut f_full, &mask_full);

    let ezs = slab_ranges(mesh.ez, ranks);
    let epl = mesh.ex * mesh.ey; // elements per z layer
    let mut slabs = Vec::with_capacity(ranks);
    for (rank, &(z0, z1)) in ezs.iter().enumerate() {
        let e0 = z0 * epl;
        let e1 = z1 * epl;
        let nelt_local = e1 - e0;
        // Localize global ids: dense renumbering over this slab.
        let mut gids = Vec::with_capacity(nelt_local * np);
        for e in e0..e1 {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        gids.push(mesh.global_id(e, k, j, i));
                    }
                }
            }
        }
        let mut sorted: Vec<usize> = gids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        let local_of = |gid: usize| sorted.binary_search(&gid).unwrap();
        let local_ids: Vec<usize> = gids.iter().map(|&g| local_of(g)).collect();
        let gs = GatherScatter::from_ids(local_ids, sorted.len());

        // Shared planes: global grid z = z0*(n-1) (with previous rank) and
        // z = z1*(n-1) (with next rank).
        let plane = |pz: usize| -> Vec<(usize, Vec<usize>)> {
            let mut out: Vec<(usize, Vec<usize>)> = Vec::new();
            for (l, &gid) in gids.iter().enumerate() {
                let z = gid / (mesh.gx * mesh.gy);
                if z == pz {
                    match out.binary_search_by_key(&gid, |(g, _)| *g) {
                        Ok(pos) => out[pos].1.push(l),
                        Err(pos) => out.insert(pos, (gid, vec![l])),
                    }
                }
            }
            out
        };
        let lo_plane = if rank > 0 { plane(z0 * (n - 1)) } else { Vec::new() };
        let hi_plane = if rank + 1 < ranks { plane(z1 * (n - 1)) } else { Vec::new() };

        slabs.push(RankSlab {
            rank,
            e0,
            e1,
            gs,
            lo_plane,
            hi_plane,
            mask: mask_full[e0 * np..e1 * np].to_vec(),
            c: c_full[e0 * np..e1 * np].to_vec(),
            f: f_full[e0 * np..e1 * np].to_vec(),
            g: geom.g[e0 * 6 * np..e1 * 6 * np].to_vec(),
        });
    }
    Ok(slabs)
}

/// Distributed dssum: rank-local gather–scatter + halo exchange with the
/// slab neighbors.
fn dssum_ranked(
    slab: &mut RankSlab,
    comm: &mut Comm,
    v: &mut [f64],
    tag: u64,
) -> Result<()> {
    slab.gs.dssum(v);
    // Exchange partial sums on the shared planes. Both sides enumerate the
    // plane in ascending-gid order, so the vectors align; the pair tag is
    // derived from the plane's first global id, identical on both sides.
    if !slab.lo_plane.is_empty() {
        let pair_tag = halo_pair_tag(tag, slab.lo_plane[0].0);
        let mine: Vec<f64> = slab.lo_plane.iter().map(|(_, ls)| v[ls[0]]).collect();
        let theirs = comm.sendrecv(slab.rank - 1, pair_tag, mine)?;
        for ((_, ls), t) in slab.lo_plane.iter().zip(&theirs) {
            let total = v[ls[0]] + t;
            for &l in ls {
                v[l] = total;
            }
        }
    }
    if !slab.hi_plane.is_empty() {
        let pair_tag = halo_pair_tag(tag, slab.hi_plane[0].0);
        let mine: Vec<f64> = slab.hi_plane.iter().map(|(_, ls)| v[ls[0]]).collect();
        let theirs = comm.sendrecv(slab.rank + 1, pair_tag, mine)?;
        for ((_, ls), t) in slab.hi_plane.iter().zip(&theirs) {
            let total = v[ls[0]] + t;
            for &l in ls {
                v[l] = total;
            }
        }
    }
    Ok(())
}

/// What one rank reports back from its CG loop.
struct RankOutcome {
    /// Global residual norm (allreduced — must agree across ranks).
    rnorm: f64,
    /// Wall time inside the local operator.
    ax_seconds: f64,
    /// Iterations executed (may undershoot `niter` on exact convergence).
    iterations: usize,
}

/// SPMD CG over the slabs. Mirrors `solver::cg_solve` with allreduce in
/// place of plain sums, `dssum_ranked` in place of serial dssum, and the
/// rank-local operator built by name from the registry. Fused operators
/// take the same shortcut as the serial solver: the rank's pap
/// contribution is the operator's fused value plus a correction over the
/// dofs the distributed dssum can change (rank-local shared dofs + halo
/// planes), so the full-length `glsc3(w, c, p)` sweep is skipped.
fn rank_main(
    mut slab: RankSlab,
    mut comm: Comm,
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RankOutcome> {
    let n = cfg.n;
    let np = n * n * n;
    let nelt_local = slab.e1 - slab.e0;
    let ndof = nelt_local * np;
    let d = crate::basis::derivative_matrix(n);

    // Each rank owns its operator instance, set up on the slab's data.
    let ctx = OperatorCtx {
        n,
        nelt: nelt_local,
        chunk: cfg.chunk,
        threads: cfg.cpu_threads,
        artifacts_dir: &cfg.artifacts_dir,
        d: &d,
        g: &slab.g,
        c: &slab.c,
    };
    let mut op = registry.build(operator, &ctx)?;
    // The operator cloned (or uploaded) what it needs from the slab's
    // geometric factors; free the slab copy so the two don't coexist for
    // the whole solve (mirrors the serial pipeline dropping `geom`).
    slab.g = Vec::new();

    // Fused hot path: dssum_ranked changes `w` only on the rank-local
    // shared dofs and the halo planes, so the fused pap is patched over
    // those dofs alone — the same [`PapCorrection`] the serial solver uses.
    let fused = op.is_fused();
    let mut correction = PapCorrection::new(if fused && !cfg.no_comm {
        let mut s: Vec<u32> = slab.gs.shared_dofs().to_vec();
        for (_, ls) in slab.lo_plane.iter().chain(slab.hi_plane.iter()) {
            for &l in ls {
                s.push(l as u32);
            }
        }
        s.sort_unstable();
        s.dedup();
        s
    } else {
        Vec::new()
    });

    let mut x = vec![0.0; ndof];
    let mut r = slab.f.clone();
    mask_apply(&mut r, &slab.mask);
    let mut p = vec![0.0; ndof];
    let mut w = vec![0.0; ndof];
    let mut rtz1 = 1.0f64;
    let mut rtz_first: Option<f64> = None;
    let mut ax_seconds = 0.0;
    let mut iterations = cfg.niter;

    for iter in 0..cfg.niter {
        let rtz2 = rtz1;
        rtz1 = comm.allreduce_sum(glsc3(&r, &slab.c, &r), iter_tag(iter, 0))?;
        if !rtz1.is_finite() {
            return Err(Error::Numerical(format!(
                "ranked CG breakdown at iter {iter} on rank {}: rtz1 = {rtz1}",
                slab.rank
            )));
        }
        let first = *rtz_first.get_or_insert(rtz1.max(f64::MIN_POSITIVE));
        if rtz1 <= 1e-30 * first {
            // Exact convergence well inside the iteration budget (mirrors
            // `cg_solve`): stop instead of dividing by ~0 and reporting a
            // spurious pap breakdown. rtz1 is an allreduced value —
            // bit-identical on every rank — so all ranks exit together.
            iterations = iter;
            break;
        }
        let beta = if iter == 0 { 0.0 } else { rtz1 / rtz2 };
        add2s1(&mut p, &r, beta);

        let t0 = Instant::now();
        op.apply(&p, &mut w)?;
        ax_seconds += t0.elapsed().as_secs_f64();
        let pap_fused = if fused {
            let local = op.last_pap().ok_or_else(|| {
                Error::Numerical("fused operator did not produce a pap value".into())
            })?;
            correction.snapshot(&w);
            Some(local)
        } else {
            None
        };
        if !cfg.no_comm {
            dssum_ranked(&mut slab, &mut comm, &mut w, iter_tag(iter, 1))?;
        }
        mask_apply(&mut w, &slab.mask);

        let pap_local = match pap_fused {
            Some(local) => correction.patch(local, &w, &slab.c, &p),
            None => glsc3(&w, &slab.c, &p),
        };
        let pap = comm.allreduce_sum(pap_local, iter_tag(iter, 2))?;
        if pap <= 0.0 || !pap.is_finite() {
            return Err(Error::Numerical(format!(
                "ranked CG breakdown at iter {iter} on rank {}: pap = {pap}",
                slab.rank
            )));
        }
        let alpha = rtz1 / pap;
        add2s2(&mut x, &p, alpha);
        add2s2(&mut r, &w, -alpha);
    }
    let rr = comm.allreduce_sum(glsc3(&r, &slab.c, &r), TAG_FINAL)?;
    Ok(RankOutcome { rnorm: rr.max(0.0).sqrt(), ax_seconds, iterations })
}

/// Run Nekbone across `cfg.ranks` simulated ranks with the default
/// operator ([`DEFAULT_RANK_OPERATOR`]).
pub fn run_ranked(cfg: &RunConfig) -> Result<RunReport> {
    run_ranked_with(cfg, DEFAULT_RANK_OPERATOR)
}

/// Run Nekbone across `cfg.ranks` simulated ranks, with the per-rank local
/// operator built by registry name from the built-in registry; returns the
/// report (the global residual, wall time of the slowest rank path).
pub fn run_ranked_with(cfg: &RunConfig, operator: &str) -> Result<RunReport> {
    run_ranked_in(cfg, operator, &OperatorRegistry::with_builtins())
}

/// [`run_ranked_with`] against a caller-supplied registry, so
/// runtime-registered operators run ranked too (the registry is shared by
/// reference across the rank threads).
pub fn run_ranked_in(
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RunReport> {
    cfg.validate()?;
    // Fail fast on unknown operators (and get the canonical label) before
    // spawning any rank thread.
    let label = registry.resolve(operator)?.name.clone();
    let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
    check_tag_capacity(cfg.niter, mesh.ndof_global())?;
    let basis = Basis::new(cfg.n);
    let slabs = build_slabs(&mesh, &basis, cfg)?;
    let comms = Comm::mesh(cfg.ranks);

    let sw = Instant::now();
    let mut results = Vec::with_capacity(cfg.ranks);
    std::thread::scope(|scope| {
        let handles: Vec<_> = slabs
            .into_iter()
            .zip(comms)
            .map(|(slab, comm)| scope.spawn(|| rank_main(slab, comm, cfg, &label, registry)))
            .collect();
        for h in handles {
            results.push(h.join().map_err(|_| Error::Rank("rank thread panicked".into())));
        }
    });
    let seconds = sw.elapsed().as_secs_f64();

    let mut outcomes = Vec::with_capacity(cfg.ranks);
    for res in results {
        outcomes.push(res??);
    }
    // Every rank's residual comes out of the same allreduce, so they must
    // agree; verify instead of assuming, so a future halo/tag bug fails
    // loudly here rather than silently reporting one rank's value.
    let first = &outcomes[0];
    let (final_residual, iterations) = (first.rnorm, first.iterations);
    let mut ax_seconds: f64 = 0.0;
    for (rank, o) in outcomes.iter().enumerate() {
        let denom = final_residual.abs().max(1e-30);
        if (o.rnorm - final_residual).abs() / denom > 1e-12 {
            return Err(Error::Rank(format!(
                "rank {rank} disagrees on the final residual: {} vs {} \
                 (halo exchange or collective-tag bug?)",
                o.rnorm, final_residual
            )));
        }
        if o.iterations != iterations {
            return Err(Error::Rank(format!(
                "rank {rank} executed {} iterations, rank 0 executed {iterations}",
                o.iterations
            )));
        }
        ax_seconds = ax_seconds.max(o.ax_seconds);
    }
    let cm = CostModel::new(cfg.n, cfg.nelt);
    Ok(RunReport {
        backend: format!("ranked-{}-r{}", label, cfg.ranks),
        nelt: cfg.nelt,
        n: cfg.n,
        iterations,
        final_residual,
        seconds,
        ax_seconds,
        flops: cm.flops_per_iter() * iterations as u64,
        rnorms: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Nekbone;

    #[test]
    fn slab_ranges_cover() {
        for (ez, ranks) in [(8, 3), (4, 4), (7, 2), (16, 5)] {
            let rs = slab_ranges(ez, ranks);
            assert_eq!(rs.len(), ranks);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs.last().unwrap().1, ez);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn tag_layout_has_no_collisions_at_old_boundary() {
        // niter >= 8192 used to fold the iteration bits into the halo-pair
        // bits; every tag kind must now be distinct across iterations
        // around (and far past) that boundary.
        let mut seen = std::collections::BTreeSet::new();
        let iters = [0usize, 1, 8190, 8191, 8192, 8193, 1_000_000, (1 << 31) - 2];
        let gids = [0usize, 1, 4095, (1 << TAG_PAIR_BITS) - 2];
        for &iter in &iters {
            for coll in 0..3u64 {
                assert!(seen.insert(iter_tag(iter, coll)), "collective tag collision");
            }
            for &gid in &gids {
                let t = halo_pair_tag(iter_tag(iter, 1), gid);
                assert!(seen.insert(t), "halo tag collision at iter {iter} gid {gid}");
            }
        }
        // None of them may collide with the final-residual tag or set the
        // allreduce broadcast bit.
        assert!(!seen.contains(&TAG_FINAL));
        for &t in &seen {
            assert_eq!(t & (1 << 63), 0, "tag {t:#x} sets the broadcast bit");
        }
    }

    #[test]
    fn tag_capacity_limits_are_config_errors() {
        check_tag_capacity(8192, 1000).unwrap();
        check_tag_capacity((1 << 31) - 1, 1000).unwrap();
        assert!(matches!(check_tag_capacity(1 << 31, 1000), Err(Error::Config(_))));
        assert!(matches!(
            check_tag_capacity(100, 1 << TAG_PAIR_BITS),
            Err(Error::Config(_))
        ));
        // And the runtime rejects such a run up front.
        let cfg = RunConfig { nelt: 8, n: 3, niter: 1 << 31, ranks: 2, ..Default::default() };
        let err = run_ranked(&cfg).unwrap_err().to_string();
        assert!(err.contains("tag space"), "{err}");
    }

    #[test]
    fn halo_exchange_clean_at_high_iterations() {
        // Drive the distributed dssum + the per-iteration collectives
        // directly at iterations around the old 8192 boundary: partial
        // sums must still route to the right collective.
        let cfg = RunConfig { nelt: 8, n: 3, ranks: 2, ..Default::default() };
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
        let basis = Basis::new(cfg.n);
        let slabs = build_slabs(&mesh, &basis, &cfg).unwrap();
        let comms = Comm::mesh(cfg.ranks);
        // Serial reference: dssum of all-ones is the global multiplicity.
        let mut gs_full = GatherScatter::new(&mesh);
        let mut want_full = vec![1.0; mesh.ndof_local()];
        gs_full.dssum(&mut want_full);
        let np = cfg.n * cfg.n * cfg.n;
        std::thread::scope(|scope| {
            for (mut slab, mut comm) in slabs.into_iter().zip(comms) {
                let want = want_full[slab.e0 * np..slab.e1 * np].to_vec();
                scope.spawn(move || {
                    for iter in [8190usize, 8191, 8192, 8193] {
                        let s = comm.allreduce_sum(1.0, iter_tag(iter, 0)).unwrap();
                        assert_eq!(s, 2.0);
                        let mut v = vec![1.0; want.len()];
                        dssum_ranked(&mut slab, &mut comm, &mut v, iter_tag(iter, 1))
                            .unwrap();
                        assert_eq!(v, want, "iter {iter}");
                        let s = comm
                            .allreduce_sum(iter as f64, iter_tag(iter, 2))
                            .unwrap();
                        assert_eq!(s, 2.0 * iter as f64);
                    }
                });
            }
        });
    }

    #[test]
    fn ranked_niter_8192_matches_serial() {
        // End-to-end run at the old tag-collision boundary (a release
        // build with niter >= 8192 used to exchange wrong halo data). On
        // this 864-dof system finite-precision CG typically stalls above
        // the exact-convergence floor and runs the full 8192 iterations —
        // straight through the old collision point — but whether or not
        // the floor fires, ranked must match serial on the
        // initial-residual scale (~10); corrupted halos would miss by many
        // orders of magnitude. (Deterministic coverage of the boundary
        // itself, independent of CG's convergence behavior, is in
        // `halo_exchange_clean_at_high_iterations`.)
        let base = RunConfig { nelt: 8, n: 4, niter: 8192, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        let got = run_ranked(&RunConfig { ranks: 2, ..base }).unwrap();
        assert!(want.final_residual < 1e-10, "serial residual {}", want.final_residual);
        assert!(got.final_residual < 1e-10, "ranked residual {}", got.final_residual);
        assert!(
            (got.final_residual - want.final_residual).abs() < 1e-10,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_exact_convergence_early_exits_instead_of_breakdown() {
        // A system that converges exactly mid-budget (here: a zero RHS,
        // converged at iteration 0 — the degenerate endpoint serial
        // cg_solve already handles) used to abort the ranked path with a
        // spurious "pap breakdown". The ported rtz floor must exit all
        // ranks together instead.
        let cfg = RunConfig { nelt: 8, n: 3, niter: 50, ranks: 2, ..Default::default() };
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
        let basis = Basis::new(cfg.n);
        let mut slabs = build_slabs(&mesh, &basis, &cfg).unwrap();
        for slab in &mut slabs {
            slab.f.iter_mut().for_each(|v| *v = 0.0);
        }
        let comms = Comm::mesh(cfg.ranks);
        let registry = OperatorRegistry::with_builtins();
        std::thread::scope(|scope| {
            let handles: Vec<_> = slabs
                .into_iter()
                .zip(comms)
                .map(|(slab, comm)| {
                    scope.spawn(|| rank_main(slab, comm, &cfg, "cpu-layered", &registry))
                })
                .collect();
            for h in handles {
                let out = h
                    .join()
                    .unwrap()
                    .expect("exact convergence must early-exit, not break down");
                assert_eq!(out.iterations, 0, "all ranks exit together at iteration 0");
                assert_eq!(out.rnorm, 0.0);
            }
        });
        // Serial cg_solve agrees on the same degenerate system.
        let mut app = Nekbone::builder(RunConfig { ranks: 1, ..cfg.clone() })
            .operator("cpu-layered")
            .build()
            .unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![0.0; ndof]).unwrap();
        let rep = app.run().unwrap();
        assert_eq!(rep.iterations, 0);
        assert_eq!(rep.final_residual, 0.0);
    }

    #[test]
    fn ranked_large_budget_no_spurious_breakdown() {
        // Generous budgets on small systems must never error out, and the
        // ranked residual must track serial on the initial-residual scale.
        let base = RunConfig { nelt: 8, n: 4, niter: 400, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let got = run_ranked(&RunConfig { ranks, ..base.clone() }).unwrap();
            assert!(got.final_residual < 1e-10, "ranks={ranks}: {}", got.final_residual);
            assert!(
                (got.final_residual - want.final_residual).abs() < 1e-10,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_fused_operators_match_default() {
        // The fused hot path through the rank runtime (operator-side pap +
        // shared/halo correction) must track the unfused operator.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let want = run_ranked(&base).unwrap();
        for name in ["cpu-layered-fused", "cpu-threaded-fused"] {
            let got = run_ranked_with(&base, name).unwrap();
            assert!(got.backend.contains(name), "{}", got.backend);
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-9,
                "{name}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_matches_serial_residual() {
        // The distributed CG must track the serial one to round-off.
        let base = RunConfig { nelt: 8, n: 4, niter: 25, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let cfg = RunConfig { ranks, ..base.clone() };
            let got = run_ranked(&cfg).unwrap();
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-6,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_more_ranks_same_answer() {
        let base = RunConfig { nelt: 64, n: 3, niter: 15, ..Default::default() };
        let r1 = run_ranked(&RunConfig { ranks: 1, ..base.clone() }).unwrap();
        let r4 = run_ranked(&RunConfig { ranks: 4, ..base.clone() }).unwrap();
        let denom = r1.final_residual.abs().max(1e-30);
        assert!(
            (r1.final_residual - r4.final_residual).abs() / denom < 1e-6,
            "{} vs {}",
            r1.final_residual,
            r4.final_residual
        );
    }

    #[test]
    fn ranked_with_other_cpu_operator_matches() {
        // Any registered (artifact-free) operator slots into the rank loop.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ..Default::default() };
        let layered = run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-layered")
            .unwrap();
        let naive =
            run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-naive").unwrap();
        assert!(naive.backend.contains("cpu-naive"), "{}", naive.backend);
        let denom = layered.final_residual.abs().max(1e-30);
        assert!(
            (layered.final_residual - naive.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            layered.final_residual,
            naive.final_residual
        );
    }

    #[test]
    fn ranked_runs_custom_registry_operator() {
        use crate::operators::{ax_layered, AxOperator, OperatorCtx};

        /// Test-only operator delegating to the layered kernel.
        struct Wrapped {
            st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
        }
        impl AxOperator for Wrapped {
            fn label(&self) -> String {
                "test-ranked-custom".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                let (n, nelt, d, g) = self.st.as_ref().unwrap();
                ax_layered(*n, *nelt, u, d, g, w);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut registry = OperatorRegistry::with_builtins();
        registry
            .register("test-ranked-custom", false, || Box::new(Wrapped { st: None }))
            .unwrap();
        let cfg = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let got = run_ranked_in(&cfg, "test-ranked-custom", &registry).unwrap();
        assert!(got.backend.contains("test-ranked-custom"), "{}", got.backend);
        let want = run_ranked(&cfg).unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_unknown_operator_fails_fast() {
        let cfg = RunConfig { nelt: 8, n: 4, niter: 5, ranks: 2, ..Default::default() };
        let err = run_ranked_with(&cfg, "no-such-op").unwrap_err().to_string();
        assert!(err.contains("no-such-op"), "{err}");
    }

    #[test]
    fn too_many_ranks_rejected() {
        let cfg = RunConfig { nelt: 8, n: 3, ranks: 5, ..Default::default() };
        assert!(run_ranked(&cfg).is_err());
    }
}
