//! Simulated multi-rank runtime: the MPI layer of Nekbone as threads +
//! channels (experiment E8, strong scaling).
//!
//! The element grid is partitioned by a [`Decomposition`] — **slab** (z
//! layers, the original layout), **pencil** (z×y columns), or **box**
//! (z×y×x bricks), selected with `--decomp`. Neighboring bricks share
//! faces, edges, or single corner points of global ids, so the
//! distributed `dssum` is a rank-local gather–scatter followed by one
//! pairwise exchange per neighbor link (up to 26 for an interior box
//! brick) — the communication structure of the real code, with
//! `std::sync::mpsc` standing in for MPI.
//!
//! There is **no CG code here**. Each rank wraps its channels in a
//! [`ThreadComm`] (the [`Communicator`](crate::solver::Communicator)
//! adapter) and its brick assembly in a `BrickExchange` (the distributed
//! [`DomainExchange`](crate::solver::DomainExchange)), then calls the same
//! [`cg_solve`] the serial pipeline uses — residual updates, the
//! convergence floor, fused-pap accounting, and sweep counters all live in
//! exactly one place (`solver/cg.rs`).
//!
//! ## Bitwise agreement with the serial solve
//!
//! Ranked reports are not merely rank-identical — they are **bitwise
//! identical to the serial solve**, for every decomposition shape. Three
//! mechanisms pin this down:
//!
//! 1. **Reductions** go through the workspace's element-blocked reduce
//!    plan: one partial per element, folded in ascending *global element
//!    id* order by `allreduce_ordered_sum` — the same fold expression the
//!    serial pipeline evaluates, independent of which rank owns which
//!    element.
//! 2. **Local assembly**: each brick enumerates its elements in ascending
//!    global id, so the rank-local gather–scatter folds purely-local
//!    shared groups in exactly the serial group order.
//! 3. **Cross-rank assembly**: `BrickExchange` snapshots every boundary
//!    point's per-element raw contributions *before* local assembly,
//!    exchanges them per neighbor link, and refolds each boundary point
//!    from all contributions sorted by owning element id — again the
//!    serial fold, reproduced rather than approximated.
//!
//! [`run_ranked_in`] asserts the cross-rank half of this exactly (bitwise
//! report equality across ranks); `tests/rank.rs` holds the
//! ranked-vs-serial half across the shape × ranks × degree grid.
//!
//! The per-rank compute dispatches through a `Box<dyn AxOperator>` built by
//! name from the [`OperatorRegistry`], so any registered operator (default:
//! the paper's layered CPU schedule, the CPU/MPI baseline) runs inside the
//! rank loop without this module knowing about it.

mod comm;
mod decomp;

pub use comm::{Comm, Packet, ThreadComm};
pub use decomp::{Brick, DecompShape, Decomposition};

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::RunReport;
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::{OperatorCtx, OperatorRegistry};
use crate::solver::{
    cg_solve, mask_apply, CgOptions, CgReport, CgWorkspace, DomainExchange, NoExchange,
    TimedAx,
};

/// The operator each rank runs when the caller does not pick one.
pub const DEFAULT_RANK_OPERATOR: &str = "cpu-layered";

/// Reject runs whose boundary-exchange tags cannot be represented (see the
/// tag-space layout in [`comm`]): one exchange round per CG iteration, and
/// link ids drawn from the global point numbering.
fn check_tag_capacity(niter: usize, ndof_global: usize) -> Result<()> {
    if niter as u64 >= 1u64 << comm::TAG_ROUND_BITS {
        return Err(Error::Config(format!(
            "niter = {niter} is unrepresentable in the halo-exchange tag space \
             (max {})",
            (1u64 << comm::TAG_ROUND_BITS) - 1
        )));
    }
    if ndof_global as u64 >= 1u64 << comm::TAG_PAIR_BITS {
        return Err(Error::Config(format!(
            "global dof count {ndof_global} is unrepresentable in the \
             halo-pair tag space (max {})",
            (1u64 << comm::TAG_PAIR_BITS) - 1
        )));
    }
    Ok(())
}

/// How one rank sees the mesh: its brick's elements, the rank-local
/// assembly, the neighbor links, and the local field slices.
struct RankDomain {
    /// Global ids of this rank's elements, ascending (the brick
    /// enumerates them k-major, which is ascending by construction).
    elems: Vec<usize>,
    /// Rank-local gather–scatter over the brick's own elements.
    gs: GatherScatter,
    /// Global point id of every local dof (element-major).
    point_gids: Vec<usize>,
    /// Neighbor links: `(peer rank, ascending shared global point ids)`.
    links: Vec<(usize, Vec<usize>)>,
    /// Rank-local fields.
    mask: Vec<f64>,
    c: Vec<f64>,
    f: Vec<f64>,
    g: Vec<f64>,
}

/// Build the per-rank domains (global ids, neighbor links, local fields)
/// for a decomposition.
fn build_domains(
    mesh: &Mesh,
    basis: &Basis,
    cfg: &RunConfig,
    decomp: &Decomposition,
) -> Result<Vec<RankDomain>> {
    let n = mesh.n;
    let np = n * n * n;
    let geom = GeomFactors::affine(mesh, basis);
    let mask_full = mesh.boundary_mask();
    let c_full = mesh.inv_multiplicity();
    let mut rng = crate::rng::Rng::new(cfg.seed);
    let mut f_full = rng.normal_vec(mesh.ndof_local());
    // Make f dssum-consistent + masked globally (same as single-rank setup).
    let mut gs_full = GatherScatter::new(mesh);
    gs_full.dssum(&mut f_full);
    mask_apply(&mut f_full, &mask_full);

    let mut domains = Vec::with_capacity(decomp.ranks());
    for (rank, brick) in decomp.bricks().iter().enumerate() {
        let elems = brick.elems(mesh);
        // Localize global point ids: dense renumbering over this brick.
        let mut point_gids = Vec::with_capacity(elems.len() * np);
        for &e in &elems {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        point_gids.push(mesh.global_id(e, k, j, i));
                    }
                }
            }
        }
        let mut sorted: Vec<usize> = point_gids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        let local_ids: Vec<usize> =
            point_gids.iter().map(|&g| sorted.binary_search(&g).unwrap()).collect();
        let gs = GatherScatter::from_ids(local_ids, sorted.len());

        // Gather the full-mesh fields element by element (bricks are not
        // contiguous in the full arrays except for slabs).
        let gather = |src: &[f64], width: usize| -> Vec<f64> {
            let mut out = Vec::with_capacity(elems.len() * width);
            for &e in &elems {
                out.extend_from_slice(&src[e * width..(e + 1) * width]);
            }
            out
        };
        domains.push(RankDomain {
            gs,
            point_gids,
            links: decomp.neighbors(rank).to_vec(),
            mask: gather(&mask_full, np),
            c: gather(&c_full, np),
            f: gather(&f_full, np),
            g: gather(&geom.g, 6 * np),
            elems,
        });
    }
    Ok(domains)
}

/// The distributed [`DomainExchange`]: rank-local gather–scatter + one
/// pairwise message per neighbor link, for any decomposition shape.
///
/// Cross-rank boundary points are not patched with partial sums — they
/// are **refolded from per-element raw contributions** so the assembled
/// value is bitwise the serial gather–scatter's: each copy's pre-assembly
/// value is snapshotted, exchanged (tagged with the link's first shared
/// gid — identical on both sides without negotiation; two links *from
/// one rank to different peers* may share a tag, which is harmless
/// because receives are keyed on `(from, tag)`), and every boundary
/// point is then summed from all its copies in ascending owning-element
/// order — the exact order the serial dssum folds them in, since the
/// serial mesh stores dofs element-major. Any two ranks co-sharing a
/// point are themselves neighbors (both bricks contain it), so every
/// rank holds every copy when it refolds.
pub(crate) struct BrickExchange {
    gs: GatherScatter,
    comm: Rc<RefCell<Comm>>,
    /// Exchange rounds completed (tags are keyed on this; the solver calls
    /// one exchange per iteration on every rank, so the counters agree).
    round: u64,
    /// Cross-rank boundary points, ascending by global point id.
    points: Vec<CrossPoint>,
    links: Vec<Link>,
    /// Union of the rank-local shared dofs and the boundary-point dofs —
    /// everything `exchange` may change, i.e. the support of the fused-pap
    /// correction.
    shared: Vec<u32>,
    /// Merge scratch, one `(element gid, raw)` list per boundary point —
    /// reused every round so the solve loop does not allocate.
    merge: Vec<Vec<(u64, f64)>>,
}

/// One cross-rank boundary point as this rank sees it.
struct CrossPoint {
    /// Global point id.
    gid: usize,
    /// Local copies as `(owning global element id, local dof)`, ascending
    /// by element id (local dofs are scanned in ascending order and local
    /// elements ascend by global id).
    copies: Vec<(usize, u32)>,
    /// Pre-assembly copy values, snapshotted at the top of each exchange.
    raw: Vec<f64>,
}

/// One neighbor link.
struct Link {
    peer: usize,
    /// First shared gid — the tag key both endpoints derive.
    first_gid: usize,
    /// Indices into `BrickExchange::points` shared with this peer.
    points: Vec<u32>,
}

impl BrickExchange {
    fn new(
        gs: GatherScatter,
        point_gids: &[usize],
        elems: &[usize],
        neighbor_links: Vec<(usize, Vec<usize>)>,
        np: usize,
        comm: Rc<RefCell<Comm>>,
    ) -> Self {
        // The cross-rank point set: union of every link's shared gids.
        let mut cross: Vec<usize> =
            neighbor_links.iter().flat_map(|(_, gids)| gids.iter().copied()).collect();
        cross.sort_unstable();
        cross.dedup();
        let mut points: Vec<CrossPoint> = cross
            .iter()
            .map(|&gid| CrossPoint { gid, copies: Vec::new(), raw: Vec::new() })
            .collect();
        for (l, &gid) in point_gids.iter().enumerate() {
            if let Ok(ci) = cross.binary_search(&gid) {
                points[ci].copies.push((elems[l / np], l as u32));
            }
        }
        for cp in &mut points {
            cp.raw = vec![0.0; cp.copies.len()];
        }
        let links: Vec<Link> = neighbor_links
            .into_iter()
            .map(|(peer, gids)| Link {
                peer,
                first_gid: gids[0],
                points: gids
                    .iter()
                    .map(|g| cross.binary_search(g).unwrap() as u32)
                    .collect(),
            })
            .collect();
        let mut shared: Vec<u32> = gs.shared_dofs().to_vec();
        for cp in &points {
            for &(_, l) in &cp.copies {
                shared.push(l);
            }
        }
        shared.sort_unstable();
        shared.dedup();
        let merge = points.iter().map(|_| Vec::new()).collect();
        BrickExchange { gs, comm, round: 0, points, links, shared, merge }
    }
}

impl DomainExchange for BrickExchange {
    fn exchange(&mut self, v: &mut [f64]) -> Result<()> {
        let round = self.round;
        self.round += 1;
        // Snapshot each boundary point's raw per-element contributions
        // *before* local assembly: the global refold must combine raw
        // element copies, not partially assembled local sums, to land in
        // the serial fold order.
        for cp in &mut self.points {
            for (slot, &(_, l)) in cp.raw.iter_mut().zip(&cp.copies) {
                *slot = v[l as usize];
            }
        }
        self.gs.dssum(v);
        if self.links.is_empty() {
            return Ok(());
        }
        let mut comm = self.comm.borrow_mut();
        // Send every link's message before receiving any (the channels
        // are unbounded, so sends never block): flat (point gid, element
        // gid, raw) triples for every local copy of every shared point.
        for link in &self.links {
            let tag = comm::exchange_tag(round, link.first_gid)?;
            let mut msg = Vec::new();
            for &ci in &link.points {
                let cp = &self.points[ci as usize];
                for (&(eg, _), &raw) in cp.copies.iter().zip(&cp.raw) {
                    msg.push(cp.gid as f64);
                    msg.push(eg as f64);
                    msg.push(raw);
                }
            }
            comm.send(link.peer, tag, msg)?;
        }
        // Merge: seed every point with its own copies, add each
        // neighbor's, then refold in ascending owning-element order. The
        // element ids are globally unique per point (an element holds at
        // most one copy of a point, and ranks own disjoint elements), so
        // the sort fully determines the fold — the serial expression.
        for (cp, buf) in self.points.iter().zip(self.merge.iter_mut()) {
            buf.clear();
            for (&(eg, _), &raw) in cp.copies.iter().zip(&cp.raw) {
                buf.push((eg as u64, raw));
            }
        }
        for link in &self.links {
            let tag = comm::exchange_tag(round, link.first_gid)?;
            let data = comm.recv(link.peer, tag)?;
            for ch in data.chunks_exact(3) {
                let gid = ch[0] as usize;
                let ci = self
                    .points
                    .binary_search_by_key(&gid, |cp| cp.gid)
                    .map_err(|_| {
                        Error::Rank(format!(
                            "rank {}: received unknown shared point {gid} from rank {}",
                            comm.rank, link.peer
                        ))
                    })?;
                self.merge[ci].push((ch[1] as u64, ch[2]));
            }
        }
        for (cp, buf) in self.points.iter().zip(self.merge.iter_mut()) {
            buf.sort_unstable_by_key(|&(eg, _)| eg);
            let total = buf.iter().fold(0.0, |acc, &(_, raw)| acc + raw);
            for &(_, l) in &cp.copies {
                v[l as usize] = total;
            }
        }
        Ok(())
    }

    fn shared_dofs(&self) -> &[u32] {
        &self.shared
    }
}

/// What one rank reports back: the shared solver's report (bitwise
/// identical across ranks — every scalar in it is allreduced) plus this
/// rank's wall time inside the local operator.
struct RankOutcome {
    report: CgReport,
    ax_seconds: f64,
}

/// One rank's solve: build the operator from the registry, wrap the
/// channels in a [`ThreadComm`] and the brick assembly in a
/// [`BrickExchange`], and hand everything to the shared [`cg_solve`].
fn rank_main(
    domain: RankDomain,
    comm: Comm,
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RankOutcome> {
    let n = cfg.n;
    let np = n * n * n;
    let nelt_local = domain.elems.len();
    let ndof = nelt_local * np;
    let d = crate::basis::derivative_matrix(n);

    // Assembly fold plan for `cpu-asm*`: only when this rank's brick has
    // no neighbor links — the exchange is then exactly the local dssum the
    // plan folds, so in-operator assembly stays bitwise with the
    // standalone pass. With neighbors the operators degrade to their
    // plain layered sweep and [`BrickExchange`] keeps doing the assembly.
    let plan = if cfg.no_comm || !domain.links.is_empty() {
        None
    } else {
        Some(domain.gs.assembly_plan(np, (!cfg.no_mask).then_some(domain.mask.as_slice()))?)
    };
    // Each rank owns its operator instance, set up on the brick's data.
    let ctx = OperatorCtx {
        n,
        nelt: nelt_local,
        chunk: cfg.chunk,
        threads: cfg.cpu_threads,
        artifacts_dir: &cfg.artifacts_dir,
        d: &d,
        g: &domain.g,
        c: &domain.c,
        assemble: plan.as_ref(),
    };
    let mut op = registry.build(operator, &ctx)?;
    // The operator cloned (or uploaded) what it needs from the brick's
    // geometric factors; destructuring drops the domain copy so the two
    // don't coexist for the whole solve (mirrors the serial pipeline
    // dropping `geom`).
    let RankDomain { gs, point_gids, links, elems, mask, c, f, .. } = domain;

    // The communicator and the boundary exchange share the rank's
    // channels; their tag namespaces are disjoint (see `comm`).
    let comm = Rc::new(RefCell::new(comm));
    let mut thread_comm = ThreadComm::new(Rc::clone(&comm));
    let mut brick = BrickExchange::new(gs, &point_gids, &elems, links, np, comm);
    let mut no_exchange = NoExchange;
    let exchange: &mut dyn DomainExchange =
        if cfg.no_comm { &mut no_exchange } else { &mut brick };

    let opts = CgOptions {
        niter: cfg.niter,
        rtol: cfg.rtol,
        record_residuals: cfg.record_residuals,
    };
    let mask_opt = (!cfg.no_mask).then_some(mask.as_slice());
    let mut ax = TimedAx::new(op.as_mut());
    let mut x = vec![0.0; ndof];
    let mut ws = CgWorkspace::new(ndof);
    // Element-blocked reductions, folded in global element order: the
    // ranked dot products evaluate the serial fold expression exactly.
    ws.set_reduce_plan(np, elems.iter().map(|&e| e as u64).collect())?;
    // Cache-blocked iteration pipeline, same knob as the serial path.
    // `resolved_block_dofs` validated against the *global* ndof; the
    // workspace clamps the segment to this rank's local share, and the
    // blocked walk stays bitwise identical to serial either way.
    if let Some(block_dofs) = cfg.resolved_block_dofs()? {
        ws.set_iteration_plan(block_dofs)?;
    }
    let report = cg_solve(
        &mut ax,
        exchange,
        &mut thread_comm,
        mask_opt,
        &c,
        &f,
        &mut x,
        &opts,
        &mut ws,
    )?;
    Ok(RankOutcome { report, ax_seconds: ax.seconds })
}

/// Run Nekbone across `cfg.ranks` simulated ranks with the default
/// operator ([`DEFAULT_RANK_OPERATOR`]).
pub fn run_ranked(cfg: &RunConfig) -> Result<RunReport> {
    run_ranked_with(cfg, DEFAULT_RANK_OPERATOR)
}

/// Run Nekbone across `cfg.ranks` simulated ranks, with the per-rank local
/// operator built by registry name from the built-in registry; returns the
/// report (the global residual, wall time of the slowest rank path).
pub fn run_ranked_with(cfg: &RunConfig, operator: &str) -> Result<RunReport> {
    run_ranked_in(cfg, operator, crate::operators::registry())
}

/// [`run_ranked_with`] against a caller-supplied registry, so
/// runtime-registered operators run ranked too (the registry is shared by
/// reference across the rank threads).
pub fn run_ranked_in(
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RunReport> {
    cfg.validate()?;
    if cfg.precond != "none" {
        // The preconditioners are assembled against the serial pipeline's
        // whole-mesh gather-scatter; the ranked path would need per-slab
        // assembly + halo-consistent diagonals. Refuse rather than
        // silently solving unpreconditioned.
        return Err(Error::Config(format!(
            "--precond {} is not supported on the ranked path (use ranks = 1)",
            cfg.precond
        )));
    }
    // Fail fast on unknown operators (and get the canonical label) before
    // spawning any rank thread.
    let label = registry.resolve(operator)?.name.clone();
    let shape = DecompShape::parse(&cfg.decomp)?;
    let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
    check_tag_capacity(cfg.niter, mesh.ndof_global())?;
    let decomp = Decomposition::new(shape, cfg.ranks, &mesh)?;
    let basis = Basis::new(cfg.n);
    let domains = build_domains(&mesh, &basis, cfg, &decomp)?;
    let comms = Comm::mesh(cfg.ranks);

    let sw = Instant::now();
    let mut results = Vec::with_capacity(cfg.ranks);
    std::thread::scope(|scope| {
        let handles: Vec<_> = domains
            .into_iter()
            .zip(comms)
            .map(|(dom, comm)| scope.spawn(|| rank_main(dom, comm, cfg, &label, registry)))
            .collect();
        for h in handles {
            results.push(h.join().map_err(|_| Error::Rank("rank thread panicked".into())));
        }
    });
    let seconds = sw.elapsed().as_secs_f64();

    let mut outcomes = Vec::with_capacity(cfg.ranks);
    for res in results {
        outcomes.push(res??);
    }
    // Every scalar in a CgReport is an order-deterministic allreduce, so
    // the per-rank reports must be **bitwise identical** — verify exactly
    // (not to a tolerance), so a future halo/tag bug fails loudly here
    // rather than silently reporting one rank's value.
    let first = outcomes[0].report.clone();
    let mut ax_seconds: f64 = 0.0;
    for (rank, o) in outcomes.iter().enumerate() {
        let r = &o.report;
        let identical = r.iterations == first.iterations
            && r.final_rnorm.to_bits() == first.final_rnorm.to_bits()
            && r.rtz1.to_bits() == first.rtz1.to_bits()
            && r.glsc3_sweeps == first.glsc3_sweeps
            && r.rnorms.len() == first.rnorms.len()
            && r.rnorms.iter().zip(&first.rnorms).all(|(a, b)| a.to_bits() == b.to_bits());
        if !identical {
            return Err(Error::Rank(format!(
                "rank {rank} CG report diverged from rank 0: \
                 {} iters |r| = {} vs {} iters |r| = {} \
                 (all scalars are allreduced; reports must be bitwise \
                 identical — halo exchange or collective-ordering bug?)",
                r.iterations, r.final_rnorm, first.iterations, first.final_rnorm
            )));
        }
        ax_seconds = ax_seconds.max(o.ax_seconds);
    }
    let cm = CostModel::new(cfg.n, cfg.nelt);
    // Fusedness is a static property of the operator type: a blank
    // (un-setup) instance answers it without building a rank's state.
    let fused = registry.create(&label).map(|op| op.is_fused()).unwrap_or(false);
    Ok(RunReport {
        backend: format!("ranked-{}-r{}-{}", label, cfg.ranks, shape.as_str()),
        nelt: cfg.nelt,
        n: cfg.n,
        iterations: first.iterations,
        final_residual: first.final_rnorm,
        seconds,
        ax_seconds,
        flops: cm.flops_per_iter() * first.iterations as u64,
        fused,
        rnorms: first.rnorms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Nekbone;

    #[test]
    fn tag_capacity_limits_are_config_errors() {
        check_tag_capacity(100, 1000).unwrap();
        check_tag_capacity((1u64 << 32) as usize - 1, 1000).unwrap();
        assert!(matches!(
            check_tag_capacity(1usize << 32, 1000),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            check_tag_capacity(100, 1usize << 30),
            Err(Error::Config(_))
        ));
        // And the runtime rejects such a run up front.
        let cfg =
            RunConfig { nelt: 8, n: 3, niter: 1usize << 32, ranks: 2, ..Default::default() };
        let err = run_ranked(&cfg).unwrap_err().to_string();
        assert!(err.contains("tag space"), "{err}");
    }

    #[test]
    fn brick_exchange_clean_across_rounds() {
        // Drive the distributed exchange directly for several rounds, for
        // every decomposition shape: partial sums must keep routing to the
        // right (round, link) tag, the assembled values must equal the
        // serial dssum, and the exchange's shared-dof support must be
        // exactly what it changes.
        for (shape, ranks) in
            [(DecompShape::Slab, 2), (DecompShape::Pencil, 4), (DecompShape::Box, 8)]
        {
            let cfg = RunConfig {
                nelt: 8,
                n: 3,
                ranks,
                decomp: shape.as_str().into(),
                ..Default::default()
            };
            let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
            let basis = Basis::new(cfg.n);
            let decomp = Decomposition::new(shape, ranks, &mesh).unwrap();
            let domains = build_domains(&mesh, &basis, &cfg, &decomp).unwrap();
            let comms = Comm::mesh(ranks);
            // Serial reference: dssum of all-ones is the global multiplicity.
            let mut gs_full = GatherScatter::new(&mesh);
            let mut want_full = vec![1.0; mesh.ndof_local()];
            gs_full.dssum(&mut want_full);
            let np = cfg.n * cfg.n * cfg.n;
            std::thread::scope(|scope| {
                for (domain, comm) in domains.into_iter().zip(comms) {
                    let want: Vec<f64> = domain
                        .elems
                        .iter()
                        .flat_map(|&e| want_full[e * np..(e + 1) * np].iter().copied())
                        .collect();
                    scope.spawn(move || {
                        let RankDomain { gs, point_gids, links, elems, .. } = domain;
                        let mut ex = BrickExchange::new(
                            gs,
                            &point_gids,
                            &elems,
                            links,
                            np,
                            Rc::new(RefCell::new(comm)),
                        );
                        let shared: std::collections::BTreeSet<usize> =
                            ex.shared_dofs().iter().map(|&l| l as usize).collect();
                        for round in 0..4 {
                            let mut v = vec![1.0; want.len()];
                            ex.exchange(&mut v).unwrap();
                            for (l, (&got, &w)) in v.iter().zip(&want).enumerate() {
                                assert_eq!(
                                    got.to_bits(),
                                    w.to_bits(),
                                    "{shape:?} round {round} dof {l}: {got} vs {w}"
                                );
                            }
                            // The exchange changed nothing outside shared_dofs.
                            for (l, &val) in v.iter().enumerate() {
                                if !shared.contains(&l) {
                                    assert_eq!(val, 1.0, "dof {l} changed outside support");
                                }
                            }
                        }
                    });
                }
            });
        }
    }

    #[test]
    fn ranked_niter_8192_matches_serial() {
        // End-to-end run at a large iteration budget (8192 once collided
        // halo tags with iteration tags under the pre-unification layout).
        // On this 864-dof system finite-precision CG typically stalls
        // above the exact-convergence floor and runs the full budget; but
        // whether or not the floor fires, ranked must match serial —
        // corrupted halos would miss by many orders of magnitude.
        // (Deterministic round coverage independent of CG's convergence
        // behavior is in `halo_exchange_clean_across_rounds`.)
        let base = RunConfig { nelt: 8, n: 4, niter: 8192, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        let got = run_ranked(&RunConfig { ranks: 2, ..base }).unwrap();
        assert!(want.final_residual < 1e-10, "serial residual {}", want.final_residual);
        assert!(got.final_residual < 1e-10, "ranked residual {}", got.final_residual);
        assert!(
            (got.final_residual - want.final_residual).abs() < 1e-10,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_exact_convergence_early_exits_instead_of_breakdown() {
        // A system that converges exactly mid-budget (here: a zero RHS,
        // converged at iteration 0 — the degenerate endpoint serial
        // cg_solve already handles) used to abort the ranked path with a
        // spurious "pap breakdown". The shared solver's rtz floor must
        // exit all ranks together instead.
        let cfg = RunConfig { nelt: 8, n: 3, niter: 50, ranks: 2, ..Default::default() };
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
        let basis = Basis::new(cfg.n);
        let decomp = Decomposition::new(DecompShape::Slab, cfg.ranks, &mesh).unwrap();
        let mut domains = build_domains(&mesh, &basis, &cfg, &decomp).unwrap();
        for domain in &mut domains {
            domain.f.iter_mut().for_each(|v| *v = 0.0);
        }
        let comms = Comm::mesh(cfg.ranks);
        let registry = OperatorRegistry::with_builtins();
        std::thread::scope(|scope| {
            let handles: Vec<_> = domains
                .into_iter()
                .zip(comms)
                .map(|(dom, comm)| {
                    scope.spawn(|| rank_main(dom, comm, &cfg, "cpu-layered", &registry))
                })
                .collect();
            for h in handles {
                let out = h
                    .join()
                    .unwrap()
                    .expect("exact convergence must early-exit, not break down");
                assert_eq!(
                    out.report.iterations, 0,
                    "all ranks exit together at iteration 0"
                );
                assert_eq!(out.report.final_rnorm, 0.0);
            }
        });
        // Serial cg_solve agrees on the same degenerate system.
        let mut app = Nekbone::builder(RunConfig { ranks: 1, ..cfg.clone() })
            .operator("cpu-layered")
            .build()
            .unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![0.0; ndof]).unwrap();
        let rep = app.run().unwrap();
        assert_eq!(rep.iterations, 0);
        assert_eq!(rep.final_residual, 0.0);
    }

    #[test]
    fn ranked_large_budget_no_spurious_breakdown() {
        // Generous budgets on small systems must never error out, and the
        // ranked residual must track serial on the initial-residual scale.
        let base = RunConfig { nelt: 8, n: 4, niter: 400, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let got = run_ranked(&RunConfig { ranks, ..base.clone() }).unwrap();
            assert!(got.final_residual < 1e-10, "ranks={ranks}: {}", got.final_residual);
            assert!(
                (got.final_residual - want.final_residual).abs() < 1e-10,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_fused_operators_match_default() {
        // The fused hot path through the rank runtime (operator-side pap +
        // shared/halo correction) must track the unfused operator.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let want = run_ranked(&base).unwrap();
        for name in ["cpu-layered-fused", "cpu-threaded-fused"] {
            let got = run_ranked_with(&base, name).unwrap();
            assert!(got.backend.contains(name), "{}", got.backend);
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-9,
                "{name}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_matches_serial_residual() {
        // The distributed CG must track the serial one to round-off.
        let base = RunConfig { nelt: 8, n: 4, niter: 25, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let cfg = RunConfig { ranks, ..base.clone() };
            let got = run_ranked(&cfg).unwrap();
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-6,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_report_content_matches_serial() {
        // The unification regression (satellite of the one-solver
        // redesign): ranked runs must produce the same *report content* as
        // serial ones — residual history recorded, rtol honored — because
        // both paths run the same solver. Before, the ranked path returned
        // `rnorms: vec![]` and ignored `record_residuals`/`rtol`.
        let base = RunConfig {
            nelt: 8,
            n: 4,
            niter: 25,
            record_residuals: true,
            ..Default::default()
        };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        let got = run_ranked(&RunConfig { ranks: 2, ..base.clone() }).unwrap();
        assert_eq!(want.rnorms.len(), want.iterations, "serial records every iteration");
        assert_eq!(
            got.rnorms.len(),
            got.iterations,
            "ranked must record the same history serial does"
        );
        assert_eq!(got.iterations, want.iterations);
        for (i, (a, b)) in got.rnorms.iter().zip(&want.rnorms).enumerate() {
            let denom = b.abs().max(1e-30);
            assert!(
                (a - b).abs() / denom < 1e-9,
                "iteration {i}: ranked rnorm {a} vs serial {b}"
            );
        }

        // rtol early exit fires identically: pick a tolerance from the
        // recorded history (between two consecutive residuals, away from
        // either, so roundoff cannot flip the crossing iteration) and both
        // paths must stop at the same iteration, under budget.
        let k = want.rnorms.len() / 2;
        let tol = (want.rnorms[k - 1] * want.rnorms[k]).sqrt(); // geometric midpoint
        let tcfg = RunConfig { rtol: Some(tol), record_residuals: false, ..base };
        let mut serial_t =
            Nekbone::builder(tcfg.clone()).operator("cpu-layered").build().unwrap();
        let want_t = serial_t.run().unwrap();
        let got_t = run_ranked(&RunConfig { ranks: 2, ..tcfg }).unwrap();
        assert!(want_t.iterations < 25, "tolerance must fire early: {}", want_t.iterations);
        assert_eq!(got_t.iterations, want_t.iterations, "rtol honored identically");
        assert!(got_t.final_residual <= tol);
    }

    #[test]
    fn ranked_more_ranks_same_answer() {
        let base = RunConfig { nelt: 64, n: 3, niter: 15, ..Default::default() };
        let r1 = run_ranked(&RunConfig { ranks: 1, ..base.clone() }).unwrap();
        let r4 = run_ranked(&RunConfig { ranks: 4, ..base.clone() }).unwrap();
        let denom = r1.final_residual.abs().max(1e-30);
        assert!(
            (r1.final_residual - r4.final_residual).abs() / denom < 1e-6,
            "{} vs {}",
            r1.final_residual,
            r4.final_residual
        );
    }

    #[test]
    fn ranked_with_other_cpu_operator_matches() {
        // Any registered (artifact-free) operator slots into the rank loop.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ..Default::default() };
        let layered = run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-layered")
            .unwrap();
        let naive =
            run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-naive").unwrap();
        assert!(naive.backend.contains("cpu-naive"), "{}", naive.backend);
        let denom = layered.final_residual.abs().max(1e-30);
        assert!(
            (layered.final_residual - naive.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            layered.final_residual,
            naive.final_residual
        );
    }

    #[test]
    fn ranked_assembled_operator_is_bitwise_layered() {
        // ISSUE 9 acceptance, ranked leg: `cpu-asm` must reproduce
        // `cpu-layered` bitwise through the rank runtime. At ranks=1 the
        // brick has no links, so the fold plan is built and assembly runs
        // inside the sweep; at ranks=2 the operators degrade to the plain
        // layered sweep (plan withheld) and BrickExchange assembles — both
        // legs exercise the capability gate end to end.
        let base = RunConfig {
            nelt: 8,
            n: 4,
            niter: 20,
            record_residuals: true,
            ..Default::default()
        };
        for ranks in [1usize, 2] {
            let cfg = RunConfig { ranks, ..base.clone() };
            let layered = run_ranked_with(&cfg, "cpu-layered").unwrap();
            let asm = run_ranked_with(&cfg, "cpu-asm").unwrap();
            assert!(asm.backend.contains("cpu-asm"), "{}", asm.backend);
            assert_eq!(asm.iterations, layered.iterations, "ranks={ranks}");
            assert_eq!(asm.rnorms.len(), layered.rnorms.len(), "ranks={ranks}");
            for (i, (a, l)) in asm.rnorms.iter().zip(&layered.rnorms).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    l.to_bits(),
                    "ranks={ranks} rnorm[{i}]: {a} vs {l}"
                );
            }
            assert_eq!(
                asm.final_residual.to_bits(),
                layered.final_residual.to_bits(),
                "ranks={ranks}: {} vs {}",
                asm.final_residual,
                layered.final_residual
            );
        }
    }

    #[test]
    fn ranked_runs_custom_registry_operator() {
        use crate::operators::{ax_layered, AxOperator, OperatorCtx};

        /// Test-only operator delegating to the layered kernel.
        struct Wrapped {
            st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
        }
        impl AxOperator for Wrapped {
            fn label(&self) -> String {
                "test-ranked-custom".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                let (n, nelt, d, g) = self.st.as_ref().unwrap();
                ax_layered(*n, *nelt, u, d, g, w);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut registry = OperatorRegistry::with_builtins();
        registry
            .register("test-ranked-custom", false, || Box::new(Wrapped { st: None }))
            .unwrap();
        let cfg = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let got = run_ranked_in(&cfg, "test-ranked-custom", &registry).unwrap();
        assert!(got.backend.contains("test-ranked-custom"), "{}", got.backend);
        let want = run_ranked(&cfg).unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_unknown_operator_fails_fast() {
        let cfg = RunConfig { nelt: 8, n: 4, niter: 5, ranks: 2, ..Default::default() };
        let err = run_ranked_with(&cfg, "no-such-op").unwrap_err().to_string();
        assert!(err.contains("no-such-op"), "{err}");
    }

    #[test]
    fn too_many_ranks_rejected() {
        let cfg = RunConfig { nelt: 8, n: 3, ranks: 5, ..Default::default() };
        assert!(run_ranked(&cfg).is_err());
    }

    #[test]
    fn over_split_axes_are_structured_config_errors() {
        // Splitting an axis finer than its element count must come back as
        // a structured Error::Config naming the decomposition shape and the
        // axis limits — for every shape (satellite of the scenario lab).
        // nelt = 8 → a 2×2×2 element grid.
        for (ranks, shape, needle) in
            [(3, "slab", "ez (2)"), (5, "pencil", "ey (2)"), (7, "box", "ex (2)")]
        {
            let cfg = RunConfig {
                nelt: 8,
                n: 3,
                ranks,
                decomp: shape.into(),
                ..Default::default()
            };
            let err = run_ranked(&cfg).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{shape}/{ranks}: {err:?}");
            let msg = err.to_string();
            assert!(msg.contains(shape), "{shape}/{ranks}: {msg}");
            assert!(msg.contains(needle), "{shape}/{ranks}: {msg}");
        }
    }
}
