//! Simulated multi-rank runtime: the MPI layer of Nekbone as threads +
//! channels (experiment E8, strong scaling).
//!
//! The element grid is partitioned into contiguous **z slabs** (ranks own
//! `ez/R` element layers each, remainder to the low ranks). Adjacent slabs
//! share one plane of global points, so the distributed `dssum` is a local
//! gather–scatter followed by one pairwise halo exchange per neighbor —
//! exactly the communication structure of the real code, with
//! `std::sync::mpsc` standing in for MPI.
//!
//! There is **no CG code here**. Each rank wraps its channels in a
//! [`ThreadComm`] (the [`Communicator`](crate::solver::Communicator)
//! adapter) and its slab assembly in a `HaloExchange` (the distributed
//! [`DomainExchange`](crate::solver::DomainExchange)), then calls the same
//! [`cg_solve`] the serial pipeline uses — residual updates, the
//! convergence floor, fused-pap accounting, and sweep counters all live in
//! exactly one place (`solver/cg.rs`). Because every CG scalar is an
//! order-deterministic allreduce, the per-rank [`CgReport`]s are bitwise
//! identical; [`run_ranked_in`] asserts that exactly.
//!
//! The per-rank compute dispatches through a `Box<dyn AxOperator>` built by
//! name from the [`OperatorRegistry`], so any registered operator (default:
//! the paper's layered CPU schedule, the CPU/MPI baseline) runs inside the
//! rank loop without this module knowing about it.

mod comm;

pub use comm::{Comm, Packet, ThreadComm};

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::RunReport;
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::{OperatorCtx, OperatorRegistry};
use crate::solver::{
    cg_solve, mask_apply, CgOptions, CgReport, CgWorkspace, DomainExchange, NoExchange,
    TimedAx,
};

/// The operator each rank runs when the caller does not pick one.
pub const DEFAULT_RANK_OPERATOR: &str = "cpu-layered";

/// Reject runs whose halo-exchange tags cannot be represented (see the
/// tag-space layout in [`comm`]): one exchange round per CG iteration, and
/// plane ids drawn from the global dof numbering.
fn check_tag_capacity(niter: usize, ndof_global: usize) -> Result<()> {
    if niter as u64 >= 1u64 << comm::TAG_ROUND_BITS {
        return Err(Error::Config(format!(
            "niter = {niter} is unrepresentable in the halo-exchange tag space \
             (max {})",
            (1u64 << comm::TAG_ROUND_BITS) - 1
        )));
    }
    if ndof_global as u64 >= 1u64 << comm::TAG_PAIR_BITS {
        return Err(Error::Config(format!(
            "global dof count {ndof_global} is unrepresentable in the \
             halo-pair tag space (max {})",
            (1u64 << comm::TAG_PAIR_BITS) - 1
        )));
    }
    Ok(())
}

/// How one rank sees the mesh.
struct RankSlab {
    /// Global element range [e0, e1).
    e0: usize,
    e1: usize,
    /// Rank-local gather–scatter over the slab's own elements.
    gs: GatherScatter,
    /// Sorted global ids of the plane shared with the previous / next rank,
    /// and for each, the rank-local dof indices holding copies.
    lo_plane: Vec<(usize, Vec<usize>)>,
    hi_plane: Vec<(usize, Vec<usize>)>,
    /// Rank-local fields.
    mask: Vec<f64>,
    c: Vec<f64>,
    f: Vec<f64>,
    g: Vec<f64>,
}

/// Partition `ez` layers over `ranks`: contiguous, remainder to low ranks.
fn slab_ranges(ez: usize, ranks: usize) -> Vec<(usize, usize)> {
    let base = ez / ranks;
    let rem = ez % ranks;
    let mut out = Vec::with_capacity(ranks);
    let mut z = 0;
    for r in 0..ranks {
        let h = base + usize::from(r < rem);
        out.push((z, z + h));
        z += h;
    }
    out
}

/// Build the per-rank slabs (global ids, shared planes, local fields).
fn build_slabs(mesh: &Mesh, basis: &Basis, cfg: &RunConfig) -> Result<Vec<RankSlab>> {
    let ranks = cfg.ranks;
    if mesh.ez < ranks {
        return Err(Error::Config(format!(
            "ranks ({ranks}) exceed element layers ez ({}); pick nelt with more z layers",
            mesh.ez
        )));
    }
    let n = mesh.n;
    let np = n * n * n;
    let geom = GeomFactors::affine(mesh, basis);
    let mask_full = mesh.boundary_mask();
    let c_full = mesh.inv_multiplicity();
    let mut rng = crate::rng::Rng::new(cfg.seed);
    let mut f_full = rng.normal_vec(mesh.ndof_local());
    // Make f dssum-consistent + masked globally (same as single-rank setup).
    let mut gs_full = GatherScatter::new(mesh);
    gs_full.dssum(&mut f_full);
    mask_apply(&mut f_full, &mask_full);

    let ezs = slab_ranges(mesh.ez, ranks);
    let epl = mesh.ex * mesh.ey; // elements per z layer
    let mut slabs = Vec::with_capacity(ranks);
    for (rank, &(z0, z1)) in ezs.iter().enumerate() {
        let e0 = z0 * epl;
        let e1 = z1 * epl;
        let nelt_local = e1 - e0;
        // Localize global ids: dense renumbering over this slab.
        let mut gids = Vec::with_capacity(nelt_local * np);
        for e in e0..e1 {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        gids.push(mesh.global_id(e, k, j, i));
                    }
                }
            }
        }
        let mut sorted: Vec<usize> = gids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        let local_of = |gid: usize| sorted.binary_search(&gid).unwrap();
        let local_ids: Vec<usize> = gids.iter().map(|&g| local_of(g)).collect();
        let gs = GatherScatter::from_ids(local_ids, sorted.len());

        // Shared planes: global grid z = z0*(n-1) (with previous rank) and
        // z = z1*(n-1) (with next rank).
        let plane = |pz: usize| -> Vec<(usize, Vec<usize>)> {
            let mut out: Vec<(usize, Vec<usize>)> = Vec::new();
            for (l, &gid) in gids.iter().enumerate() {
                let z = gid / (mesh.gx * mesh.gy);
                if z == pz {
                    match out.binary_search_by_key(&gid, |(g, _)| *g) {
                        Ok(pos) => out[pos].1.push(l),
                        Err(pos) => out.insert(pos, (gid, vec![l])),
                    }
                }
            }
            out
        };
        let lo_plane = if rank > 0 { plane(z0 * (n - 1)) } else { Vec::new() };
        let hi_plane = if rank + 1 < ranks { plane(z1 * (n - 1)) } else { Vec::new() };

        slabs.push(RankSlab {
            e0,
            e1,
            gs,
            lo_plane,
            hi_plane,
            mask: mask_full[e0 * np..e1 * np].to_vec(),
            c: c_full[e0 * np..e1 * np].to_vec(),
            f: f_full[e0 * np..e1 * np].to_vec(),
            g: geom.g[e0 * 6 * np..e1 * 6 * np].to_vec(),
        });
    }
    Ok(slabs)
}

/// The distributed [`DomainExchange`]: rank-local gather–scatter + one
/// pairwise halo exchange per slab neighbor. Both sides enumerate each
/// shared plane in ascending-gid order, so the exchanged vectors align;
/// the pair tag is derived from the exchange round and the plane's first
/// global id, identical on both sides without negotiation.
pub(crate) struct HaloExchange {
    gs: GatherScatter,
    lo_plane: Vec<(usize, Vec<usize>)>,
    hi_plane: Vec<(usize, Vec<usize>)>,
    comm: Rc<RefCell<Comm>>,
    /// Exchange rounds completed (tags are keyed on this; the solver calls
    /// one exchange per iteration on every rank, so the counters agree).
    round: u64,
    /// Union of the rank-local shared dofs and the halo-plane dofs —
    /// everything `exchange` may change, i.e. the support of the fused-pap
    /// correction.
    shared: Vec<u32>,
}

impl HaloExchange {
    fn new(
        gs: GatherScatter,
        lo_plane: Vec<(usize, Vec<usize>)>,
        hi_plane: Vec<(usize, Vec<usize>)>,
        comm: Rc<RefCell<Comm>>,
    ) -> Self {
        let mut shared: Vec<u32> = gs.shared_dofs().to_vec();
        for (_, ls) in lo_plane.iter().chain(hi_plane.iter()) {
            for &l in ls {
                shared.push(l as u32);
            }
        }
        shared.sort_unstable();
        shared.dedup();
        HaloExchange { gs, lo_plane, hi_plane, comm, round: 0, shared }
    }

    /// Exchange partial sums on one shared plane with `peer`.
    fn exchange_plane(
        comm: &mut Comm,
        plane: &[(usize, Vec<usize>)],
        peer: usize,
        round: u64,
        v: &mut [f64],
    ) -> Result<()> {
        if plane.is_empty() {
            return Ok(());
        }
        let tag = comm::exchange_tag(round, plane[0].0)?;
        let mine: Vec<f64> = plane.iter().map(|(_, ls)| v[ls[0]]).collect();
        let theirs = comm.sendrecv(peer, tag, mine)?;
        for ((_, ls), t) in plane.iter().zip(&theirs) {
            let total = v[ls[0]] + t;
            for &l in ls {
                v[l] = total;
            }
        }
        Ok(())
    }
}

impl DomainExchange for HaloExchange {
    fn exchange(&mut self, v: &mut [f64]) -> Result<()> {
        let round = self.round;
        self.round += 1;
        self.gs.dssum(v);
        let mut comm = self.comm.borrow_mut();
        let rank = comm.rank;
        Self::exchange_plane(&mut comm, &self.lo_plane, rank.wrapping_sub(1), round, v)?;
        Self::exchange_plane(&mut comm, &self.hi_plane, rank + 1, round, v)?;
        Ok(())
    }

    fn shared_dofs(&self) -> &[u32] {
        &self.shared
    }
}

/// What one rank reports back: the shared solver's report (bitwise
/// identical across ranks — every scalar in it is allreduced) plus this
/// rank's wall time inside the local operator.
struct RankOutcome {
    report: CgReport,
    ax_seconds: f64,
}

/// One rank's solve: build the operator from the registry, wrap the
/// channels in a [`ThreadComm`] and the slab assembly in a
/// [`HaloExchange`], and hand everything to the shared [`cg_solve`].
fn rank_main(
    slab: RankSlab,
    comm: Comm,
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RankOutcome> {
    let n = cfg.n;
    let np = n * n * n;
    let nelt_local = slab.e1 - slab.e0;
    let ndof = nelt_local * np;
    let d = crate::basis::derivative_matrix(n);

    // Each rank owns its operator instance, set up on the slab's data.
    let ctx = OperatorCtx {
        n,
        nelt: nelt_local,
        chunk: cfg.chunk,
        threads: cfg.cpu_threads,
        artifacts_dir: &cfg.artifacts_dir,
        d: &d,
        g: &slab.g,
        c: &slab.c,
    };
    let mut op = registry.build(operator, &ctx)?;
    // The operator cloned (or uploaded) what it needs from the slab's
    // geometric factors; destructuring drops the slab copy so the two
    // don't coexist for the whole solve (mirrors the serial pipeline
    // dropping `geom`).
    let RankSlab { gs, lo_plane, hi_plane, mask, c, f, .. } = slab;

    // The communicator and the halo exchange share the rank's channels;
    // their tag namespaces are disjoint (see `comm`).
    let comm = Rc::new(RefCell::new(comm));
    let mut thread_comm = ThreadComm::new(Rc::clone(&comm));
    let mut halo = HaloExchange::new(gs, lo_plane, hi_plane, comm);
    let mut no_exchange = NoExchange;
    let exchange: &mut dyn DomainExchange =
        if cfg.no_comm { &mut no_exchange } else { &mut halo };

    let opts = CgOptions {
        niter: cfg.niter,
        rtol: cfg.rtol,
        record_residuals: cfg.record_residuals,
    };
    let mask_opt = (!cfg.no_mask).then_some(mask.as_slice());
    let mut ax = TimedAx::new(op.as_mut());
    let mut x = vec![0.0; ndof];
    let mut ws = CgWorkspace::new(ndof);
    let report = cg_solve(
        &mut ax,
        exchange,
        &mut thread_comm,
        mask_opt,
        &c,
        &f,
        &mut x,
        &opts,
        &mut ws,
    )?;
    Ok(RankOutcome { report, ax_seconds: ax.seconds })
}

/// Run Nekbone across `cfg.ranks` simulated ranks with the default
/// operator ([`DEFAULT_RANK_OPERATOR`]).
pub fn run_ranked(cfg: &RunConfig) -> Result<RunReport> {
    run_ranked_with(cfg, DEFAULT_RANK_OPERATOR)
}

/// Run Nekbone across `cfg.ranks` simulated ranks, with the per-rank local
/// operator built by registry name from the built-in registry; returns the
/// report (the global residual, wall time of the slowest rank path).
pub fn run_ranked_with(cfg: &RunConfig, operator: &str) -> Result<RunReport> {
    run_ranked_in(cfg, operator, crate::operators::registry())
}

/// [`run_ranked_with`] against a caller-supplied registry, so
/// runtime-registered operators run ranked too (the registry is shared by
/// reference across the rank threads).
pub fn run_ranked_in(
    cfg: &RunConfig,
    operator: &str,
    registry: &OperatorRegistry,
) -> Result<RunReport> {
    cfg.validate()?;
    if cfg.precond != "none" {
        // The preconditioners are assembled against the serial pipeline's
        // whole-mesh gather-scatter; the ranked path would need per-slab
        // assembly + halo-consistent diagonals. Refuse rather than
        // silently solving unpreconditioned.
        return Err(Error::Config(format!(
            "--precond {} is not supported on the ranked path (use ranks = 1)",
            cfg.precond
        )));
    }
    // Fail fast on unknown operators (and get the canonical label) before
    // spawning any rank thread.
    let label = registry.resolve(operator)?.name.clone();
    let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
    check_tag_capacity(cfg.niter, mesh.ndof_global())?;
    let basis = Basis::new(cfg.n);
    let slabs = build_slabs(&mesh, &basis, cfg)?;
    let comms = Comm::mesh(cfg.ranks);

    let sw = Instant::now();
    let mut results = Vec::with_capacity(cfg.ranks);
    std::thread::scope(|scope| {
        let handles: Vec<_> = slabs
            .into_iter()
            .zip(comms)
            .map(|(slab, comm)| scope.spawn(|| rank_main(slab, comm, cfg, &label, registry)))
            .collect();
        for h in handles {
            results.push(h.join().map_err(|_| Error::Rank("rank thread panicked".into())));
        }
    });
    let seconds = sw.elapsed().as_secs_f64();

    let mut outcomes = Vec::with_capacity(cfg.ranks);
    for res in results {
        outcomes.push(res??);
    }
    // Every scalar in a CgReport is an order-deterministic allreduce, so
    // the per-rank reports must be **bitwise identical** — verify exactly
    // (not to a tolerance), so a future halo/tag bug fails loudly here
    // rather than silently reporting one rank's value.
    let first = outcomes[0].report.clone();
    let mut ax_seconds: f64 = 0.0;
    for (rank, o) in outcomes.iter().enumerate() {
        let r = &o.report;
        let identical = r.iterations == first.iterations
            && r.final_rnorm.to_bits() == first.final_rnorm.to_bits()
            && r.rtz1.to_bits() == first.rtz1.to_bits()
            && r.glsc3_sweeps == first.glsc3_sweeps
            && r.rnorms.len() == first.rnorms.len()
            && r.rnorms.iter().zip(&first.rnorms).all(|(a, b)| a.to_bits() == b.to_bits());
        if !identical {
            return Err(Error::Rank(format!(
                "rank {rank} CG report diverged from rank 0: \
                 {} iters |r| = {} vs {} iters |r| = {} \
                 (all scalars are allreduced; reports must be bitwise \
                 identical — halo exchange or collective-ordering bug?)",
                r.iterations, r.final_rnorm, first.iterations, first.final_rnorm
            )));
        }
        ax_seconds = ax_seconds.max(o.ax_seconds);
    }
    let cm = CostModel::new(cfg.n, cfg.nelt);
    // Fusedness is a static property of the operator type: a blank
    // (un-setup) instance answers it without building a rank's state.
    let fused = registry.create(&label).map(|op| op.is_fused()).unwrap_or(false);
    Ok(RunReport {
        backend: format!("ranked-{}-r{}", label, cfg.ranks),
        nelt: cfg.nelt,
        n: cfg.n,
        iterations: first.iterations,
        final_residual: first.final_rnorm,
        seconds,
        ax_seconds,
        flops: cm.flops_per_iter() * first.iterations as u64,
        fused,
        rnorms: first.rnorms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Nekbone;

    #[test]
    fn slab_ranges_cover() {
        for (ez, ranks) in [(8, 3), (4, 4), (7, 2), (16, 5)] {
            let rs = slab_ranges(ez, ranks);
            assert_eq!(rs.len(), ranks);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs.last().unwrap().1, ez);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn tag_capacity_limits_are_config_errors() {
        check_tag_capacity(100, 1000).unwrap();
        check_tag_capacity((1u64 << 32) as usize - 1, 1000).unwrap();
        assert!(matches!(
            check_tag_capacity(1usize << 32, 1000),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            check_tag_capacity(100, 1usize << 30),
            Err(Error::Config(_))
        ));
        // And the runtime rejects such a run up front.
        let cfg =
            RunConfig { nelt: 8, n: 3, niter: 1usize << 32, ranks: 2, ..Default::default() };
        let err = run_ranked(&cfg).unwrap_err().to_string();
        assert!(err.contains("tag space"), "{err}");
    }

    #[test]
    fn halo_exchange_clean_across_rounds() {
        // Drive the distributed exchange directly for many rounds
        // (including round indices far past any realistic niter): partial
        // sums must keep routing to the right round, and the exchange's
        // shared-dof support must be exactly what it changes.
        let cfg = RunConfig { nelt: 8, n: 3, ranks: 2, ..Default::default() };
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
        let basis = Basis::new(cfg.n);
        let slabs = build_slabs(&mesh, &basis, &cfg).unwrap();
        let comms = Comm::mesh(cfg.ranks);
        // Serial reference: dssum of all-ones is the global multiplicity.
        let mut gs_full = GatherScatter::new(&mesh);
        let mut want_full = vec![1.0; mesh.ndof_local()];
        gs_full.dssum(&mut want_full);
        let np = cfg.n * cfg.n * cfg.n;
        std::thread::scope(|scope| {
            for (slab, comm) in slabs.into_iter().zip(comms) {
                let want = want_full[slab.e0 * np..slab.e1 * np].to_vec();
                scope.spawn(move || {
                    let RankSlab { gs, lo_plane, hi_plane, .. } = slab;
                    let mut halo = HaloExchange::new(
                        gs,
                        lo_plane,
                        hi_plane,
                        Rc::new(RefCell::new(comm)),
                    );
                    let shared: std::collections::BTreeSet<usize> =
                        halo.shared_dofs().iter().map(|&l| l as usize).collect();
                    for round in 0..4 {
                        let mut v = vec![1.0; want.len()];
                        halo.exchange(&mut v).unwrap();
                        assert_eq!(v, want, "round {round}");
                        // The exchange changed nothing outside shared_dofs.
                        for (l, &val) in v.iter().enumerate() {
                            if !shared.contains(&l) {
                                assert_eq!(val, 1.0, "dof {l} changed outside support");
                            }
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn ranked_niter_8192_matches_serial() {
        // End-to-end run at a large iteration budget (8192 once collided
        // halo tags with iteration tags under the pre-unification layout).
        // On this 864-dof system finite-precision CG typically stalls
        // above the exact-convergence floor and runs the full budget; but
        // whether or not the floor fires, ranked must match serial —
        // corrupted halos would miss by many orders of magnitude.
        // (Deterministic round coverage independent of CG's convergence
        // behavior is in `halo_exchange_clean_across_rounds`.)
        let base = RunConfig { nelt: 8, n: 4, niter: 8192, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        let got = run_ranked(&RunConfig { ranks: 2, ..base }).unwrap();
        assert!(want.final_residual < 1e-10, "serial residual {}", want.final_residual);
        assert!(got.final_residual < 1e-10, "ranked residual {}", got.final_residual);
        assert!(
            (got.final_residual - want.final_residual).abs() < 1e-10,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_exact_convergence_early_exits_instead_of_breakdown() {
        // A system that converges exactly mid-budget (here: a zero RHS,
        // converged at iteration 0 — the degenerate endpoint serial
        // cg_solve already handles) used to abort the ranked path with a
        // spurious "pap breakdown". The shared solver's rtz floor must
        // exit all ranks together instead.
        let cfg = RunConfig { nelt: 8, n: 3, niter: 50, ranks: 2, ..Default::default() };
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n).unwrap();
        let basis = Basis::new(cfg.n);
        let mut slabs = build_slabs(&mesh, &basis, &cfg).unwrap();
        for slab in &mut slabs {
            slab.f.iter_mut().for_each(|v| *v = 0.0);
        }
        let comms = Comm::mesh(cfg.ranks);
        let registry = OperatorRegistry::with_builtins();
        std::thread::scope(|scope| {
            let handles: Vec<_> = slabs
                .into_iter()
                .zip(comms)
                .map(|(slab, comm)| {
                    scope.spawn(|| rank_main(slab, comm, &cfg, "cpu-layered", &registry))
                })
                .collect();
            for h in handles {
                let out = h
                    .join()
                    .unwrap()
                    .expect("exact convergence must early-exit, not break down");
                assert_eq!(
                    out.report.iterations, 0,
                    "all ranks exit together at iteration 0"
                );
                assert_eq!(out.report.final_rnorm, 0.0);
            }
        });
        // Serial cg_solve agrees on the same degenerate system.
        let mut app = Nekbone::builder(RunConfig { ranks: 1, ..cfg.clone() })
            .operator("cpu-layered")
            .build()
            .unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![0.0; ndof]).unwrap();
        let rep = app.run().unwrap();
        assert_eq!(rep.iterations, 0);
        assert_eq!(rep.final_residual, 0.0);
    }

    #[test]
    fn ranked_large_budget_no_spurious_breakdown() {
        // Generous budgets on small systems must never error out, and the
        // ranked residual must track serial on the initial-residual scale.
        let base = RunConfig { nelt: 8, n: 4, niter: 400, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let got = run_ranked(&RunConfig { ranks, ..base.clone() }).unwrap();
            assert!(got.final_residual < 1e-10, "ranks={ranks}: {}", got.final_residual);
            assert!(
                (got.final_residual - want.final_residual).abs() < 1e-10,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_fused_operators_match_default() {
        // The fused hot path through the rank runtime (operator-side pap +
        // shared/halo correction) must track the unfused operator.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let want = run_ranked(&base).unwrap();
        for name in ["cpu-layered-fused", "cpu-threaded-fused"] {
            let got = run_ranked_with(&base, name).unwrap();
            assert!(got.backend.contains(name), "{}", got.backend);
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-9,
                "{name}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_matches_serial_residual() {
        // The distributed CG must track the serial one to round-off.
        let base = RunConfig { nelt: 8, n: 4, niter: 25, ..Default::default() };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        for ranks in [1, 2] {
            let cfg = RunConfig { ranks, ..base.clone() };
            let got = run_ranked(&cfg).unwrap();
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-6,
                "ranks={ranks}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }

    #[test]
    fn ranked_report_content_matches_serial() {
        // The unification regression (satellite of the one-solver
        // redesign): ranked runs must produce the same *report content* as
        // serial ones — residual history recorded, rtol honored — because
        // both paths run the same solver. Before, the ranked path returned
        // `rnorms: vec![]` and ignored `record_residuals`/`rtol`.
        let base = RunConfig {
            nelt: 8,
            n: 4,
            niter: 25,
            record_residuals: true,
            ..Default::default()
        };
        let mut serial =
            Nekbone::builder(base.clone()).operator("cpu-layered").build().unwrap();
        let want = serial.run().unwrap();
        let got = run_ranked(&RunConfig { ranks: 2, ..base.clone() }).unwrap();
        assert_eq!(want.rnorms.len(), want.iterations, "serial records every iteration");
        assert_eq!(
            got.rnorms.len(),
            got.iterations,
            "ranked must record the same history serial does"
        );
        assert_eq!(got.iterations, want.iterations);
        for (i, (a, b)) in got.rnorms.iter().zip(&want.rnorms).enumerate() {
            let denom = b.abs().max(1e-30);
            assert!(
                (a - b).abs() / denom < 1e-9,
                "iteration {i}: ranked rnorm {a} vs serial {b}"
            );
        }

        // rtol early exit fires identically: pick a tolerance from the
        // recorded history (between two consecutive residuals, away from
        // either, so roundoff cannot flip the crossing iteration) and both
        // paths must stop at the same iteration, under budget.
        let k = want.rnorms.len() / 2;
        let tol = (want.rnorms[k - 1] * want.rnorms[k]).sqrt(); // geometric midpoint
        let tcfg = RunConfig { rtol: Some(tol), record_residuals: false, ..base };
        let mut serial_t =
            Nekbone::builder(tcfg.clone()).operator("cpu-layered").build().unwrap();
        let want_t = serial_t.run().unwrap();
        let got_t = run_ranked(&RunConfig { ranks: 2, ..tcfg }).unwrap();
        assert!(want_t.iterations < 25, "tolerance must fire early: {}", want_t.iterations);
        assert_eq!(got_t.iterations, want_t.iterations, "rtol honored identically");
        assert!(got_t.final_residual <= tol);
    }

    #[test]
    fn ranked_more_ranks_same_answer() {
        let base = RunConfig { nelt: 64, n: 3, niter: 15, ..Default::default() };
        let r1 = run_ranked(&RunConfig { ranks: 1, ..base.clone() }).unwrap();
        let r4 = run_ranked(&RunConfig { ranks: 4, ..base.clone() }).unwrap();
        let denom = r1.final_residual.abs().max(1e-30);
        assert!(
            (r1.final_residual - r4.final_residual).abs() / denom < 1e-6,
            "{} vs {}",
            r1.final_residual,
            r4.final_residual
        );
    }

    #[test]
    fn ranked_with_other_cpu_operator_matches() {
        // Any registered (artifact-free) operator slots into the rank loop.
        let base = RunConfig { nelt: 8, n: 4, niter: 20, ..Default::default() };
        let layered = run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-layered")
            .unwrap();
        let naive =
            run_ranked_with(&RunConfig { ranks: 2, ..base.clone() }, "cpu-naive").unwrap();
        assert!(naive.backend.contains("cpu-naive"), "{}", naive.backend);
        let denom = layered.final_residual.abs().max(1e-30);
        assert!(
            (layered.final_residual - naive.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            layered.final_residual,
            naive.final_residual
        );
    }

    #[test]
    fn ranked_runs_custom_registry_operator() {
        use crate::operators::{ax_layered, AxOperator, OperatorCtx};

        /// Test-only operator delegating to the layered kernel.
        struct Wrapped {
            st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
        }
        impl AxOperator for Wrapped {
            fn label(&self) -> String {
                "test-ranked-custom".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                let (n, nelt, d, g) = self.st.as_ref().unwrap();
                ax_layered(*n, *nelt, u, d, g, w);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut registry = OperatorRegistry::with_builtins();
        registry
            .register("test-ranked-custom", false, || Box::new(Wrapped { st: None }))
            .unwrap();
        let cfg = RunConfig { nelt: 8, n: 4, niter: 20, ranks: 2, ..Default::default() };
        let got = run_ranked_in(&cfg, "test-ranked-custom", &registry).unwrap();
        assert!(got.backend.contains("test-ranked-custom"), "{}", got.backend);
        let want = run_ranked(&cfg).unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            got.final_residual,
            want.final_residual
        );
    }

    #[test]
    fn ranked_unknown_operator_fails_fast() {
        let cfg = RunConfig { nelt: 8, n: 4, niter: 5, ranks: 2, ..Default::default() };
        let err = run_ranked_with(&cfg, "no-such-op").unwrap_err().to_string();
        assert!(err.contains("no-such-op"), "{err}");
    }

    #[test]
    fn too_many_ranks_rejected() {
        let cfg = RunConfig { nelt: 8, n: 3, ranks: 5, ..Default::default() };
        assert!(run_ranked(&cfg).is_err());
    }
}
