//! Property-testing helpers.
//!
//! The offline crate set has no `proptest`, so this module carries a small
//! seeded-case generator with the same spirit: deterministic random inputs,
//! many cases per invariant, and a failure report that includes the case
//! seed so a failure reproduces exactly. No shrinking — our generators take
//! explicit size parameters, so failing cases are already small.

use crate::rng::Rng;

/// Deterministic case generator for property tests.
pub struct Cases {
    rng: Rng,
    case: usize,
}

impl Cases {
    /// New generator from a test-level seed.
    pub fn new(seed: u64) -> Self {
        Cases { rng: Rng::new(seed), case: 0 }
    }

    /// Index of the current case (increment with [`Cases::next_case`]).
    pub fn case(&self) -> usize {
        self.case
    }

    /// Advance to the next case; returns its index for failure messages.
    pub fn next_case(&mut self) -> usize {
        self.case += 1;
        self.case
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// Standard-normal vector of length `len`.
    pub fn vec_normal(&mut self, len: usize) -> Vec<f64> {
        self.rng.normal_vec(len)
    }

    /// Vector uniform in `[lo, hi)`.
    pub fn vec_uniform(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    /// A random permutation of `0..len`.
    pub fn permutation(&mut self, len: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..len).collect();
        for i in (1..len).rev() {
            let j = self.rng.below(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// A random surjective map `0..len -> 0..n_classes` (every class hit),
    /// useful for gather–scatter id maps. Requires `len >= n_classes`.
    pub fn surjection(&mut self, len: usize, n_classes: usize) -> Vec<usize> {
        assert!(len >= n_classes);
        let mut ids: Vec<usize> = (0..len)
            .map(|i| if i < n_classes { i } else { self.rng.below(n_classes) })
            .collect();
        // Shuffle so the guaranteed-coverage prefix is not special.
        for i in (1..len).rev() {
            let j = self.rng.below(i + 1);
            ids.swap(i, j);
        }
        ids
    }
}

/// Run `cases` independent property cases; panics with the case index and
/// seed on the first failure.
pub fn forall<F: FnMut(&mut Cases)>(seed: u64, cases: usize, mut prop: F) {
    let mut gen = Cases::new(seed);
    for c in 0..cases {
        gen.next_case();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut gen)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {}/{cases} (seed {seed:#x}): {msg}", c + 1);
        }
    }
}

/// Assert a fused-pap value matches a reference `glsc3(w, c, u)` within
/// `tol` scaled by the reduction's absolute-term sum `Σ |w_i c_i u_i|`.
/// Scaling by the unsigned sum keeps the check meaningful when the signed
/// reduction cancels toward zero (a plain relative check would then
/// reject legitimate roundoff), while staying tight enough to catch a
/// real defect. Shared by every fused-operator suite so the tolerance
/// convention lives in one place.
#[track_caller]
pub fn assert_pap_close(
    pap: f64,
    want: f64,
    w: &[f64],
    c: &[f64],
    u: &[f64],
    tol: f64,
    what: &str,
) {
    let scale: f64 = w.iter().zip(c).zip(u).map(|((wi, ci), ui)| (wi * ci * ui).abs()).sum();
    assert!(
        (pap - want).abs() <= tol * scale.max(1e-300),
        "{what}: pap {pap} vs {want} (tol {tol:e}, term scale {scale:e})"
    );
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_allclose(got: &[f64], want: &[f64], rtol: f64, atol: f64) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at {idx}: got {g}, want {w} (|diff| = {:.3e} > tol {:.3e})",
            (g - w).abs(),
            tol
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(1, 50, |c| {
            let len = c.size(1, 16);
            let v = c.vec_normal(len);
            assert_eq!(v.len(), len);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_case() {
        forall(2, 10, |c| {
            assert!(c.case() < 5, "boom at case {}", c.case());
        });
    }

    #[test]
    fn permutation_is_permutation() {
        let mut c = Cases::new(3);
        for len in [1usize, 2, 7, 64] {
            let p = c.permutation(len);
            let mut seen = vec![false; len];
            for &i in &p {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn surjection_covers() {
        let mut c = Cases::new(4);
        for _ in 0..20 {
            let n = c.size(1, 10);
            let len = n + c.size(0, 30);
            let ids = c.surjection(len, n);
            let mut seen = vec![false; n];
            for &g in &ids {
                assert!(g < n);
                seen[g] = true;
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn allclose_detects_mismatch() {
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0, 2.0], &[1.0, 2.5], 1e-9, 1e-9)
        });
        assert!(r.is_err());
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 1e-9);
    }
}
