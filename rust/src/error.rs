//! Crate-wide error type.

use thiserror::Error;

/// All errors the library surfaces to callers.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid run configuration (sizes, degrees, backend combinations).
    #[error("configuration error: {0}")]
    Config(String),

    /// An artifact referenced by the manifest is missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// JSON parse failure (manifest).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Failure in the XLA/PJRT runtime layer.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Numerical failure (CG breakdown, non-finite values).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Multi-rank runtime failure (a worker panicked or a channel closed).
    #[error("rank runtime error: {0}")]
    Rank(String),

    /// I/O error with context.
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: I/O error with path context.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}
