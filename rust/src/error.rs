//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the crate
//! builds offline, so no proc-macro derive dependency).

use std::fmt;

/// All errors the library surfaces to callers.
#[derive(Debug)]
pub enum Error {
    /// Invalid run configuration (sizes, degrees, backend combinations).
    Config(String),

    /// An artifact referenced by the manifest is missing or malformed.
    Artifact(String),

    /// JSON parse failure (manifest).
    Json { offset: usize, msg: String },

    /// Failure in the XLA/PJRT runtime layer.
    Xla(String),

    /// Numerical failure (CG breakdown, non-finite values).
    Numerical(String),

    /// Multi-rank runtime failure (a worker panicked or a channel closed).
    Rank(String),

    /// I/O error with context.
    Io { path: String, source: std::io::Error },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "configuration error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Json { offset, msg } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical error: {msg}"),
            Error::Rank(msg) => write!(f, "rank runtime error: {msg}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: I/O error with path context.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_variants() {
        assert_eq!(Error::Config("bad".into()).to_string(), "configuration error: bad");
        assert_eq!(Error::Artifact("gone".into()).to_string(), "artifact error: gone");
        assert_eq!(
            Error::Json { offset: 7, msg: "oops".into() }.to_string(),
            "json error at byte 7: oops"
        );
    }

    #[test]
    fn io_error_chains_source() {
        use std::error::Error as _;
        let e = Error::io("m.json", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.to_string().contains("m.json"));
        assert!(e.source().is_some());
    }
}
