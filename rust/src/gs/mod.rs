//! Gather–scatter: direct-stiffness summation across element boundaries
//! (Nekbone's `dssum`, the role gslib plays in the real code).
//!
//! The local Poisson operator (`Ax` on each element) produces independent
//! per-element results; `dssum` adds together the values all local copies of
//! a shared global point hold and writes the sum back to every copy:
//!
//! ```text
//! v_local = Q Q^T v_local      (Q = local-to-global boolean scatter)
//! ```
//!
//! This is the "communicate the local results to the neighboring elements"
//! step of the paper (section III), which the paper's roofline methodology
//! excludes (`--no-comm`).
//!
//! The serial implementation here gathers into a dense global buffer — the
//! right choice for a single address space. The distributed analog (halo
//! exchange between rank threads) lives in [`crate::rank`] and is
//! property-tested against this one.

use crate::mesh::Mesh;

/// Precomputed gather–scatter operator for one mesh.
#[derive(Clone, Debug)]
pub struct GatherScatter {
    /// local dof -> global dof.
    ids: Vec<usize>,
    /// Number of distinct global dofs.
    nglobal: usize,
    /// Hot-path structure: only dofs with multiplicity > 1 participate in
    /// the summation (a single-copy dof's "sum" is itself). `shared_offsets`
    /// delimits groups inside `shared_locals`; each group lists the local
    /// copies of one shared global dof. Built once; `dssum` then touches
    /// only shared copies (~half the dofs at n = 10) instead of
    /// gather+scatter over a dense global scratch (perf pass, see
    /// EXPERIMENTS.md §Perf L3).
    shared_offsets: Vec<u32>,
    shared_locals: Vec<u32>,
}

impl GatherScatter {
    /// Build from a mesh's local→global map.
    pub fn new(mesh: &Mesh) -> Self {
        Self::from_ids(mesh.global_ids(), mesh.ndof_global())
    }

    /// Build from an explicit map (used by tests and the rank runtime).
    pub fn from_ids(ids: Vec<usize>, nglobal: usize) -> Self {
        debug_assert!(ids.iter().all(|&g| g < nglobal));
        // Count copies per global dof, then group the local indices of
        // every dof that has more than one copy.
        let mut count = vec![0u32; nglobal];
        for &g in &ids {
            count[g] += 1;
        }
        // Dense index for shared globals only.
        let mut shared_index = vec![u32::MAX; nglobal];
        let mut nshared = 0u32;
        for (g, &c) in count.iter().enumerate() {
            if c > 1 {
                shared_index[g] = nshared;
                nshared += 1;
            }
        }
        let mut shared_offsets = vec![0u32; nshared as usize + 1];
        for (g, &c) in count.iter().enumerate() {
            if c > 1 {
                shared_offsets[shared_index[g] as usize + 1] = c;
            }
        }
        for i in 1..shared_offsets.len() {
            shared_offsets[i] += shared_offsets[i - 1];
        }
        let mut cursor = shared_offsets.clone();
        let mut shared_locals = vec![0u32; *shared_offsets.last().unwrap() as usize];
        for (l, &g) in ids.iter().enumerate() {
            let si = shared_index[g];
            if si != u32::MAX {
                shared_locals[cursor[si as usize] as usize] = l as u32;
                cursor[si as usize] += 1;
            }
        }
        GatherScatter { ids, nglobal, shared_offsets, shared_locals }
    }

    /// Number of local dofs this operator acts on.
    pub fn ndof_local(&self) -> usize {
        self.ids.len()
    }

    /// Number of distinct global dofs.
    pub fn ndof_global(&self) -> usize {
        self.nglobal
    }

    /// The local→global map.
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }

    /// Direct-stiffness summation in place: every local copy of a global
    /// point receives the sum over all copies. Only shared dofs are
    /// touched; single-copy dofs already equal their own sum.
    pub fn dssum(&mut self, v: &mut [f64]) {
        assert_eq!(v.len(), self.ids.len(), "dssum length mismatch");
        for w in self.shared_offsets.windows(2) {
            let group = &self.shared_locals[w[0] as usize..w[1] as usize];
            let mut sum = 0.0;
            for &l in group {
                sum += v[l as usize];
            }
            for &l in group {
                v[l as usize] = sum;
            }
        }
    }

    /// Gather only: returns the global vector `Q^T v` (sum over copies).
    pub fn gather(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.ids.len());
        let mut out = vec![0.0; self.nglobal];
        for (l, &g) in self.ids.iter().enumerate() {
            out[g] += v[l];
        }
        out
    }

    /// Scatter only: `v_local[l] = u_global[ids[l]]`.
    pub fn scatter(&self, u: &[f64], v: &mut [f64]) {
        assert_eq!(u.len(), self.nglobal);
        assert_eq!(v.len(), self.ids.len());
        for (l, &g) in self.ids.iter().enumerate() {
            v[l] = u[g];
        }
    }

    /// Local indices of every dof with multiplicity > 1 — exactly the dofs
    /// `dssum` can change (each copy listed once, grouped by global dof).
    /// The fused Ax+pap solver path snapshots `w` here before `dssum` and
    /// patches the fused reduction afterwards, turning a full `ndof` sweep
    /// into an O(surface) correction.
    pub fn shared_dofs(&self) -> &[u32] {
        &self.shared_locals
    }

    /// Multiplicity of every local dof (copies per global point) — the
    /// denominator of Nekbone's `c` weight vector.
    pub fn multiplicity(&self) -> Vec<f64> {
        let ones = vec![1.0; self.ids.len()];
        let counts = self.gather(&ones);
        self.ids.iter().map(|&g| counts[g]).collect()
    }
}

/// The serial [`DomainExchange`](crate::solver::DomainExchange):
/// `exchange` is [`GatherScatter::dssum`] and the exchange support is
/// exactly [`GatherScatter::shared_dofs`]. This is what lets the one
/// generic CG driver run single-address-space solves — the rank runtime
/// plugs in its halo exchange behind the same trait.
impl crate::solver::DomainExchange for GatherScatter {
    fn exchange(&mut self, v: &mut [f64]) -> crate::error::Result<()> {
        self.dssum(v);
        Ok(())
    }

    fn shared_dofs(&self) -> &[u32] {
        GatherScatter::shared_dofs(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::Cases;

    fn mesh() -> Mesh {
        Mesh::new(2, 2, 2, 3).unwrap()
    }

    #[test]
    fn dssum_on_distinct_ids_is_identity() {
        let mut gs = GatherScatter::from_ids(vec![0, 1, 2, 3], 4);
        let mut v = vec![1.0, -2.0, 3.0, 0.5];
        let orig = v.clone();
        gs.dssum(&mut v);
        assert_eq!(v, orig);
    }

    #[test]
    fn dssum_sums_copies() {
        let mut gs = GatherScatter::from_ids(vec![0, 1, 0, 1], 2);
        let mut v = vec![1.0, 10.0, 2.0, 20.0];
        gs.dssum(&mut v);
        assert_eq!(v, vec![3.0, 30.0, 3.0, 30.0]);
    }

    #[test]
    fn dssum_preserves_global_sum_weighted() {
        // sum_l v_l / mult_l is invariant under dssum... actually
        // sum_global(gather(v)) is invariant; check that.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| (i as f64 * 0.7).sin()).collect();
        let before: f64 = gs.gather(&v).iter().sum();
        gs.dssum(&mut v);
        // After dssum, gather multiplies each global value by its multiplicity.
        let ones = vec![1.0; m.ndof_local()];
        let counts = gs.gather(&ones);
        let after: f64 = gs
            .gather(&v)
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| s / c)
            .sum();
        assert!((before - after).abs() < 1e-9 * before.abs().max(1.0));
    }

    #[test]
    fn dssum_idempotent_up_to_multiplicity() {
        // dssum(dssum(v)) == dssum(mult * ... ) — specifically for v already
        // summed, a second dssum multiplies each global value by mult.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| i as f64).collect();
        gs.dssum(&mut v);
        let summed = v.clone();
        gs.dssum(&mut v);
        let mult = gs.multiplicity();
        for ((a, b), m) in v.iter().zip(&summed).zip(&mult) {
            assert!((a - b * m).abs() < 1e-9, "{a} vs {b} * {m}");
        }
    }

    #[test]
    fn dssum_symmetric() {
        // <dssum(u), v> == <u, dssum(v)> : Q Q^T is symmetric.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut cases = Cases::new(0xD55);
        for _ in 0..10 {
            let u0 = cases.vec_normal(m.ndof_local());
            let v0 = cases.vec_normal(m.ndof_local());
            let mut u = u0.clone();
            let mut v = v0.clone();
            gs.dssum(&mut u);
            gs.dssum(&mut v);
            let lhs: f64 = u.iter().zip(&v0).map(|(a, b)| a * b).sum();
            let rhs: f64 = u0.iter().zip(&v).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0));
        }
    }

    #[test]
    fn shared_dofs_are_exactly_the_dssum_support() {
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mult = gs.multiplicity();
        let shared: std::collections::BTreeSet<usize> =
            gs.shared_dofs().iter().map(|&l| l as usize).collect();
        for (l, &mu) in mult.iter().enumerate() {
            assert_eq!(shared.contains(&l), mu > 1.0, "dof {l} mult {mu}");
        }
        // dssum never changes a value outside shared_dofs.
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| (i as f64 * 0.3).cos()).collect();
        let before = v.clone();
        gs.dssum(&mut v);
        for (l, (a, b)) in before.iter().zip(&v).enumerate() {
            if !shared.contains(&l) {
                assert_eq!(a, b, "dssum changed unshared dof {l}");
            }
        }
    }

    #[test]
    fn multiplicity_matches_mesh() {
        let m = mesh();
        let gs = GatherScatter::new(&m);
        assert_eq!(gs.multiplicity(), m.multiplicity());
    }

    #[test]
    fn constant_field_fixed_point() {
        // A globally consistent field times multiplicity: dssum(1) = mult.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v = vec![1.0; m.ndof_local()];
        gs.dssum(&mut v);
        assert_eq!(v, gs.multiplicity());
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v = vec![0.0; 3];
        gs.dssum(&mut v);
    }
}
