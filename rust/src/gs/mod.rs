//! Gather–scatter: direct-stiffness summation across element boundaries
//! (Nekbone's `dssum`, the role gslib plays in the real code).
//!
//! The local Poisson operator (`Ax` on each element) produces independent
//! per-element results; `dssum` adds together the values all local copies of
//! a shared global point hold and writes the sum back to every copy:
//!
//! ```text
//! v_local = Q Q^T v_local      (Q = local-to-global boolean scatter)
//! ```
//!
//! This is the "communicate the local results to the neighboring elements"
//! step of the paper (section III), which the paper's roofline methodology
//! excludes (`--no-comm`).
//!
//! The serial implementation here gathers into a dense global buffer — the
//! right choice for a single address space. The distributed analog (halo
//! exchange between rank threads) lives in [`crate::rank`] and is
//! property-tested against this one.

use crate::error::{Error, Result};
use crate::mesh::Mesh;

/// Precomputed gather–scatter operator for one mesh.
#[derive(Clone, Debug)]
pub struct GatherScatter {
    /// local dof -> global dof.
    ids: Vec<usize>,
    /// Number of distinct global dofs.
    nglobal: usize,
    /// Hot-path structure: only dofs with multiplicity > 1 participate in
    /// the summation (a single-copy dof's "sum" is itself). `shared_offsets`
    /// delimits groups inside `shared_locals`; each group lists the local
    /// copies of one shared global dof. Built once; `dssum` then touches
    /// only shared copies (~half the dofs at n = 10) instead of
    /// gather+scatter over a dense global scratch (perf pass, see
    /// EXPERIMENTS.md §Perf L3).
    shared_offsets: Vec<u32>,
    shared_locals: Vec<u32>,
}

impl GatherScatter {
    /// Build from a mesh's local→global map.
    pub fn new(mesh: &Mesh) -> Self {
        Self::from_ids(mesh.global_ids(), mesh.ndof_global())
    }

    /// Build from an explicit map (used by tests and the rank runtime).
    pub fn from_ids(ids: Vec<usize>, nglobal: usize) -> Self {
        debug_assert!(ids.iter().all(|&g| g < nglobal));
        // Count copies per global dof, then group the local indices of
        // every dof that has more than one copy.
        let mut count = vec![0u32; nglobal];
        for &g in &ids {
            count[g] += 1;
        }
        // Dense index for shared globals only.
        let mut shared_index = vec![u32::MAX; nglobal];
        let mut nshared = 0u32;
        for (g, &c) in count.iter().enumerate() {
            if c > 1 {
                shared_index[g] = nshared;
                nshared += 1;
            }
        }
        let mut shared_offsets = vec![0u32; nshared as usize + 1];
        for (g, &c) in count.iter().enumerate() {
            if c > 1 {
                shared_offsets[shared_index[g] as usize + 1] = c;
            }
        }
        for i in 1..shared_offsets.len() {
            shared_offsets[i] += shared_offsets[i - 1];
        }
        let mut cursor = shared_offsets.clone();
        let mut shared_locals = vec![0u32; *shared_offsets.last().unwrap() as usize];
        for (l, &g) in ids.iter().enumerate() {
            let si = shared_index[g];
            if si != u32::MAX {
                shared_locals[cursor[si as usize] as usize] = l as u32;
                cursor[si as usize] += 1;
            }
        }
        GatherScatter { ids, nglobal, shared_offsets, shared_locals }
    }

    /// Number of local dofs this operator acts on.
    pub fn ndof_local(&self) -> usize {
        self.ids.len()
    }

    /// Number of distinct global dofs.
    pub fn ndof_global(&self) -> usize {
        self.nglobal
    }

    /// The local→global map.
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }

    /// Direct-stiffness summation in place: every local copy of a global
    /// point receives the sum over all copies. Only shared dofs are
    /// touched; single-copy dofs already equal their own sum.
    pub fn dssum(&mut self, v: &mut [f64]) {
        assert_eq!(v.len(), self.ids.len(), "dssum length mismatch");
        for w in self.shared_offsets.windows(2) {
            let group = &self.shared_locals[w[0] as usize..w[1] as usize];
            let mut sum = 0.0;
            for &l in group {
                sum += v[l as usize];
            }
            for &l in group {
                v[l as usize] = sum;
            }
        }
    }

    /// Gather only: returns the global vector `Q^T v` (sum over copies).
    pub fn gather(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.ids.len());
        let mut out = vec![0.0; self.nglobal];
        for (l, &g) in self.ids.iter().enumerate() {
            out[g] += v[l];
        }
        out
    }

    /// Scatter only: `v_local[l] = u_global[ids[l]]`.
    pub fn scatter(&self, u: &[f64], v: &mut [f64]) {
        assert_eq!(u.len(), self.nglobal);
        assert_eq!(v.len(), self.ids.len());
        for (l, &g) in self.ids.iter().enumerate() {
            v[l] = u[g];
        }
    }

    /// Local indices of every dof with multiplicity > 1 — exactly the dofs
    /// `dssum` can change (each copy listed once, grouped by global dof).
    /// The fused Ax+pap solver path snapshots `w` here before `dssum` and
    /// patches the fused reduction afterwards, turning a full `ndof` sweep
    /// into an O(surface) correction.
    pub fn shared_dofs(&self) -> &[u32] {
        &self.shared_locals
    }

    /// Multiplicity of every local dof (copies per global point) — the
    /// denominator of Nekbone's `c` weight vector.
    pub fn multiplicity(&self) -> Vec<f64> {
        let ones = vec![1.0; self.ids.len()];
        let counts = self.gather(&ones);
        self.ids.iter().map(|&g| counts[g]).collect()
    }

    /// Build the ownership/fold plan an assembly-fused operator needs to
    /// perform dssum + mask *inside* its element sweep (the `cpu-asm`
    /// family). `np = n^3` is the dofs-per-element block size; `mask` is
    /// the solve's boundary mask (or `None` under `--no-mask`).
    ///
    /// The plan re-buckets this gather–scatter's fold groups by **ready
    /// element** — the element holding a group's last (highest) local copy
    /// — so a kernel can fold each shared dof the moment its final
    /// contribution is written, while the face data is cache-hot. Within a
    /// group the copies stay in ascending-local order and the fold is the
    /// same sum-then-broadcast [`GatherScatter::dssum`] performs, and
    /// distinct groups touch disjoint dofs, so the assembled result is
    /// **bitwise identical** to running the serial sweep-then-dssum path.
    pub fn assembly_plan(&self, np: usize, mask: Option<&[f64]>) -> Result<AssemblyPlan> {
        let ndof = self.ids.len();
        if np == 0 || ndof % np != 0 {
            return Err(Error::Config(format!(
                "assembly plan: local dofs ({ndof}) must be a multiple of n^3 ({np})"
            )));
        }
        let nelt = ndof / np;
        if let Some(m) = mask {
            if m.len() != ndof {
                return Err(Error::Config(format!(
                    "assembly plan: mask must be ndof = {ndof}, got {}",
                    m.len()
                )));
            }
        }
        // Bucket-sort the fold groups by ready element; the stable pass
        // keeps gid order within each bucket (deterministic, testable).
        let ngroups = self.shared_offsets.len() - 1;
        let ready_of = |gi: usize| {
            let hi = self.shared_offsets[gi + 1] as usize;
            self.shared_locals[hi - 1] as usize / np
        };
        let mut ready_offsets = vec![0u32; nelt + 1];
        for gi in 0..ngroups {
            ready_offsets[ready_of(gi) + 1] += 1;
        }
        for e in 1..=nelt {
            ready_offsets[e] += ready_offsets[e - 1];
        }
        let mut cursor: Vec<u32> = ready_offsets[..nelt].to_vec();
        let mut order = vec![0u32; ngroups];
        for gi in 0..ngroups {
            let e = ready_of(gi);
            order[cursor[e] as usize] = gi as u32;
            cursor[e] += 1;
        }
        let mut offsets = Vec::with_capacity(ngroups + 1);
        let mut locals = Vec::with_capacity(self.shared_locals.len());
        offsets.push(0u32);
        for &gi in &order {
            let (lo, hi) =
                (self.shared_offsets[gi as usize] as usize, self.shared_offsets[gi as usize + 1] as usize);
            locals.extend_from_slice(&self.shared_locals[lo..hi]);
            offsets.push(locals.len() as u32);
        }
        // Interior (multiplicity-1) dofs per element: everything dssum
        // never touches — the fused pap accumulates these per element.
        let mut is_shared = vec![false; ndof];
        for &l in &self.shared_locals {
            is_shared[l as usize] = true;
        }
        let mut interior_offsets = Vec::with_capacity(nelt + 1);
        let mut interior = Vec::with_capacity(ndof - self.shared_locals.len());
        interior_offsets.push(0u32);
        for e in 0..nelt {
            for l in e * np..(e + 1) * np {
                if !is_shared[l] {
                    interior.push(l as u32);
                }
            }
            interior_offsets.push(interior.len() as u32);
        }
        // Only dofs whose mask entry actually scales (value != 1.0) are
        // listed: x * 1.0 == x bitwise, so skipping identity entries keeps
        // the plan's mask pass bit-identical to a full `mask_apply`.
        let masked = mask
            .map(|m| {
                m.iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 1.0)
                    .map(|(l, &v)| (l as u32, v))
                    .collect()
            })
            .unwrap_or_default();
        Ok(AssemblyPlan { np, ndof, offsets, locals, ready_offsets, interior_offsets, interior, masked })
    }
}

/// Precomputed ownership/fold plan for performing direct-stiffness
/// assembly (and the boundary mask) *inside* an operator's element sweep
/// — built by [`GatherScatter::assembly_plan`], consumed by the `cpu-asm`
/// operator family through [`OperatorCtx::assemble`](crate::operators::OperatorCtx).
///
/// Invariants (each one load-bearing for the bitwise guarantee):
///
/// * every fold group lists the local copies of one shared global dof in
///   ascending-local order — the exact order [`GatherScatter::dssum`]
///   sums, so each group's fold reproduces dssum's result bit for bit;
/// * groups are bucketed by ready element (the element owning the group's
///   highest copy); distinct groups are disjoint, so fold order across
///   groups cannot change any dof's value;
/// * the mask pass multiplies only dofs whose mask value differs from 1.0,
///   after all folds — the dssum-then-mask order of the standalone path.
#[derive(Clone, Debug)]
pub struct AssemblyPlan {
    /// Dofs per element (n^3).
    np: usize,
    /// Total local dofs the plan covers.
    ndof: usize,
    /// Group boundaries into `locals` (ngroups + 1 entries).
    offsets: Vec<u32>,
    /// Local copies of each shared dof, ascending within a group.
    locals: Vec<u32>,
    /// `ready_offsets[e]..ready_offsets[e+1]` = groups ready after
    /// element `e`'s values are written (nelt + 1 entries).
    ready_offsets: Vec<u32>,
    /// Interior-dof boundaries into `interior` (nelt + 1 entries).
    interior_offsets: Vec<u32>,
    /// Multiplicity-1 dofs, bucketed per element.
    interior: Vec<u32>,
    /// `(dof, mask value)` for every dof whose mask entry != 1.0.
    masked: Vec<(u32, f64)>,
}

impl AssemblyPlan {
    /// Local dofs the plan covers.
    pub fn ndof(&self) -> usize {
        self.ndof
    }

    /// Elements the plan covers.
    pub fn nelt(&self) -> usize {
        self.ndof / self.np
    }

    /// Fold every group that became ready when element `e`'s values were
    /// written: sum the copies in ascending-local order, broadcast the sum
    /// — the same arithmetic [`GatherScatter::dssum`] performs on that
    /// group, just scheduled while the face data is cache-hot.
    pub fn fold_ready(&self, e: usize, w: &mut [f64]) {
        let (lo, hi) = (self.ready_offsets[e] as usize, self.ready_offsets[e + 1] as usize);
        for gi in lo..hi {
            let group =
                &self.locals[self.offsets[gi] as usize..self.offsets[gi + 1] as usize];
            let mut sum = 0.0;
            for &l in group {
                sum += w[l as usize];
            }
            for &l in group {
                w[l as usize] = sum;
            }
        }
    }

    /// Fused-pap contribution of everything finalized at element `e`: the
    /// groups just folded by [`AssemblyPlan::fold_ready`] plus element
    /// `e`'s interior dofs — `sum(c_l * u_l * w_l)` over exactly those
    /// copies, with `w` already folded. Summing each dof the moment it is
    /// final lets the fused asm operator report an **assembled** pap
    /// without a second full-vector sweep.
    pub fn pap_ready(&self, e: usize, w: &[f64], u: &[f64], c: &[f64]) -> f64 {
        let mut pap = 0.0;
        let (lo, hi) = (self.ready_offsets[e] as usize, self.ready_offsets[e + 1] as usize);
        for gi in lo..hi {
            let group =
                &self.locals[self.offsets[gi] as usize..self.offsets[gi + 1] as usize];
            for &l in group {
                let l = l as usize;
                pap += c[l] * u[l] * w[l];
            }
        }
        let (lo, hi) =
            (self.interior_offsets[e] as usize, self.interior_offsets[e + 1] as usize);
        for &l in &self.interior[lo..hi] {
            let l = l as usize;
            pap += c[l] * u[l] * w[l];
        }
        pap
    }

    /// The mask pass: scale every dof whose mask value != 1.0. Run after
    /// all folds — bitwise identical to
    /// [`mask_apply`](crate::solver::mask_apply) on the full mask.
    pub fn apply_mask(&self, w: &mut [f64]) {
        for &(l, m) in &self.masked {
            w[l as usize] *= m;
        }
    }

    /// Whole-vector assembly (every fold, then the mask) — the reference
    /// the eager per-element schedule is tested against, and the path a
    /// caller without an element loop can use.
    pub fn assemble(&self, w: &mut [f64]) {
        for e in 0..self.nelt() {
            self.fold_ready(e, w);
        }
        self.apply_mask(w);
    }
}

/// The serial [`DomainExchange`](crate::solver::DomainExchange):
/// `exchange` is [`GatherScatter::dssum`] and the exchange support is
/// exactly [`GatherScatter::shared_dofs`]. This is what lets the one
/// generic CG driver run single-address-space solves — the rank runtime
/// plugs in its halo exchange behind the same trait.
impl crate::solver::DomainExchange for GatherScatter {
    fn exchange(&mut self, v: &mut [f64]) -> crate::error::Result<()> {
        self.dssum(v);
        Ok(())
    }

    fn shared_dofs(&self) -> &[u32] {
        GatherScatter::shared_dofs(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::Cases;

    fn mesh() -> Mesh {
        Mesh::new(2, 2, 2, 3).unwrap()
    }

    #[test]
    fn dssum_on_distinct_ids_is_identity() {
        let mut gs = GatherScatter::from_ids(vec![0, 1, 2, 3], 4);
        let mut v = vec![1.0, -2.0, 3.0, 0.5];
        let orig = v.clone();
        gs.dssum(&mut v);
        assert_eq!(v, orig);
    }

    #[test]
    fn dssum_sums_copies() {
        let mut gs = GatherScatter::from_ids(vec![0, 1, 0, 1], 2);
        let mut v = vec![1.0, 10.0, 2.0, 20.0];
        gs.dssum(&mut v);
        assert_eq!(v, vec![3.0, 30.0, 3.0, 30.0]);
    }

    #[test]
    fn dssum_preserves_global_sum_weighted() {
        // sum_l v_l / mult_l is invariant under dssum... actually
        // sum_global(gather(v)) is invariant; check that.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| (i as f64 * 0.7).sin()).collect();
        let before: f64 = gs.gather(&v).iter().sum();
        gs.dssum(&mut v);
        // After dssum, gather multiplies each global value by its multiplicity.
        let ones = vec![1.0; m.ndof_local()];
        let counts = gs.gather(&ones);
        let after: f64 = gs
            .gather(&v)
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| s / c)
            .sum();
        assert!((before - after).abs() < 1e-9 * before.abs().max(1.0));
    }

    #[test]
    fn dssum_idempotent_up_to_multiplicity() {
        // dssum(dssum(v)) == dssum(mult * ... ) — specifically for v already
        // summed, a second dssum multiplies each global value by mult.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| i as f64).collect();
        gs.dssum(&mut v);
        let summed = v.clone();
        gs.dssum(&mut v);
        let mult = gs.multiplicity();
        for ((a, b), m) in v.iter().zip(&summed).zip(&mult) {
            assert!((a - b * m).abs() < 1e-9, "{a} vs {b} * {m}");
        }
    }

    #[test]
    fn dssum_symmetric() {
        // <dssum(u), v> == <u, dssum(v)> : Q Q^T is symmetric.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut cases = Cases::new(0xD55);
        for _ in 0..10 {
            let u0 = cases.vec_normal(m.ndof_local());
            let v0 = cases.vec_normal(m.ndof_local());
            let mut u = u0.clone();
            let mut v = v0.clone();
            gs.dssum(&mut u);
            gs.dssum(&mut v);
            let lhs: f64 = u.iter().zip(&v0).map(|(a, b)| a * b).sum();
            let rhs: f64 = u0.iter().zip(&v).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0));
        }
    }

    #[test]
    fn shared_dofs_are_exactly_the_dssum_support() {
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mult = gs.multiplicity();
        let shared: std::collections::BTreeSet<usize> =
            gs.shared_dofs().iter().map(|&l| l as usize).collect();
        for (l, &mu) in mult.iter().enumerate() {
            assert_eq!(shared.contains(&l), mu > 1.0, "dof {l} mult {mu}");
        }
        // dssum never changes a value outside shared_dofs.
        let mut v: Vec<f64> = (0..m.ndof_local()).map(|i| (i as f64 * 0.3).cos()).collect();
        let before = v.clone();
        gs.dssum(&mut v);
        for (l, (a, b)) in before.iter().zip(&v).enumerate() {
            if !shared.contains(&l) {
                assert_eq!(a, b, "dssum changed unshared dof {l}");
            }
        }
    }

    #[test]
    fn multiplicity_matches_mesh() {
        let m = mesh();
        let gs = GatherScatter::new(&m);
        assert_eq!(gs.multiplicity(), m.multiplicity());
    }

    #[test]
    fn constant_field_fixed_point() {
        // A globally consistent field times multiplicity: dssum(1) = mult.
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v = vec![1.0; m.ndof_local()];
        gs.dssum(&mut v);
        assert_eq!(v, gs.multiplicity());
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let m = mesh();
        let mut gs = GatherScatter::new(&m);
        let mut v = vec![0.0; 3];
        gs.dssum(&mut v);
    }

    #[test]
    fn assembly_plan_assemble_is_bitwise_dssum_then_mask() {
        let m = mesh();
        let np = m.n * m.n * m.n;
        let mask = m.boundary_mask();
        let mut gs = GatherScatter::new(&m);
        let plan = gs.assembly_plan(np, Some(&mask)).unwrap();
        let mut cases = Cases::new(0xA5);
        for _ in 0..10 {
            let v0 = cases.vec_normal(m.ndof_local());
            let mut want = v0.clone();
            gs.dssum(&mut want);
            crate::solver::mask_apply(&mut want, &mask);
            let mut got = v0.clone();
            plan.assemble(&mut got);
            // Bitwise, not allclose: the fold order inside each group and
            // the mask multiply are identical operations.
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "assembled vector must be bit-identical to dssum+mask"
            );
        }
    }

    #[test]
    fn assembly_plan_eager_folds_cover_every_group_once() {
        // Folding per ready element must equal folding everything at the
        // end — same groups, different schedule.
        let m = mesh();
        let np = m.n * m.n * m.n;
        let mut gs = GatherScatter::new(&m);
        let plan = gs.assembly_plan(np, None).unwrap();
        assert_eq!(plan.nelt(), m.nelt());
        assert_eq!(plan.ndof(), m.ndof_local());
        let mut eager: Vec<f64> =
            (0..m.ndof_local()).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = eager.clone();
        gs.dssum(&mut want);
        for e in 0..plan.nelt() {
            plan.fold_ready(e, &mut eager);
        }
        assert!(
            eager.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "eager per-element folds must reproduce dssum bit for bit"
        );
        // Every group lands in the bucket of its highest copy's element —
        // fold_ready(e) must never read dofs beyond element e.
        for e in 0..plan.nelt() {
            let (lo, hi) =
                (plan.ready_offsets[e] as usize, plan.ready_offsets[e + 1] as usize);
            for gi in lo..hi {
                let group =
                    &plan.locals[plan.offsets[gi] as usize..plan.offsets[gi + 1] as usize];
                assert!(group.windows(2).all(|w| w[0] < w[1]), "copies ascending");
                assert_eq!(*group.last().unwrap() as usize / np, e, "ready element");
            }
        }
    }

    #[test]
    fn assembly_plan_pap_ready_sums_assembled_glsc3() {
        // Accumulating pap per finalized dof must equal the full
        // glsc3(assembled w, c, u) to roundoff.
        let m = mesh();
        let np = m.n * m.n * m.n;
        let mask = m.boundary_mask();
        let mut gs = GatherScatter::new(&m);
        let plan = gs.assembly_plan(np, Some(&mask)).unwrap();
        let c = m.inv_multiplicity();
        let mut cases = Cases::new(0xA6);
        let mut u = cases.vec_normal(m.ndof_local());
        crate::solver::mask_apply(&mut u, &mask);
        let mut w = cases.vec_normal(m.ndof_local());
        let mut pap = 0.0;
        for e in 0..plan.nelt() {
            plan.fold_ready(e, &mut w);
            pap += plan.pap_ready(e, &w, &u, &c);
        }
        plan.apply_mask(&mut w);
        let want: f64 = w.iter().zip(&c).zip(&u).map(|((w, c), u)| w * c * u).sum();
        assert!((pap - want).abs() <= 1e-12 * want.abs().max(1.0), "{pap} vs {want}");
    }

    #[test]
    fn assembly_plan_rejects_bad_shapes() {
        let m = mesh();
        let gs = GatherScatter::new(&m);
        let err = gs.assembly_plan(7, None).err().unwrap();
        assert!(err.to_string().contains("multiple of n^3"), "{err}");
        let err = gs.assembly_plan(m.n * m.n * m.n, Some(&[1.0; 3])).err().unwrap();
        assert!(err.to_string().contains("mask must be ndof"), "{err}");
    }
}
