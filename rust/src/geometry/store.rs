//! Storage precision for geometric factors — the mixed-precision seam.
//!
//! The Ax sweep is bandwidth-bound (the paper measures 77–92% of the
//! roofline), and six of its eight per-point streams are geometric
//! factors. HipBone (arXiv 2202.12477) showed that storing those factors
//! in f32 while keeping **all arithmetic and accumulation in f64** moves
//! the roofline itself: per grid point the unfused sweep drops from
//! 64 to 40 bytes (72 → 48 fused), raising arithmetic intensity by 8/5
//! (9/6 fused) at identical flop counts.
//!
//! This module is the one place that knows which widths exist:
//!
//! * [`GeomScalar`] — the sealed compile-time face. Kernels and operator
//!   shells are generic over it; `f64` is the identity instantiation
//!   (same codegen as before the refactor), `f32` converts once at
//!   operator `setup` and is widened back per element inside the kernels.
//! * [`Precision`] / [`GeomStore`] — the runtime face, for layers that
//!   pick a width from a name (the worker pool, the registry).
//!
//! Accumulation precision is **not** negotiable here by design: every
//! kernel computes in f64 regardless of the stored width, so the only
//! error introduced is the one f32 rounding of each factor at setup.
//! The conformance tier for this family (`ReducedStorage`) bounds
//! exactly that.

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// A scalar type geometric factors may be *stored* in. Sealed: the
/// conformance tiers and the stream accounting enumerate exactly these.
pub trait GeomScalar: sealed::Sealed + Copy + Send + Sync + 'static {
    /// Bytes each stored factor occupies (the stream-accounting input).
    const STORED_BYTES: u64;
    /// The runtime tag for this width.
    const PRECISION: Precision;
    /// Round a setup-time f64 factor to the stored width.
    fn from_f64(x: f64) -> Self;
    /// Widen a stored factor back to f64 for kernel arithmetic.
    fn widen(self) -> f64;
    /// Convert a full factor slice at setup (one-time cost).
    fn convert(g: &[f64]) -> Vec<Self> {
        g.iter().map(|&x| Self::from_f64(x)).collect()
    }
}

impl GeomScalar for f64 {
    const STORED_BYTES: u64 = 8;
    const PRECISION: Precision = Precision::F64;
    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline(always)]
    fn widen(self) -> f64 {
        self
    }
    fn convert(g: &[f64]) -> Vec<f64> {
        g.to_vec()
    }
}

impl GeomScalar for f32 {
    const STORED_BYTES: u64 = 4;
    const PRECISION: Precision = Precision::F32;
    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn widen(self) -> f64 {
        self as f64
    }
}

/// Widen one element's stored factors into an f64 scratch tile. For
/// `S = f64` this is a plain copy (and the f64 operators skip it
/// entirely); for `S = f32` it is the per-element widening step the
/// mixed-precision kernels run before the unchanged f64 arithmetic.
#[inline]
pub fn widen_into<S: GeomScalar>(src: &[S], dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len(), "widen_into: length mismatch");
    for (d, s) in dst.iter_mut().zip(src) {
        *d = s.widen();
    }
}

/// Runtime tag for a stored-factor width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// 8-byte factors — the historical default, bit-identical path.
    F64,
    /// 4-byte factors, f64 accumulation (HipBone-style mixed precision).
    F32,
}

impl Precision {
    /// Bytes per stored factor.
    pub fn stored_bytes(self) -> u64 {
        match self {
            Precision::F64 => 8,
            Precision::F32 => 4,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Precision::F64 => write!(f, "f64"),
            Precision::F32 => write!(f, "f32"),
        }
    }
}

/// Owned geometric-factor storage at a runtime-chosen width. The layers
/// that cannot be generic (the worker pool's per-worker slices, anything
/// resolved by registry name) hold one of these.
#[derive(Clone, Debug)]
pub enum GeomStore {
    F64(Vec<f64>),
    F32(Vec<f32>),
}

impl GeomStore {
    /// Convert setup-time f64 factors into the requested storage width —
    /// the *single* narrowing point of the whole pipeline.
    pub fn from_f64(g: &[f64], precision: Precision) -> Self {
        match precision {
            Precision::F64 => GeomStore::F64(g.to_vec()),
            Precision::F32 => GeomStore::F32(g.iter().map(|&x| x as f32).collect()),
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            GeomStore::F64(_) => Precision::F64,
            GeomStore::F32(_) => Precision::F32,
        }
    }

    /// Bytes per stored factor (stream-accounting input).
    pub fn stored_bytes(&self) -> u64 {
        self.precision().stored_bytes()
    }

    /// Number of stored factors.
    pub fn len(&self) -> usize {
        match self {
            GeomStore::F64(v) => v.len(),
            GeomStore::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_roundtrip_is_identity() {
        let g = [1.0, -2.5, 1e300, -1e-300, 0.0];
        let v = <f64 as GeomScalar>::convert(&g);
        for (a, b) in g.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut wide = vec![0.0; g.len()];
        widen_into(&v, &mut wide);
        for (a, b) in g.iter().zip(&wide) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_narrowing_is_one_rounding() {
        let g = [1.0 + 1e-10, std::f64::consts::PI, -0.1];
        let v = <f32 as GeomScalar>::convert(&g);
        let mut wide = vec![0.0; g.len()];
        widen_into(&v, &mut wide);
        for (orig, w) in g.iter().zip(&wide) {
            // One rounding to 24-bit mantissa: relative error <= 2^-24.
            assert!(
                (orig - w).abs() <= orig.abs() * 6.0e-8,
                "widened {w} too far from {orig}"
            );
            // And widening is exact (f32 -> f64 is lossless).
            assert_eq!(*w, (*orig as f32) as f64);
        }
    }

    #[test]
    fn store_tags_and_bytes() {
        let g = [1.0, 2.0, 3.0];
        let s64 = GeomStore::from_f64(&g, Precision::F64);
        let s32 = GeomStore::from_f64(&g, Precision::F32);
        assert_eq!(s64.precision(), Precision::F64);
        assert_eq!(s32.precision(), Precision::F32);
        assert_eq!(s64.stored_bytes(), 8);
        assert_eq!(s32.stored_bytes(), 4);
        assert_eq!(s64.len(), 3);
        assert_eq!(s32.len(), 3);
        assert!(!s32.is_empty());
        assert_eq!(Precision::F64.to_string(), "f64");
        assert_eq!(Precision::F32.to_string(), "f32");
    }

    #[test]
    fn scalar_consts_match_runtime_tags() {
        assert_eq!(<f64 as GeomScalar>::STORED_BYTES, Precision::F64.stored_bytes());
        assert_eq!(<f32 as GeomScalar>::STORED_BYTES, Precision::F32.stored_bytes());
        assert_eq!(<f64 as GeomScalar>::PRECISION, Precision::F64);
        assert_eq!(<f32 as GeomScalar>::PRECISION, Precision::F32);
    }
}
