//! Geometric factors for the spectral-element Poisson operator (Nekbone's
//! `setup_g`).
//!
//! For the mapping `x(r)` from the reference cube `[-1,1]^3` to a physical
//! element, the weak Poisson operator needs, at every GLL point,
//!
//! ```text
//! G_pq = w_i w_j w_k |J| * sum_m (dr_p/dx_m)(dr_q/dx_m),   p,q in {r,s,t}
//! ```
//!
//! stored in upper-triangular order `[G11, G12, G13, G22, G23, G33]` — the
//! `gxyz(i,j,k,1..6,e)` of the paper's Listing 1. The tensor is symmetric
//! positive definite for any non-degenerate mapping, which is what makes the
//! assembled operator SPD and CG applicable.
//!
//! Two construction paths:
//! * [`GeomFactors::affine`] — closed form for the box mesh (diagonal
//!   Jacobian; G12 = G13 = G23 = 0), what Nekbone's cube setup produces;
//! * [`GeomFactors::from_coordinates`] — the general curvilinear path: the
//!   coordinate fields are differentiated with the spectral `D`, the 3x3
//!   Jacobian is inverted per point. Used for deformed-mesh tests and as a
//!   cross-check of the closed form.

use crate::basis::Basis;
use crate::error::{Error, Result};
use crate::mesh::Mesh;

mod store;

pub use store::{widen_into, GeomScalar, GeomStore, Precision};

/// Geometric factors for every element, layout `[e][m][k][j][i]`, `m < 6`.
#[derive(Clone, Debug)]
pub struct GeomFactors {
    pub n: usize,
    pub nelt: usize,
    /// `nelt * 6 * n^3` values.
    pub g: Vec<f64>,
}

impl GeomFactors {
    /// Closed-form factors for the affine box mesh.
    pub fn affine(mesh: &Mesh, basis: &Basis) -> Self {
        let n = mesh.n;
        let nelt = mesh.nelt();
        let w = &basis.weights;
        let mut g = vec![0.0; nelt * 6 * n * n * n];
        for e in 0..nelt {
            let (lo, hi) = mesh.element_bounds(e);
            let hx = hi[0] - lo[0];
            let hy = hi[1] - lo[1];
            let hz = hi[2] - lo[2];
            let det_j = hx * hy * hz / 8.0;
            let rx = 2.0 / hx; // dr/dx
            let sy = 2.0 / hy;
            let tz = 2.0 / hz;
            let (g11, g22, g33) = (det_j * rx * rx, det_j * sy * sy, det_j * tz * tz);
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let wq = w[i] * w[j] * w[k];
                        let base = Self::index(n, e, 0, k, j, i);
                        let stride = n * n * n;
                        g[base] = wq * g11;
                        // G12, G13 stay zero
                        g[base + 3 * stride] = wq * g22;
                        // G23 stays zero
                        g[base + 5 * stride] = wq * g33;
                    }
                }
            }
        }
        GeomFactors { n, nelt, g }
    }

    /// General curvilinear factors from per-dof physical coordinates
    /// (local fields in the `(e,k,j,i)` layout, e.g. from
    /// [`Mesh::coordinates`], possibly deformed).
    pub fn from_coordinates(
        n: usize,
        nelt: usize,
        basis: &Basis,
        xs: &[f64],
        ys: &[f64],
        zs: &[f64],
    ) -> Result<Self> {
        let npts = n * n * n;
        if xs.len() != nelt * npts || ys.len() != nelt * npts || zs.len() != nelt * npts {
            return Err(Error::Config("coordinate field size mismatch".into()));
        }
        let d = &basis.d;
        let w = &basis.weights;
        let mut g = vec![0.0; nelt * 6 * npts];
        // Per-element scratch for the nine Jacobian entries.
        let mut jac = vec![[0.0f64; 9]; npts];
        for e in 0..nelt {
            let off = e * npts;
            // d(x,y,z)/d(r,s,t) by differentiating the coordinate fields.
            for (p, field) in [xs, ys, zs].iter().enumerate() {
                let f = &field[off..off + npts];
                for k in 0..n {
                    for j in 0..n {
                        for i in 0..n {
                            let (mut fr, mut fs, mut ft) = (0.0, 0.0, 0.0);
                            for l in 0..n {
                                fr += d[i * n + l] * f[(k * n + j) * n + l];
                                fs += d[j * n + l] * f[(k * n + l) * n + i];
                                ft += d[k * n + l] * f[(l * n + j) * n + i];
                            }
                            let idx = (k * n + j) * n + i;
                            jac[idx][p * 3] = fr;
                            jac[idx][p * 3 + 1] = fs;
                            jac[idx][p * 3 + 2] = ft;
                        }
                    }
                }
            }
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let idx = (k * n + j) * n + i;
                        let m = &jac[idx];
                        // m = [xr xs xt; yr ys yt; zr zs zt]
                        let det = m[0] * (m[4] * m[8] - m[5] * m[7])
                            - m[1] * (m[3] * m[8] - m[5] * m[6])
                            + m[2] * (m[3] * m[7] - m[4] * m[6]);
                        if det.abs() < 1e-14 {
                            return Err(Error::Numerical(format!(
                                "degenerate element {e} at point ({i},{j},{k}): |J| = {det}"
                            )));
                        }
                        // Inverse (dr/dx as rows: [rx ry rz; sx sy sz; tx ty tz]).
                        let inv_det = 1.0 / det;
                        let inv = [
                            (m[4] * m[8] - m[5] * m[7]) * inv_det,
                            (m[2] * m[7] - m[1] * m[8]) * inv_det,
                            (m[1] * m[5] - m[2] * m[4]) * inv_det,
                            (m[5] * m[6] - m[3] * m[8]) * inv_det,
                            (m[0] * m[8] - m[2] * m[6]) * inv_det,
                            (m[2] * m[3] - m[0] * m[5]) * inv_det,
                            (m[3] * m[7] - m[4] * m[6]) * inv_det,
                            (m[1] * m[6] - m[0] * m[7]) * inv_det,
                            (m[0] * m[4] - m[1] * m[3]) * inv_det,
                        ];
                        let wq = w[i] * w[j] * w[k] * det.abs();
                        let dot = |p: usize, q: usize| {
                            inv[p * 3] * inv[q * 3]
                                + inv[p * 3 + 1] * inv[q * 3 + 1]
                                + inv[p * 3 + 2] * inv[q * 3 + 2]
                        };
                        let stride = npts;
                        let base = Self::index(n, e, 0, k, j, i);
                        g[base] = wq * dot(0, 0);
                        g[base + stride] = wq * dot(0, 1);
                        g[base + 2 * stride] = wq * dot(0, 2);
                        g[base + 3 * stride] = wq * dot(1, 1);
                        g[base + 4 * stride] = wq * dot(1, 2);
                        g[base + 5 * stride] = wq * dot(2, 2);
                    }
                }
            }
        }
        Ok(GeomFactors { n, nelt, g })
    }

    /// Flat index of `g[e][m][k][j][i]`.
    #[inline]
    pub fn index(n: usize, e: usize, m: usize, k: usize, j: usize, i: usize) -> usize {
        (((e * 6 + m) * n + k) * n + j) * n + i
    }

    /// Slice of all six factors for one element.
    pub fn element(&self, e: usize) -> &[f64] {
        let len = 6 * self.n * self.n * self.n;
        &self.g[e * len..(e + 1) * len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(ex: usize, ey: usize, ez: usize, n: usize) -> (Mesh, Basis) {
        (Mesh::new(ex, ey, ez, n).unwrap(), Basis::new(n))
    }

    #[test]
    fn affine_matches_general_on_box() {
        let (mesh, basis) = setup(2, 3, 1, 5);
        let affine = GeomFactors::affine(&mesh, &basis);
        let (xs, ys, zs) = mesh.coordinates(&basis.points);
        let general =
            GeomFactors::from_coordinates(mesh.n, mesh.nelt(), &basis, &xs, &ys, &zs).unwrap();
        for (a, b) in affine.g.iter().zip(&general.g) {
            assert!((a - b).abs() < 1e-10, "affine {a} vs general {b}");
        }
    }

    #[test]
    fn affine_offdiagonals_zero() {
        let (mesh, basis) = setup(2, 2, 2, 4);
        let gf = GeomFactors::affine(&mesh, &basis);
        let n = mesh.n;
        for e in 0..mesh.nelt() {
            for m in [1usize, 2, 4] {
                for k in 0..n {
                    for j in 0..n {
                        for i in 0..n {
                            assert_eq!(gf.g[GeomFactors::index(n, e, m, k, j, i)], 0.0);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn factors_integrate_volume() {
        // sum over dofs of w_ijk |J| = volume of the domain. G11 has an
        // extra (dr/dx)^2; check via G11 * (hx/2)^2 summed = volume.
        let (mesh, basis) = setup(2, 2, 2, 6);
        let gf = GeomFactors::affine(&mesh, &basis);
        let n = mesh.n;
        let mut vol = 0.0;
        for e in 0..mesh.nelt() {
            let (lo, hi) = mesh.element_bounds(e);
            let hx = hi[0] - lo[0];
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        vol += gf.g[GeomFactors::index(n, e, 0, k, j, i)] * (hx / 2.0) * (hx / 2.0);
                    }
                }
            }
        }
        assert!((vol - 1.0).abs() < 1e-12, "volume {vol}");
    }

    #[test]
    fn general_path_spd_on_deformed_mesh() {
        // Smoothly deform the unit cube; the per-point 3x3 G must stay SPD.
        let (mesh, basis) = setup(2, 2, 2, 5);
        let (mut xs, mut ys, mut zs) = mesh.coordinates(&basis.points);
        for idx in 0..xs.len() {
            let (x, y, z) = (xs[idx], ys[idx], zs[idx]);
            xs[idx] = x + 0.05 * (std::f64::consts::PI * y).sin();
            ys[idx] = y + 0.05 * (std::f64::consts::PI * z).sin();
            zs[idx] = z + 0.05 * (std::f64::consts::PI * x).sin();
        }
        let gf =
            GeomFactors::from_coordinates(mesh.n, mesh.nelt(), &basis, &xs, &ys, &zs).unwrap();
        let n = mesh.n;
        let npts = n * n * n;
        for e in 0..mesh.nelt() {
            for p in 0..npts {
                let at = |m: usize| gf.g[(e * 6 + m) * npts + p];
                let (g11, g12, g13, g22, g23, g33) = (at(0), at(1), at(2), at(3), at(4), at(5));
                // Sylvester's criterion for the symmetric 3x3.
                assert!(g11 > 0.0);
                assert!(g11 * g22 - g12 * g12 > 0.0);
                let det = g11 * (g22 * g33 - g23 * g23) - g12 * (g12 * g33 - g23 * g13)
                    + g13 * (g12 * g23 - g22 * g13);
                assert!(det > 0.0, "e={e} p={p} det={det}");
            }
        }
    }

    #[test]
    fn degenerate_mapping_rejected() {
        let (mesh, basis) = setup(1, 1, 1, 3);
        let (xs, ys, _) = mesh.coordinates(&basis.points);
        let zs = vec![0.0; xs.len()]; // collapsed in z
        assert!(
            GeomFactors::from_coordinates(mesh.n, 1, &basis, &xs, &ys, &zs).is_err()
        );
    }

    #[test]
    fn element_slice() {
        let (mesh, basis) = setup(2, 1, 1, 3);
        let gf = GeomFactors::affine(&mesh, &basis);
        assert_eq!(gf.element(0).len(), 6 * 27);
        assert_eq!(gf.element(1)[0], gf.g[6 * 27]);
    }
}
