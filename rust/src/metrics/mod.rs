//! Cost model (paper Eqs. 1–2) and measurement primitives.
//!
//! The paper weights all floating-point operations equally and counts, per
//! CG iteration over `D = nelt * n^3` degrees of freedom:
//!
//! ```text
//! C(D, n) = D (12 n + 34) flops          (Eq. 1)
//! 24 D reads + 6 D writes (f64)          => 240 D bytes
//! I(n)    = (12 n + 34) / 240 flop/byte  (Eq. 2)
//! ```

/// The paper's cost model for one CG iteration.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// GLL points per dimension.
    pub n: usize,
    /// Degrees of freedom `D = nelt * n^3` (local, with duplicates — the
    /// paper counts local work).
    pub dof: usize,
}

impl CostModel {
    pub fn new(n: usize, nelt: usize) -> Self {
        CostModel { n, dof: nelt * n * n * n }
    }

    /// Eq. (1): flops per CG iteration.
    pub fn flops_per_iter(&self) -> u64 {
        self.dof as u64 * (12 * self.n as u64 + 34)
    }

    /// Reads per iteration in f64 values (24 D).
    pub fn reads_per_iter(&self) -> u64 {
        24 * self.dof as u64
    }

    /// Writes per iteration in f64 values (6 D).
    pub fn writes_per_iter(&self) -> u64 {
        6 * self.dof as u64
    }

    /// Bytes moved per iteration (f64).
    pub fn bytes_per_iter(&self) -> u64 {
        8 * (self.reads_per_iter() + self.writes_per_iter())
    }

    /// Eq. (2): computational intensity in flop/byte.
    pub fn intensity(&self) -> f64 {
        (12.0 * self.n as f64 + 34.0) / 240.0
    }

    /// Roofline performance in GFlop/s for a given bandwidth (GB/s):
    /// memory-bound, so `P = I * BW`.
    pub fn roofline_gflops(&self, bandwidth_gbs: f64) -> f64 {
        self.intensity() * bandwidth_gbs
    }
}

/// A single timed measurement with its work accounting.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub seconds: f64,
    pub flops: u64,
    pub bytes: u64,
}

impl Measurement {
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.seconds / 1e9
    }

    pub fn bandwidth_gbs(&self) -> f64 {
        self.bytes as f64 / self.seconds / 1e9
    }
}

/// Instrumented flop counter — lets the `cost_model` bench compare the
/// paper's formula against operations actually executed (experiment E4).
#[derive(Clone, Copy, Debug, Default)]
pub struct FlopCounter {
    pub flops: u64,
    pub reads: u64,
    pub writes: u64,
}

impl FlopCounter {
    /// Tensor stage of Ax: per grid point, 2x3 contractions of length n at
    /// 2 flops (mul+add) each stage, plus 15 flops applying G
    /// (9 mul + 6 add).
    pub fn count_ax_local(&mut self, n: usize, nelt: usize) {
        let d = (nelt * n * n * n) as u64;
        self.flops += d * (12 * n as u64 + 15);
        // u read once per contraction direction per stage is the naive
        // count; the paper's 24D read model counts streams: u, 6 g, w plus
        // CG vectors. Stream accounting happens in `count_cg_vectors`.
        self.reads += d * 7; // u + 6 geometric factors
        self.writes += d; // w
    }

    /// Vector algebra of one CG iteration: glsc3 x2 (3 flops each),
    /// add2s1/add2s2 x3 (2 flops each), preconditioner copy.
    pub fn count_cg_vectors(&mut self, ndof: usize) {
        let d = ndof as u64;
        self.flops += d * (2 * 3 + 3 * 2);
        self.reads += d * (2 * 3 + 3 * 2); // operands of the 5 ops
        self.writes += d * 4; // z, p, x, r
    }

    /// One full CG iteration.
    pub fn count_cg_iter(&mut self, n: usize, nelt: usize) {
        self.count_ax_local(n, nelt);
        self.count_cg_vectors(nelt * n * n * n);
    }
}

/// Wall-clock stopwatch.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers_degree9() {
        // n = 10: I = (120+34)/240 = 0.641666...
        let cm = CostModel::new(10, 1024);
        assert!((cm.intensity() - 154.0 / 240.0).abs() < 1e-15);
        assert_eq!(cm.dof, 1024 * 1000);
        assert_eq!(cm.flops_per_iter(), 1024 * 1000 * 154);
    }

    #[test]
    fn paper_theoretical_peaks() {
        // Paper section VI-B: with peak bandwidth, P100 (720 GB/s) -> 462
        // GFlop/s and V100 (900 GB/s) -> 577 GFlop/s at n = 10.
        let cm = CostModel::new(10, 1024);
        assert!((cm.roofline_gflops(720.0) - 462.0).abs() < 0.5);
        assert!((cm.roofline_gflops(900.0) - 577.5).abs() < 0.5);
    }

    #[test]
    fn bytes_per_iter() {
        let cm = CostModel::new(10, 2);
        assert_eq!(cm.bytes_per_iter(), 8 * 30 * 2000);
    }

    #[test]
    fn counter_close_to_formula() {
        // The instrumented count must land within ~15% of Eq. 1 (the paper
        // rounds the vector-op tail into the +34).
        let (n, nelt) = (10, 64);
        let mut fc = FlopCounter::default();
        fc.count_cg_iter(n, nelt);
        let formula = CostModel::new(n, nelt).flops_per_iter();
        let ratio = fc.flops as f64 / formula as f64;
        assert!((0.85..=1.15).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn measurement_units() {
        let m = Measurement { seconds: 2.0, flops: 4_000_000_000, bytes: 8_000_000_000 };
        assert_eq!(m.gflops(), 2.0);
        assert_eq!(m.bandwidth_gbs(), 4.0);
    }
}
