//! CPU implementations of the local Poisson operator (paper Listing 1).
//!
//! These serve three roles:
//! * the **CPU baseline** of the paper's Fig. 3 (Kebnekaise's 28-core node),
//!   here `ax_threaded`;
//! * the **oracle** the XLA artifacts are integration-tested against;
//! * the **naive baseline** whose structure mirrors the original
//!   global-memory GPU kernel (`ax_naive`).
//!
//! Layouts match the kernels: `u[e][k][j][i]`, `g[e][m][k][j][i]`,
//! `d[i][j]` row-major (see `python/compile/kernels/ref.py`).

mod naive;
mod layered;
mod threaded;

pub use layered::ax_layered;
pub use naive::ax_naive;
pub use threaded::ax_threaded;

/// Floating-point operations of one local-Ax application, counted exactly
/// as the paper's Eq. (1) does for the tensor part: `12 n + 15` flops per
/// grid point (6n mul-add in each contraction stage + 15 for the geometric
/// factors), times `nelt * n^3` points.
pub fn ax_flops(n: usize, nelt: usize) -> u64 {
    let per_point = 12 * n as u64 + 15;
    per_point * (nelt as u64) * (n as u64).pow(3)
}

/// Dispatchable CPU variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuVariant {
    /// Listing-1 structure with full-size intermediates ("global memory").
    Naive,
    /// Layer-by-layer sweep, the paper's schedule on CPU.
    Layered,
    /// Layered, parallelized over elements with std threads.
    Threaded,
}

impl CpuVariant {
    /// Apply the variant. `w` must be `nelt * n^3` and is overwritten.
    pub fn apply(
        &self,
        n: usize,
        nelt: usize,
        u: &[f64],
        d: &[f64],
        g: &[f64],
        w: &mut [f64],
    ) {
        match self {
            CpuVariant::Naive => ax_naive(n, nelt, u, d, g, w),
            CpuVariant::Layered => ax_layered(n, nelt, u, d, g, w),
            CpuVariant::Threaded => ax_threaded(n, nelt, u, d, g, w, 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{assert_allclose, Cases};

    /// Scalar, index-literal transcription of paper Listing 1 — slow and
    /// obviously correct; the oracle for the optimized versions.
    pub fn ax_listing1(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64]) -> Vec<f64> {
        let np = n * n * n;
        let uat = |e: usize, k: usize, j: usize, i: usize| u[((e * n + k) * n + j) * n + i];
        let gat = |e: usize, m: usize, k: usize, j: usize, i: usize| {
            g[(((e * 6 + m) * n + k) * n + j) * n + i]
        };
        let dat = |i: usize, l: usize| d[i * n + l];
        let mut ur = vec![0.0; nelt * np];
        let mut us = vec![0.0; nelt * np];
        let mut ut = vec![0.0; nelt * np];
        for e in 0..nelt {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let (mut wr, mut ws, mut wt) = (0.0, 0.0, 0.0);
                        for l in 0..n {
                            wr += dat(i, l) * uat(e, k, j, l);
                            ws += dat(j, l) * uat(e, k, l, i);
                            wt += dat(k, l) * uat(e, l, j, i);
                        }
                        let idx = ((e * n + k) * n + j) * n + i;
                        ur[idx] = gat(e, 0, k, j, i) * wr + gat(e, 1, k, j, i) * ws
                            + gat(e, 2, k, j, i) * wt;
                        us[idx] = gat(e, 1, k, j, i) * wr + gat(e, 3, k, j, i) * ws
                            + gat(e, 4, k, j, i) * wt;
                        ut[idx] = gat(e, 2, k, j, i) * wr + gat(e, 4, k, j, i) * ws
                            + gat(e, 5, k, j, i) * wt;
                    }
                }
            }
        }
        let urat = |e: usize, k: usize, j: usize, i: usize| ur[((e * n + k) * n + j) * n + i];
        let usat = |e: usize, k: usize, j: usize, i: usize| us[((e * n + k) * n + j) * n + i];
        let utat = |e: usize, k: usize, j: usize, i: usize| ut[((e * n + k) * n + j) * n + i];
        let mut w = vec![0.0; nelt * np];
        for e in 0..nelt {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let mut acc = 0.0;
                        for l in 0..n {
                            // dxtm1(i,l) = d(l,i)
                            acc += dat(l, i) * urat(e, k, j, l);
                            acc += dat(l, j) * usat(e, k, l, i);
                            acc += dat(l, k) * utat(e, l, j, i);
                        }
                        w[((e * n + k) * n + j) * n + i] = acc;
                    }
                }
            }
        }
        w
    }

    fn random_inputs(c: &mut Cases, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let u = c.vec_normal(nelt * n * n * n);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * n * n * n);
        (u, d, g)
    }

    #[test]
    fn all_variants_match_listing1() {
        crate::proputil::forall(0xAE, 12, |c| {
            let n = c.size(2, 8);
            let nelt = c.size(1, 4);
            let (u, d, g) = random_inputs(c, n, nelt);
            let want = ax_listing1(n, nelt, &u, &d, &g);
            for variant in [CpuVariant::Naive, CpuVariant::Layered, CpuVariant::Threaded] {
                let mut w = vec![0.0; nelt * n * n * n];
                variant.apply(n, nelt, &u, &d, &g, &mut w);
                assert_allclose(&w, &want, 1e-11, 1e-11);
            }
        });
    }

    #[test]
    fn paper_configuration_n10() {
        let mut c = Cases::new(0xBEEF);
        let (n, nelt) = (10, 4);
        let (u, d, g) = random_inputs(&mut c, n, nelt);
        let want = ax_listing1(n, nelt, &u, &d, &g);
        for variant in [CpuVariant::Naive, CpuVariant::Layered, CpuVariant::Threaded] {
            let mut w = vec![0.0; nelt * n * n * n];
            variant.apply(n, nelt, &u, &d, &g, &mut w);
            assert_allclose(&w, &want, 1e-11, 1e-11);
        }
    }

    #[test]
    fn constant_field_maps_to_zero() {
        let (n, nelt) = (6, 2);
        let mut c = Cases::new(1);
        let u = vec![1.0; nelt * n * n * n];
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * n * n * n);
        for variant in [CpuVariant::Naive, CpuVariant::Layered, CpuVariant::Threaded] {
            let mut w = vec![1.0; nelt * n * n * n];
            variant.apply(n, nelt, &u, &d, &g, &mut w);
            assert!(w.iter().all(|&x| x.abs() < 1e-9));
        }
    }

    #[test]
    fn flop_count_formula() {
        assert_eq!(ax_flops(10, 1), (120 + 15) * 1000);
        assert_eq!(ax_flops(2, 3), (24 + 15) * 3 * 8);
    }
}
