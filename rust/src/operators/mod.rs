//! The operator layer: the local Poisson operator (paper Listing 1) behind
//! one object-safe abstraction.
//!
//! The paper's contribution is a *family* of interchangeable tensor-product
//! kernel schedules (original, shared, layered, unrolled) measured against
//! each other; this module makes that family open-ended. Three pieces:
//!
//! * the raw CPU kernels ([`ax_naive`], [`ax_layered`], [`ax_threaded`],
//!   the degree-specialized [`ax_spec`] / [`ax_spec_fused`] family, and the
//!   explicit-SIMD [`ax_simd`] / [`ax_simd_fused`] family with runtime
//!   AVX2+FMA dispatch) — the Fig. 3 CPU baseline and the parity oracle
//!   for the XLA artifacts;
//! * the [`AxOperator`] trait — one `apply(u, w)` interface over every
//!   implementation, CPU or AOT-compiled;
//! * the [`registry::OperatorRegistry`] — string names → constructors, so
//!   backend selection is data, not a `match`.
//!
//! Layouts match the kernels: `u[e][k][j][i]`, `g[e][m][k][j][i]`,
//! `d[i][j]` row-major (see `python/compile/kernels/ref.py`).
//!
//! ## Adding a backend
//!
//! A new schedule variant (SIMD, cached-plan, sharded, a future GPU path)
//! plugs in without touching any dispatch site:
//!
//! 1. Implement [`AxOperator`]. `setup` receives an [`OperatorCtx`] with the
//!    problem shape and the mesh data (`d`, `g`, `c`); clone what `apply`
//!    needs. `apply` computes `w = A_local u` — no dssum, no mask; the
//!    solver applies those.
//! 2. Register a constructor under a unique kebab-case name:
//!    `registry.register("my-op", false, || Box::new(MyOp::default()))`.
//! 3. Build through the application builder:
//!    `Nekbone::builder(cfg).registry(registry).operator("my-op").build()`.
//!
//! Every consumer — the CLI, the CG solver, the simulated-rank runtime, the
//! paper-figure benches — resolves operators by name through the registry,
//! so a registered variant is immediately runnable everywhere.
//!
//! ## The fused-operator contract
//!
//! An operator that returns `true` from [`AxOperator::is_fused`] promises
//! to compute the CG reduction in the same pass as the operator itself
//! (`cpu-layered-fused`, `cpu-threaded-fused`, `xla-fused-layered`), and
//! the one shared solver ([`cg_solve`](crate::solver::cg_solve) — serial
//! and ranked alike) then **skips the separate full-length
//! `glsc3(w, c, p)` sweep**. The promise, precisely:
//!
//! * After every successful `apply(u, w)`, [`AxOperator::last_pap`] is
//!   `Some(Σ_i w_i · c_i · u_i)` over the operator's **local, pre-dssum**
//!   output, with `c` as captured from [`OperatorCtx::c`] at `setup` (fused
//!   operators must reject an empty/mis-sized `c`). Before the first
//!   `apply` it is `None`.
//! * Determinism: for a fixed setup, the same `u` must reproduce the same
//!   `pap` bit for bit, run to run. Parallel implementations reduce
//!   per-worker partial sums in element order (see
//!   [`pool::WorkerPool::run`]) rather than in completion order.
//! * Callers must set the operator up with the **same** `c` they pass to
//!   the solve as inner-product weights: the solver turns the local fused
//!   value into the assembled `glsc3(dssum(w), c, p)` by patching only the
//!   gather–scatter's shared dofs (an O(surface) correction), which is only
//!   exact when the two weight vectors agree and the iterate `p` is zero on
//!   masked dofs (true for every CG iterate).

pub(crate) mod asm;
pub(crate) mod fused;
mod layered;
mod naive;
pub(crate) mod pool;
pub mod registry;
pub mod simd;
pub mod specialized;
mod threaded;

pub use fused::{ax_layered_fused, ax_layered_fused_store};
pub use layered::{ax_layered, ax_layered_store};
pub use naive::ax_naive;
pub use pool::{resolve_threads, WorkerPool};
pub use registry::{registry, OperatorRegistry, OperatorSpec, PrecisionTier};
pub use simd::{
    ax_simd, ax_simd_f32, ax_simd_f32_with_arm, ax_simd_fused, ax_simd_fused_f32,
    ax_simd_fused_f32_with_arm, ax_simd_fused_with_arm, ax_simd_with_arm, simd_arm, SimdArm,
};
pub use specialized::{
    ax_spec, ax_spec_fused, ax_spec_fused_store, ax_spec_store, is_specialized, SPEC_MAX_N,
    SPEC_MIN_N,
};
pub use threaded::ax_threaded;

use std::sync::Arc;

use crate::error::Result;
use crate::runtime::XlaRuntime;

/// Floating-point operations of one **unfused** local-Ax application,
/// counted exactly as the paper's Eq. (1) does for the tensor part:
/// `12 n + 15` flops per grid point (6n mul-add in each contraction stage
/// + 15 for the geometric factors), times `nelt * n^3` points.
pub fn ax_flops(n: usize, nelt: usize) -> u64 {
    let per_point = 12 * n as u64 + 15;
    per_point * (nelt as u64) * (n as u64).pow(3)
}

/// Floating-point operations of one **fused** Ax+pap application: the
/// tensor part ([`ax_flops`]) plus the in-kernel reduction — `w·c·u` is
/// 2 multiplies + 1 add per grid point. Fused operators must report this
/// from [`AxOperator::flops`] (the roofline harness asserts it); counting
/// only [`ax_flops`] would understate the work the kernel actually does.
pub fn fused_ax_flops(n: usize, nelt: usize) -> u64 {
    ax_flops(n, nelt) + 3 * (nelt as u64) * (n as u64).pow(3)
}

/// Minimum main-memory traffic of one **assembled** Ax application in
/// bytes, under stream accounting (each operand array is read or written
/// once; `d` and the per-layer tiles are cache-resident), parameterized
/// by the **storage width of the geometric factors**: the kernel streams
/// `u` (1 read, always f64), the six geometric-factor arrays (6 reads at
/// `stored_bytes` each) and `w` (1 write, always f64), plus the fused `c`
/// read (f64). This is what the `cpu-asm` family moves: assembly happens
/// inside the sweep (the fold groups are O(surface) and cache-hot), so no
/// separate pass over `w` remains. At `stored_bytes = 8` that is 64 bytes
/// per point (72 fused); at `stored_bytes = 4` six streams halve, 40 (48
/// fused).
pub fn ax_bytes_moved_assembled(
    n: usize,
    nelt: usize,
    fused: bool,
    stored_bytes: u64,
) -> u64 {
    // u read + w write (f64) + six g streams at the stored width + fused c.
    let per_point: u64 = 16 + 6 * stored_bytes + if fused { 8 } else { 0 };
    per_point * (nelt as u64) * (n as u64).pow(3)
}

/// Minimum main-memory traffic of one local-Ax application **plus the
/// standalone dssum + mask pass the solver must then run** to assemble
/// it: [`ax_bytes_moved_assembled`] plus one full re-read and re-write of
/// `w` (16 bytes per point). This is the honest per-iteration cost of
/// every operator that leaves assembly to the solver — 80 bytes per point
/// unfused f64 (88 fused), 56 f32-storage (64 fused) — and the
/// denominator of those operators' arithmetic intensity in the measured
/// roofline ([`crate::bench::roofline`]). The `cpu-asm` family skips the
/// extra pass and reports [`ax_bytes_moved_assembled`] instead; the
/// pinned intensity ratios (80/64, 88/72, 56/40, 64/48) are what the
/// roofline tests assert.
pub fn ax_bytes_moved_stored(n: usize, nelt: usize, fused: bool, stored_bytes: u64) -> u64 {
    // Kernel streams + the separate assembly pass re-streaming w
    // (1 read + 1 write of every dof).
    ax_bytes_moved_assembled(n, nelt, fused, stored_bytes)
        + 16 * (nelt as u64) * (n as u64).pow(3)
}

/// [`ax_bytes_moved_stored`] at the historical all-f64 storage width
/// (8-byte geometric factors). Kept as the stable entry point for callers
/// that predate mixed-precision storage.
pub fn ax_bytes_moved(n: usize, nelt: usize, fused: bool) -> u64 {
    ax_bytes_moved_stored(n, nelt, fused, 8)
}

/// Floating-point operations of one whole CG **iteration**: the Ax
/// application plus the solver's vector algebra — `rtz = glsc3(r,c,z)` (3),
/// `p = z + beta·p` (2), `x += alpha·p` (2), `r -= alpha·w` (2) flops per
/// dof, plus `pap = glsc3(w,c,p)` (3) when the operator is not fused (a
/// fused Ax already counts that reduction in [`fused_ax_flops`]). The total
/// is identical for fused and unfused — and for blocked and unblocked,
/// which only reorder the same arithmetic — so a `cg-iteration` roofline
/// point's intensity moves purely through [`cg_bytes_moved`].
pub fn cg_flops(n: usize, nelt: usize, fused: bool) -> u64 {
    let ndof = (nelt as u64) * (n as u64).pow(3);
    let (ax, vec_per_dof) =
        if fused { (fused_ax_flops(n, nelt), 9) } else { (ax_flops(n, nelt), 12) };
    ax + vec_per_dof * ndof
}

/// Minimum main-memory traffic of one whole CG **iteration** in bytes,
/// under the same stream accounting as the Ax models (8 bytes per f64
/// read or write), parameterized by the geometric factors' storage width.
///
/// The Ax part is [`ax_bytes_moved_assembled`] when the operator folds
/// assembly into its sweep and [`ax_bytes_moved_stored`] otherwise. The
/// vector part streams, per dof:
///
/// * head/tail work: z production (read r, write z: 16) + rtz `glsc3`
///   (read r,c,z: 24) + the two `add2s2` (read p,w + read/write x,r: 48)
///   — 88 bytes unblocked. The cache-blocked pipeline fuses those four
///   passes into one walk, so r is read once and z never leaves cache
///   between production and the rtz partials: 64.
/// * `add2s1` (read z + read/write p): 24 in either mode.
/// * plus 24 (read w,c,p) for the standalone pap reduction when `fused`
///   is false.
///
/// So the vector part is 136/112 unfused and 112/88 fused
/// (unblocked/blocked) — cache-blocking removes 24 bytes per dof per
/// iteration in either mode, which is what the `cg-iteration` roofline
/// family visualizes.
pub fn cg_bytes_moved_stored(
    n: usize,
    nelt: usize,
    fused: bool,
    assembled: bool,
    blocked: bool,
    stored_bytes: u64,
) -> u64 {
    let ax = if assembled {
        ax_bytes_moved_assembled(n, nelt, fused, stored_bytes)
    } else {
        ax_bytes_moved_stored(n, nelt, fused, stored_bytes)
    };
    let vec_per_dof: u64 =
        if blocked { 64 } else { 88 } + 24 + if fused { 0 } else { 24 };
    ax + vec_per_dof * (nelt as u64) * (n as u64).pow(3)
}

/// [`cg_bytes_moved_stored`] at the all-f64 storage width — the
/// per-iteration stream model behind the `cg-iteration` roofline points.
pub fn cg_bytes_moved(n: usize, nelt: usize, fused: bool, assembled: bool, blocked: bool) -> u64 {
    cg_bytes_moved_stored(n, nelt, fused, assembled, blocked, 8)
}

/// Everything an operator needs to bind itself to one problem: the shape,
/// the launch chunking, and the mesh data. Borrowed — implementations clone
/// (or upload) what `apply` will need, so during `setup` the caller's copy
/// of `g` and the operator's coexist; callers drop theirs right after
/// (the builder drops `geom`, the rank runtime clears `slab.g`).
pub struct OperatorCtx<'a> {
    /// GLL points per dimension.
    pub n: usize,
    /// Local element count.
    pub nelt: usize,
    /// Elements per accelerator launch (ignored by CPU operators).
    pub chunk: usize,
    /// Worker threads for threaded operators (0 = all cores).
    pub threads: usize,
    /// Directory holding `manifest.json` + AOT artifacts.
    pub artifacts_dir: &'a str,
    /// Differentiation matrix, `n * n`, row-major.
    pub d: &'a [f64],
    /// Geometric factors, `nelt * 6 * n^3`.
    pub g: &'a [f64],
    /// Inverse multiplicity (inner-product weights), `nelt * n^3`.
    pub c: &'a [f64],
    /// Ownership/fold plan for operators that perform dssum + mask inside
    /// the sweep (the `cpu-asm` family). `None` for every other caller —
    /// and for solves where in-sweep assembly would be wrong (`--no-comm`,
    /// multi-rank bricks whose halo exchange needs raw pre-assembly
    /// copies); assembly-capable operators then fall back to the plain
    /// sweep. Operators that do not assemble ignore the field entirely.
    pub assemble: Option<&'a crate::gs::AssemblyPlan>,
}

/// Validate the mesh-data shapes of an [`OperatorCtx`] at `setup`; fused
/// operators additionally require the inner-product weights `c` (their
/// `last_pap` contract needs them).
pub(crate) fn check_setup_shapes(ctx: &OperatorCtx, need_c: bool) -> Result<()> {
    let np = ctx.n * ctx.n * ctx.n;
    if ctx.d.len() != ctx.n * ctx.n {
        return Err(crate::error::Error::Config(format!(
            "operator setup: d must be n*n = {}, got {}",
            ctx.n * ctx.n,
            ctx.d.len()
        )));
    }
    if ctx.g.len() != ctx.nelt * 6 * np {
        return Err(crate::error::Error::Config(format!(
            "operator setup: g must be nelt*6*n^3 = {}, got {}",
            ctx.nelt * 6 * np,
            ctx.g.len()
        )));
    }
    if need_c && ctx.c.len() != ctx.nelt * np {
        return Err(crate::error::Error::Config(format!(
            "operator setup: fused operators need the inner-product weights \
             c (nelt*n^3 = {}), got {}",
            ctx.nelt * np,
            ctx.c.len()
        )));
    }
    Ok(())
}

/// Validate the field lengths of one `apply` call.
pub(crate) fn check_apply_shapes(n: usize, nelt: usize, u: &[f64], w: &[f64]) -> Result<()> {
    let ndof = nelt * n * n * n;
    if u.len() != ndof || w.len() != ndof {
        return Err(crate::error::Error::Config(format!(
            "operator apply: fields must be nelt*n^3 = {ndof}, got u={} w={}",
            u.len(),
            w.len()
        )));
    }
    Ok(())
}

/// One local-Ax implementation: `apply` computes `w = A_local(u)` over the
/// whole local mesh (`nelt * n^3` dofs), with no dssum and no mask — the
/// solver layers those on top.
///
/// Object-safe by design: the application, the rank runtime, and the
/// benches all hold a `Box<dyn AxOperator>` built by name through the
/// [`OperatorRegistry`], so adding an implementation never touches a
/// dispatch site.
///
/// `Send` is a supertrait: the serve layer builds operators on an
/// acceptor thread and hands the owning session to a shard worker, so
/// every implementation must be movable across threads. Operators that
/// keep worker pools satisfy this by holding only channel endpoints and
/// join handles (see [`pool::WorkerPool`]); the XLA operators share their
/// runtime through `Arc`.
pub trait AxOperator: Send {
    /// Stable display name; for registered operators this is the canonical
    /// registry name, so it parses back to the same operator.
    fn label(&self) -> String;

    /// Bind to one problem: validate shapes, clone/upload mesh data,
    /// compile/load artifacts. Must be called before `apply`.
    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()>;

    /// `w <- A_local(u)`. Both slices are `nelt * n^3` as given at setup.
    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()>;

    /// Flops of one `apply` (0 before `setup`): [`ax_flops`] for plain
    /// operators, [`fused_ax_flops`] for fused ones — the in-kernel pap
    /// multiply-adds are real work and must be counted.
    fn flops(&self) -> u64;

    /// Minimum main-memory bytes one `apply` moves under stream accounting
    /// (see [`ax_bytes_moved`]); 0 before `setup`, or when the
    /// implementation does not model its traffic. The roofline harness
    /// divides [`AxOperator::flops`] by this to place the operator on the
    /// measured roofline.
    fn bytes_moved(&self) -> u64 {
        0
    }

    /// Does `apply` also compute the CG `pap` reduction in the same pass
    /// (the fused hot path)? Fused operators make [`AxOperator::last_pap`]
    /// available after each `apply`.
    fn is_fused(&self) -> bool {
        false
    }

    /// The fused `pap = sum(w * c * u)` from the most recent `apply`;
    /// `None` for unfused operators or before the first application.
    fn last_pap(&self) -> Option<f64> {
        None
    }

    /// Does `apply` also perform the domain assembly (dssum + mask) inside
    /// its sweep? When `true`, the output of `apply` is already
    /// `mask(dssum(A_local u))` and the solver must **skip** its
    /// standalone exchange + mask (and, for fused operators, consume
    /// [`AxOperator::last_pap`] as the assembled value with no shared-dof
    /// correction). Only meaningful after `setup`: the `cpu-asm` family
    /// answers `true` exactly when [`OperatorCtx::assemble`] supplied a
    /// plan.
    fn applies_assembly(&self) -> bool {
        false
    }

    /// The PJRT runtime backing this operator, when there is one (lets the
    /// vector-algebra offload share the operator's client and buffers).
    fn xla_runtime(&self) -> Option<Arc<XlaRuntime>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{assert_allclose, Cases};

    /// Scalar, index-literal transcription of paper Listing 1 — slow and
    /// obviously correct; the oracle for the optimized versions.
    pub fn ax_listing1(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64]) -> Vec<f64> {
        let np = n * n * n;
        let uat = |e: usize, k: usize, j: usize, i: usize| u[((e * n + k) * n + j) * n + i];
        let gat = |e: usize, m: usize, k: usize, j: usize, i: usize| {
            g[(((e * 6 + m) * n + k) * n + j) * n + i]
        };
        let dat = |i: usize, l: usize| d[i * n + l];
        let mut ur = vec![0.0; nelt * np];
        let mut us = vec![0.0; nelt * np];
        let mut ut = vec![0.0; nelt * np];
        for e in 0..nelt {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let (mut wr, mut ws, mut wt) = (0.0, 0.0, 0.0);
                        for l in 0..n {
                            wr += dat(i, l) * uat(e, k, j, l);
                            ws += dat(j, l) * uat(e, k, l, i);
                            wt += dat(k, l) * uat(e, l, j, i);
                        }
                        let idx = ((e * n + k) * n + j) * n + i;
                        ur[idx] = gat(e, 0, k, j, i) * wr + gat(e, 1, k, j, i) * ws
                            + gat(e, 2, k, j, i) * wt;
                        us[idx] = gat(e, 1, k, j, i) * wr + gat(e, 3, k, j, i) * ws
                            + gat(e, 4, k, j, i) * wt;
                        ut[idx] = gat(e, 2, k, j, i) * wr + gat(e, 4, k, j, i) * ws
                            + gat(e, 5, k, j, i) * wt;
                    }
                }
            }
        }
        let urat = |e: usize, k: usize, j: usize, i: usize| ur[((e * n + k) * n + j) * n + i];
        let usat = |e: usize, k: usize, j: usize, i: usize| us[((e * n + k) * n + j) * n + i];
        let utat = |e: usize, k: usize, j: usize, i: usize| ut[((e * n + k) * n + j) * n + i];
        let mut w = vec![0.0; nelt * np];
        for e in 0..nelt {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let mut acc = 0.0;
                        for l in 0..n {
                            // dxtm1(i,l) = d(l,i)
                            acc += dat(l, i) * urat(e, k, j, l);
                            acc += dat(l, j) * usat(e, k, l, i);
                            acc += dat(l, k) * utat(e, l, j, i);
                        }
                        w[((e * n + k) * n + j) * n + i] = acc;
                    }
                }
            }
        }
        w
    }

    fn random_inputs(c: &mut Cases, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let u = c.vec_normal(nelt * n * n * n);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * n * n * n);
        (u, d, g)
    }

    /// Build every registered CPU operator (fused ones included — their
    /// `w` output must match Listing 1 exactly like the unfused ones) for
    /// the given inputs. Enumerated from the registry, not a name list, so
    /// a newly registered artifact-free operator is covered automatically.
    fn cpu_operators(
        n: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
    ) -> Vec<Box<dyn AxOperator>> {
        let reg = OperatorRegistry::with_builtins();
        // Unit weights satisfy the fused operators' setup requirement; the
        // unfused ones ignore them.
        let c = vec![1.0; nelt * n * n * n];
        let ctx = OperatorCtx {
            n,
            nelt,
            chunk: nelt.max(1),
            threads: 0,
            artifacts_dir: "artifacts",
            d,
            g,
            c: &c,
            assemble: None,
        };
        let ops: Vec<Box<dyn AxOperator>> = reg
            .names()
            .iter()
            .filter(|name| !reg.resolve(name).unwrap().needs_artifacts)
            .map(|name| reg.build(name, &ctx).expect("cpu operator setup"))
            .collect();
        assert!(ops.len() >= 21, "registry lost CPU operators ({} left)", ops.len());
        ops
    }

    /// Tier-aware closeness check against the Listing 1 oracle: operators
    /// that store the geometric factors in f32 (the `-f32` family) are held
    /// to the cancellation-robust reduced-storage band
    /// `1e-5 * (|want| + max|want|)`; every f64-storage operator stays in
    /// the strict FMA band.
    fn assert_matches_oracle(op: &dyn AxOperator, got: &[f64], want: &[f64]) {
        if op.label().ends_with("-f32") {
            let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
            for (idx, (a, b)) in got.iter().zip(want).enumerate() {
                let tol = 1e-5 * (b.abs() + scale);
                assert!(
                    (a - b).abs() <= tol,
                    "{} point {idx}: {a} vs {b} (tol {tol:e})",
                    op.label()
                );
            }
        } else {
            assert_allclose(got, want, 1e-11, 1e-11);
        }
    }

    #[test]
    fn all_variants_match_listing1() {
        crate::proputil::forall(0xAE, 12, |c| {
            let n = c.size(2, 8);
            let nelt = c.size(1, 4);
            let (u, d, g) = random_inputs(c, n, nelt);
            let want = ax_listing1(n, nelt, &u, &d, &g);
            for mut op in cpu_operators(n, nelt, &d, &g) {
                let mut w = vec![0.0; nelt * n * n * n];
                op.apply(&u, &mut w).unwrap();
                assert_matches_oracle(op.as_ref(), &w, &want);
            }
        });
    }

    #[test]
    fn paper_configuration_n10() {
        let mut c = Cases::new(0xBEEF);
        let (n, nelt) = (10, 4);
        let (u, d, g) = random_inputs(&mut c, n, nelt);
        let want = ax_listing1(n, nelt, &u, &d, &g);
        for mut op in cpu_operators(n, nelt, &d, &g) {
            let mut w = vec![0.0; nelt * n * n * n];
            op.apply(&u, &mut w).unwrap();
            assert_matches_oracle(op.as_ref(), &w, &want);
        }
    }

    #[test]
    fn constant_field_maps_to_zero() {
        let (n, nelt) = (6, 2);
        let mut c = Cases::new(1);
        let u = vec![1.0; nelt * n * n * n];
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * n * n * n);
        for mut op in cpu_operators(n, nelt, &d, &g) {
            let mut w = vec![1.0; nelt * n * n * n];
            op.apply(&u, &mut w).unwrap();
            assert!(w.iter().all(|&x| x.abs() < 1e-9), "{}", op.label());
        }
    }

    #[test]
    fn operator_flops_match_formula() {
        // Fused operators do the pap multiply-adds inside the kernel, so
        // their per-apply count is the fused formula, not the plain one.
        let (n, nelt) = (5, 3);
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; nelt * 6 * n * n * n];
        for op in cpu_operators(n, nelt, &d, &g) {
            let want =
                if op.is_fused() { fused_ax_flops(n, nelt) } else { ax_flops(n, nelt) };
            assert_eq!(op.flops(), want, "{}", op.label());
        }
    }

    #[test]
    fn operator_bytes_match_stream_accounting() {
        let (n, nelt) = (5, 3);
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; nelt * 6 * n * n * n];
        for op in cpu_operators(n, nelt, &d, &g) {
            let stored = if op.label().ends_with("-f32") { 4 } else { 8 };
            let want = ax_bytes_moved_stored(n, nelt, op.is_fused(), stored);
            assert_eq!(op.bytes_moved(), want, "{}", op.label());
        }
    }

    #[test]
    fn flop_count_formula() {
        assert_eq!(ax_flops(10, 1), (120 + 15) * 1000);
        assert_eq!(ax_flops(2, 3), (24 + 15) * 3 * 8);
        // Fused adds 3 flops (2 mul + 1 add) per grid point.
        assert_eq!(fused_ax_flops(10, 1), (120 + 15 + 3) * 1000);
        // Assembled stream accounting: 8 f64 kernel streams per point,
        // 9 fused — what the cpu-asm family moves.
        assert_eq!(ax_bytes_moved_assembled(10, 1, false, 8), 8 * 8 * 1000);
        assert_eq!(ax_bytes_moved_assembled(10, 1, true, 8), 8 * 9 * 1000);
        // Every other operator additionally pays the standalone dssum+mask
        // pass: +2 f64 streams of w, 80 bytes per point (88 fused).
        assert_eq!(ax_bytes_moved(10, 1, false), 8 * 10 * 1000);
        assert_eq!(ax_bytes_moved(10, 1, true), 8 * 11 * 1000);
        // The f64 wrapper is exactly the stored-width formula at 8 bytes.
        assert_eq!(ax_bytes_moved_stored(10, 1, false, 8), ax_bytes_moved(10, 1, false));
        assert_eq!(ax_bytes_moved_stored(10, 1, true, 8), ax_bytes_moved(10, 1, true));
        // f32 factor storage: 6 of the kernel streams halve, 80 -> 56
        // bytes per point unfused (88 -> 64 fused); assembled 40 (48).
        assert_eq!(ax_bytes_moved_stored(10, 1, false, 4), 56 * 1000);
        assert_eq!(ax_bytes_moved_stored(10, 1, true, 4), 64 * 1000);
        assert_eq!(ax_bytes_moved_assembled(10, 1, false, 4), 40 * 1000);
        assert_eq!(ax_bytes_moved_assembled(10, 1, true, 4), 48 * 1000);
    }

    #[test]
    fn cg_iteration_stream_model_is_pinned() {
        let (n, nelt) = (10, 1);
        let ndof = 1000u64;
        // Flops: the total is invariant across fused/unfused (a fused Ax
        // counts the pap reduction's 3 flops/dof inside fused_ax_flops and
        // the solver skips its own) — and across blocked/unblocked, which
        // only reorder the same arithmetic.
        assert_eq!(cg_flops(n, nelt, false), ax_flops(n, nelt) + 12 * ndof);
        assert_eq!(cg_flops(n, nelt, true), fused_ax_flops(n, nelt) + 9 * ndof);
        assert_eq!(cg_flops(n, nelt, false), cg_flops(n, nelt, true));
        // Vector-part bytes per dof: 136 unfused / 112 fused unblocked,
        // 112 / 88 blocked — cache-blocking removes 24 B/dof either way.
        for (fused, assembled) in [(false, false), (true, false), (false, true), (true, true)] {
            let ax = if assembled {
                ax_bytes_moved_assembled(n, nelt, fused, 8)
            } else {
                ax_bytes_moved(n, nelt, fused)
            };
            let unblocked = cg_bytes_moved(n, nelt, fused, assembled, false);
            let blocked = cg_bytes_moved(n, nelt, fused, assembled, true);
            let vec_unblocked = if fused { 112 } else { 136 };
            assert_eq!(unblocked, ax + vec_unblocked * ndof, "fused={fused}");
            assert_eq!(unblocked - blocked, 24 * ndof, "fused={fused} assembled={assembled}");
        }
        // The f64 wrapper is the stored-width formula at 8 bytes, and f32
        // factor storage thins only the Ax part.
        assert_eq!(
            cg_bytes_moved(n, nelt, false, false, true),
            cg_bytes_moved_stored(n, nelt, false, false, true, 8)
        );
        assert_eq!(
            cg_bytes_moved_stored(n, nelt, false, false, true, 8)
                - cg_bytes_moved_stored(n, nelt, false, false, true, 4),
            ax_bytes_moved(n, nelt, false) - ax_bytes_moved_stored(n, nelt, false, 4)
        );
        // Whole-solve intensity strictly rises under blocking (same flops,
        // fewer bytes) — the cg-iteration roofline family's claim.
        let i_u = cg_flops(n, nelt, false) as f64
            / cg_bytes_moved(n, nelt, false, false, false) as f64;
        let i_b = cg_flops(n, nelt, false) as f64
            / cg_bytes_moved(n, nelt, false, false, true) as f64;
        assert!(i_b > i_u);
    }

    #[test]
    fn assembled_vs_stored_intensity_ratios_are_pinned() {
        // The roofline claim of the cpu-asm family, as exact rationals:
        // same flops, fewer bytes, so intensity rises by stored/assembled.
        // f64: 80/64 = 1.25 unfused, 88/72 fused; f32 storage: 56/40 = 1.4
        // unfused, 64/48 = 4/3 fused.
        let (n, nelt) = (10, 3);
        for (stored, fused, want) in [
            (8u64, false, 80.0 / 64.0),
            (8, true, 88.0 / 72.0),
            (4, false, 56.0 / 40.0),
            (4, true, 64.0 / 48.0),
        ] {
            let full = ax_bytes_moved_stored(n, nelt, fused, stored) as f64;
            let asm = ax_bytes_moved_assembled(n, nelt, fused, stored) as f64;
            assert_eq!(full / asm, want, "stored={stored} fused={fused}");
            assert!(full / asm > 1.0);
        }
    }
}
