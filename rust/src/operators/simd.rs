//! Explicit-SIMD CPU Ax: AVX2+FMA element kernels with runtime dispatch.
//!
//! The paper reaches 77–92% of the measured roofline by managing registers
//! and fast memory explicitly instead of hoping the compiler does it
//! (PAPER.md §V); Świrydowicz et al. (arXiv:1711.00903) make the same
//! point for small tensor contractions — the empirical roof is only
//! approached with vector-width-aware data layout. The degree-specialized
//! kernels ([`super::ax_spec`]) unroll but still rely on autovectorization;
//! this module is the explicit rung: the layered schedule rewritten over
//! 4-wide `f64` vectors with `core::arch::x86_64` intrinsics.
//!
//! ## Dispatch
//!
//! [`ax_simd`] / [`ax_simd_fused`] pick an arm at runtime
//! ([`simd_arm`], backed by `is_x86_feature_detected!`):
//!
//! * **`SimdArm::Avx2`** — the intrinsics kernel, compiled behind
//!   `#[target_feature(enable = "avx2", enable = "fma")]` so it exists in
//!   every build (no compile-time ISA assumption) and only runs after the
//!   CPU has been probed.
//! * **`SimdArm::Scalar`** — the portable fallback: the degree-specialized
//!   dispatch table ([`super::ax_spec`]), bit-identical to the layered
//!   family. Non-x86 targets and feature-less CPUs always take this arm;
//!   requesting the AVX2 arm on such a host degrades to it safely
//!   (see [`ax_simd_with_arm`]).
//!
//! The registered operators (`cpu-simd`, `cpu-simd-fused`) and the worker
//! pool behind `cpu-threaded` / `cpu-threaded-fused` all dispatch through
//! these entry points, so every threaded apply picks the vector kernels up
//! automatically — exactly how the pool adopted the specialized kernels.
//!
//! ## Vectorization scheme and accuracy contract
//!
//! Vectors run across the **output lanes** of each layer tile (the `i`
//! index, unit stride), never across the contraction dimension `l`: each
//! output point keeps its own accumulator and contracts over `l` in
//! exactly the order of `ax_layered_element`, so lane results do not
//! depend on vector width and the kernel is deterministic run to run. The
//! stage-1 `r`-derivative needs `d[i][l]` contiguous across `i`, so the
//! kernel carries a transposed copy of the differentiation matrix — the
//! CPU analog of the paper's explicit shared-memory staging.
//!
//! The one divergence from the scalar family: FMA contraction
//! (`vfmadd231pd`, and `f64::mul_add` on the remainder lanes) fuses the
//! multiply-adds the scalar kernels round twice. Where that happens the
//! result differs from the layered/spec family by at most a few ulps per
//! contraction; the tests compare the AVX2 arm at a tight relative band
//! (1e-13) and require the scalar arm to stay **bit-identical**.

use crate::operators::specialized::{ax_spec, ax_spec_fused, ax_spec_fused_store, ax_spec_store};

/// Which kernel arm the explicit-SIMD entry points dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdArm {
    /// 4-wide AVX2 + FMA intrinsics (x86_64 hosts with runtime support).
    Avx2,
    /// Portable scalar fallback: the degree-specialized kernel family,
    /// bit-identical to `ax_layered`.
    Scalar,
}

impl std::fmt::Display for SimdArm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdArm::Avx2 => "avx2",
            SimdArm::Scalar => "scalar",
        })
    }
}

/// The arm [`ax_simd`] and [`ax_simd_fused`] take on this host: `Avx2`
/// when the CPU reports both AVX2 and FMA at runtime, `Scalar` otherwise
/// (always `Scalar` off x86_64). Detection is cached by the standard
/// library, so calling this per apply is free.
pub fn simd_arm() -> SimdArm {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdArm::Avx2;
        }
    }
    SimdArm::Scalar
}

/// Explicit-SIMD local Poisson operator. Signature and layout as
/// [`super::ax_layered`]; dispatches to the arm [`simd_arm`] reports.
pub fn ax_simd(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    ax_simd_with_arm(simd_arm(), n, nelt, u, d, g, w);
}

/// Explicit-SIMD fused Ax+pap: computes `w = A_local(u)` as [`ax_simd`]
/// and returns `pap = Σ_i w_i c_i u_i` over the local dofs, accumulated
/// element by element in ascending element order (the fused determinism
/// contract, see [`super::ax_layered_fused`]).
pub fn ax_simd_fused(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    ax_simd_fused_with_arm(simd_arm(), n, nelt, u, d, g, c, w)
}

/// [`ax_simd`] with the arm chosen by the caller — the test hook that
/// forces the scalar kernel on a SIMD-capable host. Requesting
/// `SimdArm::Avx2` on a host without AVX2+FMA support (or off x86_64)
/// degrades to the scalar arm instead of executing unsupported
/// instructions.
pub fn ax_simd_with_arm(
    arm: SimdArm,
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    w: &mut [f64],
) {
    match arm {
        SimdArm::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if simd_arm() == SimdArm::Avx2 {
                // SAFETY: AVX2 and FMA support was verified at runtime on
                // the line above.
                unsafe { avx2::ax_mesh(n, nelt, u, d, g, w) };
                return;
            }
            ax_spec(n, nelt, u, d, g, w);
        }
        SimdArm::Scalar => ax_spec(n, nelt, u, d, g, w),
    }
}

/// [`ax_simd_fused`] with the arm chosen by the caller; same degrade
/// semantics as [`ax_simd_with_arm`].
#[allow(clippy::too_many_arguments)]
pub fn ax_simd_fused_with_arm(
    arm: SimdArm,
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    match arm {
        SimdArm::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if simd_arm() == SimdArm::Avx2 {
                // SAFETY: AVX2 and FMA support was verified at runtime on
                // the line above.
                return unsafe { avx2::ax_fused_mesh(n, nelt, u, d, g, c, w) };
            }
            ax_spec_fused(n, nelt, u, d, g, c, w)
        }
        SimdArm::Scalar => ax_spec_fused(n, nelt, u, d, g, c, w),
    }
}

/// Explicit-SIMD local Poisson operator over f32-stored geometric factors
/// (the `cpu-simd-f32` kernel, and what the worker pool dispatches for
/// `cpu-threaded-f32`): each element's factors widen into an L1-resident
/// f64 tile, then the unchanged f64 arm runs — AVX2+FMA intrinsics or the
/// scalar spec family, per [`simd_arm`]. All arithmetic and accumulation
/// stay f64; only the `g` stream shrinks to 4 bytes.
pub fn ax_simd_f32(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f32], w: &mut [f64]) {
    ax_simd_f32_with_arm(simd_arm(), n, nelt, u, d, g, w);
}

/// Fused Ax+pap twin of [`ax_simd_f32`] (the `cpu-simd-fused-f32`
/// kernel): same `w`, plus the element-order pap reduction of
/// [`ax_simd_fused`].
pub fn ax_simd_fused_f32(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f32],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    ax_simd_fused_f32_with_arm(simd_arm(), n, nelt, u, d, g, c, w)
}

/// [`ax_simd_f32`] with the arm chosen by the caller; same degrade
/// semantics as [`ax_simd_with_arm`].
pub fn ax_simd_f32_with_arm(
    arm: SimdArm,
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f32],
    w: &mut [f64],
) {
    match arm {
        SimdArm::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if simd_arm() == SimdArm::Avx2 {
                // SAFETY: AVX2 and FMA support was verified at runtime on
                // the line above.
                unsafe { avx2::ax_mesh_f32(n, nelt, u, d, g, w) };
                return;
            }
            ax_spec_store::<f32>(n, nelt, u, d, g, w);
        }
        SimdArm::Scalar => ax_spec_store::<f32>(n, nelt, u, d, g, w),
    }
}

/// [`ax_simd_fused_f32`] with the arm chosen by the caller; same degrade
/// semantics as [`ax_simd_with_arm`].
#[allow(clippy::too_many_arguments)]
pub fn ax_simd_fused_f32_with_arm(
    arm: SimdArm,
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f32],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    match arm {
        SimdArm::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if simd_arm() == SimdArm::Avx2 {
                // SAFETY: AVX2 and FMA support was verified at runtime on
                // the line above.
                return unsafe { avx2::ax_fused_mesh_f32(n, nelt, u, d, g, c, w) };
            }
            ax_spec_fused_store::<f32>(n, nelt, u, d, g, c, w)
        }
        SimdArm::Scalar => ax_spec_fused_store::<f32>(n, nelt, u, d, g, c, w),
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! The intrinsics arm. Everything here is behind
    //! `#[target_feature(enable = "avx2", enable = "fma")]`: compiled into
    //! every x86_64 build, executed only after runtime detection (the
    //! dispatchers in the parent module are the only callers).

    use core::arch::x86_64::{
        _mm256_add_pd, _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_setzero_pd, _mm256_storeu_pd,
    };

    /// f64 lanes per AVX2 vector.
    const LANES: usize = 4;

    /// Per-layer tiles (the vector analog of `LayeredScratch`) plus `dt`,
    /// the transposed differentiation matrix: the stage-1 `r`-derivative
    /// reads `d[i][l]` across the vectorized `i` lanes, which is only a
    /// contiguous load through the transpose. Allocated once per mesh
    /// apply and reused across elements.
    struct Scratch {
        dt: Vec<f64>,
        wr: Vec<f64>,
        ws: Vec<f64>,
        wt: Vec<f64>,
        ur: Vec<f64>,
        us: Vec<f64>,
        ut: Vec<f64>,
    }

    impl Scratch {
        fn new(n: usize, d: &[f64]) -> Self {
            let nn = n * n;
            let mut dt = vec![0.0; nn];
            for i in 0..n {
                for l in 0..n {
                    dt[l * n + i] = d[i * n + l];
                }
            }
            Scratch {
                dt,
                wr: vec![0.0; nn],
                ws: vec![0.0; nn],
                wt: vec![0.0; nn],
                ur: vec![0.0; nn],
                us: vec![0.0; nn],
                ut: vec![0.0; nn],
            }
        }
    }

    #[inline]
    fn check_shapes(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &[f64]) {
        let np = n * n * n;
        assert_eq!(u.len(), nelt * np);
        assert_eq!(d.len(), n * n);
        assert_eq!(g.len(), nelt * 6 * np);
        assert_eq!(w.len(), nelt * np);
    }

    /// One element of the AVX2 schedule: `we = A_local u_e`, structurally
    /// identical to `ax_layered_element` with the `i`/`p` loops run 4 lanes
    /// at a time (scalar `mul_add` on the remainder lanes, so the whole arm
    /// is uniformly fused-multiply-add). Per-lane accumulation order
    /// matches the layered kernel exactly; only FMA rounding differs.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2+FMA support at runtime. Slice
    /// lengths must satisfy the layered-element contract (`ue`/`we` of
    /// `n^3`, `ge` of `6 n^3`, `d`/`s.dt` of `n^2`) — asserted by
    /// [`ax_mesh`] / [`ax_fused_mesh`] before any element runs; every
    /// vector load/store below stays inside those bounds because the lane
    /// loops stop `LANES - 1` short of each row end.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn ax_element(
        n: usize,
        d: &[f64],
        s: &mut Scratch,
        ue: &[f64],
        ge: &[f64],
        we: &mut [f64],
    ) {
        let nn = n * n;
        let np = nn * n;
        let Scratch { dt, wr, ws, wt, ur, us, ut } = s;
        we.fill(0.0);

        for k in 0..n {
            let uk = &ue[k * nn..(k + 1) * nn]; // the staged layer
            // stage 1: r and s derivatives of the layer tile, vector
            // across the i output lanes, contraction over l per lane.
            for j in 0..n {
                let mut i = 0;
                while i + LANES <= n {
                    let mut accr = _mm256_setzero_pd();
                    let mut accs = _mm256_setzero_pd();
                    for l in 0..n {
                        let dcol = _mm256_loadu_pd(dt.as_ptr().add(l * n + i));
                        let urow = _mm256_set1_pd(uk[j * n + l]);
                        accr = _mm256_fmadd_pd(dcol, urow, accr);
                        let drow = _mm256_set1_pd(d[j * n + l]);
                        let ucol = _mm256_loadu_pd(uk.as_ptr().add(l * n + i));
                        accs = _mm256_fmadd_pd(drow, ucol, accs);
                    }
                    _mm256_storeu_pd(wr.as_mut_ptr().add(j * n + i), accr);
                    _mm256_storeu_pd(ws.as_mut_ptr().add(j * n + i), accs);
                    i += LANES;
                }
                while i < n {
                    let mut accr = 0.0;
                    let mut accs = 0.0;
                    for l in 0..n {
                        accr = dt[l * n + i].mul_add(uk[j * n + l], accr);
                        accs = d[j * n + l].mul_add(uk[l * n + i], accs);
                    }
                    wr[j * n + i] = accr;
                    ws[j * n + i] = accs;
                    i += 1;
                }
            }
            // t derivative from the register column u(i,j,:).
            let dk = &d[k * n..(k + 1) * n];
            let mut p = 0;
            while p + LANES <= nn {
                let mut acc = _mm256_setzero_pd();
                for (l, &dkl) in dk.iter().enumerate() {
                    let dl = _mm256_set1_pd(dkl);
                    let ucol = _mm256_loadu_pd(ue.as_ptr().add(l * nn + p));
                    acc = _mm256_fmadd_pd(dl, ucol, acc);
                }
                _mm256_storeu_pd(wt.as_mut_ptr().add(p), acc);
                p += LANES;
            }
            while p < nn {
                let mut acc = 0.0;
                for (l, &dkl) in dk.iter().enumerate() {
                    acc = dkl.mul_add(ue[l * nn + p], acc);
                }
                wt[p] = acc;
                p += 1;
            }
            // geometric factors, loaded per layer. Addition order matches
            // the layered kernel (g11·wr + g12·ws, then + g13·wt, ...);
            // the products stay unrounded inside the FMAs.
            let gk = k * nn;
            let mut p = 0;
            while p + LANES <= nn {
                let wrv = _mm256_loadu_pd(wr.as_ptr().add(p));
                let wsv = _mm256_loadu_pd(ws.as_ptr().add(p));
                let wtv = _mm256_loadu_pd(wt.as_ptr().add(p));
                let g11 = _mm256_loadu_pd(ge.as_ptr().add(gk + p));
                let g12 = _mm256_loadu_pd(ge.as_ptr().add(np + gk + p));
                let g13 = _mm256_loadu_pd(ge.as_ptr().add(2 * np + gk + p));
                let g22 = _mm256_loadu_pd(ge.as_ptr().add(3 * np + gk + p));
                let g23 = _mm256_loadu_pd(ge.as_ptr().add(4 * np + gk + p));
                let g33 = _mm256_loadu_pd(ge.as_ptr().add(5 * np + gk + p));
                let urv =
                    _mm256_fmadd_pd(g13, wtv, _mm256_fmadd_pd(g12, wsv, _mm256_mul_pd(g11, wrv)));
                let usv =
                    _mm256_fmadd_pd(g23, wtv, _mm256_fmadd_pd(g22, wsv, _mm256_mul_pd(g12, wrv)));
                let utv =
                    _mm256_fmadd_pd(g33, wtv, _mm256_fmadd_pd(g23, wsv, _mm256_mul_pd(g13, wrv)));
                _mm256_storeu_pd(ur.as_mut_ptr().add(p), urv);
                _mm256_storeu_pd(us.as_mut_ptr().add(p), usv);
                _mm256_storeu_pd(ut.as_mut_ptr().add(p), utv);
                p += LANES;
            }
            while p < nn {
                let (wrp, wsp, wtp) = (wr[p], ws[p], wt[p]);
                let g11 = ge[gk + p];
                let g12 = ge[np + gk + p];
                let g13 = ge[2 * np + gk + p];
                let g22 = ge[3 * np + gk + p];
                let g23 = ge[4 * np + gk + p];
                let g33 = ge[5 * np + gk + p];
                ur[p] = g13.mul_add(wtp, g12.mul_add(wsp, g11 * wrp));
                us[p] = g23.mul_add(wtp, g22.mul_add(wsp, g12 * wrp));
                ut[p] = g33.mul_add(wtp, g23.mul_add(wsp, g13 * wrp));
                p += 1;
            }
            // stage 2, r/s parts land in layer k: d[l][i] is contiguous
            // across the i lanes as stored, no transpose needed.
            for j in 0..n {
                let mut i = 0;
                while i + LANES <= n {
                    let mut acc = _mm256_setzero_pd();
                    for l in 0..n {
                        let dcol = _mm256_loadu_pd(d.as_ptr().add(l * n + i));
                        let urb = _mm256_set1_pd(ur[j * n + l]);
                        acc = _mm256_fmadd_pd(dcol, urb, acc);
                        let drow = _mm256_set1_pd(d[l * n + j]);
                        let usv = _mm256_loadu_pd(us.as_ptr().add(l * n + i));
                        acc = _mm256_fmadd_pd(drow, usv, acc);
                    }
                    let idx = k * nn + j * n + i;
                    let prev = _mm256_loadu_pd(we.as_ptr().add(idx));
                    _mm256_storeu_pd(we.as_mut_ptr().add(idx), _mm256_add_pd(prev, acc));
                    i += LANES;
                }
                while i < n {
                    let mut acc = 0.0;
                    for l in 0..n {
                        acc = d[l * n + i].mul_add(ur[j * n + l], acc);
                        acc = d[l * n + j].mul_add(us[l * n + i], acc);
                    }
                    we[k * nn + j * n + i] += acc;
                    i += 1;
                }
            }
            // stage 2, t part scatters into all layers m with weight
            // d[k,m] (the zero-weight skip is part of the family contract).
            for m in 0..n {
                let dkm = d[k * n + m];
                if dkm != 0.0 {
                    let base = m * nn;
                    let dv = _mm256_set1_pd(dkm);
                    let mut p = 0;
                    while p + LANES <= nn {
                        let prev = _mm256_loadu_pd(we.as_ptr().add(base + p));
                        let utv = _mm256_loadu_pd(ut.as_ptr().add(p));
                        _mm256_storeu_pd(
                            we.as_mut_ptr().add(base + p),
                            _mm256_fmadd_pd(dv, utv, prev),
                        );
                        p += LANES;
                    }
                    while p < nn {
                        we[base + p] = dkm.mul_add(ut[p], we[base + p]);
                        p += 1;
                    }
                }
            }
        }
    }

    /// Whole-mesh AVX2 driver.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2+FMA support at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn ax_mesh(
        n: usize,
        nelt: usize,
        u: &[f64],
        d: &[f64],
        g: &[f64],
        w: &mut [f64],
    ) {
        check_shapes(n, nelt, u, d, g, w);
        let np = n * n * n;
        let mut s = Scratch::new(n, d);
        for e in 0..nelt {
            let ue = &u[e * np..(e + 1) * np];
            let ge = &g[e * 6 * np..(e + 1) * 6 * np];
            let we = &mut w[e * np..(e + 1) * np];
            ax_element(n, d, &mut s, ue, ge, we);
        }
    }

    /// Whole-mesh fused AVX2 driver: pap streams per element in linear dof
    /// order (plain multiply-add, matching the layered fused reduction),
    /// summed in ascending element order.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2+FMA support at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn ax_fused_mesh(
        n: usize,
        nelt: usize,
        u: &[f64],
        d: &[f64],
        g: &[f64],
        c: &[f64],
        w: &mut [f64],
    ) -> f64 {
        check_shapes(n, nelt, u, d, g, w);
        let np = n * n * n;
        assert_eq!(c.len(), nelt * np);
        let mut s = Scratch::new(n, d);
        let mut pap = 0.0;
        for e in 0..nelt {
            let ue = &u[e * np..(e + 1) * np];
            let ge = &g[e * 6 * np..(e + 1) * 6 * np];
            let ce = &c[e * np..(e + 1) * np];
            let we = &mut w[e * np..(e + 1) * np];
            ax_element(n, d, &mut s, ue, ge, we);
            let mut pap_e = 0.0;
            for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
                pap_e += wi * ci * ui;
            }
            pap += pap_e;
        }
        pap
    }

    /// Whole-mesh AVX2 driver over f32-stored factors: widen one element's
    /// factors into an L1-resident f64 tile, then run the unchanged
    /// [`ax_element`]. The mesh-level `g` traffic is the 4-byte stream;
    /// the widened tile stays cache-resident across the element's k-sweep.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2+FMA support at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn ax_mesh_f32(
        n: usize,
        nelt: usize,
        u: &[f64],
        d: &[f64],
        g: &[f32],
        w: &mut [f64],
    ) {
        let np = n * n * n;
        assert_eq!(u.len(), nelt * np);
        assert_eq!(d.len(), n * n);
        assert_eq!(g.len(), nelt * 6 * np);
        assert_eq!(w.len(), nelt * np);
        let mut s = Scratch::new(n, d);
        let mut ge64 = vec![0.0f64; 6 * np];
        for e in 0..nelt {
            let ue = &u[e * np..(e + 1) * np];
            crate::geometry::widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
            let we = &mut w[e * np..(e + 1) * np];
            ax_element(n, d, &mut s, ue, &ge64, we);
        }
    }

    /// Whole-mesh fused AVX2 driver over f32-stored factors; pap contract
    /// as [`ax_fused_mesh`].
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2+FMA support at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn ax_fused_mesh_f32(
        n: usize,
        nelt: usize,
        u: &[f64],
        d: &[f64],
        g: &[f32],
        c: &[f64],
        w: &mut [f64],
    ) -> f64 {
        let np = n * n * n;
        assert_eq!(u.len(), nelt * np);
        assert_eq!(d.len(), n * n);
        assert_eq!(g.len(), nelt * 6 * np);
        assert_eq!(c.len(), nelt * np);
        assert_eq!(w.len(), nelt * np);
        let mut s = Scratch::new(n, d);
        let mut ge64 = vec![0.0f64; 6 * np];
        let mut pap = 0.0;
        for e in 0..nelt {
            let ue = &u[e * np..(e + 1) * np];
            crate::geometry::widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
            let ce = &c[e * np..(e + 1) * np];
            let we = &mut w[e * np..(e + 1) * np];
            ax_element(n, d, &mut s, ue, &ge64, we);
            let mut pap_e = 0.0;
            for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
                pap_e += wi * ci * ui;
            }
            pap += pap_e;
        }
        pap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{ax_layered, ax_layered_fused};
    use crate::proputil::Cases;

    fn inputs(seed: u64, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut cases = Cases::new(seed);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        (u, d, g, c)
    }

    /// The AVX2 arm is allowed to differ from the layered family only by
    /// FMA rounding: a tight relative band scaled by the field magnitude.
    /// The scalar arm has no such license — bit-identical.
    fn assert_fma_band(got: &[f64], want: &[f64], what: &str) {
        let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
        for (idx, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-13 * (w.abs() + scale);
            assert!(
                (g - w).abs() <= tol,
                "{what}: mismatch at {idx}: got {g}, want {w} (tol {tol:e})"
            );
        }
    }

    #[test]
    fn scalar_arm_bit_identical_to_layered() {
        for n in [2, 3, 5, 8, 13] {
            let nelt = 2;
            let (u, d, g, _c) = inputs(0xA1 + n as u64, n, nelt);
            let np = n * n * n;
            let mut want = vec![0.0; nelt * np];
            ax_layered(n, nelt, &u, &d, &g, &mut want);
            let mut got = vec![123.0; nelt * np]; // poisoned
            ax_simd_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g, &mut got);
            assert_eq!(got, want, "n={n}: scalar arm must be bit-identical to layered");
        }
    }

    #[test]
    fn dispatched_kernel_stays_in_the_fma_band() {
        for n in 2..=12usize {
            let nelt = 3;
            let (u, d, g, _c) = inputs(0xA2 + n as u64, n, nelt);
            let np = n * n * n;
            let mut want = vec![0.0; nelt * np];
            ax_layered(n, nelt, &u, &d, &g, &mut want);
            let mut got = vec![123.0; nelt * np];
            ax_simd(n, nelt, &u, &d, &g, &mut got);
            match simd_arm() {
                SimdArm::Scalar => assert_eq!(got, want, "n={n}"),
                SimdArm::Avx2 => assert_fma_band(&got, &want, &format!("n={n}")),
            }
        }
    }

    #[test]
    fn fused_pap_matches_own_output() {
        // The fused contract binds pap to the operator's *own* w (which on
        // the AVX2 arm differs from layered within the FMA band).
        for n in 2..=9usize {
            let nelt = 2;
            let (u, d, g, c) = inputs(0xA3 + n as u64, n, nelt);
            let np = n * n * n;
            let mut w = vec![0.0; nelt * np];
            let pap = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut w);
            let mut w2 = vec![0.0; nelt * np];
            ax_simd(n, nelt, &u, &d, &g, &mut w2);
            assert_eq!(w, w2, "n={n}: fused w must be bit-identical to unfused simd");
            let want = crate::solver::glsc3(&w, &c, &u);
            crate::proputil::assert_pap_close(pap, want, &w, &c, &u, 1e-12, &format!("n={n}"));
        }
    }

    #[test]
    fn deterministic_run_to_run() {
        let (n, nelt) = (7, 3);
        let (u, d, g, c) = inputs(0xA4, n, nelt);
        let np = n * n * n;
        let mut w1 = vec![0.0; nelt * np];
        let mut w2 = vec![0.0; nelt * np];
        let p1 = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut w1);
        let p2 = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut w2);
        assert_eq!(w1, w2);
        assert_eq!(p1.to_bits(), p2.to_bits(), "pap must be run-to-run reproducible");
    }

    #[test]
    fn forcing_avx2_without_support_degrades_to_scalar() {
        // On a host without AVX2 the request must degrade safely (and on
        // an AVX2 host this just re-checks the dispatched arm).
        let (n, nelt) = (5, 2);
        let (u, d, g, c) = inputs(0xA5, n, nelt);
        let np = n * n * n;
        let mut got = vec![0.0; nelt * np];
        ax_simd_with_arm(SimdArm::Avx2, n, nelt, &u, &d, &g, &mut got);
        let mut want = vec![0.0; nelt * np];
        ax_simd(n, nelt, &u, &d, &g, &mut want);
        assert_eq!(got, want, "requested-avx2 must equal the dispatched kernel");
        let mut wf = vec![0.0; nelt * np];
        let pap = ax_simd_fused_with_arm(SimdArm::Avx2, n, nelt, &u, &d, &g, &c, &mut wf);
        let pap_want = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut want);
        assert_eq!(pap.to_bits(), pap_want.to_bits());
    }

    #[test]
    fn scalar_fused_arm_bit_identical_to_layered_fused() {
        let (n, nelt) = (6, 2);
        let (u, d, g, c) = inputs(0xA6, n, nelt);
        let np = n * n * n;
        let mut w_l = vec![0.0; nelt * np];
        let pap_l = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w_l);
        let mut w_s = vec![123.0; nelt * np];
        let pap_s = ax_simd_fused_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g, &c, &mut w_s);
        assert_eq!(w_s, w_l);
        assert_eq!(pap_s.to_bits(), pap_l.to_bits());
    }

    #[test]
    fn f32_path_bit_identical_to_f64_path_on_prerounded_factors() {
        // Widening is exact and the arithmetic is the same f64 kernel, so
        // feeding the f64 entry points factors that are *already*
        // f32-rounded must reproduce the mixed-precision path bitwise —
        // on both dispatch arms, fused and unfused.
        for n in [3usize, 5, 9, 13] {
            let nelt = 2;
            let (u, d, g, c) = inputs(0xA7 + n as u64, n, nelt);
            let np = n * n * n;
            let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
            let g_rounded: Vec<f64> = g32.iter().map(|&x| x as f64).collect();
            let mut want = vec![0.0; nelt * np];
            ax_simd(n, nelt, &u, &d, &g_rounded, &mut want);
            let mut got = vec![123.0; nelt * np];
            ax_simd_f32(n, nelt, &u, &d, &g32, &mut got);
            assert_eq!(got, want, "n={n}: f32 path vs pre-rounded f64 path");

            let mut w_f = vec![0.0; nelt * np];
            let pap_f = ax_simd_fused(n, nelt, &u, &d, &g_rounded, &c, &mut w_f);
            let mut w_s = vec![0.0; nelt * np];
            let pap_s = ax_simd_fused_f32(n, nelt, &u, &d, &g32, &c, &mut w_s);
            assert_eq!(w_s, w_f, "n={n}: fused w");
            assert_eq!(pap_s.to_bits(), pap_f.to_bits(), "n={n}: fused pap");

            // Forced-scalar arm stays bit-identical to the spec family.
            let mut w_sc = vec![0.0; nelt * np];
            ax_simd_f32_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g32, &mut w_sc);
            let mut w_spec = vec![0.0; nelt * np];
            crate::operators::specialized::ax_spec_store::<f32>(
                n, nelt, &u, &d, &g32, &mut w_spec,
            );
            assert_eq!(w_sc, w_spec, "n={n}: forced scalar f32 arm");
            let mut w_fs = vec![0.0; nelt * np];
            let pap_fs =
                ax_simd_fused_f32_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g32, &c, &mut w_fs);
            let mut w_fspec = vec![0.0; nelt * np];
            let pap_fspec = crate::operators::specialized::ax_spec_fused_store::<f32>(
                n, nelt, &u, &d, &g32, &c, &mut w_fspec,
            );
            assert_eq!(w_fs, w_fspec, "n={n}");
            assert_eq!(pap_fs.to_bits(), pap_fspec.to_bits(), "n={n}");
        }
    }

    #[test]
    fn arm_labels_render() {
        assert_eq!(SimdArm::Avx2.to_string(), "avx2");
        assert_eq!(SimdArm::Scalar.to_string(), "scalar");
    }
}
