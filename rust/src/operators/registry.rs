//! The operator registry: string names → [`AxOperator`] constructors.
//!
//! This is the **only** module that knows which concrete operator backs
//! which name. Everything else — the application builder, the CLI, the
//! rank runtime, the benches — resolves operators by name through
//! [`OperatorRegistry`] and dispatches through `Box<dyn AxOperator>`.
//!
//! Canonical names are chosen so that `label()` output is re-parseable:
//! every operator's label **is** its canonical registry name. Aliases
//! (`xla-openacc` → `xla-jnp`, `xla-fused` → `xla-fused-layered`) resolve
//! to the canonical entry at parse time.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::operators::{ax_flops, ax_layered, ax_naive, ax_threaded, AxOperator, OperatorCtx};
use crate::runtime::{AxEngine, CgIterEngine, Manifest, XlaRuntime};

/// Constructor for a blank (un-setup) operator.
pub type OperatorCtor = Box<dyn Fn() -> Box<dyn AxOperator> + Send + Sync>;

/// One registered operator: canonical name, artifact requirement, and the
/// constructor.
pub struct OperatorSpec {
    /// Canonical registry name (also the operator's label).
    pub name: String,
    /// Does the operator load AOT artifacts / the PJRT runtime?
    pub needs_artifacts: bool,
    ctor: OperatorCtor,
}

impl OperatorSpec {
    /// Construct a blank operator (call `setup` before `apply`).
    pub fn create(&self) -> Box<dyn AxOperator> {
        (self.ctor)()
    }
}

/// Maps operator names to constructors. Third parties (tests, benches,
/// downstream crates) register additional variants at runtime; the
/// application builder accepts a custom registry.
pub struct OperatorRegistry {
    specs: BTreeMap<String, OperatorSpec>,
    aliases: BTreeMap<String, String>,
}

impl Default for OperatorRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl OperatorRegistry {
    /// An empty registry (no names resolve).
    pub fn empty() -> Self {
        OperatorRegistry { specs: BTreeMap::new(), aliases: BTreeMap::new() }
    }

    /// The built-in operator family: the three CPU schedules, the paper's
    /// five AOT kernel variants, and the fused Ax+pap hot path.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        let must = |res: Result<()>| res.expect("builtin registration cannot clash");
        must(r.register("cpu-naive", false, || Box::new(CpuOp::new("cpu-naive", kernel_naive))));
        must(r.register("cpu-layered", false, || {
            Box::new(CpuOp::new("cpu-layered", kernel_layered))
        }));
        must(r.register("cpu-threaded", false, || {
            Box::new(CpuOp::new("cpu-threaded", kernel_threaded))
        }));
        for variant in ["jnp", "original", "shared", "layered", "layered_unroll2"] {
            must(r.register(&xla_name(variant), true, move || {
                Box::new(XlaAxOp::new(variant))
            }));
        }
        must(r.register("xla-fused-layered", true, || Box::new(XlaFusedOp::new("layered"))));
        must(r.alias("xla-openacc", "xla-jnp"));
        must(r.alias("xla-fused", "xla-fused-layered"));
        r
    }

    /// Register a constructor under a canonical name. Errors if the name
    /// (or an alias of it) is already taken.
    pub fn register(
        &mut self,
        name: &str,
        needs_artifacts: bool,
        ctor: impl Fn() -> Box<dyn AxOperator> + Send + Sync + 'static,
    ) -> Result<()> {
        if self.specs.contains_key(name) || self.aliases.contains_key(name) {
            return Err(Error::Config(format!(
                "operator {name:?} is already registered (registered: {})",
                self.known_names().join(", ")
            )));
        }
        self.specs.insert(
            name.to_string(),
            OperatorSpec { name: name.to_string(), needs_artifacts, ctor: Box::new(ctor) },
        );
        Ok(())
    }

    /// Register an alias for an existing canonical name.
    pub fn alias(&mut self, alias: &str, target: &str) -> Result<()> {
        if self.specs.contains_key(alias) || self.aliases.contains_key(alias) {
            return Err(Error::Config(format!("operator alias {alias:?} is already taken")));
        }
        if !self.specs.contains_key(target) {
            return Err(Error::Config(format!(
                "alias {alias:?} targets unregistered operator {target:?}"
            )));
        }
        self.aliases.insert(alias.to_string(), target.to_string());
        Ok(())
    }

    /// Resolve a name (canonical or alias) to its spec. The error for an
    /// unknown name lists every registered name.
    pub fn resolve(&self, name: &str) -> Result<&OperatorSpec> {
        let canonical = self.aliases.get(name).map(String::as_str).unwrap_or(name);
        self.specs.get(canonical).ok_or_else(|| {
            Error::Config(format!(
                "unknown operator {name:?}; registered operators: {}",
                self.known_names().join(", ")
            ))
        })
    }

    /// Is the name (canonical or alias) registered?
    pub fn contains(&self, name: &str) -> bool {
        self.specs.contains_key(name) || self.aliases.contains_key(name)
    }

    /// Construct a blank operator by name (no setup).
    pub fn create(&self, name: &str) -> Result<Box<dyn AxOperator>> {
        Ok(self.resolve(name)?.create())
    }

    /// Construct and set up an operator for one problem.
    pub fn build(&self, name: &str, ctx: &OperatorCtx) -> Result<Box<dyn AxOperator>> {
        let mut op = self.create(name)?;
        op.setup(ctx)?;
        Ok(op)
    }

    /// Canonical names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.specs.keys().cloned().collect()
    }

    /// Canonical names + aliases, sorted (for error messages and `info`).
    pub fn known_names(&self) -> Vec<String> {
        let mut all: Vec<String> =
            self.specs.keys().chain(self.aliases.keys()).cloned().collect();
        all.sort();
        all
    }
}

/// Canonical registry name of an XLA kernel variant
/// (`layered_unroll2` → `xla-layered-unroll2`).
fn xla_name(variant: &str) -> String {
    format!("xla-{}", variant.replace('_', "-"))
}

// ---------------------------------------------------------------------------
// CPU operators
// ---------------------------------------------------------------------------

/// Shape + cloned mesh data shared by the CPU operators.
struct CpuState {
    n: usize,
    nelt: usize,
    threads: usize,
    d: Vec<f64>,
    g: Vec<f64>,
}

impl CpuState {
    fn capture(ctx: &OperatorCtx) -> Result<Self> {
        let np = ctx.n * ctx.n * ctx.n;
        if ctx.d.len() != ctx.n * ctx.n {
            return Err(Error::Config(format!(
                "operator setup: d must be n*n = {}, got {}",
                ctx.n * ctx.n,
                ctx.d.len()
            )));
        }
        if ctx.g.len() != ctx.nelt * 6 * np {
            return Err(Error::Config(format!(
                "operator setup: g must be nelt*6*n^3 = {}, got {}",
                ctx.nelt * 6 * np,
                ctx.g.len()
            )));
        }
        Ok(CpuState {
            n: ctx.n,
            nelt: ctx.nelt,
            threads: ctx.threads,
            d: ctx.d.to_vec(),
            g: ctx.g.to_vec(),
        })
    }

    fn check_lengths(&self, u: &[f64], w: &[f64]) -> Result<()> {
        let ndof = self.nelt * self.n * self.n * self.n;
        if u.len() != ndof || w.len() != ndof {
            return Err(Error::Config(format!(
                "operator apply: fields must be nelt*n^3 = {ndof}, got u={} w={}",
                u.len(),
                w.len()
            )));
        }
        Ok(())
    }
}

fn not_setup(label: &str) -> Error {
    Error::Config(format!("operator {label:?} used before setup"))
}

/// Unified CPU-kernel signature; the trailing argument is the thread count
/// (ignored by the single-thread schedules).
type CpuKernel = fn(usize, usize, &[f64], &[f64], &[f64], &mut [f64], usize);

fn kernel_naive(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64], _t: usize) {
    ax_naive(n, nelt, u, d, g, w);
}

fn kernel_layered(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64], _t: usize) {
    ax_layered(n, nelt, u, d, g, w);
}

fn kernel_threaded(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64], t: usize) {
    ax_threaded(n, nelt, u, d, g, w, t);
}

/// A CPU schedule behind the operator trait: `cpu-naive` (Listing-1
/// structure, full-size intermediates), `cpu-layered` (the paper's
/// schedule, one thread), `cpu-threaded` (layered across cores — the
/// paper's CPU/MPI baseline).
struct CpuOp {
    label: &'static str,
    kernel: CpuKernel,
    st: Option<CpuState>,
}

impl CpuOp {
    fn new(label: &'static str, kernel: CpuKernel) -> Self {
        CpuOp { label, kernel, st: None }
    }
}

impl AxOperator for CpuOp {
    fn label(&self) -> String {
        self.label.into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        self.st = Some(CpuState::capture(ctx)?);
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let st = self.st.as_ref().ok_or_else(|| not_setup(self.label))?;
        st.check_lengths(u, w)?;
        (self.kernel)(st.n, st.nelt, u, &st.d, &st.g, w, st.threads);
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_flops(s.n, s.nelt))
    }
}

// ---------------------------------------------------------------------------
// XLA operators (AOT artifacts through the PJRT runtime)
// ---------------------------------------------------------------------------

struct XlaAxState {
    rt: Rc<XlaRuntime>,
    engine: AxEngine,
    n: usize,
    nelt: usize,
}

/// An AOT-compiled kernel variant run via PJRT: "jnp" (OpenACC analog),
/// "original", "shared", "layered" (the paper's contribution),
/// "layered_unroll2" (CUDA-Fortran analog).
struct XlaAxOp {
    variant: &'static str,
    st: Option<XlaAxState>,
}

impl XlaAxOp {
    fn new(variant: &'static str) -> Self {
        XlaAxOp { variant, st: None }
    }
}

impl AxOperator for XlaAxOp {
    fn label(&self) -> String {
        xla_name(self.variant)
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        // Check artifact presence before constructing the PJRT client, so a
        // missing artifact reports as an Artifact error even when the
        // native runtime is unavailable.
        let manifest = Manifest::load(ctx.artifacts_dir)?;
        manifest.find_ax(self.variant, ctx.n, ctx.chunk)?;
        let rt = Rc::new(XlaRuntime::with_manifest(manifest)?);
        let engine =
            AxEngine::new(&rt, self.variant, ctx.n, ctx.chunk, ctx.nelt, ctx.d, ctx.g)?;
        self.st = Some(XlaAxState { rt, engine, n: ctx.n, nelt: ctx.nelt });
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let variant = self.variant;
        let st = self.st.as_mut().ok_or_else(|| not_setup(&xla_name(variant)))?;
        st.engine.apply(&st.rt, u, w)
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_flops(s.n, s.nelt))
    }

    fn xla_runtime(&self) -> Option<Rc<XlaRuntime>> {
        self.st.as_ref().map(|s| Rc::clone(&s.rt))
    }
}

struct XlaFusedState {
    rt: Rc<XlaRuntime>,
    engine: CgIterEngine,
    n: usize,
    nelt: usize,
}

/// The fused Ax + partial-pap executable (perf-pass hot path): one launch
/// per chunk computes `w = Ax(p)` and the partial `pap` reduction.
struct XlaFusedOp {
    variant: &'static str,
    st: Option<XlaFusedState>,
    last_pap: Option<f64>,
}

impl XlaFusedOp {
    fn new(variant: &'static str) -> Self {
        XlaFusedOp { variant, st: None, last_pap: None }
    }
}

/// Canonical registry name of a fused variant
/// (`layered` → `xla-fused-layered`).
fn fused_name(variant: &str) -> String {
    format!("xla-fused-{}", variant.replace('_', "-"))
}

impl AxOperator for XlaFusedOp {
    fn label(&self) -> String {
        fused_name(self.variant)
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        let manifest = Manifest::load(ctx.artifacts_dir)?;
        manifest.find(&format!("cg_iter_{}_n{}_e{}", self.variant, ctx.n, ctx.chunk))?;
        let rt = Rc::new(XlaRuntime::with_manifest(manifest)?);
        let engine = CgIterEngine::new(
            &rt,
            self.variant,
            ctx.n,
            ctx.chunk,
            ctx.nelt,
            ctx.d,
            ctx.g,
            ctx.c,
        )?;
        self.st = Some(XlaFusedState { rt, engine, n: ctx.n, nelt: ctx.nelt });
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let variant = self.variant;
        let st = self.st.as_mut().ok_or_else(|| not_setup(&fused_name(variant)))?;
        let pap = st.engine.apply(&st.rt, u, w)?;
        self.last_pap = Some(pap);
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_flops(s.n, s.nelt))
    }

    fn is_fused(&self) -> bool {
        true
    }

    fn last_pap(&self) -> Option<f64> {
        self.last_pap
    }

    fn xla_runtime(&self) -> Option<Rc<XlaRuntime>> {
        self.st.as_ref().map(|s| Rc::clone(&s.rt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::assert_allclose;

    fn tiny_ctx<'a>(n: usize, nelt: usize, d: &'a [f64], g: &'a [f64]) -> OperatorCtx<'a> {
        OperatorCtx {
            n,
            nelt,
            chunk: nelt,
            threads: 0,
            artifacts_dir: "artifacts",
            d,
            g,
            c: &[],
        }
    }

    #[test]
    fn builtins_present() {
        let r = OperatorRegistry::with_builtins();
        for name in [
            "cpu-naive",
            "cpu-layered",
            "cpu-threaded",
            "xla-jnp",
            "xla-original",
            "xla-shared",
            "xla-layered",
            "xla-layered-unroll2",
            "xla-fused-layered",
        ] {
            assert!(r.contains(name), "missing builtin {name}");
            assert_eq!(r.resolve(name).unwrap().name, name);
        }
        // Aliases resolve to their canonical entries.
        assert_eq!(r.resolve("xla-openacc").unwrap().name, "xla-jnp");
        assert_eq!(r.resolve("xla-fused").unwrap().name, "xla-fused-layered");
    }

    #[test]
    fn unknown_name_error_lists_registered() {
        let r = OperatorRegistry::with_builtins();
        let err = r.resolve("cuda").unwrap_err().to_string();
        for name in r.known_names() {
            assert!(err.contains(&name), "error {err:?} missing {name}");
        }
    }

    #[test]
    fn duplicate_registration_errors() {
        let mut r = OperatorRegistry::with_builtins();
        let dup = || Box::new(CpuOp::new("dup", kernel_layered)) as Box<dyn AxOperator>;
        let err = r.register("cpu-layered", false, dup);
        assert!(err.is_err(), "duplicate canonical name accepted");
        // A name colliding with an alias is also rejected.
        let err = r.register("xla-fused", false, dup);
        assert!(err.is_err(), "name shadowing an alias accepted");
        // And so is a duplicate alias, or an alias to nothing.
        assert!(r.alias("xla-openacc", "cpu-naive").is_err());
        assert!(r.alias("fresh-alias", "no-such-op").is_err());
    }

    #[test]
    fn labels_are_canonical_names() {
        // Every builtin's label is exactly its canonical registry name, so
        // labels printed in reports/benches parse back to the operator.
        let r = OperatorRegistry::with_builtins();
        for name in r.names() {
            let op = r.create(&name).unwrap();
            assert_eq!(op.label(), name);
        }
    }

    #[test]
    fn custom_operator_registers_and_applies() {
        /// Test-only operator: identity (w = u).
        #[derive(Default)]
        struct IdentityOp {
            ndof: usize,
        }
        impl AxOperator for IdentityOp {
            fn label(&self) -> String {
                "test-identity".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.ndof = ctx.nelt * ctx.n * ctx.n * ctx.n;
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                if u.len() != self.ndof {
                    return Err(Error::Config("identity: length mismatch".into()));
                }
                w.copy_from_slice(u);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut r = OperatorRegistry::with_builtins();
        r.register("test-identity", false, || Box::<IdentityOp>::default()).unwrap();
        let n = 3;
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; 6 * n * n * n];
        let mut op = r.build("test-identity", &tiny_ctx(n, 1, &d, &g)).unwrap();
        let u: Vec<f64> = (0..n * n * n).map(|i| i as f64).collect();
        let mut w = vec![0.0; n * n * n];
        op.apply(&u, &mut w).unwrap();
        assert_eq!(u, w);
    }

    #[test]
    fn cpu_operators_validate_shapes() {
        let r = OperatorRegistry::with_builtins();
        let n = 3;
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; 6 * n * n * n];
        // Wrong g length at setup.
        let bad = OperatorCtx { g: &g[..10], ..tiny_ctx(n, 1, &d, &g) };
        assert!(r.build("cpu-layered", &bad).is_err());
        // Wrong field length at apply.
        let mut op = r.build("cpu-layered", &tiny_ctx(n, 1, &d, &g)).unwrap();
        let mut w = vec![0.0; 5];
        assert!(op.apply(&[0.0; 27], &mut w).is_err());
        // Un-setup operator refuses to apply.
        let mut blank = r.create("cpu-layered").unwrap();
        let mut w = vec![0.0; 27];
        assert!(blank.apply(&[0.0; 27], &mut w).is_err());
    }

    #[test]
    fn registry_built_cpu_ops_agree() {
        let n = 4;
        let nelt = 2;
        let mut rng = crate::rng::Rng::new(42);
        let u = rng.normal_vec(nelt * n * n * n);
        let g = rng.normal_vec(nelt * 6 * n * n * n);
        let d = crate::basis::derivative_matrix(n);
        let r = OperatorRegistry::with_builtins();
        let mut want = vec![0.0; nelt * n * n * n];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        for name in ["cpu-naive", "cpu-layered", "cpu-threaded"] {
            let mut op = r.build(name, &tiny_ctx(n, nelt, &d, &g)).unwrap();
            let mut w = vec![0.0; nelt * n * n * n];
            op.apply(&u, &mut w).unwrap();
            assert_allclose(&w, &want, 1e-11, 1e-11);
        }
    }
}
