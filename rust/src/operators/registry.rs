//! The operator registry: string names → [`AxOperator`] constructors.
//!
//! This is the **only** module that knows which concrete operator backs
//! which name. Everything else — the application builder, the CLI, the
//! rank runtime, the benches — resolves operators by name through
//! [`OperatorRegistry`] and dispatches through `Box<dyn AxOperator>`.
//!
//! Canonical names are chosen so that `label()` output is re-parseable:
//! every operator's label **is** its canonical registry name. Aliases
//! (`xla-openacc` → `xla-jnp`, `xla-fused` → `xla-fused-layered`) resolve
//! to the canonical entry at parse time.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::geometry::{GeomScalar, Precision};
use crate::operators::asm::AsmOp;
use crate::operators::fused::FusedCpuOp;
use crate::operators::pool::PooledOp;
use crate::operators::{
    ax_bytes_moved, ax_bytes_moved_stored, ax_flops, ax_layered, ax_layered_store, ax_naive,
    ax_simd, ax_simd_f32, ax_spec, ax_spec_store, fused_ax_flops, AxOperator, OperatorCtx,
};
use crate::runtime::{AxEngine, CgIterEngine, Manifest, XlaRuntime};

/// The numerical-accuracy contract an operator declares against the f64
/// reference family, checked operator-by-operator by the conformance suite
/// (`tests/conformance.rs`). Tiers are ordered strict → loose.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PrecisionTier {
    /// Bit-identical to the layered f64 reference schedule
    /// ([`crate::operators::ax_layered`]): same per-point operation order,
    /// same rounding, compared with `==` on every dof. The scalar ladder
    /// (`cpu-layered`, `cpu-spec`, and their fused twins) lives here.
    Exact,
    /// Same f64 arithmetic up to instruction-level reassociation and FMA
    /// contraction (the AVX2 arm, threaded reductions, XLA codegen):
    /// `1e-11`-band agreement with the reference, the repo's historical
    /// conformance tolerance.
    FmaBand,
    /// Geometric factors *stored* in f32 (one rounding per factor at
    /// setup), all arithmetic still f64: agreement within the
    /// cancellation-robust band `1e-5 * (|ref| + max|ref|)`. Only the
    /// `-f32` operator family may declare this tier — the conformance
    /// suite enforces the naming contract both ways.
    ReducedStorage,
}

impl PrecisionTier {
    /// Stable lower-case name (used in conformance reports).
    pub fn as_str(self) -> &'static str {
        match self {
            PrecisionTier::Exact => "exact",
            PrecisionTier::FmaBand => "fma-band",
            PrecisionTier::ReducedStorage => "reduced-storage",
        }
    }
}

impl std::fmt::Display for PrecisionTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The process-wide shared registry: the built-in operator family,
/// constructed once (first call) and shared by every lookup site — the
/// CLI, the benches, and the serve layer all resolve through this one
/// instance, so the alias tables are built once per process, not per
/// call. Callers that need *extra* registrations (tests, downstream
/// crates) still construct their own [`OperatorRegistry`] and pass it to
/// the application builder; this accessor is the default everyone else
/// shares.
///
/// `&'static` is sound because [`OperatorRegistry`] is `Sync` (its
/// constructors are `Send + Sync` closures and lookup never mutates).
pub fn registry() -> &'static OperatorRegistry {
    static INSTANCE: OnceLock<OperatorRegistry> = OnceLock::new();
    INSTANCE.get_or_init(OperatorRegistry::with_builtins)
}

/// Constructor for a blank (un-setup) operator.
pub type OperatorCtor = Box<dyn Fn() -> Box<dyn AxOperator> + Send + Sync>;

/// One registered operator: canonical name, artifact requirement, declared
/// precision tier, and the constructor.
pub struct OperatorSpec {
    /// Canonical registry name (also the operator's label).
    pub name: String,
    /// Does the operator load AOT artifacts / the PJRT runtime?
    pub needs_artifacts: bool,
    /// Accuracy contract vs the f64 reference (see [`PrecisionTier`]).
    pub tier: PrecisionTier,
    /// Can the operator perform dssum + mask inside its sweep when given
    /// an [`OperatorCtx::assemble`] plan (the `cpu-asm` family)? Such
    /// operators report [`crate::operators::ax_bytes_moved_assembled`]
    /// traffic in assembled mode; the conformance suite enforces the
    /// `cpu-asm` naming contract both ways.
    pub assembles: bool,
    ctor: OperatorCtor,
}

impl OperatorSpec {
    /// Construct a blank operator (call `setup` before `apply`).
    pub fn create(&self) -> Box<dyn AxOperator> {
        (self.ctor)()
    }
}

// Hand-rolled: the constructor is a closure, so `derive(Debug)` cannot
// apply; tests (and callers) still want `unwrap_err` & friends on
// `Result<&OperatorSpec, _>`.
impl std::fmt::Debug for OperatorSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OperatorSpec")
            .field("name", &self.name)
            .field("needs_artifacts", &self.needs_artifacts)
            .field("tier", &self.tier)
            .field("assembles", &self.assembles)
            .finish_non_exhaustive()
    }
}

/// Maps operator names to constructors. Third parties (tests, benches,
/// downstream crates) register additional variants at runtime; the
/// application builder accepts a custom registry.
pub struct OperatorRegistry {
    specs: BTreeMap<String, OperatorSpec>,
    aliases: BTreeMap<String, String>,
}

impl Default for OperatorRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl OperatorRegistry {
    /// An empty registry (no names resolve).
    pub fn empty() -> Self {
        OperatorRegistry { specs: BTreeMap::new(), aliases: BTreeMap::new() }
    }

    /// The built-in operator family: the CPU schedules (plain,
    /// degree-specialized, explicit-SIMD, fused, and worker-pool
    /// threaded), their `-f32` reduced-storage twins, the paper's five AOT
    /// kernel variants, and the fused Ax+pap hot paths.
    pub fn with_builtins() -> Self {
        use PrecisionTier::{Exact, FmaBand, ReducedStorage};
        let mut r = Self::empty();
        let must = |res: Result<()>| res.expect("builtin registration cannot clash");
        must(r.register_tiered("cpu-naive", false, FmaBand, || {
            Box::new(CpuOp::new("cpu-naive", kernel_naive))
        }));
        must(r.register_tiered("cpu-layered", false, Exact, || {
            Box::new(CpuOp::new("cpu-layered", kernel_layered))
        }));
        must(r.register_tiered("cpu-spec", false, Exact, || {
            Box::new(CpuOp::new("cpu-spec", kernel_spec))
        }));
        must(r.register_tiered("cpu-simd", false, FmaBand, || {
            Box::new(CpuOp::new("cpu-simd", kernel_simd))
        }));
        must(r.register_tiered("cpu-threaded", false, FmaBand, || {
            Box::new(PooledOp::new("cpu-threaded", false, Precision::F64))
        }));
        must(r.register_tiered("cpu-layered-fused", false, Exact, || {
            Box::new(FusedCpuOp::new("cpu-layered-fused", crate::operators::ax_layered_fused))
        }));
        must(r.register_tiered("cpu-spec-fused", false, Exact, || {
            Box::new(FusedCpuOp::new("cpu-spec-fused", crate::operators::ax_spec_fused))
        }));
        must(r.register_tiered("cpu-simd-fused", false, FmaBand, || {
            Box::new(FusedCpuOp::new("cpu-simd-fused", crate::operators::ax_simd_fused))
        }));
        must(r.register_tiered("cpu-threaded-fused", false, FmaBand, || {
            Box::new(PooledOp::new("cpu-threaded-fused", true, Precision::F64))
        }));
        // The reduced-storage (f32 geometric factors, f64 accumulation)
        // twins of the whole CPU ladder. Same schedules, 6 of the 8
        // per-point streams at half width — the HipBone-style
        // bandwidth/accuracy trade, declared via the ReducedStorage tier.
        must(r.register_tiered("cpu-layered-f32", false, ReducedStorage, || {
            Box::new(CpuOp::new("cpu-layered-f32", kernel_layered_f32))
        }));
        must(r.register_tiered("cpu-spec-f32", false, ReducedStorage, || {
            Box::new(CpuOp::new("cpu-spec-f32", kernel_spec_f32))
        }));
        must(r.register_tiered("cpu-simd-f32", false, ReducedStorage, || {
            Box::new(CpuOp::new("cpu-simd-f32", kernel_simd_f32))
        }));
        must(r.register_tiered("cpu-threaded-f32", false, ReducedStorage, || {
            Box::new(PooledOp::new("cpu-threaded-f32", false, Precision::F32))
        }));
        must(r.register_tiered("cpu-layered-fused-f32", false, ReducedStorage, || {
            Box::new(FusedCpuOp::new(
                "cpu-layered-fused-f32",
                crate::operators::ax_layered_fused_store::<f32>,
            ))
        }));
        must(r.register_tiered("cpu-spec-fused-f32", false, ReducedStorage, || {
            Box::new(FusedCpuOp::new(
                "cpu-spec-fused-f32",
                crate::operators::ax_spec_fused_store::<f32>,
            ))
        }));
        must(r.register_tiered("cpu-simd-fused-f32", false, ReducedStorage, || {
            Box::new(FusedCpuOp::new(
                "cpu-simd-fused-f32",
                crate::operators::ax_simd_fused_f32,
            ))
        }));
        must(r.register_tiered("cpu-threaded-fused-f32", false, ReducedStorage, || {
            Box::new(PooledOp::new("cpu-threaded-fused-f32", true, Precision::F32))
        }));
        // The assembly-fused family: the layered sweep with dssum + mask
        // folded in (when the builder supplies an AssemblyPlan; plain
        // layered otherwise). The f64 pair assembles bitwise identically
        // to sweep-then-dssum, so it shares the Exact tier.
        must(r.register_assembled("cpu-asm", false, Exact, || {
            Box::new(AsmOp::<f64>::new("cpu-asm", false))
        }));
        must(r.register_assembled("cpu-asm-fused", false, Exact, || {
            Box::new(AsmOp::<f64>::new("cpu-asm-fused", true))
        }));
        must(r.register_assembled("cpu-asm-f32", false, ReducedStorage, || {
            Box::new(AsmOp::<f32>::new("cpu-asm-f32", false))
        }));
        must(r.register_assembled("cpu-asm-fused-f32", false, ReducedStorage, || {
            Box::new(AsmOp::<f32>::new("cpu-asm-fused-f32", true))
        }));
        for variant in ["jnp", "original", "shared", "layered", "layered_unroll2"] {
            must(r.register_tiered(&xla_name(variant), true, FmaBand, move || {
                Box::new(XlaAxOp::new(variant))
            }));
        }
        must(r.register_tiered("xla-fused-layered", true, FmaBand, || {
            Box::new(XlaFusedOp::new("layered"))
        }));
        must(r.alias("xla-openacc", "xla-jnp"));
        must(r.alias("xla-fused", "xla-fused-layered"));
        r
    }

    /// Register a constructor under a canonical name, at the default
    /// [`PrecisionTier::FmaBand`] accuracy contract (right for anything
    /// that does full f64 arithmetic without promising the reference's
    /// exact operation order). Errors if the name (or an alias of it) is
    /// already taken.
    pub fn register(
        &mut self,
        name: &str,
        needs_artifacts: bool,
        ctor: impl Fn() -> Box<dyn AxOperator> + Send + Sync + 'static,
    ) -> Result<()> {
        self.register_tiered(name, needs_artifacts, PrecisionTier::FmaBand, ctor)
    }

    /// [`OperatorRegistry::register`] with an explicit precision tier. The
    /// conformance suite holds every registered operator to its declared
    /// tier, and rejects [`PrecisionTier::ReducedStorage`] claims from
    /// operators whose name does not end in `-f32`.
    pub fn register_tiered(
        &mut self,
        name: &str,
        needs_artifacts: bool,
        tier: PrecisionTier,
        ctor: impl Fn() -> Box<dyn AxOperator> + Send + Sync + 'static,
    ) -> Result<()> {
        self.register_spec(name, needs_artifacts, tier, false, ctor)
    }

    /// [`OperatorRegistry::register_tiered`] for operators that perform
    /// assembly inside their sweep when handed an
    /// [`OperatorCtx::assemble`] plan. The conformance suite requires such
    /// names to start with `cpu-asm` (and vice versa), mirroring the
    /// `-f32`/ReducedStorage contract.
    pub fn register_assembled(
        &mut self,
        name: &str,
        needs_artifacts: bool,
        tier: PrecisionTier,
        ctor: impl Fn() -> Box<dyn AxOperator> + Send + Sync + 'static,
    ) -> Result<()> {
        self.register_spec(name, needs_artifacts, tier, true, ctor)
    }

    fn register_spec(
        &mut self,
        name: &str,
        needs_artifacts: bool,
        tier: PrecisionTier,
        assembles: bool,
        ctor: impl Fn() -> Box<dyn AxOperator> + Send + Sync + 'static,
    ) -> Result<()> {
        if self.specs.contains_key(name) || self.aliases.contains_key(name) {
            return Err(Error::Config(format!(
                "operator {name:?} is already registered (registered: {})",
                self.known_names().join(", ")
            )));
        }
        self.specs.insert(
            name.to_string(),
            OperatorSpec {
                name: name.to_string(),
                needs_artifacts,
                tier,
                assembles,
                ctor: Box::new(ctor),
            },
        );
        Ok(())
    }

    /// Register an alias for an existing canonical name.
    pub fn alias(&mut self, alias: &str, target: &str) -> Result<()> {
        if self.specs.contains_key(alias) || self.aliases.contains_key(alias) {
            return Err(Error::Config(format!("operator alias {alias:?} is already taken")));
        }
        if !self.specs.contains_key(target) {
            return Err(Error::Config(format!(
                "alias {alias:?} targets unregistered operator {target:?}"
            )));
        }
        self.aliases.insert(alias.to_string(), target.to_string());
        Ok(())
    }

    /// Resolve a name (canonical or alias) to its spec. The error for an
    /// unknown name lists every registered name.
    ///
    /// # Examples
    ///
    /// ```
    /// use nekbone::operators::OperatorRegistry;
    ///
    /// let registry = OperatorRegistry::with_builtins();
    /// // Canonical names resolve to themselves …
    /// assert_eq!(registry.resolve("cpu-layered").unwrap().name, "cpu-layered");
    /// // … aliases resolve to their canonical entry …
    /// assert_eq!(registry.resolve("xla-fused").unwrap().name, "xla-fused-layered");
    /// // … and an unknown name errors, listing everything registered.
    /// let err = registry.resolve("gpu-magic").err().unwrap().to_string();
    /// assert!(err.contains("cpu-spec"));
    /// ```
    pub fn resolve(&self, name: &str) -> Result<&OperatorSpec> {
        let canonical = self.aliases.get(name).map(String::as_str).unwrap_or(name);
        self.specs.get(canonical).ok_or_else(|| {
            Error::Config(format!(
                "unknown operator {name:?}; registered operators: {}",
                self.known_names().join(", ")
            ))
        })
    }

    /// Is the name (canonical or alias) registered?
    pub fn contains(&self, name: &str) -> bool {
        self.specs.contains_key(name) || self.aliases.contains_key(name)
    }

    /// Construct a blank operator by name (no setup).
    pub fn create(&self, name: &str) -> Result<Box<dyn AxOperator>> {
        Ok(self.resolve(name)?.create())
    }

    /// Construct and set up an operator for one problem.
    pub fn build(&self, name: &str, ctx: &OperatorCtx) -> Result<Box<dyn AxOperator>> {
        let mut op = self.create(name)?;
        op.setup(ctx)?;
        Ok(op)
    }

    /// Canonical names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.specs.keys().cloned().collect()
    }

    /// Canonical names + aliases, sorted (for error messages and `info`).
    pub fn known_names(&self) -> Vec<String> {
        let mut all: Vec<String> =
            self.specs.keys().chain(self.aliases.keys()).cloned().collect();
        all.sort();
        all
    }

    /// The aliases registered for a canonical name, sorted (empty when the
    /// name has none, or is not a canonical name at all). The CLI help is
    /// generated from this plus [`OperatorRegistry::names`], so a new
    /// registration can never be missing from `--backend`'s list.
    pub fn aliases_of(&self, canonical: &str) -> Vec<String> {
        self.aliases
            .iter()
            .filter(|(_, target)| target.as_str() == canonical)
            .map(|(alias, _)| alias.clone())
            .collect()
    }
}

/// Canonical registry name of an XLA kernel variant
/// (`layered_unroll2` → `xla-layered-unroll2`).
fn xla_name(variant: &str) -> String {
    format!("xla-{}", variant.replace('_', "-"))
}

// ---------------------------------------------------------------------------
// CPU operators
// ---------------------------------------------------------------------------

/// Shape + cloned mesh data shared by the single-thread CPU operators,
/// with the geometric factors held at storage width `S` (converted once
/// from the caller's f64 slice at capture — the mixed-precision seam).
struct CpuState<S> {
    n: usize,
    nelt: usize,
    d: Vec<f64>,
    g: Vec<S>,
}

impl<S: GeomScalar> CpuState<S> {
    fn capture(ctx: &OperatorCtx) -> Result<Self> {
        crate::operators::check_setup_shapes(ctx, false)?;
        Ok(CpuState { n: ctx.n, nelt: ctx.nelt, d: ctx.d.to_vec(), g: S::convert(ctx.g) })
    }
}

fn not_setup(label: &str) -> Error {
    Error::Config(format!("operator {label:?} used before setup"))
}

/// Unified single-thread CPU-kernel signature over stored factor width
/// `S` (f64 for the classic family, f32 for the reduced-storage twins).
type CpuKernel<S> = fn(usize, usize, &[f64], &[f64], &[S], &mut [f64]);

fn kernel_naive(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    ax_naive(n, nelt, u, d, g, w);
}

fn kernel_layered(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    ax_layered(n, nelt, u, d, g, w);
}

fn kernel_spec(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    ax_spec(n, nelt, u, d, g, w);
}

fn kernel_simd(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    ax_simd(n, nelt, u, d, g, w);
}

fn kernel_layered_f32(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f32], w: &mut [f64]) {
    ax_layered_store::<f32>(n, nelt, u, d, g, w);
}

fn kernel_spec_f32(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f32], w: &mut [f64]) {
    ax_spec_store::<f32>(n, nelt, u, d, g, w);
}

fn kernel_simd_f32(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f32], w: &mut [f64]) {
    ax_simd_f32(n, nelt, u, d, g, w);
}

/// A single-thread CPU schedule behind the operator trait: `cpu-naive`
/// (Listing-1 structure, full-size intermediates), `cpu-layered` (the
/// paper's schedule), `cpu-spec` (degree-specialized unrolled kernels,
/// layered fallback out of range), `cpu-simd` (explicit AVX2+FMA kernels,
/// runtime-dispatched with a scalar fallback) — and their `-f32` twins,
/// which hold the geometric factors at 4 bytes (converted once at setup)
/// and report the correspondingly smaller stream traffic. The threaded
/// variants (`cpu-threaded*`) live in [`crate::operators::pool`] on a
/// persistent worker pool; the fused single-thread variants
/// (`cpu-*-fused*`) in [`crate::operators::fused`].
struct CpuOp<S: GeomScalar> {
    label: &'static str,
    kernel: CpuKernel<S>,
    st: Option<CpuState<S>>,
}

impl<S: GeomScalar> CpuOp<S> {
    fn new(label: &'static str, kernel: CpuKernel<S>) -> Self {
        CpuOp { label, kernel, st: None }
    }
}

impl<S: GeomScalar> AxOperator for CpuOp<S> {
    fn label(&self) -> String {
        self.label.into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        self.st = Some(CpuState::capture(ctx)?);
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let st = self.st.as_ref().ok_or_else(|| not_setup(self.label))?;
        crate::operators::check_apply_shapes(st.n, st.nelt, u, w)?;
        (self.kernel)(st.n, st.nelt, u, &st.d, &st.g, w);
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_flops(s.n, s.nelt))
    }

    fn bytes_moved(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |s| ax_bytes_moved_stored(s.n, s.nelt, false, S::STORED_BYTES))
    }
}

// ---------------------------------------------------------------------------
// XLA operators (AOT artifacts through the PJRT runtime)
// ---------------------------------------------------------------------------

struct XlaAxState {
    rt: Arc<XlaRuntime>,
    engine: AxEngine,
    n: usize,
    nelt: usize,
}

/// An AOT-compiled kernel variant run via PJRT: "jnp" (OpenACC analog),
/// "original", "shared", "layered" (the paper's contribution),
/// "layered_unroll2" (CUDA-Fortran analog).
struct XlaAxOp {
    variant: &'static str,
    st: Option<XlaAxState>,
}

impl XlaAxOp {
    fn new(variant: &'static str) -> Self {
        XlaAxOp { variant, st: None }
    }
}

impl AxOperator for XlaAxOp {
    fn label(&self) -> String {
        xla_name(self.variant)
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        // Check artifact presence before constructing the PJRT client, so a
        // missing artifact reports as an Artifact error even when the
        // native runtime is unavailable.
        let manifest = Manifest::load(ctx.artifacts_dir)?;
        manifest.find_ax(self.variant, ctx.n, ctx.chunk)?;
        let rt = Arc::new(XlaRuntime::with_manifest(manifest)?);
        let engine =
            AxEngine::new(&rt, self.variant, ctx.n, ctx.chunk, ctx.nelt, ctx.d, ctx.g)?;
        self.st = Some(XlaAxState { rt, engine, n: ctx.n, nelt: ctx.nelt });
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let variant = self.variant;
        let st = self.st.as_mut().ok_or_else(|| not_setup(&xla_name(variant)))?;
        st.engine.apply(&st.rt, u, w)
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_flops(s.n, s.nelt))
    }

    fn bytes_moved(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_bytes_moved(s.n, s.nelt, false))
    }

    fn xla_runtime(&self) -> Option<Arc<XlaRuntime>> {
        self.st.as_ref().map(|s| Arc::clone(&s.rt))
    }
}

struct XlaFusedState {
    rt: Arc<XlaRuntime>,
    engine: CgIterEngine,
    n: usize,
    nelt: usize,
}

/// The fused Ax + partial-pap executable (perf-pass hot path): one launch
/// per chunk computes `w = Ax(p)` and the partial `pap` reduction.
struct XlaFusedOp {
    variant: &'static str,
    st: Option<XlaFusedState>,
    last_pap: Option<f64>,
}

impl XlaFusedOp {
    fn new(variant: &'static str) -> Self {
        XlaFusedOp { variant, st: None, last_pap: None }
    }
}

/// Canonical registry name of a fused variant
/// (`layered` → `xla-fused-layered`).
fn fused_name(variant: &str) -> String {
    format!("xla-fused-{}", variant.replace('_', "-"))
}

impl AxOperator for XlaFusedOp {
    fn label(&self) -> String {
        fused_name(self.variant)
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        // Fused-operator contract (see `operators` module docs): the
        // weights must be present and well-shaped, and a stale pap from a
        // previous setup must not leak through `last_pap`.
        crate::operators::check_setup_shapes(ctx, true)?;
        let manifest = Manifest::load(ctx.artifacts_dir)?;
        manifest.find(&format!("cg_iter_{}_n{}_e{}", self.variant, ctx.n, ctx.chunk))?;
        let rt = Arc::new(XlaRuntime::with_manifest(manifest)?);
        let engine = CgIterEngine::new(
            &rt,
            self.variant,
            ctx.n,
            ctx.chunk,
            ctx.nelt,
            ctx.d,
            ctx.g,
            ctx.c,
        )?;
        self.st = Some(XlaFusedState { rt, engine, n: ctx.n, nelt: ctx.nelt });
        self.last_pap = None;
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let variant = self.variant;
        let st = self.st.as_mut().ok_or_else(|| not_setup(&fused_name(variant)))?;
        let pap = st.engine.apply(&st.rt, u, w)?;
        self.last_pap = Some(pap);
        Ok(())
    }

    fn flops(&self) -> u64 {
        // The fused executable computes the pap reduction in-kernel: count
        // it (see `fused_ax_flops`), or the roofline would credit the
        // fused path with free flops.
        self.st.as_ref().map_or(0, |s| fused_ax_flops(s.n, s.nelt))
    }

    fn bytes_moved(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_bytes_moved(s.n, s.nelt, true))
    }

    fn is_fused(&self) -> bool {
        true
    }

    fn last_pap(&self) -> Option<f64> {
        self.last_pap
    }

    fn xla_runtime(&self) -> Option<Arc<XlaRuntime>> {
        self.st.as_ref().map(|s| Arc::clone(&s.rt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::assert_allclose;

    fn tiny_ctx<'a>(n: usize, nelt: usize, d: &'a [f64], g: &'a [f64]) -> OperatorCtx<'a> {
        OperatorCtx {
            n,
            nelt,
            chunk: nelt,
            threads: 0,
            artifacts_dir: "artifacts",
            d,
            g,
            c: &[],
            assemble: None,
        }
    }

    /// Artifact-free canonical names of one fusion class — derived from
    /// the registry, never hand-listed, so a new CPU registration is
    /// covered by these suites without a list edit.
    fn cpu_names(r: &OperatorRegistry, fused: bool) -> Vec<String> {
        let names: Vec<String> = r
            .names()
            .into_iter()
            .filter(|name| {
                let spec = r.resolve(name).unwrap();
                !spec.needs_artifacts && spec.create().is_fused() == fused
            })
            .collect();
        assert!(names.len() >= 4, "registry lost CPU operators (fused={fused}): {names:?}");
        names
    }

    #[test]
    fn shared_registry_is_one_instance() {
        // `registry()` hands every call site the same process-wide table.
        let a: *const OperatorRegistry = registry();
        let b: *const OperatorRegistry = registry();
        assert_eq!(a, b);
        assert!(registry().contains("cpu-layered"));
        assert_eq!(registry().names(), OperatorRegistry::with_builtins().names());
    }

    #[test]
    fn operators_and_registry_cross_threads() {
        fn assert_send<T: Send + ?Sized>() {}
        fn assert_sync<T: Sync + ?Sized>() {}
        // The serve layer's two hand-off shapes: moving an owned operator
        // to a shard worker, and sharing the registry across acceptors.
        assert_send::<Box<dyn AxOperator>>();
        assert_send::<OperatorRegistry>();
        assert_sync::<OperatorRegistry>();

        // And a built operator really works after the move: set up on this
        // thread, apply on another.
        let n = 4;
        let nelt = 2;
        let d = crate::basis::derivative_matrix(n);
        let mut rng = crate::rng::Rng::new(11);
        let u = rng.normal_vec(nelt * n * n * n);
        let g = rng.normal_vec(nelt * 6 * n * n * n);
        let mut want = vec![0.0; nelt * n * n * n];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        for name in ["cpu-layered", "cpu-threaded"] {
            let mut op = registry().build(name, &tiny_ctx(n, nelt, &d, &g)).unwrap();
            let u = u.clone();
            let got = std::thread::spawn(move || {
                let mut w = vec![0.0; u.len()];
                op.apply(&u, &mut w).unwrap();
                w
            })
            .join()
            .unwrap();
            assert_allclose(&got, &want, 1e-11, 1e-11);
        }
    }

    #[test]
    fn builtins_present() {
        let r = OperatorRegistry::with_builtins();
        for name in [
            "cpu-naive",
            "cpu-layered",
            "cpu-spec",
            "cpu-simd",
            "cpu-threaded",
            "cpu-layered-fused",
            "cpu-spec-fused",
            "cpu-simd-fused",
            "cpu-threaded-fused",
            "cpu-layered-f32",
            "cpu-spec-f32",
            "cpu-simd-f32",
            "cpu-threaded-f32",
            "cpu-layered-fused-f32",
            "cpu-spec-fused-f32",
            "cpu-simd-fused-f32",
            "cpu-threaded-fused-f32",
            "cpu-asm",
            "cpu-asm-fused",
            "cpu-asm-f32",
            "cpu-asm-fused-f32",
            "xla-jnp",
            "xla-original",
            "xla-shared",
            "xla-layered",
            "xla-layered-unroll2",
            "xla-fused-layered",
        ] {
            assert!(r.contains(name), "missing builtin {name}");
            assert_eq!(r.resolve(name).unwrap().name, name);
        }
        // Aliases resolve to their canonical entries.
        assert_eq!(r.resolve("xla-openacc").unwrap().name, "xla-jnp");
        assert_eq!(r.resolve("xla-fused").unwrap().name, "xla-fused-layered");
    }

    #[test]
    fn tiers_match_storage_and_schedule() {
        let r = OperatorRegistry::with_builtins();
        // The ReducedStorage tier and the `-f32` name suffix imply each
        // other — the contract the conformance coverage check enforces for
        // third-party registrations too.
        for name in r.names() {
            let spec = r.resolve(&name).unwrap();
            assert_eq!(
                spec.tier == PrecisionTier::ReducedStorage,
                name.ends_with("-f32"),
                "{name}: tier {} breaks the -f32 naming contract",
                spec.tier
            );
        }
        // The scalar ladder promises bitwise agreement with the layered
        // reference; everything simd/threaded/XLA sits in the FMA band.
        // The asm pair is scalar layered underneath, so it is Exact too.
        for name in [
            "cpu-layered",
            "cpu-spec",
            "cpu-layered-fused",
            "cpu-spec-fused",
            "cpu-asm",
            "cpu-asm-fused",
        ] {
            assert_eq!(r.resolve(name).unwrap().tier, PrecisionTier::Exact, "{name}");
        }
        for name in ["cpu-naive", "cpu-simd", "cpu-threaded", "xla-layered", "xla-fused-layered"]
        {
            assert_eq!(r.resolve(name).unwrap().tier, PrecisionTier::FmaBand, "{name}");
        }
        // Plain `register` defaults new operators to the FMA band.
        let mut r = OperatorRegistry::with_builtins();
        r.register("test-default-tier", false, || {
            Box::new(CpuOp::new("test-default-tier", kernel_layered))
        })
        .unwrap();
        assert_eq!(r.resolve("test-default-tier").unwrap().tier, PrecisionTier::FmaBand);
        // … and to not assembling.
        assert!(!r.resolve("test-default-tier").unwrap().assembles);
    }

    #[test]
    fn assembles_flag_matches_naming_contract() {
        // `assembles` and the `cpu-asm` name prefix imply each other for
        // every builtin — the same both-ways contract the conformance
        // coverage check enforces for third-party registrations.
        let r = OperatorRegistry::with_builtins();
        for name in r.names() {
            let spec = r.resolve(&name).unwrap();
            assert_eq!(
                spec.assembles,
                name.starts_with("cpu-asm"),
                "{name}: assembles={} breaks the cpu-asm naming contract",
                spec.assembles
            );
        }
    }

    #[test]
    fn unknown_name_error_lists_registered() {
        let r = OperatorRegistry::with_builtins();
        let err = r.resolve("cuda").unwrap_err().to_string();
        for name in r.known_names() {
            assert!(err.contains(&name), "error {err:?} missing {name}");
        }
    }

    #[test]
    fn duplicate_registration_errors() {
        let mut r = OperatorRegistry::with_builtins();
        let dup = || Box::new(CpuOp::new("dup", kernel_layered)) as Box<dyn AxOperator>;
        let err = r.register("cpu-layered", false, dup);
        assert!(err.is_err(), "duplicate canonical name accepted");
        // A name colliding with an alias is also rejected.
        let err = r.register("xla-fused", false, dup);
        assert!(err.is_err(), "name shadowing an alias accepted");
        // And so is a duplicate alias, or an alias to nothing.
        assert!(r.alias("xla-openacc", "cpu-naive").is_err());
        assert!(r.alias("fresh-alias", "no-such-op").is_err());
    }

    #[test]
    fn labels_are_canonical_names() {
        // Every builtin's label is exactly its canonical registry name, so
        // labels printed in reports/benches parse back to the operator.
        let r = OperatorRegistry::with_builtins();
        for name in r.names() {
            let op = r.create(&name).unwrap();
            assert_eq!(op.label(), name);
        }
    }

    #[test]
    fn custom_operator_registers_and_applies() {
        /// Test-only operator: identity (w = u).
        #[derive(Default)]
        struct IdentityOp {
            ndof: usize,
        }
        impl AxOperator for IdentityOp {
            fn label(&self) -> String {
                "test-identity".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.ndof = ctx.nelt * ctx.n * ctx.n * ctx.n;
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                if u.len() != self.ndof {
                    return Err(Error::Config("identity: length mismatch".into()));
                }
                w.copy_from_slice(u);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut r = OperatorRegistry::with_builtins();
        r.register("test-identity", false, || Box::<IdentityOp>::default()).unwrap();
        let n = 3;
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; 6 * n * n * n];
        let mut op = r.build("test-identity", &tiny_ctx(n, 1, &d, &g)).unwrap();
        let u: Vec<f64> = (0..n * n * n).map(|i| i as f64).collect();
        let mut w = vec![0.0; n * n * n];
        op.apply(&u, &mut w).unwrap();
        assert_eq!(u, w);
    }

    #[test]
    fn cpu_operators_validate_shapes() {
        let r = OperatorRegistry::with_builtins();
        let n = 3;
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; 6 * n * n * n];
        // Wrong g length at setup.
        let bad = OperatorCtx { g: &g[..10], ..tiny_ctx(n, 1, &d, &g) };
        assert!(r.build("cpu-layered", &bad).is_err());
        // Wrong field length at apply.
        let mut op = r.build("cpu-layered", &tiny_ctx(n, 1, &d, &g)).unwrap();
        let mut w = vec![0.0; 5];
        assert!(op.apply(&[0.0; 27], &mut w).is_err());
        // Un-setup operator refuses to apply.
        let mut blank = r.create("cpu-layered").unwrap();
        let mut w = vec![0.0; 27];
        assert!(blank.apply(&[0.0; 27], &mut w).is_err());
    }

    #[test]
    fn fused_cpu_ops_build_and_report_pap() {
        let r = OperatorRegistry::with_builtins();
        let n = 4;
        let nelt = 2;
        let np = n * n * n;
        let mut rng = crate::rng::Rng::new(7);
        let u = rng.normal_vec(nelt * np);
        let g = rng.normal_vec(nelt * 6 * np);
        let c: Vec<f64> = (0..nelt * np).map(|i| 0.5 + (i % 3) as f64 * 0.25).collect();
        let d = crate::basis::derivative_matrix(n);
        let ctx = OperatorCtx { c: &c, ..tiny_ctx(n, nelt, &d, &g) };
        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        let want_pap = crate::solver::glsc3(&want, &c, &u);
        for name in &cpu_names(&r, true) {
            let mut op = r.build(name, &ctx).unwrap();
            assert!(op.is_fused(), "{name} must declare itself fused");
            assert_eq!(op.last_pap(), None, "{name}: no pap before first apply");
            let mut w = vec![0.0; nelt * np];
            op.apply(&u, &mut w).unwrap();
            let pap = op
                .last_pap()
                .unwrap_or_else(|| panic!("{name}: fused apply must produce a pap"));
            if name.ends_with("-f32") {
                // Reduced-storage band vs the f64 reference output …
                let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
                for (a, b) in w.iter().zip(&want) {
                    assert!((a - b).abs() <= 1e-5 * (b.abs() + scale), "{name}: {a} vs {b}");
                }
                // … but the fused contract — pap is glsc3 of the
                // operator's *own* output — holds at full f64 strictness.
                let own_pap = crate::solver::glsc3(&w, &c, &u);
                crate::proputil::assert_pap_close(pap, own_pap, &w, &c, &u, 1e-12, name);
            } else {
                assert_allclose(&w, &want, 1e-11, 1e-11);
                // Term-scaled tolerance (see `assert_pap_close`): the
                // simd-dispatched operators differ from the layered want by
                // FMA rounding, and a cancelling signed sum must not blow
                // up a plain relative check.
                crate::proputil::assert_pap_close(pap, want_pap, &w, &c, &u, 1e-12, name);
            }
        }
    }

    #[test]
    fn fused_cpu_ops_require_weights_at_setup() {
        let r = OperatorRegistry::with_builtins();
        let n = 3;
        let d = crate::basis::derivative_matrix(n);
        let g = vec![0.0; 6 * n * n * n];
        for name in &cpu_names(&r, true) {
            let err = r.build(name, &tiny_ctx(n, 1, &d, &g)).unwrap_err().to_string();
            assert!(err.contains("weights"), "{name}: {err}");
        }
        // The unfused operators accept an empty c (they never read it).
        assert!(r.build("cpu-threaded", &tiny_ctx(n, 1, &d, &g)).is_ok());
    }

    #[test]
    fn registry_built_cpu_ops_agree() {
        let n = 4;
        let nelt = 2;
        let mut rng = crate::rng::Rng::new(42);
        let u = rng.normal_vec(nelt * n * n * n);
        let g = rng.normal_vec(nelt * 6 * n * n * n);
        let d = crate::basis::derivative_matrix(n);
        let r = OperatorRegistry::with_builtins();
        let mut want = vec![0.0; nelt * n * n * n];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        for name in &cpu_names(&r, false) {
            let mut op = r.build(name, &tiny_ctx(n, nelt, &d, &g)).unwrap();
            let mut w = vec![0.0; nelt * n * n * n];
            op.apply(&u, &mut w).unwrap();
            if name.ends_with("-f32") {
                let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
                for (a, b) in w.iter().zip(&want) {
                    assert!((a - b).abs() <= 1e-5 * (b.abs() + scale), "{name}: {a} vs {b}");
                }
            } else {
                assert_allclose(&w, &want, 1e-11, 1e-11);
            }
        }
    }
}
