//! Multi-threaded CPU Ax: the explicit-SIMD kernel family parallelized
//! over elements with scoped std threads — the analog of the paper's
//! 28-core CPU baseline (Fig. 3, "one node with 28 cores and MPI for
//! parallelization").
//!
//! This is the **one-shot** entry point: it spawns and joins its threads on
//! every call, which is fine for a single application but wasteful inside a
//! solver loop (~100 applies per solve). The registered `cpu-threaded` /
//! `cpu-threaded-fused` operators instead run on a persistent
//! [`super::pool::WorkerPool`] spawned once at operator `setup`; both use
//! the same contiguous element split **and** the same per-element kernel
//! dispatch ([`super::ax_simd`]), so their outputs are bit-identical to
//! this function's.

use super::pool::{element_counts, resolve_threads};
use super::simd::ax_simd;

/// Explicit-SIMD Ax over `nthreads` workers (`0` = one per available
/// core). Elements are split into contiguous ranges (the same
/// [`element_counts`] split the worker pool uses, so the two paths are
/// bit-identical); each worker owns a disjoint slice of `w`, so no
/// synchronization is needed beyond the join.
pub fn ax_threaded(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    w: &mut [f64],
    nthreads: usize,
) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(w.len(), nelt * np);
    let nthreads = resolve_threads(nthreads, nelt);

    if nthreads <= 1 || nelt == 0 {
        ax_simd(n, nelt, u, d, g, w);
        return;
    }

    std::thread::scope(|scope| {
        let mut w_rest = &mut w[..];
        let mut start = 0usize;
        for count in element_counts(nelt, nthreads) {
            let (w_mine, tail) = w_rest.split_at_mut(count * np);
            w_rest = tail;
            let u_mine = &u[start * np..(start + count) * np];
            let g_mine = &g[start * 6 * np..(start + count) * 6 * np];
            scope.spawn(move || {
                ax_simd(n, count, u_mine, d, g_mine, w_mine);
            });
            start += count;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{assert_allclose, Cases};

    #[test]
    fn matches_single_thread_any_thread_count() {
        let mut c = Cases::new(7);
        let (n, nelt) = (5, 7); // odd counts exercise the remainder split
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let mut want = vec![0.0; nelt * np];
        ax_simd(n, nelt, &u, &d, &g, &mut want);
        for nthreads in [1, 2, 3, 7, 16] {
            let mut got = vec![0.0; nelt * np];
            ax_threaded(n, nelt, &u, &d, &g, &mut got, nthreads);
            assert_allclose(&got, &want, 0.0, 0.0); // bit-identical
        }
    }

    #[test]
    fn more_threads_than_elements() {
        let mut c = Cases::new(8);
        let (n, nelt) = (3, 2);
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let mut a = vec![0.0; nelt * np];
        let mut b = vec![0.0; nelt * np];
        ax_threaded(n, nelt, &u, &d, &g, &mut a, 64);
        ax_simd(n, nelt, &u, &d, &g, &mut b);
        assert_eq!(a, b);
    }
}
