//! Naive CPU Ax: a faithful transcription of paper Listing 1 with the three
//! gradient intermediates materialized at full size — the structure of the
//! *original* GPU implementation (global memory, poor temporal locality).
//! Allocates per call, exactly like the original round-trips through DRAM.

/// Local Poisson operator, Listing-1 structure.
///
/// `u`: `nelt*n^3`, `d`: `n^2` row-major, `g`: `nelt*6*n^3`;
/// `w` (output): `nelt*n^3`, fully overwritten.
pub fn ax_naive(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(w.len(), nelt * np);

    // Full-size intermediates: the "global memory" round-trip.
    let mut ur = vec![0.0; nelt * np];
    let mut us = vec![0.0; nelt * np];
    let mut ut = vec![0.0; nelt * np];

    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        let ge = &g[e * 6 * np..(e + 1) * 6 * np];
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    let (mut wr, mut ws, mut wt) = (0.0, 0.0, 0.0);
                    for l in 0..n {
                        wr += d[i * n + l] * ue[(k * n + j) * n + l];
                        ws += d[j * n + l] * ue[(k * n + l) * n + i];
                        wt += d[k * n + l] * ue[(l * n + j) * n + i];
                    }
                    let p = (k * n + j) * n + i;
                    let idx = e * np + p;
                    ur[idx] = ge[p] * wr + ge[np + p] * ws + ge[2 * np + p] * wt;
                    us[idx] = ge[np + p] * wr + ge[3 * np + p] * ws + ge[4 * np + p] * wt;
                    ut[idx] = ge[2 * np + p] * wr + ge[4 * np + p] * ws + ge[5 * np + p] * wt;
                }
            }
        }
    }

    for e in 0..nelt {
        let ure = &ur[e * np..(e + 1) * np];
        let use_ = &us[e * np..(e + 1) * np];
        let ute = &ut[e * np..(e + 1) * np];
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    let mut acc = 0.0;
                    for l in 0..n {
                        // dxtm1(a, l) = d(l, a)
                        acc += d[l * n + i] * ure[(k * n + j) * n + l];
                        acc += d[l * n + j] * use_[(k * n + l) * n + i];
                        acc += d[l * n + k] * ute[(l * n + j) * n + i];
                    }
                    w[e * np + (k * n + j) * n + i] = acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_element_smallest_n() {
        // n = 2, nelt = 1: compare against hand-expanded contraction at one point.
        let n = 2;
        let d = crate::basis::derivative_matrix(n); // [[-0.5, 0.5], [-0.5, 0.5]]
        let u: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let g = vec![1.0; 6 * 8]; // all factors 1
        let mut w = vec![0.0; 8];
        ax_naive(n, 1, &u, &d, &g, &mut w);
        // wr(i,j,k) = sum_l d[i,l] u(l,j,k); u = i + 2j + 4k is linear with
        // slope (per reference coordinate on [-1,1]) 1/2 along i, 1 along j,
        // 2 along k: wr = 0.5, ws = 1, wt = 2. With all g = 1:
        // ur = us = ut = 3.5.
        // Stage 2: w = sum_l (d[l,i] + d[l,j] + d[l,k]) * 3.5; column sums
        // of d for n=2 are [-1, 1].
        let colsum = [-1.0, 1.0];
        for k in 0..2 {
            for j in 0..2 {
                for i in 0..2 {
                    let want = 3.5 * (colsum[i] + colsum[j] + colsum[k]);
                    let got = w[(k * 2 + j) * 2 + i];
                    assert!((got - want).abs() < 1e-12, "({i},{j},{k}): {got} vs {want}");
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        let mut w = vec![0.0; 8];
        ax_naive(2, 1, &[0.0; 7], &[0.0; 4], &[0.0; 48], &mut w);
    }
}
