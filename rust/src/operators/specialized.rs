//! Degree-specialized tensor-product kernels: the paper's headline
//! optimization (section IV, "r3" / unrolled versions), on CPU.
//!
//! The layered schedule ([`super::ax_layered`]) runs one kernel for every
//! polynomial degree, so all inner contraction loops have runtime trip
//! counts and every tile lives behind a `Vec` indirection. The paper's
//! fastest kernels instead *specialize per degree*: the CUDA templates are
//! instantiated once per `N`, the `i`/`j`/`k` loops fully unroll, and the
//! per-layer line buffers become registers (Świrydowicz et al.,
//! arXiv:1711.00903 measure exactly this unrolling as what closes the gap
//! for small tensor contractions; HipBone, arXiv:2202.12477, ships the
//! same per-degree kernel selection at run time).
//!
//! Rust's analog of the CUDA template is a const-generic function:
//! `ax_element_spec` is monomorphized for every `N` in
//! [`SPEC_MIN_N`]`..=`[`SPEC_MAX_N`], with the per-layer tiles held in
//! `[[f64; N]; N]` arrays so the compiler can unroll the length-`N`
//! contractions and keep lines of `d` and `u` in registers. A degree
//! table ([`ax_spec`], [`ax_spec_fused`]) dispatches a runtime `n` to its
//! monomorphized instance and **falls back to the generic layered kernel**
//! for out-of-range degrees — `cpu-spec` never errors on an exotic `n`,
//! it just stops being special.
//!
//! Determinism contract: every floating-point operation happens in exactly
//! the order of the layered kernel's `ax_layered_element`, so the specialized
//! kernels are **bit-identical** to the layered ones (asserted by tests,
//! relied on by the worker pool, which dispatches through this table for
//! `cpu-threaded` / `cpu-threaded-fused` too).

use crate::geometry::{widen_into, GeomScalar};
use crate::operators::fused::{ax_layered_fused, ax_layered_fused_store};
use crate::operators::layered::{ax_layered, ax_layered_store};

/// Smallest `n` with a monomorphized kernel.
pub const SPEC_MIN_N: usize = 2;

/// Largest `n` with a monomorphized kernel (the paper's degree sweep tops
/// out at degree 11, i.e. `n = 12`).
pub const SPEC_MAX_N: usize = 12;

/// Does `n` have a degree-specialized kernel instance, or will the
/// dispatch table fall back to the generic layered kernel?
pub fn is_specialized(n: usize) -> bool {
    (SPEC_MIN_N..=SPEC_MAX_N).contains(&n)
}

/// One element of the degree-specialized schedule: `we = A_local u_e`,
/// structurally identical to `ax_layered_element` but with compile-time
/// trip counts and stack tiles. Keep the floating-point operation order in
/// lockstep with the layered kernel — bit-identical output is a tested
/// contract, not an accident.
fn ax_element_spec<const N: usize>(d: &[f64], ue: &[f64], ge: &[f64], we: &mut [f64]) {
    let nn = N * N;
    let np = nn * N;
    let mut wr = [[0.0f64; N]; N];
    let mut ws = [[0.0f64; N]; N];
    let mut wt = [[0.0f64; N]; N];
    let mut ur = [[0.0f64; N]; N];
    let mut us = [[0.0f64; N]; N];
    let mut ut = [[0.0f64; N]; N];
    we.fill(0.0);

    for k in 0..N {
        let uk = &ue[k * nn..(k + 1) * nn]; // the staged layer
        // stage 1: r and s derivatives from the layer tile.
        for j in 0..N {
            for i in 0..N {
                let mut accr = 0.0;
                let mut accs = 0.0;
                for l in 0..N {
                    accr += d[i * N + l] * uk[j * N + l];
                    accs += d[j * N + l] * uk[l * N + i];
                }
                wr[j][i] = accr;
                ws[j][i] = accs;
            }
        }
        // t derivative from the register column u(i,j,:).
        for j in 0..N {
            for i in 0..N {
                let mut acc = 0.0;
                for l in 0..N {
                    acc += d[k * N + l] * ue[l * nn + j * N + i];
                }
                wt[j][i] = acc;
            }
        }
        // geometric factors, loaded per layer
        let gbase = k * nn;
        for j in 0..N {
            for i in 0..N {
                let p = gbase + j * N + i;
                let g11 = ge[p];
                let g12 = ge[np + p];
                let g13 = ge[2 * np + p];
                let g22 = ge[3 * np + p];
                let g23 = ge[4 * np + p];
                let g33 = ge[5 * np + p];
                ur[j][i] = g11 * wr[j][i] + g12 * ws[j][i] + g13 * wt[j][i];
                us[j][i] = g12 * wr[j][i] + g22 * ws[j][i] + g23 * wt[j][i];
                ut[j][i] = g13 * wr[j][i] + g23 * ws[j][i] + g33 * wt[j][i];
            }
        }
        // stage 2, r/s parts land in layer k
        for j in 0..N {
            for i in 0..N {
                let mut acc = 0.0;
                for l in 0..N {
                    acc += d[l * N + i] * ur[j][l];
                    acc += d[l * N + j] * us[l][i];
                }
                we[k * nn + j * N + i] += acc;
            }
        }
        // stage 2, t part scatters into all layers m with weight d[k,m]
        // (the `if` guard is part of the bit-identical contract: skipping a
        // zero weight is not the same as adding ±0.0).
        for m in 0..N {
            let dkm = d[k * N + m];
            if dkm != 0.0 {
                for j in 0..N {
                    for i in 0..N {
                        we[m * nn + j * N + i] += dkm * ut[j][i];
                    }
                }
            }
        }
    }
}

/// One element at a dynamic (but specialized) degree: the per-element
/// dispatch the mixed-precision drivers use after widening their factor
/// tile. Callers must have checked [`is_specialized`].
fn ax_element_spec_dyn(n: usize, d: &[f64], ue: &[f64], ge: &[f64], we: &mut [f64]) {
    match n {
        2 => ax_element_spec::<2>(d, ue, ge, we),
        3 => ax_element_spec::<3>(d, ue, ge, we),
        4 => ax_element_spec::<4>(d, ue, ge, we),
        5 => ax_element_spec::<5>(d, ue, ge, we),
        6 => ax_element_spec::<6>(d, ue, ge, we),
        7 => ax_element_spec::<7>(d, ue, ge, we),
        8 => ax_element_spec::<8>(d, ue, ge, we),
        9 => ax_element_spec::<9>(d, ue, ge, we),
        10 => ax_element_spec::<10>(d, ue, ge, we),
        11 => ax_element_spec::<11>(d, ue, ge, we),
        12 => ax_element_spec::<12>(d, ue, ge, we),
        _ => unreachable!("ax_element_spec_dyn: caller must check is_specialized({n})"),
    }
}

/// Whole-mesh driver for one monomorphized degree.
fn ax_spec_mesh<const N: usize>(nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    let np = N * N * N;
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        let ge = &g[e * 6 * np..(e + 1) * 6 * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_element_spec::<N>(d, ue, ge, we);
    }
}

/// Whole-mesh fused driver for one monomorphized degree: the pap
/// reduction streams per element in linear dof order, exactly like
/// [`ax_layered_fused`].
fn ax_spec_fused_mesh<const N: usize>(
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    let np = N * N * N;
    let mut pap = 0.0;
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        let ge = &g[e * 6 * np..(e + 1) * 6 * np];
        let ce = &c[e * np..(e + 1) * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_element_spec::<N>(d, ue, ge, we);
        let mut pap_e = 0.0;
        for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
            pap_e += wi * ci * ui;
        }
        pap += pap_e;
    }
    pap
}

fn check_shapes(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &[f64]) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(w.len(), nelt * np);
}

/// Degree-dispatched local Poisson operator: the monomorphized kernel for
/// `n` in [`SPEC_MIN_N`]`..=`[`SPEC_MAX_N`], the generic layered kernel
/// otherwise. Signature and layout as [`super::ax_layered`]; output is
/// bit-identical to it at every degree.
pub fn ax_spec(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    check_shapes(n, nelt, u, d, g, w);
    match n {
        2 => ax_spec_mesh::<2>(nelt, u, d, g, w),
        3 => ax_spec_mesh::<3>(nelt, u, d, g, w),
        4 => ax_spec_mesh::<4>(nelt, u, d, g, w),
        5 => ax_spec_mesh::<5>(nelt, u, d, g, w),
        6 => ax_spec_mesh::<6>(nelt, u, d, g, w),
        7 => ax_spec_mesh::<7>(nelt, u, d, g, w),
        8 => ax_spec_mesh::<8>(nelt, u, d, g, w),
        9 => ax_spec_mesh::<9>(nelt, u, d, g, w),
        10 => ax_spec_mesh::<10>(nelt, u, d, g, w),
        11 => ax_spec_mesh::<11>(nelt, u, d, g, w),
        12 => ax_spec_mesh::<12>(nelt, u, d, g, w),
        _ => ax_layered(n, nelt, u, d, g, w),
    }
}

/// Degree-dispatched fused Ax+pap: computes `w = A_local(u)` exactly as
/// [`ax_spec`] and returns `pap = Σ_i w_i c_i u_i` over the local dofs
/// (same contract, and bit-identical result, as
/// [`super::ax_layered_fused`]). Falls back to the generic fused layered
/// kernel for out-of-range degrees.
pub fn ax_spec_fused(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    check_shapes(n, nelt, u, d, g, w);
    assert_eq!(c.len(), nelt * n * n * n);
    match n {
        2 => ax_spec_fused_mesh::<2>(nelt, u, d, g, c, w),
        3 => ax_spec_fused_mesh::<3>(nelt, u, d, g, c, w),
        4 => ax_spec_fused_mesh::<4>(nelt, u, d, g, c, w),
        5 => ax_spec_fused_mesh::<5>(nelt, u, d, g, c, w),
        6 => ax_spec_fused_mesh::<6>(nelt, u, d, g, c, w),
        7 => ax_spec_fused_mesh::<7>(nelt, u, d, g, c, w),
        8 => ax_spec_fused_mesh::<8>(nelt, u, d, g, c, w),
        9 => ax_spec_fused_mesh::<9>(nelt, u, d, g, c, w),
        10 => ax_spec_fused_mesh::<10>(nelt, u, d, g, c, w),
        11 => ax_spec_fused_mesh::<11>(nelt, u, d, g, c, w),
        12 => ax_spec_fused_mesh::<12>(nelt, u, d, g, c, w),
        _ => ax_layered_fused(n, nelt, u, d, g, c, w),
    }
}

/// Degree-dispatched driver over geometric factors stored at width `S`:
/// each element's factors widen into one L1-resident f64 tile, then the
/// unchanged monomorphized kernel runs — the same per-point operation
/// order as [`ax_spec`] by construction (`::<f64>` is bit-identical to
/// it). Out-of-range degrees fall back to [`ax_layered_store`].
pub fn ax_spec_store<S: GeomScalar>(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[S],
    w: &mut [f64],
) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(w.len(), nelt * np);
    if !is_specialized(n) {
        return ax_layered_store::<S>(n, nelt, u, d, g, w);
    }
    let mut ge64 = vec![0.0f64; 6 * np];
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
        let we = &mut w[e * np..(e + 1) * np];
        ax_element_spec_dyn(n, d, ue, &ge64, we);
    }
}

/// Degree-dispatched fused Ax+pap over stored width `S`: `w` exactly as
/// [`ax_spec_store`], pap reduced per element in linear dof order like
/// [`ax_spec_fused`] (the f64 instantiation is bit-identical to it).
pub fn ax_spec_fused_store<S: GeomScalar>(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[S],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(c.len(), nelt * np);
    assert_eq!(w.len(), nelt * np);
    if !is_specialized(n) {
        return ax_layered_fused_store::<S>(n, nelt, u, d, g, c, w);
    }
    let mut ge64 = vec![0.0f64; 6 * np];
    let mut pap = 0.0;
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
        let ce = &c[e * np..(e + 1) * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_element_spec_dyn(n, d, ue, &ge64, we);
        let mut pap_e = 0.0;
        for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
            pap_e += wi * ci * ui;
        }
        pap += pap_e;
    }
    pap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::Cases;

    fn inputs(seed: u64, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut cases = Cases::new(seed);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        (u, d, g, c)
    }

    #[test]
    fn bit_identical_to_layered_at_every_specialized_degree() {
        for n in SPEC_MIN_N..=SPEC_MAX_N {
            let nelt = 3;
            let (u, d, g, _c) = inputs(0x51 + n as u64, n, nelt);
            let np = n * n * n;
            let mut want = vec![0.0; nelt * np];
            ax_layered(n, nelt, &u, &d, &g, &mut want);
            let mut got = vec![123.0; nelt * np]; // poisoned
            ax_spec(n, nelt, &u, &d, &g, &mut got);
            assert_eq!(got, want, "n={n}: spec kernel must be bit-identical to layered");
        }
    }

    #[test]
    fn fused_spec_bit_identical_to_fused_layered() {
        for n in SPEC_MIN_N..=SPEC_MAX_N {
            let nelt = 2;
            let (u, d, g, c) = inputs(0x52 + n as u64, n, nelt);
            let np = n * n * n;
            let mut w_l = vec![0.0; nelt * np];
            let pap_l = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w_l);
            let mut w_s = vec![123.0; nelt * np];
            let pap_s = ax_spec_fused(n, nelt, &u, &d, &g, &c, &mut w_s);
            assert_eq!(w_s, w_l, "n={n}");
            assert_eq!(pap_s.to_bits(), pap_l.to_bits(), "n={n}: {pap_s} vs {pap_l}");
        }
    }

    #[test]
    fn out_of_range_degree_falls_back() {
        let n = SPEC_MAX_N + 1;
        assert!(!is_specialized(n));
        let nelt = 1;
        let (u, d, g, c) = inputs(0x53, n, nelt);
        let np = n * n * n;
        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        let mut got = vec![0.0; nelt * np];
        ax_spec(n, nelt, &u, &d, &g, &mut got);
        assert_eq!(got, want, "fallback must be the layered kernel");
        let mut w = vec![0.0; nelt * np];
        let pap = ax_spec_fused(n, nelt, &u, &d, &g, &c, &mut w);
        let want_pap = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut got);
        assert_eq!(pap.to_bits(), want_pap.to_bits());
    }

    #[test]
    fn store_f64_instantiation_is_bit_identical_including_fallback() {
        for n in [SPEC_MIN_N, 7, SPEC_MAX_N, SPEC_MAX_N + 1] {
            let nelt = 2;
            let (u, d, g, c) = inputs(0x54 + n as u64, n, nelt);
            let np = n * n * n;
            let mut want = vec![0.0; nelt * np];
            ax_spec(n, nelt, &u, &d, &g, &mut want);
            let mut got = vec![123.0; nelt * np];
            ax_spec_store::<f64>(n, nelt, &u, &d, &g, &mut got);
            assert_eq!(got, want, "n={n}");
            let mut w_f = vec![0.0; nelt * np];
            let pap_f = ax_spec_fused(n, nelt, &u, &d, &g, &c, &mut w_f);
            let mut w_s = vec![123.0; nelt * np];
            let pap_s = ax_spec_fused_store::<f64>(n, nelt, &u, &d, &g, &c, &mut w_s);
            assert_eq!(w_s, w_f, "n={n}: fused w");
            assert_eq!(pap_s.to_bits(), pap_f.to_bits(), "n={n}: fused pap");
        }
    }

    #[test]
    fn store_f32_equals_spec_on_prerounded_factors() {
        // Feed the f64 kernel factors that are *already* f32-rounded: the
        // mixed path must then agree bitwise (widening is exact, and the
        // arithmetic is the same f64 operation order).
        for n in [3usize, 9, SPEC_MAX_N + 2] {
            let nelt = 2;
            let (u, d, g, c) = inputs(0x55 + n as u64, n, nelt);
            let np = n * n * n;
            let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
            let g_rounded: Vec<f64> = g32.iter().map(|&x| x as f64).collect();
            let mut want = vec![0.0; nelt * np];
            ax_spec(n, nelt, &u, &d, &g_rounded, &mut want);
            let mut got = vec![123.0; nelt * np];
            ax_spec_store::<f32>(n, nelt, &u, &d, &g32, &mut got);
            assert_eq!(got, want, "n={n}: widened path must match pre-rounded f64 path");
            let mut w_f = vec![0.0; nelt * np];
            let pap_f = ax_spec_fused(n, nelt, &u, &d, &g_rounded, &c, &mut w_f);
            let mut w_s = vec![0.0; nelt * np];
            let pap_s = ax_spec_fused_store::<f32>(n, nelt, &u, &d, &g32, &c, &mut w_s);
            assert_eq!(w_s, w_f, "n={n}");
            assert_eq!(pap_s.to_bits(), pap_f.to_bits(), "n={n}");
        }
    }

    #[test]
    fn specialization_range() {
        assert!(!is_specialized(1));
        assert!(is_specialized(SPEC_MIN_N));
        assert!(is_specialized(SPEC_MAX_N));
        assert!(!is_specialized(SPEC_MAX_N + 1));
    }
}
