//! Layered CPU Ax: the paper's 2D-thread-structure schedule, on CPU.
//!
//! One element at a time, sweeping the k layers: per layer the r/s
//! contractions read an (n,n) tile that stays in L1, the t contraction reads
//! the element's "register column", and the stage-2 t part scatters into a
//! per-element accumulator — the same dataflow as the CUDA kernel and the
//! Pallas kernel (`ax_layered.py`), with no full-size intermediates.

use crate::geometry::{widen_into, GeomScalar};

/// Per-layer tiles of the layered schedule (the CUDA kernel's
/// shared-memory arrays), allocated once and reused across elements so the
/// per-element routine stays alloc-free.
pub(crate) struct LayeredScratch {
    wr: Vec<f64>,
    ws: Vec<f64>,
    wt: Vec<f64>,
    ur: Vec<f64>,
    us: Vec<f64>,
    ut: Vec<f64>,
}

impl LayeredScratch {
    pub(crate) fn new(n: usize) -> Self {
        let nn = n * n;
        LayeredScratch {
            wr: vec![0.0; nn],
            ws: vec![0.0; nn],
            wt: vec![0.0; nn],
            ur: vec![0.0; nn],
            us: vec![0.0; nn],
            ut: vec![0.0; nn],
        }
    }
}

/// One element of the layered schedule: `we = A_local u_e`. Slices are the
/// element's own `n^3` field (`ue`, `we`) and `6 n^3` geometric factors
/// (`ge`); `we` is fully overwritten. Shared by [`ax_layered`] and the
/// fused Ax+pap kernel ([`super::ax_layered_fused`]) so the two schedules
/// cannot drift apart.
pub(crate) fn ax_layered_element(
    n: usize,
    d: &[f64],
    ue: &[f64],
    ge: &[f64],
    we: &mut [f64],
    s: &mut LayeredScratch,
) {
    let nn = n * n;
    let np = nn * n;
    let (wr, ws, wt) = (&mut s.wr, &mut s.ws, &mut s.wt);
    let (ur, us, ut) = (&mut s.ur, &mut s.us, &mut s.ut);
    we.fill(0.0);

    for k in 0..n {
        let uk = &ue[k * nn..(k + 1) * nn]; // the staged layer
        // stage 1: r and s derivatives from the layer tile
        // (two (n,n)x(n,n) matmuls — the MXU-shaped pair).
        for j in 0..n {
            for i in 0..n {
                let mut accr = 0.0;
                let mut accs = 0.0;
                for l in 0..n {
                    accr += d[i * n + l] * uk[j * n + l];
                    accs += d[j * n + l] * uk[l * n + i];
                }
                wr[j * n + i] = accr;
                ws[j * n + i] = accs;
            }
        }
        // t derivative from the register column u(i,j,:).
        let dk = &d[k * n..(k + 1) * n];
        for p in 0..nn {
            let mut acc = 0.0;
            for l in 0..n {
                acc += dk[l] * ue[l * nn + p];
            }
            wt[p] = acc;
        }
        // geometric factors, preloaded per layer
        let gk = |m: usize| &ge[m * np + k * nn..m * np + (k + 1) * nn];
        let (g11, g12, g13, g22, g23, g33) = (gk(0), gk(1), gk(2), gk(3), gk(4), gk(5));
        for p in 0..nn {
            ur[p] = g11[p] * wr[p] + g12[p] * ws[p] + g13[p] * wt[p];
            us[p] = g12[p] * wr[p] + g22[p] * ws[p] + g23[p] * wt[p];
            ut[p] = g13[p] * wr[p] + g23[p] * ws[p] + g33[p] * wt[p];
        }
        // stage 2, r/s parts land in layer k
        for j in 0..n {
            for i in 0..n {
                let mut acc = 0.0;
                for l in 0..n {
                    acc += d[l * n + i] * ur[j * n + l];
                    acc += d[l * n + j] * us[l * n + i];
                }
                we[k * nn + j * n + i] += acc;
            }
        }
        // stage 2, t part scatters into all layers m with weight d[k,m]
        // (the CUDA per-thread register accumulator rw[m]).
        for m in 0..n {
            let dkm = d[k * n + m];
            if dkm != 0.0 {
                let wm = &mut we[m * nn..(m + 1) * nn];
                for p in 0..nn {
                    wm[p] += dkm * ut[p];
                }
            }
        }
    }
}

/// Local Poisson operator with the layered schedule. Signature and layout
/// as [`super::ax_naive`]. Scratch is stack/small-heap per element tile; the
/// only `n^3` temporary is the per-element output accumulator written once.
pub fn ax_layered(n: usize, nelt: usize, u: &[f64], d: &[f64], g: &[f64], w: &mut [f64]) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(w.len(), nelt * np);

    let mut scratch = LayeredScratch::new(n);
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        let ge = &g[e * 6 * np..(e + 1) * 6 * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_layered_element(n, d, ue, ge, we, &mut scratch);
    }
}

/// Layered schedule over geometric factors *stored* at width `S`
/// (mixed-precision seam; see [`crate::geometry::GeomScalar`]). Each
/// element's `6 n^3` factors are widened into one reusable f64 tile —
/// L1-resident, so the memory traffic stays at the stored width — and the
/// arithmetic then runs the unchanged f64 [`ax_layered_element`], giving
/// the exact per-point operation order of the f64 path by construction.
/// `ax_layered_store::<f64>` is bit-identical to [`ax_layered`] (widening
/// an f64 is the identity).
pub fn ax_layered_store<S: GeomScalar>(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[S],
    w: &mut [f64],
) {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(w.len(), nelt * np);

    let mut scratch = LayeredScratch::new(n);
    let mut ge64 = vec![0.0f64; 6 * np];
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
        let we = &mut w[e * np..(e + 1) * np];
        ax_layered_element(n, d, ue, &ge64, we, &mut scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::ax_naive;
    use crate::proputil::{assert_allclose, Cases};

    #[test]
    fn matches_naive_on_paper_size() {
        let mut c = Cases::new(42);
        let (n, nelt) = (10, 3);
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let mut want = vec![0.0; nelt * np];
        ax_naive(n, nelt, &u, &d, &g, &mut want);
        let mut got = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut got);
        assert_allclose(&got, &want, 1e-11, 1e-11);
    }

    #[test]
    fn store_f64_is_bit_identical_to_plain_layered() {
        let mut c = Cases::new(44);
        let (n, nelt) = (6, 3);
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        let mut got = vec![123.0; nelt * np];
        ax_layered_store::<f64>(n, nelt, &u, &d, &g, &mut got);
        assert_eq!(got, want, "f64 store must be the identity instantiation");
    }

    #[test]
    fn store_f32_matches_f64_within_reduced_band() {
        let mut c = Cases::new(45);
        let (n, nelt) = (8, 2);
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        let mut got = vec![0.0; nelt * np];
        ax_layered_store::<f32>(n, nelt, &u, &d, &g32, &mut got);
        let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
        for (idx, (a, b)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-5 * (b.abs() + scale);
            assert!((a - b).abs() <= tol, "point {idx}: {a} vs {b} (tol {tol:e})");
        }
    }

    #[test]
    fn overwrites_stale_output() {
        let mut c = Cases::new(43);
        let (n, nelt) = (4, 2);
        let np = n * n * n;
        let u = c.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = c.vec_normal(nelt * 6 * np);
        let mut a = vec![123.0; nelt * np]; // poisoned
        let mut b = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut a);
        ax_layered(n, nelt, &u, &d, &g, &mut b);
        assert_eq!(a, b);
    }
}
