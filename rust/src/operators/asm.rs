//! Assembly-fused Ax: dssum + mask performed *inside* the element sweep
//! (the `cpu-asm` operator family).
//!
//! Every other operator computes the block-diagonal `w = A_local u` and
//! leaves assembly to the solver, which then re-streams `w` end to end in
//! a separate dssum pass plus a mask pass. This family folds both into
//! the sweep itself: interior dofs are written once and are final; shared
//! dofs are folded through a precomputed ownership plan
//! ([`AssemblyPlan`], built from the gather–scatter) the moment their
//! last contribution is written — while the face data is still cache-hot.
//! The fused variants additionally accumulate the CG `pap` reduction over
//! each dof as it becomes final, so the reported pap is already the
//! **assembled** value and the solver needs no shared-dof correction.
//!
//! ## Bitwise invariant
//!
//! The element kernel is the unchanged [`ax_layered_element`], each fold
//! group sums its copies in the same ascending-local order
//! [`GatherScatter::dssum`](crate::gs::GatherScatter::dssum) uses, groups
//! are disjoint, and the mask multiplies after all folds — so the
//! assembled output is **bitwise identical** to the serial
//! sweep-then-dssum-then-mask path, and a `cpu-asm` CG trajectory
//! reproduces `cpu-layered`'s bit for bit.
//!
//! ## Plan-less fallback
//!
//! When the [`OperatorCtx`] carries no [`OperatorCtx::assemble`] plan
//! (conformance harnesses with synthetic `g`, `--no-comm` runs,
//! multi-rank bricks whose halo exchange needs the raw pre-assembly
//! copies), the operators degrade to the plain layered sweep and report
//! `applies_assembly() = false` — the solver then runs its standalone
//! dssum + mask exactly as for `cpu-layered`.

use crate::error::{Error, Result};
use crate::geometry::{widen_into, GeomScalar};
use crate::gs::AssemblyPlan;
use crate::operators::layered::{ax_layered_element, LayeredScratch};
use crate::operators::{
    ax_bytes_moved_assembled, ax_bytes_moved_stored, ax_flops, fused_ax_flops, AxOperator,
    OperatorCtx,
};

/// The `cpu-asm` family: layered element sweep with in-sweep assembly
/// (when a plan is supplied), unfused or fused, over geometric factors
/// stored at width `S`. Four registrations share this struct:
/// `cpu-asm`, `cpu-asm-fused`, `cpu-asm-f32`, `cpu-asm-fused-f32`.
pub(crate) struct AsmOp<S: GeomScalar> {
    label: &'static str,
    fused: bool,
    st: Option<AsmState<S>>,
    last_pap: Option<f64>,
}

struct AsmState<S> {
    n: usize,
    nelt: usize,
    d: Vec<f64>,
    g: Vec<S>,
    c: Vec<f64>,
    plan: Option<AssemblyPlan>,
}

impl<S: GeomScalar> AsmOp<S> {
    pub(crate) fn new(label: &'static str, fused: bool) -> Self {
        AsmOp { label, fused, st: None, last_pap: None }
    }
}

impl<S: GeomScalar> AxOperator for AsmOp<S> {
    fn label(&self) -> String {
        self.label.into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        super::check_setup_shapes(ctx, self.fused)?;
        let np = ctx.n * ctx.n * ctx.n;
        let plan = match ctx.assemble {
            Some(p) => {
                if p.ndof() != ctx.nelt * np {
                    return Err(Error::Config(format!(
                        "operator setup: assembly plan covers {} dofs, problem has {}",
                        p.ndof(),
                        ctx.nelt * np
                    )));
                }
                Some(p.clone())
            }
            None => None,
        };
        self.st = Some(AsmState {
            n: ctx.n,
            nelt: ctx.nelt,
            d: ctx.d.to_vec(),
            g: S::convert(ctx.g),
            c: ctx.c.to_vec(),
            plan,
        });
        self.last_pap = None;
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let st = self.st.as_ref().ok_or_else(|| {
            Error::Config(format!("operator {:?} used before setup", self.label))
        })?;
        super::check_apply_shapes(st.n, st.nelt, u, w)?;
        let (n, nelt) = (st.n, st.nelt);
        let np = n * n * n;
        let mut scratch = LayeredScratch::new(n);
        let mut ge64 = vec![0.0f64; 6 * np];
        let mut pap = 0.0;
        for e in 0..nelt {
            {
                let ue = &u[e * np..(e + 1) * np];
                widen_into(&st.g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
                let we = &mut w[e * np..(e + 1) * np];
                ax_layered_element(n, &st.d, ue, &ge64, we, &mut scratch);
            }
            match &st.plan {
                Some(plan) => {
                    // Eager assembly: fold every group whose last copy was
                    // just written, then (fused) bank the pap contribution
                    // of everything element e finalized.
                    plan.fold_ready(e, w);
                    if self.fused {
                        pap += plan.pap_ready(e, w, u, &st.c);
                    }
                }
                None if self.fused => {
                    // Plan-less fallback: the layered fused reduction, in
                    // the same linear dof order (bit-compatible with
                    // `ax_layered_fused`).
                    let we = &w[e * np..(e + 1) * np];
                    let ce = &st.c[e * np..(e + 1) * np];
                    let ue = &u[e * np..(e + 1) * np];
                    let mut pap_e = 0.0;
                    for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
                        pap_e += wi * ci * ui;
                    }
                    pap += pap_e;
                }
                None => {}
            }
        }
        if let Some(plan) = &st.plan {
            plan.apply_mask(w);
        }
        if self.fused {
            // With a plan this is the *assembled* pap: exact for masked
            // `u` (every CG iterate), since masked dofs contribute
            // c*u*w = 0 either way.
            self.last_pap = Some(pap);
        }
        Ok(())
    }

    fn flops(&self) -> u64 {
        // The fold adds are O(surface) and were never counted for the
        // standalone dssum either; Eq. (1) accounting stays comparable
        // across the whole family.
        self.st.as_ref().map_or(0, |s| {
            if self.fused {
                fused_ax_flops(s.n, s.nelt)
            } else {
                ax_flops(s.n, s.nelt)
            }
        })
    }

    fn bytes_moved(&self) -> u64 {
        // Assembled mode drops the separate pass's 2 x ndof re-stream of
        // `w`; plan-less the operator really is the plain sweep and the
        // solver's standalone pass still runs.
        self.st.as_ref().map_or(0, |s| {
            if s.plan.is_some() {
                ax_bytes_moved_assembled(s.n, s.nelt, self.fused, S::STORED_BYTES)
            } else {
                ax_bytes_moved_stored(s.n, s.nelt, self.fused, S::STORED_BYTES)
            }
        })
    }

    fn is_fused(&self) -> bool {
        self.fused
    }

    fn last_pap(&self) -> Option<f64> {
        self.last_pap
    }

    fn applies_assembly(&self) -> bool {
        self.st.as_ref().map_or(false, |s| s.plan.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Basis;
    use crate::geometry::GeomFactors;
    use crate::gs::GatherScatter;
    use crate::mesh::Mesh;
    use crate::operators::ax_layered;
    use crate::solver::{glsc3, mask_apply};

    /// A real mesh problem plus its assembly plan — what the builder hands
    /// the operator in production.
    fn fixture(
        nx: usize,
        ny: usize,
        nz: usize,
        n: usize,
    ) -> (Mesh, Basis, GeomFactors, Vec<f64>, Vec<f64>, AssemblyPlan, GatherScatter) {
        let mesh = Mesh::new(nx, ny, nz, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let c = mesh.inv_multiplicity();
        let gs = GatherScatter::new(&mesh);
        let plan = gs.assembly_plan(n * n * n, Some(&mask)).unwrap();
        (mesh, basis, geom, mask, c, plan, gs)
    }

    fn ctx<'a>(
        mesh: &Mesh,
        basis: &'a Basis,
        geom: &'a GeomFactors,
        c: &'a [f64],
        plan: Option<&'a AssemblyPlan>,
    ) -> OperatorCtx<'a> {
        OperatorCtx {
            n: mesh.n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 0,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c,
            assemble: plan,
        }
    }

    #[test]
    fn assembled_apply_is_bitwise_sweep_then_dssum_then_mask() {
        let (mesh, basis, geom, mask, c, plan, mut gs) = fixture(2, 2, 1, 4);
        let ndof = mesh.ndof_local();
        let mut op = AsmOp::<f64>::new("cpu-asm", false);
        op.setup(&ctx(&mesh, &basis, &geom, &c, Some(&plan))).unwrap();
        assert!(op.applies_assembly());
        let mut cases = crate::proputil::Cases::new(0xA7);
        for _ in 0..6 {
            let u = cases.vec_normal(ndof);
            let mut want = vec![0.0; ndof];
            ax_layered(mesh.n, mesh.nelt(), &u, &basis.d, &geom.g, &mut want);
            gs.dssum(&mut want);
            mask_apply(&mut want, &mask);
            let mut got = vec![123.0; ndof];
            op.apply(&u, &mut got).unwrap();
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "cpu-asm output must be bit-identical to layered + dssum + mask"
            );
        }
    }

    #[test]
    fn fused_assembled_pap_matches_assembled_glsc3_for_masked_input() {
        let (mesh, basis, geom, mask, c, plan, _) = fixture(2, 1, 2, 4);
        let ndof = mesh.ndof_local();
        let mut op = AsmOp::<f64>::new("cpu-asm-fused", true);
        op.setup(&ctx(&mesh, &basis, &geom, &c, Some(&plan))).unwrap();
        let mut cases = crate::proputil::Cases::new(0xA8);
        for _ in 0..6 {
            let mut u = cases.vec_normal(ndof);
            mask_apply(&mut u, &mask); // every CG iterate is masked
            let mut w = vec![0.0; ndof];
            op.apply(&u, &mut w).unwrap();
            let pap = op.last_pap().unwrap();
            let want = glsc3(&w, &c, &u);
            assert!(
                (pap - want).abs() <= 1e-12 * want.abs().max(1.0),
                "{pap} vs {want}"
            );
        }
    }

    #[test]
    fn plan_less_fallback_is_plain_layered_and_does_not_claim_assembly() {
        let (mesh, basis, geom, _, c, _, _) = fixture(2, 1, 1, 5);
        let ndof = mesh.ndof_local();
        let mut op = AsmOp::<f64>::new("cpu-asm", false);
        op.setup(&ctx(&mesh, &basis, &geom, &c, None)).unwrap();
        assert!(!op.applies_assembly());
        let u: Vec<f64> = (0..ndof).map(|i| (i as f64 * 0.11).sin()).collect();
        let mut want = vec![0.0; ndof];
        ax_layered(mesh.n, mesh.nelt(), &u, &basis.d, &geom.g, &mut want);
        let mut got = vec![0.0; ndof];
        op.apply(&u, &mut got).unwrap();
        assert_eq!(got, want, "without a plan cpu-asm is the layered sweep");
    }

    #[test]
    fn bytes_moved_depends_on_mode() {
        let (mesh, basis, geom, _, c, plan, _) = fixture(2, 1, 1, 4);
        let (n, nelt) = (mesh.n, mesh.nelt());
        let mut plain = AsmOp::<f64>::new("cpu-asm", false);
        plain.setup(&ctx(&mesh, &basis, &geom, &c, None)).unwrap();
        assert_eq!(plain.bytes_moved(), ax_bytes_moved_stored(n, nelt, false, 8));
        let mut asm = AsmOp::<f64>::new("cpu-asm", false);
        asm.setup(&ctx(&mesh, &basis, &geom, &c, Some(&plan))).unwrap();
        assert_eq!(asm.bytes_moved(), ax_bytes_moved_assembled(n, nelt, false, 8));
        assert!(asm.bytes_moved() < plain.bytes_moved());
    }

    #[test]
    fn mismatched_plan_is_a_config_error() {
        let (mesh, basis, geom, _, c, _, _) = fixture(2, 1, 1, 4);
        let (_, _, _, _, _, other_plan, _) = fixture(2, 2, 2, 3);
        let mut op = AsmOp::<f64>::new("cpu-asm", false);
        let err = op.setup(&ctx(&mesh, &basis, &geom, &c, Some(&other_plan))).err().unwrap();
        assert!(err.to_string().contains("assembly plan covers"), "{err}");
    }

    #[test]
    fn f32_storage_assembles_within_reduced_band() {
        let (mesh, basis, geom, mask, c, plan, mut gs) = fixture(2, 2, 1, 5);
        let ndof = mesh.ndof_local();
        let mut op = AsmOp::<f32>::new("cpu-asm-f32", false);
        op.setup(&ctx(&mesh, &basis, &geom, &c, Some(&plan))).unwrap();
        let u: Vec<f64> = (0..ndof).map(|i| (i as f64 * 0.29).cos()).collect();
        let mut want = vec![0.0; ndof];
        ax_layered(mesh.n, mesh.nelt(), &u, &basis.d, &geom.g, &mut want);
        gs.dssum(&mut want);
        mask_apply(&mut want, &mask);
        let mut got = vec![0.0; ndof];
        op.apply(&u, &mut got).unwrap();
        let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
        for (idx, (a, b)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-5 * (b.abs() + scale);
            assert!((a - b).abs() <= tol, "point {idx}: {a} vs {b} (tol {tol:e})");
        }
    }
}
