//! Persistent worker pool for the threaded CPU operators.
//!
//! [`super::ax_threaded`] parallelizes one application with
//! `std::thread::scope`, which spawns and joins OS threads on **every**
//! call — ~100 times per CG solve. [`WorkerPool`] spawns the workers once
//! (at operator `setup`) and feeds them element ranges over channels on
//! each `apply`, so the per-application cost is two channel hops per
//! worker instead of a thread spawn/join.
//!
//! Each worker owns its slice of the setup data (`d`, its element range of
//! `g` and `c`), so a job message carries only the base pointers of the
//! caller's `u`/`w` slices. Safety: `run` does not return until every
//! worker that received a job has signalled completion (or provably died),
//! so the pointers never outlive the borrow they were derived from.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::geometry::{GeomStore, Precision};
use crate::operators::simd::{ax_simd, ax_simd_f32, ax_simd_fused, ax_simd_fused_f32};
use crate::operators::{
    ax_bytes_moved_stored, ax_flops, fused_ax_flops, AxOperator, OperatorCtx,
};

/// Raw slice bounds shipped to a worker. The pointers are only
/// dereferenced between job receipt and the completion signal, while the
/// caller is blocked inside [`WorkerPool::run`] holding the borrows.
struct Job {
    u: *const f64,
    w: *mut f64,
    len: usize,
    fused: bool,
}

// SAFETY: the pointers are plain data here; the aliasing discipline is
// enforced by `run` (disjoint `w` ranges per worker, completion barrier
// before returning).
unsafe impl Send for Job {}

struct Worker {
    job_tx: Sender<Job>,
    done_rx: Receiver<f64>,
    handle: Option<JoinHandle<()>>,
}

/// Long-lived workers, each bound at construction to one contiguous
/// element range of the problem.
pub struct WorkerPool {
    workers: Vec<Worker>,
    /// Elements per worker (parallel to `workers`).
    counts: Vec<usize>,
    n: usize,
    /// Were inner-product weights supplied at spawn? Fused runs need them.
    has_weights: bool,
}

/// Resolve a requested thread count: `0` = all available cores, always
/// clamped to the element count (a worker with no elements is useless).
pub fn resolve_threads(requested: usize, nelt: usize) -> usize {
    let hw = if requested == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        requested
    };
    hw.min(nelt).max(1)
}

/// Contiguous element ranges: `nelt` split over `nworkers`, remainder
/// spread over the first workers. [`super::ax_threaded`] uses this same
/// split, so pooled and scoped execution are bit-identical.
pub(crate) fn element_counts(nelt: usize, nworkers: usize) -> Vec<usize> {
    let base = nelt / nworkers;
    let rem = nelt % nworkers;
    (0..nworkers).map(|t| base + usize::from(t < rem)).collect()
}

impl WorkerPool {
    /// Spawn `nworkers` workers for an `nelt`-element problem with f64
    /// factor storage (the historical entry point; see
    /// [`WorkerPool::spawn_stored`]).
    pub fn spawn(
        n: usize,
        nelt: usize,
        nworkers: usize,
        d: &[f64],
        g: &[f64],
        c: &[f64],
    ) -> Self {
        Self::spawn_stored(n, nelt, nworkers, d, g, c, Precision::F64)
    }

    /// Spawn `nworkers` workers holding their geometric factors at the
    /// requested storage width (narrowed once here, the pool's single
    /// conversion point). Each worker clones only its own element range of
    /// `g` (and `c`, when present), so the pool's total copy is the same
    /// size as a single-threaded operator's. Pass an empty `c` for pools
    /// that will never run fused.
    pub fn spawn_stored(
        n: usize,
        nelt: usize,
        nworkers: usize,
        d: &[f64],
        g: &[f64],
        c: &[f64],
        precision: Precision,
    ) -> Self {
        let np = n * n * n;
        let has_weights = !c.is_empty();
        let nworkers = nworkers.min(nelt).max(1);
        let counts = element_counts(nelt, nworkers);
        let mut workers = Vec::with_capacity(nworkers);
        let mut e0 = 0usize;
        for &count in &counts {
            let (job_tx, job_rx) = channel::<Job>();
            let (done_tx, done_rx) = channel::<f64>();
            let d = d.to_vec();
            let g = GeomStore::from_f64(&g[e0 * 6 * np..(e0 + count) * 6 * np], precision);
            let c = if c.is_empty() { Vec::new() } else { c[e0 * np..(e0 + count) * np].to_vec() };
            let handle = std::thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    // SAFETY: the caller of `run` holds `&[f64]`/`&mut [f64]`
                    // borrows covering exactly these ranges and blocks until
                    // our completion signal; `w` ranges are disjoint across
                    // workers.
                    let u = unsafe { std::slice::from_raw_parts(job.u, job.len) };
                    let w = unsafe { std::slice::from_raw_parts_mut(job.w, job.len) };
                    // Explicit-SIMD dispatch (the AVX2+FMA arm when the
                    // host supports it, the degree-specialized scalar
                    // family otherwise), at the worker's stored factor
                    // width, so `cpu-threaded*` picks the vector kernels
                    // up automatically. Both arms are deterministic and
                    // every worker takes the same arm, so pooled output is
                    // bit-identical to a single-thread `ax_simd` (or
                    // `ax_simd_f32`) over the same mesh.
                    let pap = match (&g, job.fused) {
                        (GeomStore::F64(g), true) => ax_simd_fused(n, count, u, &d, g, &c, w),
                        (GeomStore::F32(g), true) => {
                            ax_simd_fused_f32(n, count, u, &d, g, &c, w)
                        }
                        (GeomStore::F64(g), false) => {
                            ax_simd(n, count, u, &d, g, w);
                            0.0
                        }
                        (GeomStore::F32(g), false) => {
                            ax_simd_f32(n, count, u, &d, g, w);
                            0.0
                        }
                    };
                    if done_tx.send(pap).is_err() {
                        break; // pool dropped mid-job
                    }
                }
            });
            workers.push(Worker { job_tx, done_rx, handle: Some(handle) });
            e0 += count;
        }
        WorkerPool { workers, counts, n, has_weights }
    }

    /// Number of live workers.
    pub fn nworkers(&self) -> usize {
        self.workers.len()
    }

    /// One parallel application: `w <- A_local(u)` over all element ranges;
    /// with `fused`, additionally returns `pap = Σ w·c·u`, reduced over the
    /// per-worker partials **in element-range order** so the sum is
    /// deterministic for a fixed pool shape.
    pub fn run(&self, u: &[f64], w: &mut [f64], fused: bool) -> Result<f64> {
        if fused && !self.has_weights {
            return Err(Error::Config(
                "fused pool run requires inner-product weights; spawn the \
                 pool with a non-empty c"
                    .into(),
            ));
        }
        let np = self.n * self.n * self.n;
        // Validate BEFORE dispatching any job: a length panic after the
        // first send would unwind while a worker still writes through the
        // caller's buffers (use-after-free from safe code).
        let ndof: usize = self.counts.iter().sum::<usize>() * np;
        if u.len() != ndof || w.len() != ndof {
            return Err(Error::Config(format!(
                "pool run: fields must be nelt*n^3 = {ndof}, got u={} w={}",
                u.len(),
                w.len()
            )));
        }
        // Phase 1: dispatch one job per worker (disjoint w ranges).
        let mut sent = vec![false; self.workers.len()];
        {
            let mut w_rest = &mut w[..];
            let mut e0 = 0usize;
            for ((worker, &count), ok) in
                self.workers.iter().zip(&self.counts).zip(sent.iter_mut())
            {
                let (w_mine, tail) = w_rest.split_at_mut(count * np);
                w_rest = tail;
                let u_mine = &u[e0 * np..(e0 + count) * np];
                let job = Job {
                    u: u_mine.as_ptr(),
                    w: w_mine.as_mut_ptr(),
                    len: count * np,
                    fused,
                };
                *ok = worker.job_tx.send(job).is_ok();
                e0 += count;
            }
        }
        // Phase 2: barrier — collect every dispatched job's completion
        // before returning, even on failure, so no worker still holds the
        // borrowed pointers when `run` exits.
        let mut pap = 0.0;
        let mut dead = false;
        for (worker, &ok) in self.workers.iter().zip(&sent) {
            if !ok {
                dead = true;
                continue;
            }
            match worker.done_rx.recv() {
                Ok(partial) => pap += partial,
                Err(_) => dead = true,
            }
        }
        if dead {
            return Err(Error::Rank("worker pool thread died (panicked?)".into()));
        }
        Ok(pap)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for worker in &mut self.workers {
            // Replacing the sender with a dead channel drops the original,
            // which ends the worker's recv loop.
            let (dead_tx, _) = channel();
            worker.job_tx = dead_tx;
            if let Some(handle) = worker.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

/// `cpu-threaded` / `cpu-threaded-fused` and their `-f32` twins: the
/// explicit-SIMD kernel family ([`ax_simd`] / [`ax_simd_f32`], scalar
/// fallback included) across a persistent [`WorkerPool`] holding factors
/// at the operator's storage width. Workers spawn once at `setup` and are
/// reused by every `apply` (no per-apply thread creation).
pub(crate) struct PooledOp {
    label: &'static str,
    fused: bool,
    precision: Precision,
    st: Option<PooledState>,
    last_pap: Option<f64>,
}

struct PooledState {
    n: usize,
    nelt: usize,
    pool: WorkerPool,
}

impl PooledOp {
    pub(crate) fn new(label: &'static str, fused: bool, precision: Precision) -> Self {
        PooledOp { label, fused, precision, st: None, last_pap: None }
    }

    /// The live worker count (0 before setup) — test hook for the
    /// spawn-once contract.
    #[cfg(test)]
    fn nworkers(&self) -> usize {
        self.st.as_ref().map_or(0, |s| s.pool.nworkers())
    }
}

impl AxOperator for PooledOp {
    fn label(&self) -> String {
        self.label.into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        super::check_setup_shapes(ctx, self.fused)?;
        let nworkers = resolve_threads(ctx.threads, ctx.nelt);
        let c = if self.fused { ctx.c } else { &[] };
        // Replacing the state drops any previous pool (joins its workers).
        self.st = Some(PooledState {
            n: ctx.n,
            nelt: ctx.nelt,
            pool: WorkerPool::spawn_stored(
                ctx.n,
                ctx.nelt,
                nworkers,
                ctx.d,
                ctx.g,
                c,
                self.precision,
            ),
        });
        self.last_pap = None;
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let st = self
            .st
            .as_ref()
            .ok_or_else(|| Error::Config(format!("operator {:?} used before setup", self.label)))?;
        super::check_apply_shapes(st.n, st.nelt, u, w)?;
        let pap = st.pool.run(u, w, self.fused)?;
        if self.fused {
            self.last_pap = Some(pap);
        }
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| {
            if self.fused {
                fused_ax_flops(s.n, s.nelt)
            } else {
                ax_flops(s.n, s.nelt)
            }
        })
    }

    fn bytes_moved(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| {
            ax_bytes_moved_stored(s.n, s.nelt, self.fused, self.precision.stored_bytes())
        })
    }

    fn is_fused(&self) -> bool {
        self.fused
    }

    fn last_pap(&self) -> Option<f64> {
        self.last_pap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::ax_threaded;
    use crate::proputil::Cases;

    fn inputs(seed: u64, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut cases = Cases::new(seed);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        (u, d, g, c)
    }

    #[test]
    fn pool_matches_scoped_threads_bit_identical() {
        let (n, nelt) = (4, 7); // odd count exercises the remainder split
        let (u, d, g, _c) = inputs(11, n, nelt);
        let np = n * n * n;
        for nworkers in [1, 2, 3, 7, 16] {
            let pool = WorkerPool::spawn(n, nelt, nworkers, &d, &g, &[]);
            let mut got = vec![0.0; nelt * np];
            pool.run(&u, &mut got, false).unwrap();
            let mut want = vec![0.0; nelt * np];
            ax_threaded(n, nelt, &u, &d, &g, &mut want, nworkers);
            assert_eq!(got, want, "nworkers={nworkers}");
        }
    }

    #[test]
    fn pool_reused_across_applies() {
        let (n, nelt) = (3, 4);
        let (u, d, g, c) = inputs(12, n, nelt);
        let np = n * n * n;
        let pool = WorkerPool::spawn(n, nelt, 2, &d, &g, &c);
        let mut w1 = vec![0.0; nelt * np];
        let mut w2 = vec![0.0; nelt * np];
        let p1 = pool.run(&u, &mut w1, true).unwrap();
        let p2 = pool.run(&u, &mut w2, true).unwrap();
        assert_eq!(w1, w2);
        assert_eq!(p1.to_bits(), p2.to_bits(), "fused pap must be reproducible");
    }

    #[test]
    fn pooled_fused_pap_matches_single_thread() {
        let (n, nelt) = (5, 6);
        let (u, d, g, c) = inputs(13, n, nelt);
        let np = n * n * n;
        let mut want_w = vec![0.0; nelt * np];
        let want_pap = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut want_w);
        for nworkers in [1, 2, 3, 6] {
            let pool = WorkerPool::spawn(n, nelt, nworkers, &d, &g, &c);
            let mut w = vec![0.0; nelt * np];
            let pap = pool.run(&u, &mut w, true).unwrap();
            assert_eq!(w, want_w, "nworkers={nworkers}");
            let denom = want_pap.abs().max(1e-30);
            assert!(
                (pap - want_pap).abs() / denom < 1e-12,
                "nworkers={nworkers}: {pap} vs {want_pap}"
            );
        }
    }

    #[test]
    fn pooled_operator_spawns_once_at_setup() {
        use crate::operators::OperatorCtx;
        let (n, nelt) = (3, 4);
        let (u, d, g, c) = inputs(14, n, nelt);
        let np = n * n * n;
        let mut op = PooledOp::new("cpu-threaded", false, Precision::F64);
        assert_eq!(op.nworkers(), 0, "no workers before setup");
        op.setup(&OperatorCtx {
            n,
            nelt,
            chunk: nelt,
            threads: 2,
            artifacts_dir: "artifacts",
            d: &d,
            g: &g,
            c: &c,
            assemble: None,
        })
        .unwrap();
        assert_eq!(op.nworkers(), 2, "workers spawn at setup");
        let mut want = vec![0.0; nelt * np];
        ax_simd(n, nelt, &u, &d, &g, &mut want);
        for _ in 0..5 {
            let mut w = vec![0.0; nelt * np];
            op.apply(&u, &mut w).unwrap();
            assert_eq!(w, want);
            assert_eq!(op.nworkers(), 2, "applies reuse the same workers");
        }
    }

    #[test]
    fn f32_pool_matches_single_thread_f32_bit_identical() {
        // The pooled f32 path must be the single-thread `ax_simd_f32` cut
        // into ranges — same per-worker narrowing as the whole-mesh
        // narrowing (element-aligned ranges, pointwise conversion), so
        // output is bitwise equal for any worker count, and the fused pap
        // partials reduce in element-range order.
        let (n, nelt) = (4, 7);
        let (u, d, g, c) = inputs(18, n, nelt);
        let np = n * n * n;
        let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
        let mut want_w = vec![0.0; nelt * np];
        ax_simd_f32(n, nelt, &u, &d, &g32, &mut want_w);
        let mut want_fused = vec![0.0; nelt * np];
        let want_pap = ax_simd_fused_f32(n, nelt, &u, &d, &g32, &c, &mut want_fused);
        for nworkers in [1, 2, 3, 7] {
            let pool = WorkerPool::spawn_stored(n, nelt, nworkers, &d, &g, &c, Precision::F32);
            let mut w = vec![0.0; nelt * np];
            pool.run(&u, &mut w, false).unwrap();
            assert_eq!(w, want_w, "unfused, nworkers={nworkers}");
            let pap = pool.run(&u, &mut w, true).unwrap();
            assert_eq!(w, want_fused, "fused, nworkers={nworkers}");
            let denom = want_pap.abs().max(1e-30);
            assert!(
                (pap - want_pap).abs() / denom < 1e-12,
                "nworkers={nworkers}: {pap} vs {want_pap}"
            );
        }
    }

    #[test]
    fn mis_sized_fields_rejected_before_dispatch() {
        let (n, nelt) = (3, 4);
        let (u, d, g, _c) = inputs(17, n, nelt);
        let np = n * n * n;
        let pool = WorkerPool::spawn(n, nelt, 2, &d, &g, &[]);
        // Covers worker 0's range but not worker 1's: must error cleanly,
        // not panic mid-dispatch.
        let mut w = vec![0.0; nelt * np];
        assert!(pool.run(&u[..2 * np], &mut w, false).is_err());
        let mut w_short = vec![0.0; 2 * np];
        assert!(pool.run(&u, &mut w_short, false).is_err());
        // Pool still healthy afterwards.
        pool.run(&u, &mut w, false).unwrap();
    }

    #[test]
    fn fused_run_without_weights_is_a_config_error() {
        let (n, nelt) = (3, 2);
        let (u, d, g, _c) = inputs(16, n, nelt);
        let np = n * n * n;
        let pool = WorkerPool::spawn(n, nelt, 2, &d, &g, &[]);
        let mut w = vec![0.0; nelt * np];
        let err = pool.run(&u, &mut w, true).unwrap_err().to_string();
        assert!(err.contains("weights"), "{err}");
        // The pool is still usable for unfused runs afterwards.
        pool.run(&u, &mut w, false).unwrap();
    }

    #[test]
    fn more_workers_than_elements_clamped() {
        let (n, nelt) = (3, 2);
        let (u, d, g, _c) = inputs(15, n, nelt);
        let np = n * n * n;
        let pool = WorkerPool::spawn(n, nelt, 64, &d, &g, &[]);
        assert_eq!(pool.nworkers(), 2);
        let mut got = vec![0.0; nelt * np];
        pool.run(&u, &mut got, false).unwrap();
        let mut want = vec![0.0; nelt * np];
        ax_simd(n, nelt, &u, &d, &g, &mut want);
        assert_eq!(got, want);
    }
}
