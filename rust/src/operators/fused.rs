//! Fused Ax + pap: the paper's fusion-of-reductions hot path on CPU.
//!
//! The CG inner product `pap = glsc3(w, c, p)` normally costs one extra
//! full sweep over three `ndof` vectors after the operator has already
//! streamed them through cache. Fusing the reduction into the operator
//! (Świrydowicz et al., arXiv:1711.00903; HipBone's first-class fused
//! dot-product kernels, arXiv:2202.12477) accumulates the partial sums
//! while the element's output is still resident — the same trick the
//! `xla-fused-layered` artifact plays in one launch per chunk.
//!
//! Determinism contract: the reduction is accumulated element by element in
//! ascending element order (and layer by layer within an element), so the
//! result is bit-reproducible run to run for a fixed shape. The threaded
//! variant ([`super::pool::WorkerPool`]) reduces its per-worker partial
//! sums in element-range order for the same reason.

use crate::error::{Error, Result};
use crate::geometry::{widen_into, GeomScalar};
use crate::operators::layered::{ax_layered_element, LayeredScratch};
use crate::operators::{ax_bytes_moved_stored, fused_ax_flops, AxOperator, OperatorCtx};

/// Layered local Ax with the pap reduction fused in: computes
/// `w = A_local(u)` exactly as [`super::ax_layered`] (bit-identical output)
/// and returns `pap = Σ_i w_i c_i u_i` over the local dofs.
///
/// The accumulation runs once per element, immediately after that
/// element's k-sweep — the earliest point at which any of its `w` is final
/// (the stage-2 t-contraction scatters into every layer), and while the
/// element's `n^3` tiles are still in cache. Streaming the reduction
/// element by element is what saves the separate whole-array sweep.
pub fn ax_layered_fused(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[f64],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(c.len(), nelt * np);
    assert_eq!(w.len(), nelt * np);

    let mut scratch = LayeredScratch::new(n);
    let mut pap = 0.0;
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        let ge = &g[e * 6 * np..(e + 1) * 6 * np];
        let ce = &c[e * np..(e + 1) * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_layered_element(n, d, ue, ge, we, &mut scratch);
        // Fused reduction: one pass over the just-written element,
        // accumulated in linear dof order (determinism contract).
        let mut pap_e = 0.0;
        for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
            pap_e += wi * ci * ui;
        }
        pap += pap_e;
    }
    pap
}

/// Fused layered Ax+pap over geometric factors stored at width `S`: each
/// element's factors widen into one L1-resident f64 tile, then the
/// unchanged f64 element kernel and the linear-dof-order pap reduction
/// run exactly as [`ax_layered_fused`] (the `::<f64>` instantiation is
/// bit-identical to it).
pub fn ax_layered_fused_store<S: GeomScalar>(
    n: usize,
    nelt: usize,
    u: &[f64],
    d: &[f64],
    g: &[S],
    c: &[f64],
    w: &mut [f64],
) -> f64 {
    let np = n * n * n;
    assert_eq!(u.len(), nelt * np);
    assert_eq!(d.len(), n * n);
    assert_eq!(g.len(), nelt * 6 * np);
    assert_eq!(c.len(), nelt * np);
    assert_eq!(w.len(), nelt * np);

    let mut scratch = LayeredScratch::new(n);
    let mut ge64 = vec![0.0f64; 6 * np];
    let mut pap = 0.0;
    for e in 0..nelt {
        let ue = &u[e * np..(e + 1) * np];
        widen_into(&g[e * 6 * np..(e + 1) * 6 * np], &mut ge64);
        let ce = &c[e * np..(e + 1) * np];
        let we = &mut w[e * np..(e + 1) * np];
        ax_layered_element(n, d, ue, &ge64, we, &mut scratch);
        let mut pap_e = 0.0;
        for ((wi, ci), ui) in we.iter().zip(ce).zip(ue) {
            pap_e += wi * ci * ui;
        }
        pap += pap_e;
    }
    pap
}

/// Unified fused single-thread CPU-kernel signature over stored factor
/// width `S` (`ax_layered_fused`, `ax_spec_fused`, `ax_simd_fused` at
/// `S = f64`; their `*_store::<f32>` / `_f32` twins at `S = f32`).
pub(crate) type FusedCpuKernel<S> =
    fn(usize, usize, &[f64], &[f64], &[S], &[f64], &mut [f64]) -> f64;

/// A fused single-thread CPU schedule behind the operator trait:
/// `cpu-layered-fused` (the generic layered kernel), `cpu-spec-fused`
/// (degree-specialized, falls back to layered out of range), and
/// `cpu-simd-fused` (explicit AVX2+FMA with runtime dispatch and a scalar
/// fallback) — plus their `-f32` twins, which store the geometric factors
/// at 4 bytes (converted once at setup) and accumulate in f64.
/// `last_pap()` is `glsc3(w, c, u)` of the most recent apply, with `c` as
/// captured at setup.
pub(crate) struct FusedCpuOp<S: GeomScalar> {
    label: &'static str,
    kernel: FusedCpuKernel<S>,
    st: Option<FusedState<S>>,
    last_pap: Option<f64>,
}

struct FusedState<S> {
    n: usize,
    nelt: usize,
    d: Vec<f64>,
    g: Vec<S>,
    c: Vec<f64>,
}

impl<S: GeomScalar> FusedCpuOp<S> {
    pub(crate) fn new(label: &'static str, kernel: FusedCpuKernel<S>) -> Self {
        FusedCpuOp { label, kernel, st: None, last_pap: None }
    }
}

impl<S: GeomScalar> AxOperator for FusedCpuOp<S> {
    fn label(&self) -> String {
        self.label.into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
        super::check_setup_shapes(ctx, true)?;
        self.st = Some(FusedState {
            n: ctx.n,
            nelt: ctx.nelt,
            d: ctx.d.to_vec(),
            g: S::convert(ctx.g),
            c: ctx.c.to_vec(),
        });
        self.last_pap = None;
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
        let st = self.st.as_ref().ok_or_else(|| {
            Error::Config(format!("operator {:?} used before setup", self.label))
        })?;
        super::check_apply_shapes(st.n, st.nelt, u, w)?;
        let pap = (self.kernel)(st.n, st.nelt, u, &st.d, &st.g, &st.c, w);
        self.last_pap = Some(pap);
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| fused_ax_flops(s.n, s.nelt))
    }

    fn bytes_moved(&self) -> u64 {
        self.st.as_ref().map_or(0, |s| ax_bytes_moved_stored(s.n, s.nelt, true, S::STORED_BYTES))
    }

    fn is_fused(&self) -> bool {
        true
    }

    fn last_pap(&self) -> Option<f64> {
        self.last_pap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::ax_layered;
    use crate::proputil::{assert_allclose, Cases};
    use crate::solver::glsc3;

    #[test]
    fn fused_output_bit_identical_to_layered() {
        let mut cases = Cases::new(0xF0);
        for _ in 0..6 {
            let n = cases.size(2, 8);
            let nelt = cases.size(1, 4);
            let np = n * n * n;
            let u = cases.vec_normal(nelt * np);
            let d = crate::basis::derivative_matrix(n);
            let g = cases.vec_normal(nelt * 6 * np);
            let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
            let mut want = vec![0.0; nelt * np];
            ax_layered(n, nelt, &u, &d, &g, &mut want);
            let mut got = vec![123.0; nelt * np]; // poisoned
            ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut got);
            assert_eq!(got, want, "fused w must be bit-identical to layered");
        }
    }

    #[test]
    fn fused_pap_matches_glsc3() {
        let mut cases = Cases::new(0xF1);
        for _ in 0..6 {
            let n = cases.size(2, 7);
            let nelt = cases.size(1, 5);
            let np = n * n * n;
            let u = cases.vec_normal(nelt * np);
            let d = crate::basis::derivative_matrix(n);
            let g = cases.vec_normal(nelt * 6 * np);
            let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
            let mut w = vec![0.0; nelt * np];
            let pap = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w);
            let want = glsc3(&w, &c, &u);
            assert_allclose(&[pap], &[want], 1e-11, 1e-11);
        }
    }

    #[test]
    fn fused_store_f64_is_bit_identical() {
        let mut cases = Cases::new(0xF3);
        let (n, nelt) = (6, 3);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        let mut w_f = vec![0.0; nelt * np];
        let pap_f = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w_f);
        let mut w_s = vec![123.0; nelt * np];
        let pap_s = ax_layered_fused_store::<f64>(n, nelt, &u, &d, &g, &c, &mut w_s);
        assert_eq!(w_s, w_f);
        assert_eq!(pap_s.to_bits(), pap_f.to_bits());
    }

    #[test]
    fn fused_pap_deterministic() {
        let mut cases = Cases::new(0xF2);
        let (n, nelt) = (5, 3);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = crate::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        let mut w1 = vec![0.0; nelt * np];
        let mut w2 = vec![0.0; nelt * np];
        let p1 = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w1);
        let p2 = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w2);
        assert_eq!(p1.to_bits(), p2.to_bits(), "pap must be run-to-run reproducible");
    }
}
