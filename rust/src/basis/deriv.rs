//! The GLL pseudo-spectral differentiation matrix (Nekbone's `dxm1`).

use super::gll::gll_points;
use super::legendre::legendre;

/// Row-major `n x n` differentiation matrix `D`:
/// `(D u)_i = sum_j D[i*n + j] u_j` is the derivative of the degree-(n-1)
/// interpolant of `u` at GLL node `i`.
///
/// Closed form (Canuto et al.):
/// `D[i,j] = P(x_i) / (P(x_j) (x_i - x_j))` off-diagonal,
/// `D[0,0] = -order (order+1)/4`, `D[N,N] = +order (order+1)/4`,
/// zero elsewhere on the diagonal, with `P = P_order`, `order = n-1`.
pub fn derivative_matrix(n: usize) -> Vec<f64> {
    assert!(n >= 2, "derivative matrix needs n >= 2, got {n}");
    let order = n - 1;
    let x = gll_points(n);
    let pn: Vec<f64> = x.iter().map(|&xi| legendre(order, xi)).collect();
    let mut d = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                d[i * n + j] = pn[i] / (pn[j] * (x[i] - x[j]));
            }
        }
    }
    let corner = order as f64 * (order as f64 + 1.0) / 4.0;
    d[0] = -corner;
    d[n * n - 1] = corner;
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_monomials() {
        for n in 2..=14 {
            let x = gll_points(n);
            let d = derivative_matrix(n);
            for p in 0..n {
                // u = x^p, du = p x^(p-1)
                let u: Vec<f64> = x.iter().map(|&xi| xi.powi(p as i32)).collect();
                for i in 0..n {
                    let got: f64 = (0..n).map(|j| d[i * n + j] * u[j]).sum();
                    let want = if p == 0 { 0.0 } else { p as f64 * x[i].powi(p as i32 - 1) };
                    assert!(
                        (got - want).abs() < 5e-10 * (1.0 + want.abs()),
                        "n={n} p={p} i={i}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn rows_sum_to_zero() {
        for n in 2..=16 {
            let d = derivative_matrix(n);
            for i in 0..n {
                let s: f64 = (0..n).map(|j| d[i * n + j]).sum();
                assert!(s.abs() < 1e-11, "n={n} row {i} sums to {s}");
            }
        }
    }

    #[test]
    fn negation_symmetry() {
        // D[i,j] = -D[n-1-i, n-1-j]
        for n in 2..=16 {
            let d = derivative_matrix(n);
            for i in 0..n {
                for j in 0..n {
                    let a = d[i * n + j];
                    let b = d[(n - 1 - i) * n + (n - 1 - j)];
                    assert!((a + b).abs() < 1e-11, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn corner_values() {
        let n = 10;
        let d = derivative_matrix(n);
        let corner = 9.0 * 10.0 / 4.0;
        assert!((d[0] + corner).abs() < 1e-14);
        assert!((d[n * n - 1] - corner).abs() < 1e-14);
    }
}
