//! Gauss–Lobatto–Legendre nodes and quadrature weights.

use super::legendre::legendre;

/// The `n` GLL points on `[-1, 1]`, ascending (`n = degree + 1`).
///
/// Endpoints are exactly `±1`; interior nodes are the roots of
/// `P'_{n-1}`, found by the classic `lglnodes` fixed-point/Newton iteration
/// from the Chebyshev–Gauss–Lobatto initial guess.
///
/// # Panics
/// Panics for `n < 2`.
pub fn gll_points(n: usize) -> Vec<f64> {
    assert!(n >= 2, "GLL needs at least 2 points, got n={n}");
    let order = n - 1;
    let mut x: Vec<f64> = (0..n)
        .map(|i| -(std::f64::consts::PI * i as f64 / order as f64).cos())
        .collect();
    let mut x_old = vec![2.0; n];
    for _ in 0..100 {
        let delta = x
            .iter()
            .zip(&x_old)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max);
        if delta <= 1e-15 {
            break;
        }
        x_old.copy_from_slice(&x);
        for i in 0..n {
            let pn = legendre(order, x_old[i]);
            let pnm1 = legendre(order - 1, x_old[i]);
            x[i] = x_old[i] - (x_old[i] * pn - pnm1) / (n as f64 * pn);
        }
    }
    x[0] = -1.0;
    x[n - 1] = 1.0;
    x
}

/// GLL quadrature weights `w_j = 2 / (order (order+1) P_order(x_j)^2)`.
/// Exact for polynomials of degree `<= 2n - 3`; positive; sum to 2.
pub fn gll_weights(n: usize) -> Vec<f64> {
    let order = n - 1;
    gll_points(n)
        .iter()
        .map(|&xj| {
            let p = legendre(order, xj);
            2.0 / (order as f64 * (order as f64 + 1.0) * p * p)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn n2_endpoints_only() {
        assert_eq!(gll_points(2), vec![-1.0, 1.0]);
        let w = gll_weights(2);
        assert!(close(w[0], 1.0, 1e-15) && close(w[1], 1.0, 1e-15));
    }

    #[test]
    fn n3_midpoint() {
        let x = gll_points(3);
        assert!(close(x[1], 0.0, 1e-15));
        let w = gll_weights(3);
        assert!(close(w[0], 1.0 / 3.0, 1e-14));
        assert!(close(w[1], 4.0 / 3.0, 1e-14));
    }

    #[test]
    fn n4_known_roots() {
        let x = gll_points(4);
        let r = 1.0 / 5.0_f64.sqrt();
        assert!(close(x[1], -r, 1e-14) && close(x[2], r, 1e-14));
    }

    #[test]
    fn n5_known_roots_and_weights() {
        let x = gll_points(5);
        let r = (3.0_f64 / 7.0).sqrt();
        assert!(close(x[1], -r, 1e-14) && close(x[3], r, 1e-14) && close(x[2], 0.0, 1e-15));
        let w = gll_weights(5);
        assert!(close(w[0], 0.1, 1e-14));
        assert!(close(w[1], 49.0 / 90.0, 1e-14));
        assert!(close(w[2], 32.0 / 45.0, 1e-14));
    }

    #[test]
    fn sorted_symmetric_weights_sum_two() {
        for n in 2..=24 {
            let x = gll_points(n);
            for i in 1..n {
                assert!(x[i] > x[i - 1], "n={n} not ascending");
            }
            for i in 0..n {
                assert!(close(x[i], -x[n - 1 - i], 1e-13), "n={n} not symmetric");
            }
            let w = gll_weights(n);
            assert!(w.iter().all(|&v| v > 0.0));
            assert!(close(w.iter().sum::<f64>(), 2.0, 1e-12), "n={n} weight sum");
        }
    }

    #[test]
    fn quadrature_exact_on_polynomials() {
        // integral of x^p over [-1,1] = 2/(p+1) for even p, 0 for odd.
        for n in 2..=12 {
            let max_deg = 2 * n - 3;
            let x = gll_points(n);
            let w = gll_weights(n);
            for p in 0..=max_deg.min(14) {
                let quad: f64 = x.iter().zip(&w).map(|(&xi, &wi)| wi * xi.powi(p as i32)).sum();
                let exact = if p % 2 == 0 { 2.0 / (p as f64 + 1.0) } else { 0.0 };
                assert!(
                    close(quad, exact, 1e-11),
                    "n={n} p={p}: quad {quad} exact {exact}"
                );
            }
        }
    }
}
