//! Spectral-element basis: Gauss–Lobatto–Legendre points, weights and the
//! pseudo-spectral differentiation matrix (Nekbone's `semhat`).
//!
//! This is the Rust twin of `python/compile/basis.py`; the two are
//! cross-checked to machine precision by `rust/tests/basis_parity.rs`
//! (via values burned into both test suites) because the Rust side generates
//! the operator inputs the AOT kernels consume.

mod legendre;
mod gll;
mod deriv;

pub use deriv::derivative_matrix;
pub use gll::{gll_points, gll_weights};
pub use legendre::{legendre, legendre_deriv};

/// Bundle of everything downstream code needs for one polynomial degree.
#[derive(Clone, Debug)]
pub struct Basis {
    /// GLL points per dimension (`n = degree + 1`).
    pub n: usize,
    /// GLL nodes on `[-1, 1]`, ascending.
    pub points: Vec<f64>,
    /// GLL quadrature weights (positive, sum to 2).
    pub weights: Vec<f64>,
    /// Differentiation matrix `d`, row-major `n x n`:
    /// `(D u)_i = sum_j d[i*n + j] u_j`.
    pub d: Vec<f64>,
    /// Transpose of `d` (Nekbone's `dxtm1`), row-major.
    pub dt: Vec<f64>,
}

impl Basis {
    /// Construct the basis for `n` GLL points (polynomial degree `n - 1`).
    ///
    /// # Panics
    /// Panics for `n < 2` (a degree-0 element has no derivative).
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "GLL basis needs n >= 2, got {n}");
        let points = gll_points(n);
        let weights = gll_weights(n);
        let d = derivative_matrix(n);
        let mut dt = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                dt[j * n + i] = d[i * n + j];
            }
        }
        Basis { n, points, weights, d, dt }
    }

    /// Polynomial degree represented exactly by this basis.
    pub fn degree(&self) -> usize {
        self.n - 1
    }

    /// `d[i][j]` accessor (row-major).
    #[inline]
    pub fn d_at(&self, i: usize, j: usize) -> f64 {
        self.d[i * self.n + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basis_bundle_consistent() {
        let b = Basis::new(10);
        assert_eq!(b.n, 10);
        assert_eq!(b.degree(), 9);
        assert_eq!(b.points.len(), 10);
        assert_eq!(b.weights.len(), 10);
        assert_eq!(b.d.len(), 100);
        for i in 0..10 {
            for j in 0..10 {
                assert_eq!(b.d_at(i, j), b.dt[j * 10 + i]);
            }
        }
    }

    #[test]
    #[should_panic]
    fn n_one_panics() {
        Basis::new(1);
    }
}
