//! Legendre polynomials via the Bonnet three-term recurrence.

/// Evaluate the Legendre polynomial `P_order(x)`.
///
/// Stable on `[-1, 1]`: `(m+1) P_{m+1} = (2m+1) x P_m - m P_{m-1}`.
pub fn legendre(order: usize, x: f64) -> f64 {
    match order {
        0 => 1.0,
        1 => x,
        _ => {
            let mut p_prev = 1.0;
            let mut p = x;
            for m in 1..order {
                let m_f = m as f64;
                let p_next = ((2.0 * m_f + 1.0) * x * p - m_f * p_prev) / (m_f + 1.0);
                p_prev = p;
                p = p_next;
            }
            p
        }
    }
}

/// Evaluate `d/dx P_order(x)`.
///
/// Interior: `P'_n = n (x P_n - P_{n-1}) / (x^2 - 1)`; at the endpoints the
/// closed-form limit `P'_n(±1) = (±1)^{n-1} n (n+1) / 2`.
pub fn legendre_deriv(order: usize, x: f64) -> f64 {
    if order == 0 {
        return 0.0;
    }
    let n = order as f64;
    if (x.abs() - 1.0).abs() <= 1e-13 {
        let end = n * (n + 1.0) / 2.0;
        if x > 0.0 {
            end
        } else if order % 2 == 0 {
            -end
        } else {
            end
        }
    } else {
        n * (x * legendre(order, x) - legendre(order - 1, x)) / (x * x - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        for &x in &[-1.0, -0.5, 0.0, 0.3, 1.0] {
            assert!((legendre(0, x) - 1.0).abs() < 1e-15);
            assert!((legendre(1, x) - x).abs() < 1e-15);
            assert!((legendre(2, x) - 0.5 * (3.0 * x * x - 1.0)).abs() < 1e-14);
            assert!((legendre(3, x) - 0.5 * (5.0 * x * x * x - 3.0 * x)).abs() < 1e-14);
        }
    }

    #[test]
    fn endpoint_values() {
        // P_n(1) = 1, P_n(-1) = (-1)^n
        for order in 0..20 {
            assert!((legendre(order, 1.0) - 1.0).abs() < 1e-12);
            let want = if order % 2 == 0 { 1.0 } else { -1.0 };
            assert!((legendre(order, -1.0) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn deriv_matches_finite_difference() {
        let h = 1e-6;
        for order in 1..12 {
            for &x in &[-0.9, -0.4, 0.0, 0.55, 0.9] {
                let fd = (legendre(order, x + h) - legendre(order, x - h)) / (2.0 * h);
                let an = legendre_deriv(order, x);
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                    "order {order} x {x}: fd {fd} analytic {an}"
                );
            }
        }
    }

    #[test]
    fn deriv_endpoints() {
        for order in 1..10 {
            let n = order as f64;
            let end = n * (n + 1.0) / 2.0;
            assert!((legendre_deriv(order, 1.0) - end).abs() < 1e-12);
            let sign = if order % 2 == 0 { -1.0 } else { 1.0 };
            assert!((legendre_deriv(order, -1.0) - sign * end).abs() < 1e-12);
        }
    }
}
