//! Preconditioners — the paper's stated future work (section VII: "In the
//! future, we will investigate ... the preconditioned CG method").
//!
//! Two levels:
//!
//! * [`Jacobi`] — the assembled operator diagonal. For the affine box mesh
//!   the geometric-factor tensor is diagonal (G12 = G13 = G23 = 0), so the
//!   diagonal of the local operator has the closed form
//!
//!   ```text
//!   diag(i,j,k) = Σ_l d[l,i]² G11(l,j,k)
//!               + Σ_l d[l,j]² G22(i,l,k)
//!               + Σ_l d[l,k]² G33(i,j,l)
//!   ```
//!
//!   (each stage-2 row `D^T · G · D` picks the same column of `D` twice on
//!   the diagonal). The assembled diagonal is its dssum; the application
//!   is `z = r / diag` on unmasked dofs.
//!
//! * [`Chebyshev`] — a fixed-order Chebyshev polynomial in the
//!   Jacobi-preconditioned operator `M⁻¹A` (the classic smoother
//!   recurrence, cf. Nek5000's Chebyshev-accelerated Schwarz/Jacobi
//!   smoothing). Each application costs `order − 1` extra operator sweeps
//!   but contracts the whole band `[λmin, λmax]` at once, cutting CG
//!   iterations well below plain Jacobi. The coefficients are frozen at
//!   assembly (eigenvalue bounds from a short power iteration), so the
//!   preconditioner is a fixed SPD polynomial — a legal PCG
//!   preconditioner, not a nonlinear inner solve.

use crate::error::{Error, Result};
use crate::gs::GatherScatter;
use crate::solver::{mask_apply, AxApply, DomainExchange};

/// Assembled Jacobi preconditioner.
#[derive(Clone, Debug)]
pub struct Jacobi {
    /// Assembled (dssum'd) operator diagonal, with 1.0 on masked dofs so
    /// the division is harmless there (the mask zeroes them anyway).
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// Build from the local geometric factors (diagonal-G meshes only —
    /// the box mesh; deformed meshes would need the cross terms).
    pub fn assemble(
        n: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
        gs: &mut GatherScatter,
        mask: Option<&[f64]>,
    ) -> Result<Self> {
        let np = n * n * n;
        if d.len() != n * n || g.len() != nelt * 6 * np {
            return Err(Error::Config("Jacobi::assemble: size mismatch".into()));
        }
        // The G factor varies along the contracted axis, so the diagonal
        // needs the per-l products d[l,·]² · G(·) summed directly.
        let mut diag = vec![0.0f64; nelt * np];
        for e in 0..nelt {
            let ge = &g[e * 6 * np..(e + 1) * 6 * np];
            let g11 = &ge[0..np];
            let g22 = &ge[3 * np..4 * np];
            let g33 = &ge[5 * np..6 * np];
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let mut acc = 0.0;
                        for l in 0..n {
                            let dli = d[l * n + i];
                            let dlj = d[l * n + j];
                            let dlk = d[l * n + k];
                            acc += dli * dli * g11[(k * n + j) * n + l];
                            acc += dlj * dlj * g22[(k * n + l) * n + i];
                            acc += dlk * dlk * g33[(l * n + j) * n + i];
                        }
                        diag[e * np + (k * n + j) * n + i] = acc;
                    }
                }
            }
        }
        gs.dssum(&mut diag);
        let inv_diag = diag
            .iter()
            .zip(mask.map(|m| m.to_vec()).unwrap_or_else(|| vec![1.0; nelt * np]))
            .map(|(&a, m)| {
                if m == 0.0 || a == 0.0 {
                    1.0
                } else {
                    1.0 / a
                }
            })
            .collect();
        Ok(Jacobi { inv_diag })
    }

    /// `z = M^{-1} r` (elementwise divide by the assembled diagonal).
    pub fn apply(&self, r: &[f64], z: &mut [f64]) {
        debug_assert_eq!(r.len(), self.inv_diag.len());
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }

    /// The inverse diagonal (for tests).
    pub fn inv_diag(&self) -> &[f64] {
        &self.inv_diag
    }
}

/// Either preconditioner behind one runtime face — what
/// [`cg_solve_with`](crate::solver::cg_solve_with) takes in its
/// preconditioner slot.
#[derive(Clone, Debug)]
pub enum Precond {
    /// Plain diagonal scaling, `z = M⁻¹ r`.
    Jacobi(Jacobi),
    /// Chebyshev polynomial acceleration of the Jacobi-preconditioned
    /// operator (costs `order − 1` operator applications per CG
    /// iteration).
    Chebyshev(Chebyshev),
}

/// Scratch vectors for one [`Chebyshev::apply_with`] call, owned by the
/// caller's [`CgWorkspace`](crate::solver::CgWorkspace) so repeated solves
/// allocate nothing.
#[derive(Debug)]
pub struct ChebScratch {
    /// Current Chebyshev direction `d_k`.
    d: Vec<f64>,
    /// Running inner residual `r_k = r − A z_k`.
    rk: Vec<f64>,
    /// Operator output `A d_k`.
    t: Vec<f64>,
    /// Smoothed residual `M⁻¹ r_k`.
    mr: Vec<f64>,
}

impl ChebScratch {
    pub fn new(ndof: usize) -> Self {
        ChebScratch {
            d: vec![0.0; ndof],
            rk: vec![0.0; ndof],
            t: vec![0.0; ndof],
            mr: vec![0.0; ndof],
        }
    }

    /// The dof count this scratch was sized for.
    pub fn ndof(&self) -> usize {
        self.d.len()
    }
}

/// Chebyshev-accelerated Jacobi: the fixed-order smoother recurrence
/// applied as a PCG preconditioner. `z = p_m(M⁻¹A) M⁻¹ r` with Chebyshev
/// coefficients for the interval `[λmin, λmax]` of `M⁻¹A`, bounds
/// estimated once at assembly by power iteration.
#[derive(Clone, Debug)]
pub struct Chebyshev {
    jacobi: Jacobi,
    order: usize,
    lmin: f64,
    lmax: f64,
}

/// Power-iteration sweeps for the λmax estimate. The estimate only seeds
/// the safety-factored interval below, so a short fixed count suffices.
const POWER_ITERS: usize = 15;

impl Chebyshev {
    /// Assemble for the masked, assembled operator `A = mask ∘ dssum ∘
    /// A_local` defined by `(d, g, gs, mask)`: builds the inner [`Jacobi`]
    /// from the same data, then runs [`POWER_ITERS`] power-iteration
    /// sweeps of `M⁻¹A` to bound its spectrum. The interval is padded the
    /// standard smoother way (`λmax` up by 10% for the power-iteration
    /// shortfall, `λmin = λmax / 30` — the low end only shapes how much of
    /// the band the polynomial targets; CG handles the few modes below
    /// it). `order` ≥ 1 is the polynomial degree: each CG iteration costs
    /// `order − 1` extra operator applications, and order 1 degenerates to
    /// scaled Jacobi.
    pub fn assemble(
        n: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
        gs: &mut GatherScatter,
        mask: Option<&[f64]>,
        order: usize,
    ) -> Result<Self> {
        if order == 0 {
            return Err(Error::Config("Chebyshev order must be >= 1".into()));
        }
        let jacobi = Jacobi::assemble(n, nelt, d, g, gs, mask)?;
        let np = n * n * n;
        let ndof = nelt * np;
        // Deterministic start vector with energy in every mode.
        let mut v = crate::rng::Rng::new(0x5EB0).normal_vec(ndof);
        if let Some(m) = mask {
            mask_apply(&mut v, m);
        }
        let mut av = vec![0.0; ndof];
        let mut lmax_hat = 0.0f64;
        for _ in 0..POWER_ITERS {
            crate::operators::ax_layered(n, nelt, &v, d, g, &mut av);
            gs.dssum(&mut av);
            if let Some(m) = mask {
                mask_apply(&mut av, m);
            }
            // v <- M⁻¹ A v, normalized; the growth factor estimates λmax.
            jacobi.apply(&av, &mut v);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if !norm.is_finite() || norm == 0.0 {
                return Err(Error::Numerical(format!(
                    "Chebyshev power iteration degenerated (norm = {norm})"
                )));
            }
            lmax_hat = norm;
            for x in v.iter_mut() {
                *x /= norm;
            }
        }
        let lmax = 1.1 * lmax_hat;
        let lmin = lmax / 30.0;
        Ok(Chebyshev { jacobi, order, lmin, lmax })
    }

    /// The estimated spectrum bounds `(λmin, λmax)` (for tests).
    pub fn bounds(&self) -> (f64, f64) {
        (self.lmin, self.lmax)
    }

    /// Polynomial order.
    pub fn order(&self) -> usize {
        self.order
    }

    /// `z ≈ A⁻¹ r` by the order-`m` Chebyshev smoother recurrence over
    /// `M⁻¹A`, zero initial guess:
    ///
    /// ```text
    /// θ = (λmax+λmin)/2,  δ = (λmax−λmin)/2,  σ = θ/δ,  ρ₀ = 1/σ
    /// d₀ = (1/θ) M⁻¹ r;          z₁ = d₀;  r₀ = r
    /// for k = 1 .. m−1:
    ///     r_k = r_{k−1} − A d_{k−1}
    ///     ρ_k = 1 / (2σ − ρ_{k−1})
    ///     d_k = ρ_k ρ_{k−1} d_{k−1} + (2ρ_k/δ) M⁻¹ r_k
    ///     z  += d_k
    /// ```
    ///
    /// `A` is the same masked, exchanged composite the CG loop applies —
    /// passed in as hooks so the preconditioner exercises the session's
    /// actual operator (fused, threaded, f32, XLA alike).
    pub fn apply_with(
        &self,
        ax: &mut dyn AxApply,
        exchange: &mut dyn DomainExchange,
        mask: Option<&[f64]>,
        r: &[f64],
        z: &mut [f64],
        s: &mut ChebScratch,
    ) -> Result<()> {
        debug_assert_eq!(r.len(), z.len());
        debug_assert_eq!(r.len(), s.ndof());
        let theta = 0.5 * (self.lmax + self.lmin);
        let delta = 0.5 * (self.lmax - self.lmin);
        let sigma = theta / delta;
        let mut rho_prev = 1.0 / sigma;

        self.jacobi.apply(r, &mut s.mr);
        for ((di, zi), mi) in s.d.iter_mut().zip(z.iter_mut()).zip(&s.mr) {
            *di = mi / theta;
            *zi = *di;
        }
        s.rk.copy_from_slice(r);
        // An assembly-fused operator (see `AxApply::applies_assembly`)
        // already returns mask(dssum(·)); the recurrence must not fold or
        // mask a second time.
        let assembled = ax.applies_assembly();
        for _ in 1..self.order {
            ax.apply(&s.d, &mut s.t)?;
            if !assembled {
                exchange.exchange(&mut s.t)?;
                if let Some(m) = mask {
                    mask_apply(&mut s.t, m);
                }
            }
            for (rki, ti) in s.rk.iter_mut().zip(&s.t) {
                *rki -= ti;
            }
            let rho = 1.0 / (2.0 * sigma - rho_prev);
            self.jacobi.apply(&s.rk, &mut s.mr);
            let scale = 2.0 * rho / delta;
            for ((di, mi), zi) in s.d.iter_mut().zip(&s.mr).zip(z.iter_mut()) {
                *di = rho * rho_prev * *di + scale * mi;
                *zi += *di;
            }
            rho_prev = rho;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Basis;
    use crate::geometry::GeomFactors;
    use crate::mesh::Mesh;
    use crate::operators::ax_layered;

    /// The assembled diagonal must match A e_i probed column by column.
    #[test]
    fn diagonal_matches_operator_probe() {
        let n = 4;
        let mesh = Mesh::new(2, 1, 1, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let jac =
            Jacobi::assemble(n, mesh.nelt(), &basis.d, &geom.g, &mut gs, None).unwrap();
        let ndof = mesh.ndof_local();
        let np = n * n * n;
        // Probe a handful of dofs: diag_i = (Q Q^T A_local e_i)_i where
        // e_i is a *consistent* basis field (all copies of the global dof
        // set to 1).
        let ids = mesh.global_ids();
        for probe in [0usize, 5, np / 2, ndof - 1] {
            let gid = ids[probe];
            let mut e_i = vec![0.0; ndof];
            for (l, &g) in ids.iter().enumerate() {
                if g == gid {
                    e_i[l] = 1.0;
                }
            }
            let mut w = vec![0.0; ndof];
            ax_layered(n, mesh.nelt(), &e_i, &basis.d, &geom.g, &mut w);
            gs.dssum(&mut w);
            let want = w[probe];
            let got = 1.0 / jac.inv_diag()[probe];
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "dof {probe}: assembled {got} vs probed {want}"
            );
        }
    }

    #[test]
    fn apply_divides() {
        let jac = Jacobi { inv_diag: vec![0.5, 0.25] };
        let mut z = vec![0.0; 2];
        jac.apply(&[2.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 2.0]);
    }

    /// Shared setup for the Chebyshev tests: a small masked SEM system and
    /// a layered-operator AxApply closure over it.
    fn cheb_fixture(
        order: usize,
    ) -> (Mesh, Basis, GeomFactors, Vec<f64>, Chebyshev, GatherScatter) {
        let n = 4;
        let mesh = Mesh::new(2, 2, 1, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let mut gs = GatherScatter::new(&mesh);
        let cheb = Chebyshev::assemble(
            n,
            mesh.nelt(),
            &basis.d,
            &geom.g,
            &mut gs,
            Some(&mask),
            order,
        )
        .unwrap();
        (mesh, basis, geom, mask, cheb, gs)
    }

    #[test]
    fn chebyshev_bounds_are_sane() {
        let (_, _, _, _, cheb, _) = cheb_fixture(4);
        let (lmin, lmax) = cheb.bounds();
        assert!(lmax.is_finite() && lmax > 0.0, "lmax = {lmax}");
        assert!(lmin > 0.0 && lmin < lmax, "lmin = {lmin}, lmax = {lmax}");
        // Jacobi-preconditioned SEM operator: λmax is O(1)-to-O(10), not
        // the raw operator's mesh-dependent scale.
        assert!(lmax < 100.0, "power iteration diverged? lmax = {lmax}");
        assert_eq!(cheb.order(), 4);
    }

    #[test]
    fn chebyshev_zero_order_rejected() {
        let n = 3;
        let mesh = Mesh::new(1, 1, 1, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        assert!(Chebyshev::assemble(n, 1, &basis.d, &geom.g, &mut gs, None, 0).is_err());
    }

    #[test]
    fn chebyshev_application_is_linear() {
        // PCG is only valid for a *fixed linear* preconditioner: check
        // z(a·r1 + b·r2) = a·z(r1) + b·z(r2) through the full recurrence.
        let (mesh, basis, geom, mask, cheb, mut gs) = cheb_fixture(4);
        let n = mesh.n;
        let nelt = mesh.nelt();
        let ndof = mesh.ndof_local();
        let mut ax = |p: &[f64], w: &mut [f64]| -> crate::error::Result<()> {
            crate::operators::ax_layered(n, nelt, p, &basis.d, &geom.g, w);
            Ok(())
        };
        let mut rng = crate::rng::Rng::new(77);
        let mut r1 = rng.normal_vec(ndof);
        let mut r2 = rng.normal_vec(ndof);
        mask_apply(&mut r1, &mask);
        mask_apply(&mut r2, &mask);
        let (a, b) = (2.5, -0.75);
        let rc: Vec<f64> = r1.iter().zip(&r2).map(|(x, y)| a * x + b * y).collect();
        let mut s = ChebScratch::new(ndof);
        let mut z1 = vec![0.0; ndof];
        let mut z2 = vec![0.0; ndof];
        let mut zc = vec![0.0; ndof];
        cheb.apply_with(&mut ax, &mut gs, Some(&mask), &r1, &mut z1, &mut s).unwrap();
        cheb.apply_with(&mut ax, &mut gs, Some(&mask), &r2, &mut z2, &mut s).unwrap();
        cheb.apply_with(&mut ax, &mut gs, Some(&mask), &rc, &mut zc, &mut s).unwrap();
        let want: Vec<f64> = z1.iter().zip(&z2).map(|(x, y)| a * x + b * y).collect();
        crate::proputil::assert_allclose(&zc, &want, 1e-11, 1e-11);
    }

    #[test]
    fn chebyshev_order_one_is_scaled_jacobi() {
        let (mesh, basis, geom, mask, cheb, mut gs) = cheb_fixture(1);
        let n = mesh.n;
        let nelt = mesh.nelt();
        let ndof = mesh.ndof_local();
        let jac =
            Jacobi::assemble(n, nelt, &basis.d, &geom.g, &mut gs, Some(&mask)).unwrap();
        let mut ax = |p: &[f64], w: &mut [f64]| -> crate::error::Result<()> {
            crate::operators::ax_layered(n, nelt, p, &basis.d, &geom.g, w);
            Ok(())
        };
        let mut r = crate::rng::Rng::new(78).normal_vec(ndof);
        mask_apply(&mut r, &mask);
        let mut z = vec![0.0; ndof];
        let mut s = ChebScratch::new(ndof);
        cheb.apply_with(&mut ax, &mut gs, Some(&mask), &r, &mut z, &mut s).unwrap();
        // Order 1 stops after d0 = (1/θ) M⁻¹ r, i.e. Jacobi scaled by 1/θ.
        let (lmin, lmax) = cheb.bounds();
        let theta = 0.5 * (lmax + lmin);
        let mut mj = vec![0.0; ndof];
        jac.apply(&r, &mut mj);
        for (zi, mi) in z.iter().zip(&mj) {
            let want = mi / theta;
            assert!((zi - want).abs() <= 1e-13 * (1.0 + want.abs()), "{zi} vs {want}");
        }
    }
}
