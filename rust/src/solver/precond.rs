//! Diagonal (Jacobi) preconditioner — the paper's stated future work
//! (section VII: "In the future, we will investigate ... the
//! preconditioned CG method").
//!
//! For the affine box mesh the geometric-factor tensor is diagonal
//! (G12 = G13 = G23 = 0), so the diagonal of the local operator has the
//! closed form
//!
//! ```text
//! diag(i,j,k) = Σ_l d[l,i]² G11(l,j,k)
//!             + Σ_l d[l,j]² G22(i,l,k)
//!             + Σ_l d[l,k]² G33(i,j,l)
//! ```
//!
//! (each stage-2 row `D^T · G · D` picks the same column of `D` twice on
//! the diagonal). The assembled diagonal is its dssum; the preconditioner
//! application is `z = r / diag` on unmasked dofs.

use crate::error::{Error, Result};
use crate::gs::GatherScatter;

/// Assembled Jacobi preconditioner.
#[derive(Clone, Debug)]
pub struct Jacobi {
    /// Assembled (dssum'd) operator diagonal, with 1.0 on masked dofs so
    /// the division is harmless there (the mask zeroes them anyway).
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// Build from the local geometric factors (diagonal-G meshes only —
    /// the box mesh; deformed meshes would need the cross terms).
    pub fn assemble(
        n: usize,
        nelt: usize,
        d: &[f64],
        g: &[f64],
        gs: &mut GatherScatter,
        mask: Option<&[f64]>,
    ) -> Result<Self> {
        let np = n * n * n;
        if d.len() != n * n || g.len() != nelt * 6 * np {
            return Err(Error::Config("Jacobi::assemble: size mismatch".into()));
        }
        // Column sums of squares of D: colsq[a][i] = sum_l d[l,i]^2 is the
        // same for every a; precompute sum_l d[l,c]^2 once.
        let mut colsq = vec![0.0f64; n];
        for (c, out) in colsq.iter_mut().enumerate() {
            for l in 0..n {
                *out += d[l * n + c] * d[l * n + c];
            }
        }
        // But the G factor varies along the contracted axis, so the full
        // form needs the per-l products; do it directly.
        let mut diag = vec![0.0f64; nelt * np];
        for e in 0..nelt {
            let ge = &g[e * 6 * np..(e + 1) * 6 * np];
            let g11 = &ge[0..np];
            let g22 = &ge[3 * np..4 * np];
            let g33 = &ge[5 * np..6 * np];
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let mut acc = 0.0;
                        for l in 0..n {
                            let dli = d[l * n + i];
                            let dlj = d[l * n + j];
                            let dlk = d[l * n + k];
                            acc += dli * dli * g11[(k * n + j) * n + l];
                            acc += dlj * dlj * g22[(k * n + l) * n + i];
                            acc += dlk * dlk * g33[(l * n + j) * n + i];
                        }
                        diag[e * np + (k * n + j) * n + i] = acc;
                    }
                }
            }
        }
        let _ = colsq;
        gs.dssum(&mut diag);
        let inv_diag = diag
            .iter()
            .zip(mask.map(|m| m.to_vec()).unwrap_or_else(|| vec![1.0; nelt * np]))
            .map(|(&a, m)| {
                if m == 0.0 || a == 0.0 {
                    1.0
                } else {
                    1.0 / a
                }
            })
            .collect();
        Ok(Jacobi { inv_diag })
    }

    /// `z = M^{-1} r` (elementwise divide by the assembled diagonal).
    pub fn apply(&self, r: &[f64], z: &mut [f64]) {
        debug_assert_eq!(r.len(), self.inv_diag.len());
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }

    /// The inverse diagonal (for tests).
    pub fn inv_diag(&self) -> &[f64] {
        &self.inv_diag
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Basis;
    use crate::geometry::GeomFactors;
    use crate::mesh::Mesh;
    use crate::operators::ax_layered;

    /// The assembled diagonal must match A e_i probed column by column.
    #[test]
    fn diagonal_matches_operator_probe() {
        let n = 4;
        let mesh = Mesh::new(2, 1, 1, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let jac =
            Jacobi::assemble(n, mesh.nelt(), &basis.d, &geom.g, &mut gs, None).unwrap();
        let ndof = mesh.ndof_local();
        let np = n * n * n;
        // Probe a handful of dofs: diag_i = (Q Q^T A_local e_i)_i where
        // e_i is a *consistent* basis field (all copies of the global dof
        // set to 1).
        let ids = mesh.global_ids();
        for probe in [0usize, 5, np / 2, ndof - 1] {
            let gid = ids[probe];
            let mut e_i = vec![0.0; ndof];
            for (l, &g) in ids.iter().enumerate() {
                if g == gid {
                    e_i[l] = 1.0;
                }
            }
            let mut w = vec![0.0; ndof];
            ax_layered(n, mesh.nelt(), &e_i, &basis.d, &geom.g, &mut w);
            gs.dssum(&mut w);
            let want = w[probe];
            let got = 1.0 / jac.inv_diag()[probe];
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "dof {probe}: assembled {got} vs probed {want}"
            );
        }
    }

    #[test]
    fn apply_divides() {
        let jac = Jacobi { inv_diag: vec![0.5, 0.25] };
        let mut z = vec![0.0; 2];
        jac.apply(&[2.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 2.0]);
    }
}
