//! Conjugate-gradient solver (Nekbone's `cg.f`) and its vector algebra.

mod vector;
mod cg;
mod precond;

pub use cg::{cg_solve, cg_solve_op, cg_solve_pc, AxApply, CgOptions, CgReport, CgWorkspace};
pub(crate) use cg::PapCorrection;
pub use precond::Jacobi;
pub use vector::{add2s1, add2s2, copy, glsc3, mask_apply, rzero};
