//! Conjugate-gradient solver (Nekbone's `cg.f`), its vector algebra, and
//! the two abstractions that make **one** CG loop serve every execution
//! mode: [`Communicator`] and [`DomainExchange`].
//!
//! ## The solve-side contracts
//!
//! The CG driver ([`cg_solve`] / [`cg_solve_with`]) is written against
//! hooks, not implementations:
//!
//! * **[`Communicator`]** — the collective layer (`rank`, `size`,
//!   `allreduce_sum`, `allreduce_min`, `barrier`). Every CG scalar (`rtz1`,
//!   `pap`, the exit residual) passes through `allreduce_sum`, whose
//!   contract is a **rank-order-deterministic fold delivering the bitwise
//!   identical result to every rank**; all control flow in the solver
//!   branches only on these rank-identical values, so ranks stay in lock
//!   step and every rank's [`CgReport`] is bitwise identical.
//!   Implementations: [`NullComm`] (serial, zero-cost) and
//!   [`ThreadComm`](crate::rank::ThreadComm) (channels as simulated MPI).
//! * **[`DomainExchange`]** — direct-stiffness assembly (`exchange` =
//!   Nekbone's `dssum`, `shared_dofs` = the indices it may change,
//!   `pap_correction` = the O(surface) patch the fused Ax+pap path uses in
//!   place of a full `glsc3` sweep). Implementations:
//!   [`GatherScatter`](crate::gs::GatherScatter) (serial assembly),
//!   the rank runtime's halo exchange (rank-local assembly + neighbor
//!   exchange), and [`NoExchange`] (the paper's `--no-comm` roofline mode).
//! * **[`VectorOps`]** — where the full-vector algebra runs
//!   ([`NativeVectors`] by default; the application pipeline provides a
//!   chunked-XLA implementation for experiment E6). [`BlockedVectors`]
//!   wraps any backend into the cache-blocked iteration pipeline
//!   (`--block-dofs`): element-blocked walks that keep each segment
//!   cache-resident while staying bitwise identical to the unblocked
//!   passes.
//!
//! Any combination of the three drops into the same loop, which is the
//! only place in the crate that updates residuals, applies the
//! convergence floor, or accounts `glsc3` sweeps.

mod cg;
mod comm;
mod exchange;
mod precond;
mod vector;

pub use cg::{
    cg_solve, cg_solve_op, cg_solve_pc, cg_solve_precond, cg_solve_with, AxApply, CgOptions,
    CgReport, CgWorkspace, TimedAx,
};
pub use comm::{Communicator, NullComm};
pub use exchange::{DomainExchange, NoExchange, PapCorrection};
pub use precond::{ChebScratch, Chebyshev, Jacobi, Precond};
pub use vector::{
    add2s1, add2s2, copy, glsc3, mask_apply, rzero, BlockedVectors, NativeVectors, VectorOps,
};
