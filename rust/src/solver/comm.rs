//! The communicator abstraction: the collective operations CG needs,
//! behind one object-safe trait.
//!
//! The conjugate-gradient driver is one algorithm whether it runs on one
//! rank or many — only two things differ: how global reductions are formed
//! (here) and how the distributed field is assembled (the
//! [`DomainExchange`](crate::solver::DomainExchange) trait). Abstracting
//! both lets a single [`cg_solve`](crate::solver::cg_solve) serve the
//! serial pipeline, the `--no-comm` roofline mode, and the simulated-MPI
//! rank runtime, the way HipBone writes one solver over an MPI + gslib
//! layer.
//!
//! ## Contract
//!
//! * Collectives are **bulk-synchronous and order-matched**: every rank of
//!   the communicator must call the same sequence of collective operations
//!   in the same order. The CG driver guarantees this structurally — every
//!   branch it takes depends only on allreduced (rank-identical) values.
//! * Results are **deterministic and rank-identical**: an allreduce folds
//!   the per-rank contributions in ascending rank order and every rank
//!   receives the bitwise-identical result. Cross-rank agreement on the CG
//!   trajectory is therefore exact, not approximate — the rank runtime
//!   asserts bitwise equality of the per-rank reports.
//! * A size-1 communicator must be zero-cost: [`NullComm`] simply returns
//!   its argument, so the serial solver pays nothing for the abstraction.

use crate::error::Result;

/// Collective communication between the ranks of one solve.
///
/// Implementations: [`NullComm`] (serial, zero-cost) and
/// [`ThreadComm`](crate::rank::ThreadComm) (channel-backed simulated MPI).
pub trait Communicator {
    /// This rank's index in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn size(&self) -> usize;

    /// Global sum: contributions folded in ascending rank order; every
    /// rank receives the bitwise-identical result.
    fn allreduce_sum(&mut self, value: f64) -> Result<f64>;

    /// Global minimum, with the same determinism guarantees as
    /// [`Communicator::allreduce_sum`].
    fn allreduce_min(&mut self, value: f64) -> Result<f64>;

    /// Global sum of *keyed* partials: every rank contributes a list of
    /// `(gid, partial)` pairs (gids globally unique across ranks), and the
    /// result is the fold of **all** partials in ascending-gid order,
    /// starting from `0.0`, delivered bitwise-identically to every rank.
    ///
    /// This is the collective behind the solver's element-blocked
    /// reductions: because the fold order is a global property (the gid
    /// order), the result is independent of how the elements are
    /// distributed — a ranked solve reproduces the serial fold bit for
    /// bit. The default implementation serves any size-1 communicator:
    /// with one rank the gids are already ascending (the caller's
    /// contract), so the fold is a plain left-to-right sum.
    fn allreduce_ordered_sum(&mut self, gids: &[u64], partials: &[f64]) -> Result<f64> {
        debug_assert_eq!(gids.len(), partials.len());
        debug_assert!(gids.windows(2).all(|w| w[0] < w[1]));
        Ok(partials.iter().fold(0.0, |acc, &p| acc + p))
    }

    /// All ranks reach the barrier before any returns from it.
    fn barrier(&mut self) -> Result<()>;
}

/// The serial communicator: one rank, every collective is the identity.
/// This is the zero-cost default for single-address-space and `--no-comm`
/// runs — the compiler sees straight through it.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullComm;

impl Communicator for NullComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn allreduce_sum(&mut self, value: f64) -> Result<f64> {
        Ok(value)
    }

    fn allreduce_min(&mut self, value: f64) -> Result<f64> {
        Ok(value)
    }

    fn barrier(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_comm_is_identity() {
        let mut c = NullComm;
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        assert_eq!(c.allreduce_sum(2.5).unwrap(), 2.5);
        assert_eq!(c.allreduce_min(-7.0).unwrap(), -7.0);
        c.barrier().unwrap();
    }

    #[test]
    fn ordered_sum_folds_left_to_right() {
        // The serial ordered fold must be the plain left-to-right sum —
        // this exact expression is what a multi-rank communicator has to
        // reproduce bitwise after gathering and sorting by gid.
        let mut c = NullComm;
        let vals = [1.0e16, 1.0, -1.0e16, 3.5];
        let gids = [0u64, 1, 2, 3];
        let want = vals.iter().fold(0.0f64, |acc, &v| acc + v);
        let got = c.allreduce_ordered_sum(&gids, &vals).unwrap();
        assert_eq!(got.to_bits(), want.to_bits());
    }
}
