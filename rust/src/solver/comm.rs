//! The communicator abstraction: the collective operations CG needs,
//! behind one object-safe trait.
//!
//! The conjugate-gradient driver is one algorithm whether it runs on one
//! rank or many — only two things differ: how global reductions are formed
//! (here) and how the distributed field is assembled (the
//! [`DomainExchange`](crate::solver::DomainExchange) trait). Abstracting
//! both lets a single [`cg_solve`](crate::solver::cg_solve) serve the
//! serial pipeline, the `--no-comm` roofline mode, and the simulated-MPI
//! rank runtime, the way HipBone writes one solver over an MPI + gslib
//! layer.
//!
//! ## Contract
//!
//! * Collectives are **bulk-synchronous and order-matched**: every rank of
//!   the communicator must call the same sequence of collective operations
//!   in the same order. The CG driver guarantees this structurally — every
//!   branch it takes depends only on allreduced (rank-identical) values.
//! * Results are **deterministic and rank-identical**: an allreduce folds
//!   the per-rank contributions in ascending rank order and every rank
//!   receives the bitwise-identical result. Cross-rank agreement on the CG
//!   trajectory is therefore exact, not approximate — the rank runtime
//!   asserts bitwise equality of the per-rank reports.
//! * A size-1 communicator must be zero-cost: [`NullComm`] simply returns
//!   its argument, so the serial solver pays nothing for the abstraction.

use crate::error::Result;

/// Collective communication between the ranks of one solve.
///
/// Implementations: [`NullComm`] (serial, zero-cost) and
/// [`ThreadComm`](crate::rank::ThreadComm) (channel-backed simulated MPI).
pub trait Communicator {
    /// This rank's index in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn size(&self) -> usize;

    /// Global sum: contributions folded in ascending rank order; every
    /// rank receives the bitwise-identical result.
    fn allreduce_sum(&mut self, value: f64) -> Result<f64>;

    /// Global minimum, with the same determinism guarantees as
    /// [`Communicator::allreduce_sum`].
    fn allreduce_min(&mut self, value: f64) -> Result<f64>;

    /// All ranks reach the barrier before any returns from it.
    fn barrier(&mut self) -> Result<()>;
}

/// The serial communicator: one rank, every collective is the identity.
/// This is the zero-cost default for single-address-space and `--no-comm`
/// runs — the compiler sees straight through it.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullComm;

impl Communicator for NullComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn allreduce_sum(&mut self, value: f64) -> Result<f64> {
        Ok(value)
    }

    fn allreduce_min(&mut self, value: f64) -> Result<f64> {
        Ok(value)
    }

    fn barrier(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_comm_is_identity() {
        let mut c = NullComm;
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        assert_eq!(c.allreduce_sum(2.5).unwrap(), 2.5);
        assert_eq!(c.allreduce_min(-7.0).unwrap(), -7.0);
        c.barrier().unwrap();
    }
}
