//! Unpreconditioned conjugate gradient, structured exactly like Nekbone's
//! `cg.f` (the paper runs 100 iterations, no preconditioner).
//!
//! Per iteration (DESIGN.md section 7):
//!
//! ```text
//! z = r                                   (solveM with M = I)
//! rtz2 = rtz1;  rtz1 = allreduce(glsc3(r, c, z))
//! beta = rtz1 / rtz2   (0 on the first iteration)
//! p = z + beta p                          (add2s1)
//! w = mask(exchange(A_local p))           (the Ax of the paper)
//! pap = allreduce(glsc3(w, c, p))
//! alpha = rtz1 / pap
//! x = x + alpha p                         (add2s2)
//! r = r - alpha w                         (add2s2)
//! ```
//!
//! The weighted inner products use `c` = inverse multiplicity so every
//! global dof counts once despite local duplication.
//!
//! This is the **only** CG loop in the crate. Serial solves drive it with
//! [`NullComm`](crate::solver::NullComm) + a
//! [`GatherScatter`](crate::gs::GatherScatter) exchange, `--no-comm`
//! roofline runs with [`NoExchange`](crate::solver::NoExchange), and the
//! simulated-MPI rank runtime with
//! [`ThreadComm`](crate::rank::ThreadComm) + a halo exchange — same
//! residual updates, same convergence floor, same fused-pap accounting,
//! same sweep counters, everywhere.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::solver::vector::{copy, mask_apply, rzero, BlockedVectors, NativeVectors, VectorOps};
use crate::solver::{Communicator, DomainExchange, PapCorrection};

/// The local Ax hook: `w <- A_local(p)` (no exchange, no mask — the solver
/// applies those). Implementations: CPU operators, the PJRT runtime, or
/// plain closures.
///
/// Fused implementations (see the fused-operator contract in
/// [`crate::operators`]) also report the reduction they computed in the
/// same pass; the solver then skips its own full-length `glsc3(w, c, p)`
/// sweep, replacing it with an O(surface) correction over the exchange's
/// shared dofs.
pub trait AxApply {
    fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()>;

    /// Does `apply` also compute `pap = Σ w·c·p` in the same pass?
    fn is_fused(&self) -> bool {
        false
    }

    /// The fused `pap` of the most recent `apply` (pre-exchange,
    /// pre-mask); `None` for unfused implementations.
    fn fused_pap(&self) -> Option<f64> {
        None
    }

    /// Does `apply` already return the **assembled** `w = mask(dssum(A_local p))`?
    ///
    /// When true the solver must skip its own exchange + mask pass, and a
    /// fused implementation's `fused_pap` is the assembled local reduction
    /// (no shared-dof correction is needed — only the cross-rank
    /// allreduce). See [`crate::operators::AxOperator::applies_assembly`].
    fn applies_assembly(&self) -> bool {
        false
    }
}

impl<F> AxApply for F
where
    F: FnMut(&[f64], &mut [f64]) -> Result<()>,
{
    fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        self(p, w)
    }
}

/// Adapter giving a registry operator the [`AxApply`] face, forwarding the
/// fused-pap hooks so [`cg_solve`] can skip the separate reduction sweep.
struct OperatorAx<'a>(&'a mut dyn crate::operators::AxOperator);

impl AxApply for OperatorAx<'_> {
    fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        self.0.apply(p, w)
    }

    fn is_fused(&self) -> bool {
        self.0.is_fused()
    }

    fn fused_pap(&self) -> Option<f64> {
        self.0.last_pap()
    }

    fn applies_assembly(&self) -> bool {
        self.0.applies_assembly()
    }
}

/// [`AxApply`] adapter that times each operator application and forwards
/// the fused-pap hooks. Shared by every consumer that reports `ax_seconds`
/// (the application pipeline, the rank runtime), so one [`cg_solve`] call
/// serves fused and unfused operators alike.
pub struct TimedAx<'a> {
    op: &'a mut dyn crate::operators::AxOperator,
    /// Accumulated wall time inside `apply`.
    pub seconds: f64,
}

impl<'a> TimedAx<'a> {
    pub fn new(op: &'a mut dyn crate::operators::AxOperator) -> Self {
        TimedAx { op, seconds: 0.0 }
    }
}

impl AxApply for TimedAx<'_> {
    fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        let t0 = Instant::now();
        self.op.apply(p, w)?;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn is_fused(&self) -> bool {
        self.op.is_fused()
    }

    fn fused_pap(&self) -> Option<f64> {
        self.op.last_pap()
    }

    fn applies_assembly(&self) -> bool {
        self.op.applies_assembly()
    }
}

/// Run [`cg_solve`] with a trait-based operator (anything built through
/// the [`OperatorRegistry`](crate::operators::OperatorRegistry)): the
/// operator's `apply` is the local Ax hook, and a fused operator's
/// `last_pap` feeds the solver's fused path.
#[allow(clippy::too_many_arguments)]
pub fn cg_solve_op(
    op: &mut dyn crate::operators::AxOperator,
    exchange: &mut dyn DomainExchange,
    comm: &mut dyn Communicator,
    mask: Option<&[f64]>,
    c: &[f64],
    f: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    ws: &mut CgWorkspace,
) -> Result<CgReport> {
    let mut ax = OperatorAx(op);
    cg_solve(&mut ax, exchange, comm, mask, c, f, x, opts, ws)
}

/// Solver options.
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Fixed iteration count (the paper runs exactly 100; Nekbone does not
    /// early-exit either).
    pub niter: usize,
    /// Optional residual tolerance for early exit (‖r‖_c); `None` mirrors
    /// Nekbone.
    pub rtol: Option<f64>,
    /// Record ‖r‖ every iteration (costs one glsc3 per iteration when a
    /// tolerance is not already paying for it).
    pub record_residuals: bool,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { niter: 100, rtol: None, record_residuals: false }
    }
}

/// Outcome of a CG run.
///
/// Every scalar here derives from allreduced values, so on a multi-rank
/// communicator the report is **bitwise identical on every rank** — the
/// rank runtime asserts this rather than assuming it.
#[derive(Clone, Debug)]
pub struct CgReport {
    /// Iterations actually executed.
    pub iterations: usize,
    /// `sqrt(allreduce(glsc3(r, c, r)))` at exit.
    pub final_rnorm: f64,
    /// Residual history (empty unless requested / tolerance set).
    pub rnorms: Vec<f64>,
    /// Final `rtz1` (the CG scalar, useful for regression tests).
    pub rtz1: f64,
    /// Full-length local `glsc3` sweeps the solver performed (one per
    /// iteration for `rtz1`, one per iteration for `pap` **unless the
    /// operator is fused**, plus one for the exit residual) — the
    /// accounting behind the fused path's "one fewer sweep per iteration"
    /// win.
    pub glsc3_sweeps: usize,
    /// Full-length vector passes the solver performed (preconditioner
    /// apply, each reduction's local read, `add2s1`/`add2s2` updates —
    /// staging copies excluded). One blocked walk over all dofs counts as
    /// **one** pass however many operations it fuses, so this is the
    /// accounting behind the cache-blocked pipeline's "3 fewer passes per
    /// iteration" win (see [`CgWorkspace::set_iteration_plan`]).
    pub vector_sweeps: usize,
}

/// Workspace so repeated solves don't allocate (benchmarks and
/// [`SolveSession`](crate::coordinator::SolveSession) call the solver in a
/// loop against one workspace).
pub struct CgWorkspace {
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    w: Vec<f64>,
    /// Cached fused-pap correction, reused across solves while the
    /// exchange keeps reporting the same shared-dof support — repeated
    /// (session) solves allocate nothing.
    pap: Option<PapCorrection>,
    /// Chebyshev recurrence scratch, allocated on the first
    /// Chebyshev-preconditioned solve and reused afterwards.
    cheb: Option<crate::solver::ChebScratch>,
    /// Element-blocked reduction plan (see [`ReducePlan`]); `None` keeps
    /// the historical single-flat-fold reductions.
    reduce: Option<ReducePlan>,
    /// Cache-blocking plan for the iteration pipeline (see
    /// [`CgWorkspace::set_iteration_plan`]); `None` keeps the historical
    /// whole-vector passes.
    iter_plan: Option<IterationPlan>,
}

/// How the solver's global dot products are folded.
///
/// With a plan installed, every `glsc3` reduction is computed as one
/// partial per `block`-dof slice (one slice per element, in practice) and
/// folded through [`Communicator::allreduce_ordered_sum`] in ascending
/// `gids` order. Because that order is a *global* property — the global
/// element id — the result is bitwise independent of how the elements are
/// split across ranks: a slab, pencil, or box decomposition reproduces
/// the serial solve's reductions exactly, which is what makes the rank
/// runtime's per-rank reports bitwise-identical to serial for every
/// decomposition shape.
struct ReducePlan {
    /// Dofs per partial (the element volume `n³`).
    block: usize,
    /// Ascending global ids, one per local block.
    gids: Vec<u64>,
    /// Per-block partials, rewritten every reduction (no allocation in
    /// the solve loop).
    partials: Vec<f64>,
}

/// How the solver's per-iteration vector work is cache-blocked.
///
/// With an iteration plan installed (on top of a [`ReducePlan`]), the CG
/// loop walks the reduce plan's element blocks `seg_elems` at a time,
/// performing each iteration's elementwise updates and per-element
/// dot-product partials while that segment's `x/r/w/p/z/c` data is
/// cache-resident (see [`BlockedVectors`]). Partials still fold in
/// ascending-gid order, so the blocked trajectory is bitwise the
/// unblocked one.
#[derive(Clone, Copy, Debug)]
struct IterationPlan {
    /// Elements per cache segment.
    seg_elems: usize,
}

impl CgWorkspace {
    pub fn new(ndof: usize) -> Self {
        CgWorkspace {
            r: vec![0.0; ndof],
            z: vec![0.0; ndof],
            p: vec![0.0; ndof],
            w: vec![0.0; ndof],
            pap: None,
            cheb: None,
            reduce: None,
            iter_plan: None,
        }
    }

    /// The dof count this workspace was sized for.
    pub fn ndof(&self) -> usize {
        self.r.len()
    }

    /// Install an element-blocked reduction plan: local dofs are `block`
    /// contiguous dofs per entry of `gids` (ascending, globally unique
    /// across the communicator). Both the serial pipeline and the rank
    /// runtime install one, which pins every CG reduction to the same
    /// global fold order regardless of decomposition.
    pub fn set_reduce_plan(&mut self, block: usize, gids: Vec<u64>) -> Result<()> {
        if block == 0 || block.checked_mul(gids.len()) != Some(self.ndof()) {
            return Err(Error::Config(format!(
                "set_reduce_plan: {} blocks of {block} dofs != workspace ndof {}",
                gids.len(),
                self.ndof()
            )));
        }
        if gids.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::Config(
                "set_reduce_plan: gids must be strictly ascending".into(),
            ));
        }
        let partials = vec![0.0; gids.len()];
        self.reduce = Some(ReducePlan { block, gids, partials });
        // An iteration plan is sized against the reduce plan's blocks —
        // re-installing the reduce plan invalidates it (install order:
        // reduce plan first, then iteration plan).
        self.iter_plan = None;
        Ok(())
    }

    /// Install a cache-blocking plan for the iteration pipeline: the CG
    /// loop's vector updates and dot-product partials run over the reduce
    /// plan's element blocks roughly `block_dofs` dofs at a time (clamped
    /// to whole elements, at least one, at most all — ranked runs hand
    /// each rank a smaller local dof count than the global knob was sized
    /// against). Requires a reduce plan ([`CgWorkspace::set_reduce_plan`])
    /// installed first; zero is a structured Config error.
    ///
    /// Blocked solves are **bitwise identical** to unblocked ones — same
    /// rnorms, iteration counts, and solution — only
    /// [`CgReport::vector_sweeps`] drops.
    pub fn set_iteration_plan(&mut self, block_dofs: usize) -> Result<()> {
        let Some(plan) = self.reduce.as_ref() else {
            return Err(Error::Config(
                "set_iteration_plan: install a reduce plan first (the blocked \
                 pipeline walks its element blocks)"
                    .into(),
            ));
        };
        if block_dofs == 0 {
            return Err(Error::Config(
                "set_iteration_plan: block-dofs must be positive".into(),
            ));
        }
        let seg_elems = (block_dofs / plan.block).clamp(1, plan.gids.len().max(1));
        self.iter_plan = Some(IterationPlan { seg_elems });
        Ok(())
    }
}

/// One global weighted dot product `Σ a·b·c`, through the workspace's
/// reduction plan when one is installed (element-blocked partials folded
/// in global-gid order — bitwise decomposition-independent) and as the
/// historical flat local fold + `allreduce_sum` otherwise.
fn reduce_dot(
    vectors: &mut dyn VectorOps,
    comm: &mut dyn Communicator,
    plan: &mut Option<ReducePlan>,
    a: &[f64],
    b: &[f64],
    c: &[f64],
) -> Result<f64> {
    match plan {
        None => {
            let local = vectors.glsc3(a, b, c)?;
            comm.allreduce_sum(local)
        }
        Some(plan) => {
            let blk = plan.block;
            for (i, slot) in plan.partials.iter_mut().enumerate() {
                let s = i * blk;
                *slot = vectors.glsc3(&a[s..s + blk], &b[s..s + blk], &c[s..s + blk])?;
            }
            comm.allreduce_ordered_sum(&plan.gids, &plan.partials)
        }
    }
}

/// Solve `A x = f` with CG (native vector algebra, no preconditioner).
///
/// * `ax` — the local operator;
/// * `exchange` — domain assembly applied to `w` after the local operator
///   ([`NoExchange`](crate::solver::NoExchange) = the paper's `--no-comm`
///   roofline mode; a [`GatherScatter`](crate::gs::GatherScatter) = serial
///   assembly; the rank runtime's halo exchange = distributed assembly);
/// * `comm` — the collective layer ([`NullComm`](crate::solver::NullComm)
///   for a single rank);
/// * `mask` — Dirichlet mask applied to `f` and to `w`;
/// * `c` — inner-product weights (inverse multiplicity);
/// * `x` — output, overwritten with the solution.
#[allow(clippy::too_many_arguments)]
pub fn cg_solve(
    ax: &mut dyn AxApply,
    exchange: &mut dyn DomainExchange,
    comm: &mut dyn Communicator,
    mask: Option<&[f64]>,
    c: &[f64],
    f: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    ws: &mut CgWorkspace,
) -> Result<CgReport> {
    cg_solve_with(ax, exchange, comm, &mut NativeVectors, mask, c, f, x, opts, ws, None)
}

/// [`cg_solve`] with an optional Jacobi preconditioner (the paper's
/// future-work extension, section VII): `z = M^{-1} r` replaces the
/// identity in the preconditioner slot. Kept source-compatible with its
/// pre-[`Precond`] signature; for Chebyshev (or to avoid the clone), pass
/// a [`Precond`] to [`cg_solve_precond`] / [`cg_solve_with`] directly.
#[allow(clippy::too_many_arguments)]
pub fn cg_solve_pc(
    ax: &mut dyn AxApply,
    exchange: &mut dyn DomainExchange,
    comm: &mut dyn Communicator,
    mask: Option<&[f64]>,
    c: &[f64],
    f: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    ws: &mut CgWorkspace,
    precond: Option<&crate::solver::Jacobi>,
) -> Result<CgReport> {
    let owned = precond.map(|j| crate::solver::Precond::Jacobi(j.clone()));
    cg_solve_with(
        ax,
        exchange,
        comm,
        &mut NativeVectors,
        mask,
        c,
        f,
        x,
        opts,
        ws,
        owned.as_ref(),
    )
}

/// [`cg_solve`] with any [`Precond`] (Jacobi or Chebyshev-accelerated
/// Jacobi) and native vector algebra.
#[allow(clippy::too_many_arguments)]
pub fn cg_solve_precond(
    ax: &mut dyn AxApply,
    exchange: &mut dyn DomainExchange,
    comm: &mut dyn Communicator,
    mask: Option<&[f64]>,
    c: &[f64],
    f: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    ws: &mut CgWorkspace,
    precond: Option<&crate::solver::Precond>,
) -> Result<CgReport> {
    cg_solve_with(ax, exchange, comm, &mut NativeVectors, mask, c, f, x, opts, ws, precond)
}

/// The one CG loop, fully general: local operator, domain exchange,
/// communicator, vector-algebra backend, and optional preconditioner are
/// all hooks. Everything else in the crate — [`cg_solve`],
/// [`cg_solve_pc`], [`cg_solve_op`], the application pipeline's XLA
/// vector path, and the rank runtime — is a thin wrapper around this.
#[allow(clippy::too_many_arguments)]
pub fn cg_solve_with(
    ax: &mut dyn AxApply,
    exchange: &mut dyn DomainExchange,
    comm: &mut dyn Communicator,
    vectors: &mut dyn VectorOps,
    mask: Option<&[f64]>,
    c: &[f64],
    f: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    ws: &mut CgWorkspace,
    precond: Option<&crate::solver::Precond>,
) -> Result<CgReport> {
    let ndof = f.len();
    if x.len() != ndof || c.len() != ndof {
        return Err(Error::Config("cg_solve: length mismatch".into()));
    }
    if ws.ndof() != ndof {
        return Err(Error::Config(format!(
            "cg_solve: workspace sized for {} dofs, problem has {ndof}",
            ws.ndof()
        )));
    }
    if opts.niter == 0 {
        return Err(Error::Config("cg_solve: niter must be > 0".into()));
    }
    // Error context for breakdowns: which rank observed it (empty when the
    // communicator is serial, so serial messages stay unchanged).
    let rank_note =
        if comm.size() > 1 { format!(" on rank {}", comm.rank()) } else { String::new() };

    // Fused hot path: the operator computes the local `Σ w·c·p` inside its
    // own pass; [`PapCorrection`] turns that into the assembled pap with an
    // O(surface) patch over the exchange's shared dofs instead of a second
    // full sweep. The correction is cached in the workspace and reused
    // while the exchange's support is unchanged, so repeated solves
    // against one workspace allocate nothing.
    let fused = ax.is_fused();
    // An assembly-fused operator already folds exchange + mask into its own
    // sweep (see `AxApply::applies_assembly`): its fused pap is the
    // assembled local reduction, so no shared-dof correction is built and
    // the per-iteration exchange + mask below are skipped entirely.
    let assembled = ax.applies_assembly();
    if fused
        && !assembled
        && !ws.pap.as_ref().is_some_and(|prev| prev.covers(exchange.shared_dofs()))
    {
        ws.pap = Some(exchange.pap_correction());
    }
    // Cache-blocked iteration pipeline (ROADMAP item 4): with both a
    // reduce plan and an iteration plan installed, per-iteration vector
    // work runs block-by-block over the reduce plan's element blocks
    // while each segment's `x/r/w/p/z/c` data is cache-resident. The
    // dot-product partials still fold in ascending-gid order — the
    // ReducePlan contract — so the blocked trajectory is **bitwise** the
    // unblocked one; only `vector_sweeps` drops.
    let block = match (ws.reduce.as_ref(), ws.iter_plan) {
        (Some(rp), Some(ip)) => Some((rp.block, ip.seg_elems)),
        _ => None,
    };
    let (r, z, p, w) = (&mut ws.r, &mut ws.z, &mut ws.p, &mut ws.w);
    let cheb_scratch = &mut ws.cheb;
    let reduce = &mut ws.reduce;
    let mut correction = if fused && !assembled { ws.pap.as_mut() } else { None };

    rzero(x);
    copy(r, f);
    if let Some(m) = mask {
        mask_apply(r, m);
    }
    rzero(p);

    let mut rtz1 = 1.0f64;
    let mut rtz_first: Option<f64> = None;
    let mut rnorms = Vec::new();
    let mut iterations = 0;
    let mut glsc3_sweeps = 0usize;
    let mut vector_sweeps = 0usize;

    // Identity and Jacobi preconditioners are elementwise, so the blocked
    // pipeline fuses each iteration's tail (x/r updates) with the *next*
    // iteration's head (z production + rtz partials) in one walk — the
    // head walk below primes iteration 0. Chebyshev applies the full
    // operator to produce z and must stay a separate pass.
    let jac_inv: Option<&[f64]> = match precond {
        Some(crate::solver::Precond::Jacobi(m)) => Some(m.inv_diag()),
        _ => None,
    };
    let head_tail_fused =
        block.is_some() && !matches!(precond, Some(crate::solver::Precond::Chebyshev(_)));
    if head_tail_fused {
        let (elem, seg) = block.unwrap();
        let plan = reduce.as_mut().expect("blocked mode requires a reduce plan");
        BlockedVectors::new(&mut *vectors, elem, seg)
            .head_walk(r, z, c, jac_inv, &mut plan.partials)?;
        vector_sweeps += 1;
    }

    for iter in 0..opts.niter {
        // Preconditioner slot (identity by default — the paper runs
        // unpreconditioned; Jacobi or Chebyshev-accelerated Jacobi when
        // requested). The Chebyshev recurrence applies the same masked,
        // exchanged operator as the main loop, `order − 1` times. In
        // head-tail-fused blocked mode, z and the (r, c, z) partials were
        // already produced by the head walk (iteration 0) or the previous
        // iteration's tail walk — only the global fold is left.
        if !head_tail_fused {
            match precond {
                None => copy(z, r),
                Some(crate::solver::Precond::Jacobi(m)) => m.apply(r, z),
                Some(crate::solver::Precond::Chebyshev(ch)) => {
                    let scratch = cheb_scratch
                        .get_or_insert_with(|| crate::solver::ChebScratch::new(ndof));
                    ch.apply_with(ax, exchange, mask, r, z, scratch)?;
                }
            }
            vector_sweeps += 1;
        }
        let rtz2 = rtz1;
        glsc3_sweeps += 1;
        rtz1 = if head_tail_fused {
            let plan = reduce.as_ref().expect("blocked mode requires a reduce plan");
            comm.allreduce_ordered_sum(&plan.gids, &plan.partials)?
        } else {
            vector_sweeps += 1;
            reduce_dot(vectors, comm, reduce, r, c, z)?
        };
        if !rtz1.is_finite() {
            return Err(Error::Numerical(format!(
                "CG breakdown at iter {iter}{rank_note}: rtz1 = {rtz1}"
            )));
        }
        let first = *rtz_first.get_or_insert(rtz1.max(f64::MIN_POSITIVE));
        if rtz1 <= 1e-30 * first {
            // Exact convergence (possible on tiny systems well inside the
            // fixed iteration budget): stop instead of dividing by ~0.
            // rtz1 is an allreduced value — bit-identical on every rank —
            // so all ranks exit together.
            iterations = iter;
            let final_rnorm = rtz1.max(0.0).sqrt();
            return Ok(CgReport {
                iterations,
                final_rnorm,
                rnorms,
                rtz1,
                glsc3_sweeps,
                vector_sweeps,
            });
        }
        if opts.record_residuals || opts.rtol.is_some() {
            rnorms.push(rtz1.max(0.0).sqrt());
        }
        if let Some(tol) = opts.rtol {
            if rtz1.max(0.0).sqrt() <= tol {
                iterations = iter;
                let final_rnorm = rtz1.max(0.0).sqrt();
                return Ok(CgReport {
                    iterations,
                    final_rnorm,
                    rnorms,
                    rtz1,
                    glsc3_sweeps,
                    vector_sweeps,
                });
            }
        }
        let beta = if iter == 0 { 0.0 } else { rtz1 / rtz2 };
        if let Some((elem, seg)) = block {
            BlockedVectors::new(&mut *vectors, elem, seg).add2s1(p, z, beta)?;
        } else {
            vectors.add2s1(p, z, beta)?;
        }
        vector_sweeps += 1;

        ax.apply(p, w)?;
        let pap_fused = if fused {
            let local = ax.fused_pap().ok_or_else(|| {
                Error::Numerical("fused operator did not produce a pap value".into())
            })?;
            if let Some(corr) = correction.as_deref_mut() {
                corr.snapshot(w);
            }
            Some(local)
        } else {
            None
        };
        if !assembled {
            exchange.exchange(w)?;
            if let Some(m) = mask {
                mask_apply(w, m);
            }
        }

        // The fused path's operator-side pap is a single flat fold by
        // construction, so it stays on the plain allreduce (fused ranked
        // runs are tolerance-checked, not bitwise); the unfused path goes
        // through the reduction plan like every other dot product. An
        // assembly-fused operator's pap is already the assembled local
        // value — no correction to patch, just the cross-rank allreduce.
        let pap = match (pap_fused, correction.as_deref()) {
            (Some(local), Some(corr)) => {
                comm.allreduce_sum(corr.patch(local, w, c, p))?
            }
            (Some(local), None) => comm.allreduce_sum(local)?,
            _ => {
                glsc3_sweeps += 1;
                vector_sweeps += 1;
                reduce_dot(vectors, comm, reduce, w, c, p)?
            }
        };
        if pap <= 0.0 || !pap.is_finite() {
            return Err(Error::Numerical(format!(
                "CG breakdown at iter {iter}{rank_note}: pap = {pap} (operator not SPD?)"
            )));
        }
        let alpha = rtz1 / pap;
        match block {
            Some((elem, seg)) if head_tail_fused => {
                let plan = reduce.as_mut().expect("blocked mode requires a reduce plan");
                BlockedVectors::new(&mut *vectors, elem, seg)
                    .tail_walk(x, p, alpha, r, w, -alpha, z, c, jac_inv, &mut plan.partials)?;
                vector_sweeps += 1;
            }
            Some((elem, seg)) => {
                BlockedVectors::new(&mut *vectors, elem, seg)
                    .tail_update(x, p, alpha, r, w, -alpha)?;
                vector_sweeps += 1;
            }
            None => {
                vectors.add2s2(x, p, alpha)?;
                vectors.add2s2(r, w, -alpha)?;
                vector_sweeps += 2;
            }
        }
        iterations = iter + 1;
    }

    glsc3_sweeps += 1;
    let final_rnorm = if head_tail_fused && precond.is_none() {
        // The last tail walk's partials are per-element (r·c)·z with z a
        // bitwise copy of r (identity preconditioner), so they *are* the
        // (r·c)·r exit partials to the bit — fold them, no extra pass.
        let plan = reduce.as_ref().expect("blocked mode requires a reduce plan");
        comm.allreduce_ordered_sum(&plan.gids, &plan.partials)?.max(0.0).sqrt()
    } else {
        vector_sweeps += 1;
        reduce_dot(vectors, comm, reduce, r, c, r)?.max(0.0).sqrt()
    };
    Ok(CgReport { iterations, final_rnorm, rnorms, rtz1, glsc3_sweeps, vector_sweeps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::Cases;
    use crate::solver::{NoExchange, NullComm};

    /// Dense SPD matrix as an AxApply.
    struct Dense {
        n: usize,
        a: Vec<f64>,
    }

    impl AxApply for Dense {
        fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
            for i in 0..self.n {
                w[i] = (0..self.n).map(|j| self.a[i * self.n + j] * p[j]).sum();
            }
            Ok(())
        }
    }

    fn random_spd(c: &mut Cases, n: usize) -> Dense {
        // A = B B^T + n I
        let b = c.vec_normal(n * n);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        Dense { n, a }
    }

    #[test]
    fn solves_dense_spd() {
        crate::proputil::forall(0xC6, 10, |cases| {
            let n = cases.size(2, 20);
            let mut dense = random_spd(cases, n);
            let x_true = cases.vec_normal(n);
            let mut f = vec![0.0; n];
            dense.apply(&x_true, &mut f).unwrap();
            let c = vec![1.0; n];
            let mut x = vec![0.0; n];
            let mut ws = CgWorkspace::new(n);
            let opts = CgOptions { niter: 200, rtol: Some(1e-12), record_residuals: true };
            let rep = cg_solve(
                &mut dense,
                &mut NoExchange,
                &mut NullComm,
                None,
                &c,
                &f,
                &mut x,
                &opts,
                &mut ws,
            )
            .unwrap();
            crate::proputil::assert_allclose(&x, &x_true, 1e-6, 1e-6);
            assert!(rep.final_rnorm <= 1e-10 * (1.0 + rep.rnorms[0]));
        });
    }

    #[test]
    fn residual_monotone_in_enorm_proxy() {
        // For SPD systems the c-weighted residual norm should trend down;
        // we check the recorded history ends far below where it starts.
        let mut cases = Cases::new(0xC7);
        let n = 16;
        let mut dense = random_spd(&mut cases, n);
        let f = cases.vec_normal(n);
        let c = vec![1.0; n];
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let opts = CgOptions { niter: 60, rtol: None, record_residuals: true };
        let rep = cg_solve(
            &mut dense,
            &mut NoExchange,
            &mut NullComm,
            None,
            &c,
            &f,
            &mut x,
            &opts,
            &mut ws,
        )
        .unwrap();
        assert!(rep.rnorms.last().unwrap() < &(rep.rnorms[0] * 1e-6));
    }

    #[test]
    fn identity_solves_in_one_iteration() {
        let n = 8;
        let mut ident = Dense {
            n,
            a: (0..n * n).map(|i| if i % (n + 1) == 0 { 1.0 } else { 0.0 }).collect(),
        };
        let f: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let c = vec![1.0; n];
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let opts = CgOptions { niter: 5, rtol: Some(1e-14), record_residuals: false };
        cg_solve(
            &mut ident,
            &mut NoExchange,
            &mut NullComm,
            None,
            &c,
            &f,
            &mut x,
            &opts,
            &mut ws,
        )
        .unwrap();
        crate::proputil::assert_allclose(&x, &f, 1e-12, 1e-12);
    }

    #[test]
    fn mask_keeps_boundary_zero() {
        let mut cases = Cases::new(0xC8);
        let n = 10;
        let mut dense = random_spd(&mut cases, n);
        let f = cases.vec_normal(n);
        let mut mask = vec![1.0; n];
        mask[0] = 0.0;
        mask[7] = 0.0;
        let c = vec![1.0; n];
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let opts = CgOptions::default();
        cg_solve(
            &mut dense,
            &mut NoExchange,
            &mut NullComm,
            Some(&mask),
            &c,
            &f,
            &mut x,
            &opts,
            &mut ws,
        )
        .unwrap();
        assert_eq!(x[0], 0.0);
        assert_eq!(x[7], 0.0);
    }

    #[test]
    fn non_spd_reports_breakdown() {
        let n = 4;
        // Negative-definite: pap < 0 on the first iteration.
        let mut neg = Dense {
            n,
            a: (0..n * n).map(|i| if i % (n + 1) == 0 { -1.0 } else { 0.0 }).collect(),
        };
        let f = vec![1.0; n];
        let c = vec![1.0; n];
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let err = cg_solve(
            &mut neg,
            &mut NoExchange,
            &mut NullComm,
            None,
            &c,
            &f,
            &mut x,
            &CgOptions::default(),
            &mut ws,
        );
        assert!(matches!(err, Err(Error::Numerical(_))));
    }

    #[test]
    fn cg_solve_op_routes_registry_operator() {
        // A registry-built operator drops straight into the solver: same
        // trajectory as the closure route over the same kernel.
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 1, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(3).normal_vec(ndof);
        {
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            gs.dssum(&mut f);
        }
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 40, rtol: None, record_residuals: false };

        let mut op = OperatorRegistry::with_builtins()
            .build(
                "cpu-layered",
                &OperatorCtx {
                    n,
                    nelt: mesh.nelt(),
                    chunk: mesh.nelt(),
                    threads: 0,
                    artifacts_dir: "artifacts",
                    d: &basis.d,
                    g: &geom.g,
                    c: &cw,
                    assemble: None,
                },
            )
            .unwrap();
        let mut gs = crate::gs::GatherScatter::new(&mesh);
        let mut x_op = vec![0.0; ndof];
        let mut ws = CgWorkspace::new(ndof);
        let rep_op = cg_solve_op(
            op.as_mut(),
            &mut gs,
            &mut NullComm,
            Some(&mask),
            &cw,
            &f,
            &mut x_op,
            &opts,
            &mut ws,
        )
        .unwrap();

        let mut ax = |p: &[f64], w: &mut [f64]| -> Result<()> {
            crate::operators::ax_layered(n, mesh.nelt(), p, &basis.d, &geom.g, w);
            Ok(())
        };
        let mut gs2 = crate::gs::GatherScatter::new(&mesh);
        let mut x_cl = vec![0.0; ndof];
        let mut ws2 = CgWorkspace::new(ndof);
        let rep_cl = cg_solve(
            &mut ax,
            &mut gs2,
            &mut NullComm,
            Some(&mask),
            &cw,
            &f,
            &mut x_cl,
            &opts,
            &mut ws2,
        )
        .unwrap();
        assert_eq!(rep_op.iterations, rep_cl.iterations);
        crate::proputil::assert_allclose(&x_op, &x_cl, 1e-12, 1e-12);
    }

    #[test]
    fn fused_operator_matches_unfused_trajectory_and_saves_sweeps() {
        // The fused path (operator-side pap + shared-dof correction) must
        // reproduce the unfused trajectory through full exchange + mask,
        // while performing exactly `niter` fewer full glsc3 sweeps.
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 2, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(17).normal_vec(ndof);
        {
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            gs.dssum(&mut f);
        }
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 25, rtol: None, record_residuals: false };
        let registry = OperatorRegistry::with_builtins();
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 2,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c: &cw,
            assemble: None,
        };

        let mut solve = |name: &str| {
            let mut op = registry.build(name, &ctx).unwrap();
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            let mut x = vec![0.0; ndof];
            let mut ws = CgWorkspace::new(ndof);
            let rep = cg_solve_op(
                op.as_mut(),
                &mut gs,
                &mut NullComm,
                Some(&mask),
                &cw,
                &f,
                &mut x,
                &opts,
                &mut ws,
            )
            .unwrap();
            (rep, x)
        };

        let (rep_u, x_u) = solve("cpu-layered");
        // The f32-storage family solves the once-rounded system, so its
        // fused members are held to the matching *f32* unfused trajectory
        // (same tight tolerance — fusion itself must not add error).
        let (rep_u32, x_u32) = solve("cpu-layered-f32");
        // Every artifact-free fused operator, enumerated from the registry
        // so a new registration is held to the sweep-saving contract too.
        let fused_names: Vec<String> = registry
            .names()
            .into_iter()
            .filter(|name| {
                let spec = registry.resolve(name).unwrap();
                !spec.needs_artifacts && spec.create().is_fused()
            })
            .collect();
        assert!(fused_names.len() >= 10, "registry lost fused CPU operators: {fused_names:?}");
        for fused_name in &fused_names {
            let (rep_b, x_b) = if fused_name.ends_with("-f32") {
                (&rep_u32, &x_u32)
            } else {
                (&rep_u, &x_u)
            };
            let (rep_f, x_f) = solve(fused_name);
            assert_eq!(rep_f.iterations, rep_b.iterations, "{fused_name}");
            assert_eq!(
                rep_b.glsc3_sweeps - rep_f.glsc3_sweeps,
                opts.niter,
                "{fused_name}: fused path must save exactly one sweep per iteration \
                 (unfused {} vs fused {})",
                rep_b.glsc3_sweeps,
                rep_f.glsc3_sweeps
            );
            crate::proputil::assert_allclose(&x_f, x_b, 1e-9, 1e-11);
            let denom = rep_b.final_rnorm.abs().max(1e-30);
            assert!(
                (rep_f.final_rnorm - rep_b.final_rnorm).abs() / denom < 1e-9,
                "{fused_name}: {} vs {}",
                rep_f.final_rnorm,
                rep_b.final_rnorm
            );
        }
    }

    #[test]
    fn assembled_operator_trajectory_is_bitwise_layered() {
        // The assembly-fused contract (ISSUE 9 acceptance): `cpu-asm` with
        // its fold plan must reproduce `cpu-layered` + dssum + mask
        // **bitwise** — same iteration count, every recorded rnorm equal
        // to the bit, same final residual, same solution vector — while
        // the solver performs zero standalone exchange/mask passes.
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 5;
        let mesh = crate::mesh::Mesh::new(2, 2, 2, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(41).normal_vec(ndof);
        {
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            gs.dssum(&mut f);
        }
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 30, rtol: None, record_residuals: true };
        let registry = OperatorRegistry::with_builtins();
        let gs_plan = crate::gs::GatherScatter::new(&mesh);
        let plan = gs_plan.assembly_plan(n * n * n, Some(&mask)).unwrap();
        // One ctx for both builds: non-assembling operators ignore the
        // plan, `cpu-asm` captures it and claims assembly.
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 0,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c: &cw,
            assemble: Some(&plan),
        };
        let mut solve = |name: &str| {
            let mut op = registry.build(name, &ctx).unwrap();
            if name == "cpu-asm" {
                assert!(op.applies_assembly(), "cpu-asm with a plan must claim assembly");
            }
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            let mut x = vec![0.0; ndof];
            let mut ws = CgWorkspace::new(ndof);
            let rep = cg_solve_op(
                op.as_mut(),
                &mut gs,
                &mut NullComm,
                Some(&mask),
                &cw,
                &f,
                &mut x,
                &opts,
                &mut ws,
            )
            .unwrap();
            (rep, x)
        };
        let (rep_l, x_l) = solve("cpu-layered");
        let (rep_a, x_a) = solve("cpu-asm");
        assert_eq!(rep_a.iterations, rep_l.iterations);
        assert_eq!(rep_a.glsc3_sweeps, rep_l.glsc3_sweeps);
        assert_eq!(rep_a.rnorms.len(), rep_l.rnorms.len());
        for (i, (a, l)) in rep_a.rnorms.iter().zip(&rep_l.rnorms).enumerate() {
            assert_eq!(a.to_bits(), l.to_bits(), "rnorm[{i}]: {a} vs {l}");
        }
        assert_eq!(rep_a.final_rnorm.to_bits(), rep_l.final_rnorm.to_bits());
        assert_eq!(rep_a.rtz1.to_bits(), rep_l.rtz1.to_bits());
        for (i, (a, l)) in x_a.iter().zip(&x_l).enumerate() {
            assert_eq!(a.to_bits(), l.to_bits(), "x[{i}]: {a} vs {l}");
        }
    }

    #[test]
    fn fused_without_exchange_uses_pap_directly() {
        // no-comm mode (the paper's roofline methodology): NoExchange, so
        // the fused value needs no correction at all, and the trajectory
        // still matches the unfused one.
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 2, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(29).normal_vec(ndof);
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 10, rtol: None, record_residuals: false };
        let registry = OperatorRegistry::with_builtins();
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 0,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c: &cw,
            assemble: None,
        };
        let mut solve = |name: &str| {
            let mut op = registry.build(name, &ctx).unwrap();
            let mut x = vec![0.0; ndof];
            let mut ws = CgWorkspace::new(ndof);
            let rep = cg_solve_op(
                op.as_mut(),
                &mut NoExchange,
                &mut NullComm,
                Some(&mask),
                &cw,
                &f,
                &mut x,
                &opts,
                &mut ws,
            )
            .unwrap();
            (rep, x)
        };
        let (rep_u, x_u) = solve("cpu-layered");
        let (rep_f, x_f) = solve("cpu-layered-fused");
        assert_eq!(rep_f.iterations, rep_u.iterations);
        assert_eq!(rep_u.glsc3_sweeps - rep_f.glsc3_sweeps, opts.niter);
        crate::proputil::assert_allclose(&x_f, &x_u, 1e-9, 1e-11);
    }

    #[test]
    fn workspace_reuses_fused_correction_across_solves() {
        // The session no-allocation contract at the solver level: repeated
        // fused solves against one workspace must reuse the cached
        // PapCorrection (stable support buffer), not rebuild it per solve.
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 2, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(23).normal_vec(ndof);
        {
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            gs.dssum(&mut f);
        }
        crate::solver::mask_apply(&mut f, &mask);
        let registry = OperatorRegistry::with_builtins();
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 0,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c: &cw,
            assemble: None,
        };
        let mut op = registry.build("cpu-layered-fused", &ctx).unwrap();
        let mut gs = crate::gs::GatherScatter::new(&mesh);
        let mut x = vec![0.0; ndof];
        let mut ws = CgWorkspace::new(ndof);
        let opts = CgOptions { niter: 5, rtol: None, record_residuals: false };
        let mut solve = |ws: &mut CgWorkspace, gs: &mut crate::gs::GatherScatter| {
            cg_solve_op(
                op.as_mut(),
                gs,
                &mut NullComm,
                Some(&mask),
                &cw,
                &f,
                &mut x,
                &opts,
                ws,
            )
            .unwrap();
        };
        solve(&mut ws, &mut gs);
        let first = ws.pap.as_ref().expect("fused solve populates the cache");
        assert!(first.covers(gs.shared_dofs()));
        let ptr = first.support().as_ptr();
        solve(&mut ws, &mut gs);
        solve(&mut ws, &mut gs);
        let after = ws.pap.as_ref().unwrap();
        assert_eq!(
            after.support().as_ptr(),
            ptr,
            "repeated fused solves must reuse the cached correction buffer"
        );
    }

    #[test]
    fn zero_iterations_rejected() {
        let mut ident = Dense { n: 1, a: vec![1.0] };
        let mut ws = CgWorkspace::new(1);
        let opts = CgOptions { niter: 0, ..Default::default() };
        let err = cg_solve(
            &mut ident,
            &mut NoExchange,
            &mut NullComm,
            None,
            &[1.0],
            &[1.0],
            &mut [0.0],
            &opts,
            &mut ws,
        );
        assert!(matches!(err, Err(Error::Config(_))));
    }

    #[test]
    fn reduce_plan_validates_and_preserves_the_solve() {
        // A blocked plan changes only the *fold order* of the global
        // reductions: the trajectory stays within roundoff of the flat
        // fold, and malformed plans are structured Config errors.
        let mut cases = Cases::new(0xC9);
        let n = 16;
        let mut dense = random_spd(&mut cases, n);
        let f = cases.vec_normal(n);
        let c = vec![1.0; n];
        let opts = CgOptions { niter: 40, rtol: None, record_residuals: false };
        let mut solve = |ws: &mut CgWorkspace| {
            let mut x = vec![0.0; n];
            let rep = cg_solve(
                &mut dense,
                &mut NoExchange,
                &mut NullComm,
                None,
                &c,
                &f,
                &mut x,
                &opts,
                ws,
            )
            .unwrap();
            (rep, x)
        };
        let mut flat_ws = CgWorkspace::new(n);
        let (rep_flat, x_flat) = solve(&mut flat_ws);
        let mut ws = CgWorkspace::new(n);
        ws.set_reduce_plan(4, vec![0, 1, 2, 3]).unwrap();
        let (rep_blk, x_blk) = solve(&mut ws);
        assert_eq!(rep_blk.iterations, rep_flat.iterations);
        assert_eq!(rep_blk.glsc3_sweeps, rep_flat.glsc3_sweeps);
        crate::proputil::assert_allclose(&x_blk, &x_flat, 1e-9, 1e-12);

        let mut bad = CgWorkspace::new(n);
        assert!(bad.set_reduce_plan(3, vec![0, 1, 2, 3]).is_err(), "12 dofs != 16");
        assert!(bad.set_reduce_plan(4, vec![0, 2, 1, 3]).is_err(), "gids must ascend");
        assert!(bad.set_reduce_plan(0, vec![]).is_err(), "zero block");
    }

    #[test]
    fn blocked_pipeline_is_bitwise_identical_and_saves_three_sweeps_per_iter() {
        // The ISSUE 10 tentpole contract: with an iteration plan installed
        // the whole solve — every recorded rnorm, the iteration count, the
        // solution vector — is **bitwise** the unblocked trajectory, while
        // `vector_sweeps` drops by exactly 3·niter (head/tail fusion folds
        // z production + rtz read + the two add2s2 passes into one walk
        // and reuses the last tail's partials for the exit residual).
        use crate::operators::{OperatorCtx, OperatorRegistry};
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 2, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(53).normal_vec(ndof);
        {
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            gs.dssum(&mut f);
        }
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 12, rtol: None, record_residuals: true };
        let registry = OperatorRegistry::with_builtins();
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: 0,
            artifacts_dir: "artifacts",
            d: &basis.d,
            g: &geom.g,
            c: &cw,
            assemble: None,
        };
        let mut solve = |name: &str, block_dofs: Option<usize>| {
            let mut op = registry.build(name, &ctx).unwrap();
            let mut gs = crate::gs::GatherScatter::new(&mesh);
            let mut x = vec![0.0; ndof];
            let mut ws = CgWorkspace::new(ndof);
            ws.set_reduce_plan(n * n * n, (0..mesh.nelt() as u64).collect()).unwrap();
            if let Some(bd) = block_dofs {
                ws.set_iteration_plan(bd).unwrap();
            }
            let rep = cg_solve_op(
                op.as_mut(),
                &mut gs,
                &mut NullComm,
                Some(&mask),
                &cw,
                &f,
                &mut x,
                &opts,
                &mut ws,
            )
            .unwrap();
            (rep, x)
        };
        // Unfused and fused operators; one-element, two-element, and
        // larger-than-local segment sizes.
        for name in ["cpu-layered", "cpu-layered-fused"] {
            let (rep_u, x_u) = solve(name, None);
            for bd in [n * n * n, 2 * n * n * n, 1 << 20] {
                let (rep_b, x_b) = solve(name, Some(bd));
                assert_eq!(rep_b.iterations, rep_u.iterations, "{name} @ {bd}");
                assert_eq!(rep_b.glsc3_sweeps, rep_u.glsc3_sweeps, "{name} @ {bd}");
                assert_eq!(rep_b.rtz1.to_bits(), rep_u.rtz1.to_bits(), "{name} @ {bd}");
                assert_eq!(
                    rep_b.final_rnorm.to_bits(),
                    rep_u.final_rnorm.to_bits(),
                    "{name} @ {bd}"
                );
                assert_eq!(rep_b.rnorms.len(), rep_u.rnorms.len());
                for (i, (b, u)) in rep_b.rnorms.iter().zip(&rep_u.rnorms).enumerate() {
                    assert_eq!(b.to_bits(), u.to_bits(), "{name} @ {bd}: rnorm[{i}]");
                }
                for (i, (b, u)) in x_b.iter().zip(&x_u).enumerate() {
                    assert_eq!(b.to_bits(), u.to_bits(), "{name} @ {bd}: x[{i}]");
                }
                assert_eq!(
                    rep_u.vector_sweeps - rep_b.vector_sweeps,
                    3 * opts.niter,
                    "{name} @ {bd}: blocked path must save exactly three passes per \
                     iteration (unblocked {} vs blocked {})",
                    rep_u.vector_sweeps,
                    rep_b.vector_sweeps
                );
            }
        }
    }

    #[test]
    fn blocked_pipeline_matches_preconditioned_paths_bitwise() {
        // Jacobi rides the head/tail-fused walk (its multiply is
        // elementwise) but must recompute the exit residual pass (z ≠ r);
        // Chebyshev applies the full operator for z, so only the x/r
        // updates block. Both stay bitwise identical to unblocked.
        let n = 4;
        let mesh = crate::mesh::Mesh::new(2, 2, 1, n).unwrap();
        let basis = crate::basis::Basis::new(n);
        let geom = crate::geometry::GeomFactors::affine(&mesh, &basis);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let mut f = crate::rng::Rng::new(59).normal_vec(ndof);
        let mut gs0 = crate::gs::GatherScatter::new(&mesh);
        gs0.dssum(&mut f);
        crate::solver::mask_apply(&mut f, &mask);
        let opts = CgOptions { niter: 10, rtol: None, record_residuals: true };
        let jac = crate::solver::Jacobi::assemble(
            n,
            mesh.nelt(),
            &basis.d,
            &geom.g,
            &mut gs0,
            Some(&mask),
        )
        .unwrap();
        let cheb = crate::solver::Chebyshev::assemble(
            n,
            mesh.nelt(),
            &basis.d,
            &geom.g,
            &mut gs0,
            Some(&mask),
            2,
        )
        .unwrap();
        let preconds = [
            crate::solver::Precond::Jacobi(jac),
            crate::solver::Precond::Chebyshev(cheb),
        ];
        for pc in &preconds {
            let mut solve = |block_dofs: Option<usize>| {
                let mut ax = |p: &[f64], w: &mut [f64]| -> Result<()> {
                    crate::operators::ax_layered(n, mesh.nelt(), p, &basis.d, &geom.g, w);
                    Ok(())
                };
                let mut gs = crate::gs::GatherScatter::new(&mesh);
                let mut x = vec![0.0; ndof];
                let mut ws = CgWorkspace::new(ndof);
                ws.set_reduce_plan(n * n * n, (0..mesh.nelt() as u64).collect()).unwrap();
                if let Some(bd) = block_dofs {
                    ws.set_iteration_plan(bd).unwrap();
                }
                let rep = cg_solve_precond(
                    &mut ax,
                    &mut gs,
                    &mut NullComm,
                    Some(&mask),
                    &cw,
                    &f,
                    &mut x,
                    &opts,
                    &mut ws,
                    Some(pc),
                )
                .unwrap();
                (rep, x)
            };
            let (rep_u, x_u) = solve(None);
            let (rep_b, x_b) = solve(Some(2 * n * n * n));
            assert_eq!(rep_b.iterations, rep_u.iterations);
            assert_eq!(rep_b.glsc3_sweeps, rep_u.glsc3_sweeps);
            assert_eq!(rep_b.rtz1.to_bits(), rep_u.rtz1.to_bits());
            assert_eq!(rep_b.final_rnorm.to_bits(), rep_u.final_rnorm.to_bits());
            for (b, u) in rep_b.rnorms.iter().zip(&rep_u.rnorms) {
                assert_eq!(b.to_bits(), u.to_bits());
            }
            for (b, u) in x_b.iter().zip(&x_u) {
                assert_eq!(b.to_bits(), u.to_bits());
            }
            let saved = rep_u.vector_sweeps - rep_b.vector_sweeps;
            match pc {
                crate::solver::Precond::Jacobi(_) => {
                    assert_eq!(saved, 3 * opts.niter - 1, "jacobi pays the exit pass back")
                }
                crate::solver::Precond::Chebyshev(_) => {
                    assert_eq!(saved, opts.niter, "cheb blocks only the tail updates")
                }
            }
        }
    }

    #[test]
    fn iteration_plan_validates_and_resets_with_the_reduce_plan() {
        let mut ws = CgWorkspace::new(16);
        assert!(
            matches!(ws.set_iteration_plan(8), Err(Error::Config(_))),
            "iteration plan requires a reduce plan"
        );
        ws.set_reduce_plan(4, vec![0, 1, 2, 3]).unwrap();
        assert!(
            matches!(ws.set_iteration_plan(0), Err(Error::Config(_))),
            "zero block-dofs rejected"
        );
        ws.set_iteration_plan(usize::MAX).unwrap();
        assert_eq!(
            ws.iter_plan.unwrap().seg_elems,
            4,
            "over-large block-dofs clamps to the whole local domain"
        );
        ws.set_iteration_plan(1).unwrap();
        assert_eq!(ws.iter_plan.unwrap().seg_elems, 1, "tiny block-dofs clamps to one element");
        ws.set_reduce_plan(4, vec![0, 1, 2, 3]).unwrap();
        assert!(
            ws.iter_plan.is_none(),
            "reinstalling the reduce plan must reset the iteration plan"
        );
    }

    #[test]
    fn mis_sized_workspace_rejected() {
        // The session/benchmark reuse contract: a workspace sized for a
        // different problem is a Config error, not a panic mid-solve.
        let mut ident = Dense { n: 2, a: vec![1.0, 0.0, 0.0, 1.0] };
        let mut ws = CgWorkspace::new(3);
        assert_eq!(ws.ndof(), 3);
        let err = cg_solve(
            &mut ident,
            &mut NoExchange,
            &mut NullComm,
            None,
            &[1.0, 1.0],
            &[1.0, 1.0],
            &mut [0.0, 0.0],
            &CgOptions::default(),
            &mut ws,
        );
        assert!(matches!(err, Err(Error::Config(_))));
    }
}
