//! The domain-exchange abstraction: direct-stiffness assembly behind one
//! object-safe trait, so the CG driver does not know whether "assemble"
//! means a serial gather–scatter, a rank-local gather–scatter plus a halo
//! exchange, or nothing at all (`--no-comm`).
//!
//! ## Contract
//!
//! * [`DomainExchange::exchange`] performs `v <- Q Q^T v` in place over the
//!   caller's local dofs: every local copy of a (possibly globally) shared
//!   point ends up holding the sum over **all** copies, including copies
//!   owned by other ranks. Nekbone calls this `dssum`.
//! * [`DomainExchange::shared_dofs`] lists exactly the local dof indices
//!   `exchange` may change (dofs with multiplicity > 1, plus any halo dofs
//!   shared with neighboring ranks). `exchange` must be the identity on
//!   every index not listed — the fused Ax+pap solver path depends on this
//!   to patch the operator-side reduction with an O(surface) correction
//!   ([`PapCorrection`]) instead of a second full-vector sweep.
//! * Distributed implementations may communicate inside `exchange`; like
//!   the [`Communicator`](crate::solver::Communicator) collectives, calls
//!   must then be order-matched across ranks (the CG driver guarantees
//!   this: one exchange per iteration, on every rank).
//!
//! Implementations: [`GatherScatter`](crate::gs::GatherScatter) (serial),
//! the rank runtime's halo exchange (`crate::rank`), and [`NoExchange`]
//! (the paper's roofline mode, where communication is switched off).

use crate::error::Result;

/// Direct-stiffness summation over one rank's local dofs (see the module
/// docs for the exact contract).
pub trait DomainExchange {
    /// Assemble `v` in place: every local copy of a shared global point
    /// receives the sum over all copies (`v <- Q Q^T v`).
    fn exchange(&mut self, v: &mut [f64]) -> Result<()>;

    /// The local dof indices [`DomainExchange::exchange`] may change; it
    /// must act as the identity everywhere else.
    fn shared_dofs(&self) -> &[u32];

    /// A [`PapCorrection`] sized for this exchange's support — what the
    /// fused Ax+pap solver path snapshots/patches around each `exchange`.
    fn pap_correction(&self) -> PapCorrection {
        PapCorrection::new(self.shared_dofs().to_vec())
    }
}

/// The `--no-comm` exchange: assembly switched off, exactly as the paper's
/// roofline methodology measures the kernels ("without the communication
/// activated"). `exchange` is a no-op and nothing is shared.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoExchange;

impl DomainExchange for NoExchange {
    fn exchange(&mut self, _v: &mut [f64]) -> Result<()> {
        Ok(())
    }

    fn shared_dofs(&self) -> &[u32] {
        &[]
    }
}

/// Turns a fused operator's **local** pap into the assembled
/// `glsc3(exchange(w), c, p)` without a full sweep: [`Self::snapshot`]
/// saves `w` on the dofs the exchange can change right after the operator
/// ran, and [`Self::patch`] adds `c·p·(w_post − w_pre)` over those dofs
/// after exchange/mask. Exact because the exchange only writes its
/// [`DomainExchange::shared_dofs`] and the mask only writes dofs where
/// `p = 0` (every CG iterate is masked). Owned by the one CG driver
/// ([`cg_solve`](crate::solver::cg_solve)), so serial and ranked solves
/// cannot drift apart.
pub struct PapCorrection {
    /// Local dof indices the exchange can change.
    shared: Vec<u32>,
    w_pre: Vec<f64>,
}

impl PapCorrection {
    pub fn new(shared: Vec<u32>) -> Self {
        let w_pre = vec![0.0f64; shared.len()];
        PapCorrection { shared, w_pre }
    }

    /// A correction over no dofs (nothing snapshotted, `patch` is the
    /// identity on `local`) — for unfused solves and `--no-comm` runs.
    pub fn empty() -> Self {
        PapCorrection::new(Vec::new())
    }

    /// Does this correction cover exactly these shared dofs? The solver's
    /// workspace caches its correction across solves and reuses it when
    /// the exchange still reports the same support — an O(surface)
    /// compare instead of a per-solve allocation.
    pub fn covers(&self, shared: &[u32]) -> bool {
        self.shared.as_slice() == shared
    }

    /// The shared dofs this correction patches over (its support).
    pub fn support(&self) -> &[u32] {
        &self.shared
    }

    /// Record `w` on the shared dofs (call between the operator and the
    /// exchange).
    pub fn snapshot(&mut self, w: &[f64]) {
        for (slot, &l) in self.w_pre.iter_mut().zip(&self.shared) {
            *slot = w[l as usize];
        }
    }

    /// The assembled pap: fused `local` plus the shared-dof correction
    /// (call after exchange + mask).
    pub fn patch(&self, mut local: f64, w: &[f64], c: &[f64], p: &[f64]) -> f64 {
        for (&pre, &l) in self.w_pre.iter().zip(&self.shared) {
            let l = l as usize;
            local += c[l] * p[l] * (w[l] - pre);
        }
        local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_exchange_is_identity() {
        let mut ex = NoExchange;
        let mut v = vec![1.0, 2.0, 3.0];
        let orig = v.clone();
        ex.exchange(&mut v).unwrap();
        assert_eq!(v, orig);
        assert!(ex.shared_dofs().is_empty());
    }

    #[test]
    fn empty_correction_patch_is_identity() {
        let c = PapCorrection::empty();
        assert_eq!(c.patch(3.5, &[1.0], &[1.0], &[1.0]), 3.5);
    }

    #[test]
    fn correction_accounts_for_exchanged_dofs() {
        // local pap over w_pre, then dofs 1 and 3 change; patch must add
        // c*p*(w_post - w_pre) over exactly those dofs.
        let mut corr = PapCorrection::new(vec![1, 3]);
        let w_pre = [1.0, 2.0, 3.0, 4.0];
        let c = [0.5, 1.0, 2.0, 0.25];
        let p = [1.0, -1.0, 2.0, 4.0];
        let local: f64 = w_pre.iter().zip(&c).zip(&p).map(|((w, c), p)| w * c * p).sum();
        corr.snapshot(&w_pre);
        let w_post = [1.0, 5.0, 3.0, -2.0];
        let want: f64 = w_post.iter().zip(&c).zip(&p).map(|((w, c), p)| w * c * p).sum();
        let got = corr.patch(local, &w_post, &c, &p);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn gather_scatter_implements_exchange() {
        // The serial GatherScatter is the serial DomainExchange: exchange
        // is dssum, shared_dofs its multiplicity-over-1 support.
        let mesh = crate::mesh::Mesh::new(2, 1, 1, 3).unwrap();
        let mut gs = crate::gs::GatherScatter::new(&mesh);
        let mut a: Vec<f64> = (0..mesh.ndof_local()).map(|i| i as f64 * 0.5).collect();
        let mut b = a.clone();
        gs.dssum(&mut a);
        {
            let ex: &mut dyn DomainExchange = &mut gs;
            ex.exchange(&mut b).unwrap();
        }
        assert_eq!(a, b);
        assert_eq!(DomainExchange::shared_dofs(&gs), gs.shared_dofs());
    }
}
