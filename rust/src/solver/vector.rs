//! Nekbone's CG vector operations (the "simple vector operations" the paper
//! runs under OpenACC, section IV). Alloc-free, hot-path code; names follow
//! the Fortran originals so the cost model (paper Eq. 1) maps one-to-one.

/// `sum_i a_i b_i c_i` — Nekbone's weighted inner product `glsc3`
/// (3 flops per dof in the paper's accounting).
#[inline]
pub fn glsc3(a: &[f64], b: &[f64], c: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i] * c[i];
    }
    acc
}

/// `a <- c1 * a + b` — Nekbone's `add2s1` (2 flops per dof).
#[inline]
pub fn add2s1(a: &mut [f64], b: &[f64], c1: f64) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] = c1 * a[i] + b[i];
    }
}

/// `a <- a + c2 * b` — Nekbone's `add2s2` (2 flops per dof).
#[inline]
pub fn add2s2(a: &mut [f64], b: &[f64], c2: f64) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += c2 * b[i];
    }
}

/// `a <- a * mask` elementwise — Nekbone's boundary-condition `mask`.
#[inline]
pub fn mask_apply(a: &mut [f64], mask: &[f64]) {
    debug_assert_eq!(a.len(), mask.len());
    for i in 0..a.len() {
        a[i] *= mask[i];
    }
}

/// `a <- b` (Nekbone's `copy`).
#[inline]
pub fn copy(a: &mut [f64], b: &[f64]) {
    a.copy_from_slice(b);
}

/// `a <- 0` (Nekbone's `rzero`).
#[inline]
pub fn rzero(a: &mut [f64]) {
    a.fill(0.0);
}

/// Where the CG driver's full-vector algebra runs (experiment E6: the
/// paper measures OpenACC-offloaded "simple operations" against native
/// loops). The one generic solver
/// ([`cg_solve_with`](crate::solver::cg_solve_with)) takes this as a hook,
/// so the native path, the chunked-XLA path, and any future offload share
/// the same CG loop instead of each carrying a hand-synchronized copy.
///
/// Implementations must compute exactly the reference semantics of the
/// free functions ([`glsc3`], [`add2s1`], [`add2s2`]) — the solver's
/// breakdown checks and sweep accounting assume it.
pub trait VectorOps {
    /// `sum_i a_i b_i c_i` over the **local** dofs (the solver allreduces).
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> crate::error::Result<f64>;

    /// `a <- c1 * a + b`.
    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> crate::error::Result<()>;

    /// `a <- a + c2 * b`.
    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> crate::error::Result<()>;
}

/// Element-blocked walker over another [`VectorOps`] backend — the
/// cache-blocked CG iteration pipeline (ROADMAP item 4: keep a block's
/// `x/r/w/p/z/c` data cache-resident across the iteration's vector ops
/// instead of streaming each full-length vector separately).
///
/// A walk visits the local dofs in **segments of whole elements**
/// (`seg_elems` elements of `elem` dofs each) and performs every
/// per-point update for a segment before moving to the next. Because all
/// of the fused operations are elementwise (`add2s1`, `add2s2`, the
/// preconditioner multiply) and the dot-product partials are produced
/// **per element through the inner backend's `glsc3`** — the exact
/// granularity and fold the solver's `ReducePlan` prescribes — every
/// value a blocked walk produces is **bitwise identical** to the
/// unblocked sequence of whole-vector passes. Only the traversal order
/// changes, never the arithmetic.
///
/// The `VectorOps` impl chunks `add2s1`/`add2s2` by segment (elementwise,
/// so bitwise-equal to one flat pass) and forwards `glsc3` whole — a
/// flat reduction's fold order is part of its contract and must not be
/// re-blocked here (the solver blocks reductions through its
/// `ReducePlan`, which owns the fold order).
pub struct BlockedVectors<'a> {
    inner: &'a mut dyn VectorOps,
    /// Dofs per reduction partial (the element volume `n³`).
    elem: usize,
    /// Dofs per cache segment (`elem · seg_elems`).
    seg: usize,
}

impl<'a> BlockedVectors<'a> {
    /// Walk `seg_elems` elements of `elem` dofs at a time (both clamped
    /// to at least one).
    pub fn new(inner: &'a mut dyn VectorOps, elem: usize, seg_elems: usize) -> Self {
        let elem = elem.max(1);
        BlockedVectors { inner, elem, seg: elem * seg_elems.max(1) }
    }

    /// Segment bounds `[start, end)` covering `len` dofs.
    fn segments(&self, len: usize) -> impl Iterator<Item = (usize, usize)> {
        let seg = self.seg;
        (0..len).step_by(seg).map(move |s| (s, (s + seg).min(len)))
    }

    /// `z[s..e] = precond(r[s..e])`: the Jacobi diagonal multiply when
    /// `inv` is present (bitwise [`crate::solver::Jacobi::apply`] on the
    /// segment), a bitwise copy of `r` otherwise (identity precondition).
    fn produce_z(r: &[f64], z: &mut [f64], inv: Option<&[f64]>) {
        match inv {
            None => z.copy_from_slice(r),
            Some(d) => {
                for ((zi, ri), di) in z.iter_mut().zip(r).zip(d) {
                    *zi = ri * di;
                }
            }
        }
    }

    /// Per-element `(a, b, c)` partials for the elements inside
    /// `[s, e)`, through the inner backend's `glsc3` — the `ReducePlan`
    /// granularity, so the solver's ordered fold of these partials is
    /// bitwise the unblocked reduction.
    fn partials_in(
        &mut self,
        s: usize,
        e: usize,
        a: &[f64],
        b: &[f64],
        c: &[f64],
        partials: &mut [f64],
    ) -> crate::error::Result<()> {
        for el in (s / self.elem)..(e / self.elem) {
            let lo = el * self.elem;
            let hi = lo + self.elem;
            partials[el] = self.inner.glsc3(&a[lo..hi], &b[lo..hi], &c[lo..hi])?;
        }
        Ok(())
    }

    /// The iteration-head walk: `z = precond(r)` and the per-element
    /// `(r, c, z)` partials for the coming `rtz` fold, one cache segment
    /// at a time — `r` is read once per segment instead of once per pass.
    pub fn head_walk(
        &mut self,
        r: &[f64],
        z: &mut [f64],
        c: &[f64],
        inv: Option<&[f64]>,
        partials: &mut [f64],
    ) -> crate::error::Result<()> {
        for (s, e) in self.segments(r.len()) {
            Self::produce_z(&r[s..e], &mut z[s..e], inv.map(|d| &d[s..e]));
            self.partials_in(s, e, r, c, z, partials)?;
        }
        Ok(())
    }

    /// The iteration-tail walk, fused with the **next** iteration's head:
    /// per segment, `x += alpha·p`, `r += malpha·w` (the solver passes
    /// `-alpha`), `z = precond(r)`, and the per-element `(r, c, z)`
    /// partials — four whole-vector passes folded into one walk while the
    /// segment is cache-resident.
    #[allow(clippy::too_many_arguments)]
    pub fn tail_walk(
        &mut self,
        x: &mut [f64],
        p: &[f64],
        alpha: f64,
        r: &mut [f64],
        w: &[f64],
        malpha: f64,
        z: &mut [f64],
        c: &[f64],
        inv: Option<&[f64]>,
        partials: &mut [f64],
    ) -> crate::error::Result<()> {
        for (s, e) in self.segments(x.len()) {
            self.inner.add2s2(&mut x[s..e], &p[s..e], alpha)?;
            self.inner.add2s2(&mut r[s..e], &w[s..e], malpha)?;
            Self::produce_z(&r[s..e], &mut z[s..e], inv.map(|d| &d[s..e]));
            self.partials_in(s, e, r, c, z, partials)?;
        }
        Ok(())
    }

    /// The tail walk without the head fusion (`x` and `r` updates only) —
    /// used when the preconditioner applies the full operator to produce
    /// `z` (Chebyshev) and therefore cannot ride a blocked walk.
    pub fn tail_update(
        &mut self,
        x: &mut [f64],
        p: &[f64],
        alpha: f64,
        r: &mut [f64],
        w: &[f64],
        malpha: f64,
    ) -> crate::error::Result<()> {
        for (s, e) in self.segments(x.len()) {
            self.inner.add2s2(&mut x[s..e], &p[s..e], alpha)?;
            self.inner.add2s2(&mut r[s..e], &w[s..e], malpha)?;
        }
        Ok(())
    }
}

impl VectorOps for BlockedVectors<'_> {
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> crate::error::Result<f64> {
        // Forwarded whole: a flat reduction's fold order is part of its
        // contract (re-blocking it here would change the sum).
        self.inner.glsc3(a, b, c)
    }

    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> crate::error::Result<()> {
        for (s, e) in self.segments(a.len()) {
            self.inner.add2s1(&mut a[s..e], &b[s..e], c1)?;
        }
        Ok(())
    }

    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> crate::error::Result<()> {
        for (s, e) in self.segments(a.len()) {
            self.inner.add2s2(&mut a[s..e], &b[s..e], c2)?;
        }
        Ok(())
    }
}

/// The native-Rust vector backend (the default): straight calls into the
/// free functions above, infallible.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeVectors;

impl VectorOps for NativeVectors {
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> crate::error::Result<f64> {
        Ok(glsc3(a, b, c))
    }

    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> crate::error::Result<()> {
        add2s1(a, b, c1);
        Ok(())
    }

    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> crate::error::Result<()> {
        add2s2(a, b, c2);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{forall, Cases};

    #[test]
    fn glsc3_small() {
        assert_eq!(glsc3(&[1.0, 2.0], &[3.0, 4.0], &[1.0, 0.5]), 3.0 + 4.0);
    }

    #[test]
    fn glsc3_zero_weight_masks() {
        forall(0x91, 20, |c: &mut Cases| {
            let len = c.size(1, 200);
            let a = c.vec_normal(len);
            let b = c.vec_normal(len);
            assert_eq!(glsc3(&a, &b, &vec![0.0; len]), 0.0);
        });
    }

    #[test]
    fn add2s1_identity_scale() {
        let mut a = vec![1.0, 2.0];
        add2s1(&mut a, &[10.0, 20.0], 1.0);
        assert_eq!(a, vec![11.0, 22.0]);
    }

    #[test]
    fn add2s2_matches_axpy() {
        forall(0x92, 20, |c: &mut Cases| {
            let len = c.size(1, 100);
            let mut a = c.vec_normal(len);
            let b = c.vec_normal(len);
            let want: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + 2.5 * y).collect();
            add2s2(&mut a, &b, 2.5);
            crate::proputil::assert_allclose(&a, &want, 1e-15, 1e-15);
        });
    }

    #[test]
    fn mask_zeroes_selected() {
        let mut a = vec![1.0, 2.0, 3.0];
        mask_apply(&mut a, &[1.0, 0.0, 1.0]);
        assert_eq!(a, vec![1.0, 0.0, 3.0]);
    }

    #[test]
    fn copy_rzero() {
        let mut a = vec![1.0; 4];
        rzero(&mut a);
        assert_eq!(a, vec![0.0; 4]);
        copy(&mut a, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn blocked_axpys_are_bitwise_the_flat_pass() {
        forall(0x93, 20, |c: &mut Cases| {
            let elem = c.size(1, 8);
            let nelems = c.size(1, 12);
            let seg_elems = c.size(1, 5);
            let len = elem * nelems;
            let base = c.vec_normal(len);
            let b = c.vec_normal(len);

            let mut flat = base.clone();
            add2s1(&mut flat, &b, 0.75);
            add2s2(&mut flat, &b, -1.25);

            let mut inner = NativeVectors;
            let mut blocked = BlockedVectors::new(&mut inner, elem, seg_elems);
            let mut got = base.clone();
            blocked.add2s1(&mut got, &b, 0.75).unwrap();
            blocked.add2s2(&mut got, &b, -1.25).unwrap();
            assert_eq!(bits(&got), bits(&flat));
        });
    }

    #[test]
    fn head_walk_matches_unblocked_sequence_bitwise() {
        forall(0x94, 20, |c: &mut Cases| {
            let elem = c.size(2, 27);
            let nelems = c.size(1, 9);
            let seg_elems = c.size(1, 4);
            let len = elem * nelems;
            let r = c.vec_normal(len);
            let cw = c.vec_normal(len);
            let inv = c.vec_normal(len);

            // Unblocked reference: whole-vector z pass, then per-element
            // partials (the ReducePlan granularity).
            let z_want: Vec<f64> = r.iter().zip(&inv).map(|(ri, di)| ri * di).collect();
            let p_want: Vec<f64> = (0..nelems)
                .map(|el| {
                    let (lo, hi) = (el * elem, (el + 1) * elem);
                    glsc3(&r[lo..hi], &cw[lo..hi], &z_want[lo..hi])
                })
                .collect();

            let mut inner = NativeVectors;
            let mut blocked = BlockedVectors::new(&mut inner, elem, seg_elems);
            let mut z = vec![0.0; len];
            let mut partials = vec![0.0; nelems];
            blocked.head_walk(&r, &mut z, &cw, Some(&inv), &mut partials).unwrap();
            assert_eq!(bits(&z), bits(&z_want));
            assert_eq!(bits(&partials), bits(&p_want));

            // Identity preconditioner: z is a bitwise copy of r.
            blocked.head_walk(&r, &mut z, &cw, None, &mut partials).unwrap();
            assert_eq!(bits(&z), bits(&r));
        });
    }

    #[test]
    fn tail_walk_matches_unblocked_sequence_bitwise() {
        forall(0x95, 20, |c: &mut Cases| {
            let elem = c.size(2, 16);
            let nelems = c.size(1, 10);
            let seg_elems = c.size(1, 7);
            let len = elem * nelems;
            let x0 = c.vec_normal(len);
            let r0 = c.vec_normal(len);
            let p = c.vec_normal(len);
            let w = c.vec_normal(len);
            let cw = c.vec_normal(len);
            let alpha = 0.375;

            // Unblocked reference: x += alpha p; r -= alpha w; z = r;
            // per-element (r, c, z) partials.
            let mut x_want = x0.clone();
            let mut r_want = r0.clone();
            add2s2(&mut x_want, &p, alpha);
            add2s2(&mut r_want, &w, -alpha);
            let z_want = r_want.clone();
            let p_want: Vec<f64> = (0..nelems)
                .map(|el| {
                    let (lo, hi) = (el * elem, (el + 1) * elem);
                    glsc3(&r_want[lo..hi], &cw[lo..hi], &z_want[lo..hi])
                })
                .collect();

            let mut inner = NativeVectors;
            let mut blocked = BlockedVectors::new(&mut inner, elem, seg_elems);
            let (mut x, mut r) = (x0.clone(), r0.clone());
            let mut z = vec![0.0; len];
            let mut partials = vec![0.0; nelems];
            blocked
                .tail_walk(&mut x, &p, alpha, &mut r, &w, -alpha, &mut z, &cw, None, &mut partials)
                .unwrap();
            assert_eq!(bits(&x), bits(&x_want));
            assert_eq!(bits(&r), bits(&r_want));
            assert_eq!(bits(&z), bits(&z_want));
            assert_eq!(bits(&partials), bits(&p_want));

            // tail_update: the x/r updates alone, bitwise the same.
            let (mut x2, mut r2) = (x0.clone(), r0.clone());
            blocked.tail_update(&mut x2, &p, alpha, &mut r2, &w, -alpha).unwrap();
            assert_eq!(bits(&x2), bits(&x_want));
            assert_eq!(bits(&r2), bits(&r_want));
        });
    }
}
