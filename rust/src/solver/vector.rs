//! Nekbone's CG vector operations (the "simple vector operations" the paper
//! runs under OpenACC, section IV). Alloc-free, hot-path code; names follow
//! the Fortran originals so the cost model (paper Eq. 1) maps one-to-one.

/// `sum_i a_i b_i c_i` — Nekbone's weighted inner product `glsc3`
/// (3 flops per dof in the paper's accounting).
#[inline]
pub fn glsc3(a: &[f64], b: &[f64], c: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i] * c[i];
    }
    acc
}

/// `a <- c1 * a + b` — Nekbone's `add2s1` (2 flops per dof).
#[inline]
pub fn add2s1(a: &mut [f64], b: &[f64], c1: f64) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] = c1 * a[i] + b[i];
    }
}

/// `a <- a + c2 * b` — Nekbone's `add2s2` (2 flops per dof).
#[inline]
pub fn add2s2(a: &mut [f64], b: &[f64], c2: f64) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += c2 * b[i];
    }
}

/// `a <- a * mask` elementwise — Nekbone's boundary-condition `mask`.
#[inline]
pub fn mask_apply(a: &mut [f64], mask: &[f64]) {
    debug_assert_eq!(a.len(), mask.len());
    for i in 0..a.len() {
        a[i] *= mask[i];
    }
}

/// `a <- b` (Nekbone's `copy`).
#[inline]
pub fn copy(a: &mut [f64], b: &[f64]) {
    a.copy_from_slice(b);
}

/// `a <- 0` (Nekbone's `rzero`).
#[inline]
pub fn rzero(a: &mut [f64]) {
    a.fill(0.0);
}

/// Where the CG driver's full-vector algebra runs (experiment E6: the
/// paper measures OpenACC-offloaded "simple operations" against native
/// loops). The one generic solver
/// ([`cg_solve_with`](crate::solver::cg_solve_with)) takes this as a hook,
/// so the native path, the chunked-XLA path, and any future offload share
/// the same CG loop instead of each carrying a hand-synchronized copy.
///
/// Implementations must compute exactly the reference semantics of the
/// free functions ([`glsc3`], [`add2s1`], [`add2s2`]) — the solver's
/// breakdown checks and sweep accounting assume it.
pub trait VectorOps {
    /// `sum_i a_i b_i c_i` over the **local** dofs (the solver allreduces).
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> crate::error::Result<f64>;

    /// `a <- c1 * a + b`.
    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> crate::error::Result<()>;

    /// `a <- a + c2 * b`.
    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> crate::error::Result<()>;
}

/// The native-Rust vector backend (the default): straight calls into the
/// free functions above, infallible.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeVectors;

impl VectorOps for NativeVectors {
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> crate::error::Result<f64> {
        Ok(glsc3(a, b, c))
    }

    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> crate::error::Result<()> {
        add2s1(a, b, c1);
        Ok(())
    }

    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> crate::error::Result<()> {
        add2s2(a, b, c2);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{forall, Cases};

    #[test]
    fn glsc3_small() {
        assert_eq!(glsc3(&[1.0, 2.0], &[3.0, 4.0], &[1.0, 0.5]), 3.0 + 4.0);
    }

    #[test]
    fn glsc3_zero_weight_masks() {
        forall(0x91, 20, |c: &mut Cases| {
            let len = c.size(1, 200);
            let a = c.vec_normal(len);
            let b = c.vec_normal(len);
            assert_eq!(glsc3(&a, &b, &vec![0.0; len]), 0.0);
        });
    }

    #[test]
    fn add2s1_identity_scale() {
        let mut a = vec![1.0, 2.0];
        add2s1(&mut a, &[10.0, 20.0], 1.0);
        assert_eq!(a, vec![11.0, 22.0]);
    }

    #[test]
    fn add2s2_matches_axpy() {
        forall(0x92, 20, |c: &mut Cases| {
            let len = c.size(1, 100);
            let mut a = c.vec_normal(len);
            let b = c.vec_normal(len);
            let want: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + 2.5 * y).collect();
            add2s2(&mut a, &b, 2.5);
            crate::proputil::assert_allclose(&a, &want, 1e-15, 1e-15);
        });
    }

    #[test]
    fn mask_zeroes_selected() {
        let mut a = vec![1.0, 2.0, 3.0];
        mask_apply(&mut a, &[1.0, 0.0, 1.0]);
        assert_eq!(a, vec![1.0, 0.0, 3.0]);
    }

    #[test]
    fn copy_rzero() {
        let mut a = vec![1.0; 4];
        rzero(&mut a);
        assert_eq!(a, vec![0.0; 4]);
        copy(&mut a, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
