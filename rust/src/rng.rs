//! Deterministic pseudo-random numbers for workload generation and tests.
//!
//! The offline crate set has no `rand`, so we carry a small, well-known
//! generator: SplitMix64 for seeding and xoshiro256++ for the stream
//! (Blackman & Vigna). Deterministic across platforms — benchmark inputs and
//! property-test cases are reproducible from their seed.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free for our purposes (n << 2^64 so bias is negligible
        // for test/workload generation; we do not use this for statistics).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with standard-normal values.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// A vector of standard-normal values.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        let mut v = vec![0.0; len];
        self.fill_normal(&mut v);
        v
    }
}

/// Per-entry RHS seed for a deterministic stream of solves.
///
/// One place for the `stream·K + index` arithmetic that batch-session
/// tests, the serve load generator, and the differential-fuzz tier each
/// used to re-derive inline: `stream` names the independent source (a
/// load-gen client, a fuzz case, a batch), `index` the entry within it.
/// The stream id is spread by an odd constant so entries of one stream
/// can never alias a small index range of another — the failure mode of
/// the ad-hoc `client * 1000 + req` encoding once `req >= 1000`.
pub fn rhs_seed(stream: u64, index: u64) -> u64 {
    stream.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(index)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(4);
        let m: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            let v = r.range(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&v));
        }
    }

    #[test]
    fn rhs_seed_is_deterministic_and_collision_free_on_a_grid() {
        assert_eq!(rhs_seed(3, 7), rhs_seed(3, 7));
        // Entries ascend within a stream (index is the low-order term).
        assert_eq!(rhs_seed(5, 0) + 1, rhs_seed(5, 1));
        // No collisions across a realistic (stream × index) grid — the
        // guarantee the ad-hoc `client * 1000 + req` encoding lacked.
        let mut seen = std::collections::HashSet::new();
        for stream in 0..64u64 {
            for index in 0..4096u64 {
                assert!(
                    seen.insert(rhs_seed(stream, index)),
                    "collision at stream {stream}, index {index}"
                );
            }
        }
    }
}
