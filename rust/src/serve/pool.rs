//! The sharded session pool: bounded per-shard queues in front of worker
//! threads that own [`OwnedSession`] caches.
//!
//! Routing is by [`ShardKey`] hash, so every mesh/operator combination is
//! served by exactly one worker — sessions are never shared between
//! threads, never locked, and a key's solves are totally ordered (the
//! bitwise-reproducibility contract). Backpressure is structural: each
//! shard's queue is an `mpsc::sync_channel` of fixed capacity and
//! [`SessionPool::submit`] uses `try_send`, so a full shard answers
//! `overloaded` immediately instead of buffering without bound.
//!
//! Shutdown is drain-by-drop: [`SessionPool::begin_shutdown`] flips the
//! stop flag (new submits refused), and [`SessionPool::shutdown`] then
//! drops the queue senders — each worker's `recv` keeps yielding the jobs
//! already accepted until the channel disconnects, so nothing accepted is
//! ever lost — and joins the workers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::RunConfig;
use crate::coordinator::{Nekbone, OwnedSession};
use crate::error::Error;
use crate::json::Value;

use super::protocol::ShardKey;

/// Pool shape: how many shards, how deep each queue, how greedily a
/// worker drains.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker threads (and hash buckets).
    pub shards: usize,
    /// Bounded queue capacity per shard.
    pub queue: usize,
    /// Max jobs a worker drains per wakeup (micro-batch size).
    pub batch: usize,
}

/// One queued solve job; the reply channel closes the loop back to the
/// submitting connection handler.
struct Job {
    id: u64,
    key: ShardKey,
    rhs: Vec<f64>,
    reply: mpsc::Sender<SolveReply>,
}

/// What a worker sends back for one job.
pub struct SolveReply {
    pub id: u64,
    pub shard: usize,
    /// The canonical operator label, iterations, final rnorm, solution.
    pub outcome: Result<SolveOk, Error>,
}

/// The successful-solve payload.
pub struct SolveOk {
    pub operator: String,
    pub iterations: usize,
    pub rnorm: f64,
    pub x: Vec<f64>,
}

/// Outcome of a submit attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Submit {
    /// Queued on this shard; a [`SolveReply`] will arrive on the job's
    /// reply channel.
    Accepted { shard: usize },
    /// The shard's bounded queue is full — explicit backpressure.
    Overloaded { shard: usize },
    /// The pool is draining; no new work is accepted.
    ShuttingDown,
}

/// Live per-shard counters (atomics — updated by submitters and the
/// shard worker, read by `info` snapshots at any time).
#[derive(Default)]
struct ShardStats {
    requests: AtomicU64,
    batches: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    overloaded: AtomicU64,
    depth: AtomicUsize,
    max_depth: AtomicUsize,
}

impl ShardStats {
    fn enqueued(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_depth.fetch_max(d, Ordering::Relaxed);
    }

    fn dequeued(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A point-in-time copy of one shard's statistics (the `info` response
/// and `BENCH_serve.json` shard rows).
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Requests accepted onto this shard's queue.
    pub requests: u64,
    /// Worker wakeups (each drains 1..=batch jobs).
    pub batches: u64,
    /// Solves served by an already-warm session.
    pub cache_hits: u64,
    /// Solves that had to build (warm up) a session first.
    pub cache_misses: u64,
    /// Distinct sessions cached (no eviction: equals `cache_misses`).
    pub keys: u64,
    /// Submits refused with `overloaded`.
    pub overloaded: u64,
    /// High-water queue depth.
    pub max_depth: u64,
}

impl ShardSnapshot {
    /// As a JSON object (the `info` response and the bench report embed
    /// these rows verbatim).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: u64| {
            m.insert(k.to_string(), Value::Number(v as f64));
        };
        put("shard", self.shard as u64);
        put("requests", self.requests);
        put("batches", self.batches);
        put("cache_hits", self.cache_hits);
        put("cache_misses", self.cache_misses);
        put("keys", self.keys);
        put("overloaded", self.overloaded);
        put("max_depth", self.max_depth);
        Value::Object(m)
    }

    /// Parse back from the `info` response (the loadgen side).
    pub fn from_value(v: &Value) -> Option<ShardSnapshot> {
        let g = |k: &str| v.get(k).and_then(Value::as_u64);
        Some(ShardSnapshot {
            shard: g("shard")? as usize,
            requests: g("requests")?,
            batches: g("batches")?,
            cache_hits: g("cache_hits")?,
            cache_misses: g("cache_misses")?,
            keys: g("keys")?,
            overloaded: g("overloaded")?,
            max_depth: g("max_depth")?,
        })
    }
}

/// The pool itself. Shared as `Arc<SessionPool>` between the acceptor and
/// every connection handler; all methods take `&self`.
pub struct SessionPool {
    cfg: PoolConfig,
    stop: Arc<AtomicBool>,
    /// Senders live behind a mutex so `shutdown` can take (drop) them;
    /// `submit`'s `try_send` never blocks while holding the lock.
    senders: Mutex<Vec<SyncSender<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    stats: Vec<Arc<ShardStats>>,
}

impl SessionPool {
    /// Spawn the shard workers and open their queues.
    pub fn new(cfg: PoolConfig) -> SessionPool {
        let shards = cfg.shards.max(1);
        let stop = Arc::new(AtomicBool::new(false));
        let mut senders = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        let mut stats = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue.max(1));
            let st = Arc::new(ShardStats::default());
            let wst = Arc::clone(&st);
            let batch = cfg.batch.max(1);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("nekbone-shard-{shard}"))
                    .spawn(move || shard_worker(shard, rx, wst, batch))
                    .expect("spawn shard worker"),
            );
            senders.push(tx);
            stats.push(st);
        }
        SessionPool {
            cfg,
            stop,
            senders: Mutex::new(senders),
            workers: Mutex::new(workers),
            stats,
        }
    }

    /// The configured per-shard queue capacity.
    pub fn queue_capacity(&self) -> usize {
        self.cfg.queue.max(1)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.cfg.shards.max(1)
    }

    /// Route and enqueue one solve; never blocks. The reply arrives on
    /// `reply` unless the return value says otherwise.
    pub fn submit(
        &self,
        id: u64,
        key: ShardKey,
        rhs: Vec<f64>,
        reply: mpsc::Sender<SolveReply>,
    ) -> Submit {
        if self.stop.load(Ordering::SeqCst) {
            return Submit::ShuttingDown;
        }
        let shard = key.shard(self.shards());
        let guard = self.senders.lock().expect("pool senders poisoned");
        let Some(tx) = guard.get(shard) else {
            return Submit::ShuttingDown; // shutdown already took the senders
        };
        match tx.try_send(Job { id, key, rhs, reply }) {
            Ok(()) => {
                self.stats[shard].enqueued();
                Submit::Accepted { shard }
            }
            Err(TrySendError::Full(_)) => {
                self.stats[shard].overloaded.fetch_add(1, Ordering::Relaxed);
                Submit::Overloaded { shard }
            }
            Err(TrySendError::Disconnected(_)) => Submit::ShuttingDown,
        }
    }

    /// Refuse new submits from now on; already-queued jobs still drain.
    pub fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Drain and stop: refuse new submits, drop the queues' senders (each
    /// worker finishes its accepted backlog, then exits), and join the
    /// workers. Idempotent.
    pub fn shutdown(&self) {
        self.begin_shutdown();
        drop(std::mem::take(&mut *self.senders.lock().expect("pool senders poisoned")));
        let workers = std::mem::take(&mut *self.workers.lock().expect("pool workers poisoned"));
        for w in workers {
            let _ = w.join();
        }
    }

    /// Point-in-time statistics for every shard.
    pub fn snapshot(&self) -> Vec<ShardSnapshot> {
        self.stats
            .iter()
            .enumerate()
            .map(|(shard, s)| ShardSnapshot {
                shard,
                requests: s.requests.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                cache_hits: s.cache_hits.load(Ordering::Relaxed),
                cache_misses: s.cache_misses.load(Ordering::Relaxed),
                keys: s.cache_misses.load(Ordering::Relaxed),
                overloaded: s.overloaded.load(Ordering::Relaxed),
                max_depth: s.max_depth.load(Ordering::Relaxed) as u64,
            })
            .collect()
    }
}

impl Drop for SessionPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Build the session a key describes: a full application build (mesh,
/// geometry, gather–scatter, operator warm-up), then drop the build-time
/// half. This is the first-touch cost a warm cache amortizes away.
fn build_session(key: &ShardKey) -> Result<OwnedSession, Error> {
    let cfg = RunConfig {
        nelt: key.nelt,
        n: key.n,
        niter: key.niter,
        chunk: key.nelt.max(1),
        ..RunConfig::default()
    };
    Ok(Nekbone::builder(cfg).operator(key.operator.as_str()).build()?.into_session())
}

/// One shard's serving loop: micro-batch the queue, get-or-build the
/// session for each key, solve, reply. Exits when the queue disconnects
/// with its backlog fully served.
fn shard_worker(shard: usize, rx: Receiver<Job>, stats: Arc<ShardStats>, batch_max: usize) {
    let mut sessions: BTreeMap<ShardKey, OwnedSession> = BTreeMap::new();
    while let Ok(first) = rx.recv() {
        // Drain up to batch_max jobs in one wakeup: consecutive requests
        // against warm sessions amortize the channel wakeup, and the
        // batch counter exposes how much batching the load actually got.
        let mut batch = vec![first];
        while batch.len() < batch_max {
            match rx.try_recv() {
                Ok(job) => batch.push(job),
                Err(_) => break,
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        for job in batch {
            stats.dequeued();
            let outcome = serve_one(&mut sessions, &stats, &job.key, &job.rhs);
            // A dropped receiver (client hung up mid-solve) is fine; the
            // work is already done and nothing waits on the error.
            let _ = job.reply.send(SolveReply { id: job.id, shard, outcome });
        }
    }
}

fn serve_one(
    sessions: &mut BTreeMap<ShardKey, OwnedSession>,
    stats: &ShardStats,
    key: &ShardKey,
    rhs: &[f64],
) -> Result<SolveOk, Error> {
    if !sessions.contains_key(key) {
        stats.cache_misses.fetch_add(1, Ordering::Relaxed);
        let session = build_session(key)?;
        sessions.insert(key.clone(), session);
    } else {
        stats.cache_hits.fetch_add(1, Ordering::Relaxed);
    }
    let session = sessions.get_mut(key).expect("session just ensured");
    let report = session.solve(rhs)?;
    Ok(SolveOk {
        operator: session.operator_label(),
        iterations: report.iterations,
        rnorm: report.final_rnorm,
        x: session.solution().to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Nekbone;

    fn key(op: &str, n: usize, nelt: usize) -> ShardKey {
        ShardKey { operator: op.into(), n, nelt, niter: 10 }
    }

    fn rhs_for(k: &ShardKey, seed: u64) -> Vec<f64> {
        crate::rng::Rng::new(seed).normal_vec(k.ndof())
    }

    /// The serial oracle: an independent session, same key, same rhs.
    fn serial_solve(k: &ShardKey, rhs: &[f64]) -> (usize, f64, Vec<f64>) {
        let cfg = RunConfig {
            nelt: k.nelt,
            n: k.n,
            niter: k.niter,
            chunk: k.nelt.max(1),
            ..RunConfig::default()
        };
        let mut s = Nekbone::builder(cfg)
            .operator(k.operator.as_str())
            .build()
            .unwrap()
            .into_session();
        let rep = s.solve(rhs).unwrap();
        (rep.iterations, rep.final_rnorm, s.solution().to_vec())
    }

    fn submit_ok(pool: &SessionPool, id: u64, k: &ShardKey, rhs: Vec<f64>) -> mpsc::Receiver<SolveReply> {
        let (tx, rx) = mpsc::channel();
        match pool.submit(id, k.clone(), rhs, tx) {
            Submit::Accepted { .. } => rx,
            other => panic!("submit refused: {other:?}"),
        }
    }

    #[test]
    fn pool_answers_match_serial_sessions_bitwise() {
        let pool = SessionPool::new(PoolConfig { shards: 2, queue: 8, batch: 4 });
        let keys = [key("cpu-layered", 3, 2), key("cpu-spec", 4, 2), key("cpu-layered", 4, 4)];
        for (i, k) in keys.iter().enumerate() {
            for seed in 0..3u64 {
                let rhs = rhs_for(k, seed);
                let rx = submit_ok(&pool, (i * 10) as u64 + seed, k, rhs.clone());
                let reply = rx.recv().unwrap();
                let ok = reply.outcome.expect("solve must succeed");
                let (want_iters, want_rnorm, want_x) = serial_solve(k, &rhs);
                assert_eq!(ok.iterations, want_iters);
                assert_eq!(ok.rnorm.to_bits(), want_rnorm.to_bits(), "{}", k.label());
                assert_eq!(ok.x.len(), want_x.len());
                for (a, b) in ok.x.iter().zip(want_x.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", k.label());
                }
            }
        }
        let snaps = pool.snapshot();
        let hits: u64 = snaps.iter().map(|s| s.cache_hits).sum();
        let misses: u64 = snaps.iter().map(|s| s.cache_misses).sum();
        assert_eq!(misses, 3, "one warm-up per distinct key");
        assert_eq!(hits, 6, "repeat solves must hit the cache");
        pool.shutdown();
    }

    #[test]
    fn full_queue_overloads_instead_of_buffering() {
        // One shard, capacity 1, and a worker wedged on a real solve: the
        // queue fills and subsequent submits must refuse immediately.
        let pool = SessionPool::new(PoolConfig { shards: 1, queue: 1, batch: 1 });
        let k = key("cpu-layered", 5, 8);
        let first = submit_ok(&pool, 0, &k, rhs_for(&k, 0));
        // Fill the queue behind the in-flight job; depending on worker
        // timing the first slot may or may not have been drained yet, so
        // push until Overloaded appears — bounded by capacity + 1 tries.
        let mut saw_overload = false;
        let mut receivers = vec![first];
        for i in 0..8 {
            let (tx, rx) = mpsc::channel();
            match pool.submit(i + 1, k.clone(), rhs_for(&k, i), tx) {
                Submit::Accepted { .. } => receivers.push(rx),
                Submit::Overloaded { .. } => {
                    saw_overload = true;
                    break;
                }
                Submit::ShuttingDown => panic!("pool is not shutting down"),
            }
        }
        assert!(saw_overload, "a capacity-1 queue must overload under a burst");
        assert!(pool.snapshot()[0].overloaded >= 1);
        // Everything accepted still completes.
        for rx in receivers {
            assert!(rx.recv().unwrap().outcome.is_ok());
        }
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_accepted_and_refuses_new() {
        let pool = SessionPool::new(PoolConfig { shards: 1, queue: 16, batch: 4 });
        let k = key("cpu-layered", 3, 2);
        let receivers: Vec<_> =
            (0..6).map(|i| submit_ok(&pool, i, &k, rhs_for(&k, i))).collect();
        pool.begin_shutdown();
        // New work is refused the moment shutdown begins …
        let (tx, _rx) = mpsc::channel();
        assert_eq!(pool.submit(99, k.clone(), rhs_for(&k, 9), tx), Submit::ShuttingDown);
        // … but every accepted job still gets a real answer.
        pool.shutdown();
        for rx in receivers {
            let reply = rx.recv().expect("accepted job lost in shutdown");
            assert!(reply.outcome.is_ok());
        }
    }

    #[test]
    fn bad_keys_fail_the_job_not_the_worker() {
        let pool = SessionPool::new(PoolConfig { shards: 1, queue: 4, batch: 2 });
        // Unknown operator: builder error, reported on the reply channel.
        let bad = key("gpu-magic", 3, 2);
        let rx = submit_ok(&pool, 1, &bad, vec![0.0; bad.ndof()]);
        assert!(rx.recv().unwrap().outcome.is_err());
        // Mis-sized rhs: session-boundary Config error.
        let good = key("cpu-layered", 3, 2);
        let rx = submit_ok(&pool, 2, &good, vec![0.0; 5]);
        let err = rx.recv().unwrap().outcome.err().unwrap();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // The worker survives both: a well-formed job still solves.
        let rhs = rhs_for(&good, 1);
        let rx = submit_ok(&pool, 3, &good, rhs);
        assert!(rx.recv().unwrap().outcome.is_ok());
        pool.shutdown();
    }
}
