//! The serve wire protocol: newline-delimited JSON, one request or
//! response object per line, over the crate's own [`crate::json`].
//!
//! Requests (`"id"` is echoed back verbatim; it defaults to 0):
//!
//! ```text
//! {"op":"ping","id":1}
//! {"op":"info","id":2}
//! {"op":"solve","id":3,"operator":"cpu-layered","n":4,"nelt":8,
//!  "rhs":[...nelt*n^3 numbers...],"niter":20}
//! {"op":"shutdown","id":4}
//! ```
//!
//! Responses always carry `"id"` and `"ok"`. A successful solve echoes the
//! per-RHS [`CgReport`] essentials plus the solution vector; `dump`'s
//! shortest-round-trip number formatting makes the echoed `x` parse back
//! bitwise-identical to the solver's output. Failures carry a stable
//! `"error"` kind from the [`ERR_BAD_REQUEST`]-family constants and a
//! human `"detail"`.

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};

use crate::error::{Error, Result};
use crate::json::{parse, Value};
use crate::solver::CgReport;

use super::pool::ShardSnapshot;

/// Request refused because the line was not a well-formed request.
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// Request refused because the target shard's bounded queue is full.
pub const ERR_OVERLOADED: &str = "overloaded";
/// Request refused because the server is draining for shutdown.
pub const ERR_SHUTTING_DOWN: &str = "shutting_down";
/// Request accepted but the solve itself failed.
pub const ERR_SOLVE_FAILED: &str = "solve_failed";

/// What a solve request names: the session-cache key. Everything that
/// changes the built state is in here — two requests with equal keys hit
/// the same cached [`OwnedSession`](crate::coordinator::OwnedSession),
/// and the key hash picks the owning shard.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShardKey {
    /// Operator registry name (canonical or alias).
    pub operator: String,
    /// GLL points per dimension.
    pub n: usize,
    /// Element count.
    pub nelt: usize,
    /// CG iterations per solve.
    pub niter: usize,
}

impl ShardKey {
    /// Local dofs a solve over this key moves (`nelt * n^3`).
    pub fn ndof(&self) -> usize {
        self.nelt * self.n * self.n * self.n
    }

    /// The shard this key routes to. Deterministic for the life of the
    /// process (same-key requests always reach the same worker — the
    /// bitwise-reproducibility contract depends on it).
    pub fn shard(&self, nshards: usize) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut h);
        (h.finish() % nshards.max(1) as u64) as usize
    }

    /// Display form, `operator/n/nelt/niter`.
    pub fn label(&self) -> String {
        format!("{}/n{}/e{}/i{}", self.operator, self.n, self.nelt, self.niter)
    }
}

/// A parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping { id: u64 },
    Info { id: u64 },
    Solve { id: u64, key: ShardKey, rhs: Vec<f64> },
    Shutdown { id: u64 },
}

impl Request {
    /// The request's echo id.
    pub fn id(&self) -> u64 {
        match self {
            Request::Ping { id }
            | Request::Info { id }
            | Request::Solve { id, .. }
            | Request::Shutdown { id } => *id,
        }
    }
}

fn want_usize(v: &Value, field: &str) -> Result<usize> {
    v.get(field)
        .and_then(Value::as_usize)
        .ok_or_else(|| Error::Config(format!("solve request: {field} must be an integer")))
}

/// Parse one request line. `default_niter` fills a solve request that
/// names no `niter` (the server's configured default).
pub fn parse_request(line: &str, default_niter: usize) -> Result<Request> {
    let v = parse(line.trim())?;
    let id = v.get("id").and_then(Value::as_u64).unwrap_or(0);
    let op = v
        .get("op")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Config("request needs a string \"op\" field".into()))?;
    match op {
        "ping" => Ok(Request::Ping { id }),
        "info" => Ok(Request::Info { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "solve" => {
            let operator = v
                .get("operator")
                .and_then(Value::as_str)
                .ok_or_else(|| {
                    Error::Config("solve request: operator must be a string".into())
                })?
                .to_string();
            let n = want_usize(&v, "n")?;
            let nelt = want_usize(&v, "nelt")?;
            let niter = match v.get("niter") {
                None => default_niter,
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config("solve request: niter must be an integer".into())
                })?,
            };
            let rhs_v = v
                .get("rhs")
                .and_then(Value::as_array)
                .ok_or_else(|| Error::Config("solve request: rhs must be an array".into()))?;
            let mut rhs = Vec::with_capacity(rhs_v.len());
            for (i, x) in rhs_v.iter().enumerate() {
                rhs.push(x.as_f64().ok_or_else(|| {
                    Error::Config(format!("solve request: rhs[{i}] is not a number"))
                })?);
            }
            Ok(Request::Solve { id, key: ShardKey { operator, n, nelt, niter }, rhs })
        }
        other => Err(Error::Config(format!(
            "unknown op {other:?}; expected ping, info, solve, or shutdown"
        ))),
    }
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Object(m)
}

fn num(x: f64) -> Value {
    Value::Number(x)
}

/// `{"id":N,"ok":true,"pong":true}`.
pub fn resp_pong(id: u64) -> String {
    obj(vec![("id", num(id as f64)), ("ok", Value::Bool(true)), ("pong", Value::Bool(true))])
        .dump()
}

/// Shutdown acknowledgement: the server drains and exits after this.
pub fn resp_shutdown(id: u64) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("ok", Value::Bool(true)),
        ("draining", Value::Bool(true)),
    ])
    .dump()
}

/// Successful solve: the per-RHS report essentials + the solution field.
pub fn resp_solve_ok(
    id: u64,
    operator: &str,
    shard: usize,
    report: &CgReport,
    x: &[f64],
) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("ok", Value::Bool(true)),
        ("operator", Value::String(operator.to_string())),
        ("shard", num(shard as f64)),
        ("iterations", num(report.iterations as f64)),
        ("rnorm", num(report.final_rnorm)),
        ("x", Value::Array(x.iter().map(|&v| Value::Number(v)).collect())),
    ])
    .dump()
}

/// Any refusal/failure: stable `error` kind + human `detail`.
pub fn resp_error(id: u64, kind: &str, detail: &str) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("ok", Value::Bool(false)),
        ("error", Value::String(kind.to_string())),
        ("detail", Value::String(detail.to_string())),
    ])
    .dump()
}

/// `info` response: registered operators + live pool statistics.
pub fn resp_info(
    id: u64,
    operators: &[String],
    queue_capacity: usize,
    shards: &[ShardSnapshot],
) -> String {
    let shard_vals: Vec<Value> = shards.iter().map(ShardSnapshot::to_value).collect();
    obj(vec![
        ("id", num(id as f64)),
        ("ok", Value::Bool(true)),
        (
            "operators",
            Value::Array(operators.iter().map(|s| Value::String(s.clone())).collect()),
        ),
        ("shards", num(shards.len() as f64)),
        ("queue_capacity", num(queue_capacity as f64)),
        ("shard_stats", Value::Array(shard_vals)),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_requests() {
        assert_eq!(parse_request(r#"{"op":"ping","id":1}"#, 9).unwrap(), Request::Ping {
            id: 1
        });
        assert_eq!(parse_request(r#"{"op":"info"}"#, 9).unwrap(), Request::Info { id: 0 });
        assert_eq!(
            parse_request(r#"{"op":"shutdown","id":4}"#, 9).unwrap(),
            Request::Shutdown { id: 4 }
        );
        let r = parse_request(
            r#"{"op":"solve","id":3,"operator":"cpu-spec","n":2,"nelt":1,"rhs":[1,2,3,4,5,6,7,8]}"#,
            9,
        )
        .unwrap();
        match r {
            Request::Solve { id, key, rhs } => {
                assert_eq!(id, 3);
                assert_eq!(key, ShardKey {
                    operator: "cpu-spec".into(),
                    n: 2,
                    nelt: 1,
                    niter: 9
                });
                assert_eq!(key.ndof(), 8);
                assert_eq!(rhs, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_requests() {
        for bad in [
            "not json",
            r#"{"id":1}"#,
            r#"{"op":"warp","id":1}"#,
            r#"{"op":"solve","operator":"cpu-spec","n":2,"nelt":1}"#,
            r#"{"op":"solve","operator":"cpu-spec","n":2,"nelt":1,"rhs":["x"]}"#,
            r#"{"op":"solve","operator":7,"n":2,"nelt":1,"rhs":[]}"#,
            r#"{"op":"solve","operator":"cpu-spec","n":2.5,"nelt":1,"rhs":[]}"#,
        ] {
            assert!(parse_request(bad, 9).is_err(), "{bad}");
        }
    }

    #[test]
    fn shard_routing_is_deterministic_and_in_range() {
        let key = |op: &str, n: usize| ShardKey {
            operator: op.into(),
            n,
            nelt: 8,
            niter: 20,
        };
        for nshards in [1, 2, 4, 7] {
            for k in [key("cpu-layered", 4), key("cpu-spec", 4), key("cpu-layered", 5)] {
                let s = k.shard(nshards);
                assert!(s < nshards);
                assert_eq!(s, k.shard(nshards), "routing must be stable");
            }
        }
    }

    #[test]
    fn responses_parse_back() {
        let rep = CgReport {
            iterations: 7,
            final_rnorm: 1.5e-9,
            rnorms: vec![],
            rtz1: 0.0,
            glsc3_sweeps: 0,
        };
        let x = [0.1 + 0.2, -0.0, 3.25];
        let line = resp_solve_ok(3, "cpu-spec", 2, &rep, &x);
        let v = crate::json::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(v.get("iterations").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("shard").unwrap().as_usize(), Some(2));
        let got: Vec<f64> =
            v.get("x").unwrap().as_array().unwrap().iter().map(|e| e.as_f64().unwrap()).collect();
        // Bitwise round-trip: the conformance suite compares served
        // solutions against serial solves exactly.
        for (a, b) in got.iter().zip(x.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        let e = crate::json::parse(&resp_error(9, ERR_OVERLOADED, "queue full")).unwrap();
        assert_eq!(e.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(e.get("error").unwrap().as_str(), Some(ERR_OVERLOADED));
    }
}
