//! The TCP front-end: accept loop, per-connection line handlers, and the
//! stop-flag lifecycle tying SIGINT / `shutdown` requests to a graceful
//! pool drain.
//!
//! Everything is `std`: a non-blocking `TcpListener` polled by the accept
//! loop, one `std::thread` per connection reading newline-delimited JSON
//! with a short read timeout (so handlers notice the stop flag between
//! lines), and a shared [`SessionPool`] doing the actual solves.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::operators::registry;
use crate::solver::CgReport;

use super::pool::{PoolConfig, SessionPool, ShardSnapshot, Submit};
use super::protocol::{
    parse_request, resp_error, resp_info, resp_pong, resp_shutdown, resp_solve_ok, Request,
    ERR_BAD_REQUEST, ERR_OVERLOADED, ERR_SHUTTING_DOWN, ERR_SOLVE_FAILED,
};
use super::{spec_default, spec_usize, SERVE_OPTS};

/// How often idle handlers and the accept loop re-check the stop flag.
const POLL: Duration = Duration::from_millis(50);

/// `nekbone serve` configuration; defaults come from [`SERVE_OPTS`] so the
/// help text and the parser cannot drift apart.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    pub shards: usize,
    pub queue: usize,
    pub batch: usize,
    /// CG iterations for solve requests that name no `niter`.
    pub niter: usize,
}

impl ServeConfig {
    pub fn from_args(args: &Args) -> Result<ServeConfig> {
        let cfg = ServeConfig {
            addr: args.get("addr").unwrap_or(spec_default(SERVE_OPTS, "addr")).to_string(),
            shards: spec_usize(args, SERVE_OPTS, "shards")?,
            queue: spec_usize(args, SERVE_OPTS, "queue")?,
            batch: spec_usize(args, SERVE_OPTS, "batch")?,
            niter: spec_usize(args, SERVE_OPTS, "niter")?,
        };
        for (what, v) in
            [("shards", cfg.shards), ("queue", cfg.queue), ("batch", cfg.batch), ("niter", cfg.niter)]
        {
            if v == 0 {
                return Err(Error::Config(format!("serve: --{what} must be positive")));
            }
        }
        Ok(cfg)
    }
}

/// What a finished server reports: connection count plus the pool's final
/// per-shard statistics (the CLI prints these; the bench embeds them).
pub struct ServeReport {
    pub connections: usize,
    pub shards: Vec<ShardSnapshot>,
}

/// A bound-but-not-yet-running server. Splitting bind from run lets the
/// in-process tests and the bench learn the OS-assigned port (addr `:0`)
/// and grab the stop flag before the accept loop starts.
pub struct Server {
    listener: TcpListener,
    pool: Arc<SessionPool>,
    stop: Arc<AtomicBool>,
    niter: usize,
}

impl Server {
    /// Bind the listen socket and spawn the shard workers.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())
            .map_err(|e| Error::Config(format!("serve: cannot bind {}: {e}", cfg.addr)))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Config(format!("serve: set_nonblocking: {e}")))?;
        let pool = Arc::new(SessionPool::new(PoolConfig {
            shards: cfg.shards,
            queue: cfg.queue,
            batch: cfg.batch,
        }));
        Ok(Server { listener, pool, stop: Arc::new(AtomicBool::new(false)), niter: cfg.niter })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().map_err(|e| Error::Config(format!("serve: local_addr: {e}")))
    }

    /// The stop flag; storing `true` makes the accept loop wind down.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept until the stop flag flips (a `shutdown` request, SIGINT via
    /// [`install_sigint_handler`], or a test holding [`Server::stop_flag`]),
    /// then drain: stop accepting, join every connection handler, drain
    /// and join the pool, and report final statistics.
    pub fn run(self) -> Result<ServeReport> {
        if sigint_seen() {
            self.stop.store(true, Ordering::SeqCst);
        }
        let mut handlers = Vec::new();
        let mut connections = 0usize;
        while !self.stop.load(Ordering::SeqCst) && !sigint_seen() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    connections += 1;
                    let pool = Arc::clone(&self.pool);
                    let stop = Arc::clone(&self.stop);
                    let niter = self.niter;
                    handlers.push(
                        std::thread::Builder::new()
                            .name(format!("nekbone-conn-{connections}"))
                            .spawn(move || handle_connection(stream, pool, stop, niter))
                            .map_err(|e| Error::Config(format!("serve: spawn handler: {e}")))?,
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
                Err(e) => return Err(Error::Config(format!("serve: accept: {e}"))),
            }
        }
        self.stop.store(true, Ordering::SeqCst);
        self.pool.begin_shutdown(); // refuse new solves while handlers wind down
        drop(self.listener);
        for h in handlers {
            let _ = h.join();
        }
        self.pool.shutdown(); // drain accepted backlog, join workers
        Ok(ServeReport { connections, shards: self.pool.snapshot() })
    }
}

/// One connection: read lines until EOF, a fatal error, or shutdown.
fn handle_connection(
    stream: TcpStream,
    pool: Arc<SessionPool>,
    stop: Arc<AtomicBool>,
    default_niter: usize,
) {
    // The read timeout bounds how long a quiet connection can keep the
    // server from noticing the stop flag.
    let _ = stream.set_read_timeout(Some(POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        // On WouldBlock `read_line` may have consumed a partial line into
        // `line`; keep accumulating — only clear after a complete line.
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {
                let stop_after = {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        false
                    } else {
                        match respond(trimmed, &pool, &stop, default_niter) {
                            Some(resp) => {
                                if writeln!(writer, "{resp}").is_err() {
                                    return;
                                }
                                let _ = writer.flush();
                                stop.load(Ordering::SeqCst)
                            }
                            None => return,
                        }
                    }
                };
                line.clear();
                if stop_after {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if stop.load(Ordering::SeqCst) || sigint_seen() {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Turn one request line into one response line (`None` only when the
/// connection should drop without an answer — never happens today, but
/// the shape keeps the caller honest about the possibility).
fn respond(
    line: &str,
    pool: &SessionPool,
    stop: &AtomicBool,
    default_niter: usize,
) -> Option<String> {
    let req = match parse_request(line, default_niter) {
        Ok(r) => r,
        Err(e) => return Some(resp_error(0, ERR_BAD_REQUEST, &e.to_string())),
    };
    let id = req.id();
    Some(match req {
        Request::Ping { .. } => resp_pong(id),
        Request::Info { .. } => {
            resp_info(id, &registry().names(), pool.queue_capacity(), &pool.snapshot())
        }
        Request::Shutdown { .. } => {
            stop.store(true, Ordering::SeqCst);
            pool.begin_shutdown();
            resp_shutdown(id)
        }
        Request::Solve { key, rhs, .. } => {
            if !registry().contains(&key.operator) {
                return Some(resp_error(
                    id,
                    ERR_BAD_REQUEST,
                    &format!("unknown operator {:?}; ask `info` for the list", key.operator),
                ));
            }
            if rhs.len() != key.ndof() {
                return Some(resp_error(
                    id,
                    ERR_BAD_REQUEST,
                    &format!(
                        "rhs has {} entries, {} solves {} dofs",
                        rhs.len(),
                        key.label(),
                        key.ndof()
                    ),
                ));
            }
            let (tx, rx) = mpsc::channel();
            match pool.submit(id, key, rhs, tx) {
                Submit::Accepted { .. } => match rx.recv() {
                    Ok(reply) => match reply.outcome {
                        Ok(ok) => {
                            let report = CgReport {
                                iterations: ok.iterations,
                                final_rnorm: ok.rnorm,
                                rnorms: Vec::new(),
                                rtz1: 0.0,
                                glsc3_sweeps: 0,
                            };
                            resp_solve_ok(id, &ok.operator, reply.shard, &report, &ok.x)
                        }
                        Err(e) => {
                            let kind = match e {
                                Error::Config(_) => ERR_BAD_REQUEST,
                                _ => ERR_SOLVE_FAILED,
                            };
                            resp_error(id, kind, &e.to_string())
                        }
                    },
                    Err(_) => resp_error(id, ERR_SOLVE_FAILED, "worker dropped the request"),
                },
                Submit::Overloaded { shard } => resp_error(
                    id,
                    ERR_OVERLOADED,
                    &format!("shard {shard} queue is full; retry later"),
                ),
                Submit::ShuttingDown => {
                    resp_error(id, ERR_SHUTTING_DOWN, "server is draining; no new solves")
                }
            }
        }
    })
}

// --- SIGINT ---------------------------------------------------------------
//
// std exposes no signal API, and the no-new-dependencies rule rules out the
// usual crates, so the CLI installs a classic `signal(2)` handler that only
// flips an atomic — the accept loop and idle handlers poll it. Installed by
// `nekbone serve` alone; library users and tests drive the stop flag
// directly.

static SIGINT: AtomicBool = AtomicBool::new(false);

fn sigint_seen() -> bool {
    SIGINT.load(Ordering::SeqCst)
}

#[cfg(unix)]
extern "C" fn on_sigint(_signum: i32) {
    SIGINT.store(true, Ordering::SeqCst);
}

/// Route SIGINT to a graceful drain (unix only; a no-op elsewhere).
#[cfg(unix)]
pub fn install_sigint_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT_NUM: i32 = 2;
    unsafe {
        signal(SIGINT_NUM, on_sigint as usize);
    }
}

/// Route SIGINT to a graceful drain (unix only; a no-op elsewhere).
#[cfg(not(unix))]
pub fn install_sigint_handler() {}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> SessionPool {
        SessionPool::new(PoolConfig { shards: 1, queue: 4, batch: 2 })
    }

    #[test]
    fn respond_covers_the_refusal_paths() {
        let p = pool();
        let stop = AtomicBool::new(false);
        // Garbage line => bad_request with id 0.
        let r = respond("not json", &p, &stop, 9).unwrap();
        let v = crate::json::parse(&r).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some(ERR_BAD_REQUEST));
        // Unknown operator is refused before touching the pool.
        let r = respond(
            r#"{"op":"solve","id":5,"operator":"nope","n":2,"nelt":1,"rhs":[0,0,0,0,0,0,0,0]}"#,
            &p,
            &stop,
            9,
        )
        .unwrap();
        let v = crate::json::parse(&r).unwrap();
        assert_eq!(v.get("id").unwrap().as_u64(), Some(5));
        assert_eq!(v.get("error").unwrap().as_str(), Some(ERR_BAD_REQUEST));
        // Mis-sized rhs likewise.
        let r = respond(
            r#"{"op":"solve","id":6,"operator":"cpu-layered","n":2,"nelt":1,"rhs":[1,2]}"#,
            &p,
            &stop,
            9,
        )
        .unwrap();
        let v = crate::json::parse(&r).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some(ERR_BAD_REQUEST));
        p.shutdown();
    }

    #[test]
    fn respond_solves_and_shuts_down() {
        let p = pool();
        let stop = AtomicBool::new(false);
        let r = respond(r#"{"op":"ping","id":1}"#, &p, &stop, 9).unwrap();
        assert_eq!(crate::json::parse(&r).unwrap().get("pong"), Some(&crate::json::Value::Bool(true)));

        let rhs: Vec<String> = (0..54).map(|i| format!("{}", (i % 7) as f64 - 3.0)).collect();
        let line = format!(
            r#"{{"op":"solve","id":2,"operator":"cpu-layered","n":3,"nelt":2,"niter":8,"rhs":[{}]}}"#,
            rhs.join(",")
        );
        let r = respond(&line, &p, &stop, 9).unwrap();
        let v = crate::json::parse(&r).unwrap();
        assert_eq!(v.get("ok"), Some(&crate::json::Value::Bool(true)), "{r}");
        assert_eq!(v.get("x").unwrap().as_array().unwrap().len(), 54);

        // `info` reflects the warm session.
        let r = respond(r#"{"op":"info","id":3}"#, &p, &stop, 9).unwrap();
        let v = crate::json::parse(&r).unwrap();
        let stats = v.get("shard_stats").unwrap().as_array().unwrap();
        let misses: u64 =
            stats.iter().map(|s| s.get("cache_misses").unwrap().as_u64().unwrap()).sum();
        assert_eq!(misses, 1);

        // Shutdown flips the stop flag and begins the pool drain.
        let r = respond(r#"{"op":"shutdown","id":4}"#, &p, &stop, 9).unwrap();
        assert!(stop.load(Ordering::SeqCst));
        assert_eq!(
            crate::json::parse(&r).unwrap().get("draining"),
            Some(&crate::json::Value::Bool(true))
        );
        // And further solves are refused.
        let r = respond(&line, &p, &stop, 9).unwrap();
        let v = crate::json::parse(&r).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some(ERR_SHUTTING_DOWN));
        p.shutdown();
    }

    #[test]
    fn from_args_validates() {
        let args = |v: &[&str]| {
            crate::cli::Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        assert!(ServeConfig::from_args(&args(&["serve", "--batch", "0"])).is_err());
        assert!(ServeConfig::from_args(&args(&["serve", "--niter", "0"])).is_err());
        let s = ServeConfig::from_args(&args(&["serve", "--addr", "127.0.0.1:0"])).unwrap();
        assert_eq!(s.addr, "127.0.0.1:0");
    }
}
