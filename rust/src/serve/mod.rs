//! Solver-as-a-service: a std-only TCP front-end over sharded
//! [`OwnedSession`](crate::coordinator::OwnedSession) pools.
//!
//! The paper's kernel exists to be driven hard — thousands of small
//! tensor-product solves per second — and this layer is the "one setup,
//! many requests" deployment shape on top of the substrate PRs 1–5 built:
//! registry-dispatched operators, one generic CG, zero-per-solve-allocation
//! sessions. Per repo convention it is dependency-free: the wire format is
//! newline-delimited JSON over the crate's own [`crate::json`] machinery,
//! the network layer is `std::net`, and concurrency is `std::thread` +
//! `std::sync::mpsc`.
//!
//! ## Request lifecycle
//!
//! ```text
//! client ──line──▶ acceptor thread (one per connection)
//!                    │ parse + validate (operator name, rhs length)
//!                    ▼
//!                 SessionPool::submit ── hash(operator,n,nelt,niter) ──▶ shard s
//!                    │                                                    │
//!                    │ try_send on a bounded queue                        ▼
//!                    │   full  → {"error":"overloaded"}        shard worker thread
//!                    │   stopped → {"error":"shutting_down"}     │ get-or-build
//!                    ▼                                           │ OwnedSession
//!                 blocks on a per-request reply channel ◀─reply──┘ solve
//!                    │
//! client ◀──line── response: {"id",…,"iterations","rnorm","x",…}
//! ```
//!
//! ## Contracts
//!
//! * **Shard routing**: a request's `(operator, n, nelt, niter)` key hashes
//!   to one shard; the shard worker owns every session for its keys, so a
//!   given mesh is only ever solved by one thread and answers are
//!   bitwise-identical to a serial
//!   [`SolveSession`](crate::coordinator::SolveSession) solve (the
//!   conformance suite in `tests/serve.rs` asserts this across
//!   interleaved clients).
//! * **Backpressure**: each shard's queue is a bounded
//!   `mpsc::sync_channel`; when it is full the submit fails *immediately*
//!   with an explicit `overloaded` response. Memory is bounded by
//!   `shards * queue * max_request_size` — the pool never buffers
//!   unboundedly.
//! * **Lifecycle**: one `AtomicBool` stop flag. A `shutdown` request (or
//!   SIGINT on the CLI) flips it: new solves are refused with
//!   `shutting_down`, queued solves drain to completion (dropping the
//!   queue senders lets each worker finish its backlog before `recv`
//!   disconnects), workers and connection handlers join, and the server
//!   returns its final per-shard statistics.
//!
//! The protocol and usage are documented in `rust/README.md`; the
//! `nekbone-serve/1` bench schema next to `nekbone-roofline/1` in
//! `ROADMAP.md`.

mod loadgen;
mod pool;
pub mod protocol;
mod server;

pub use loadgen::{
    render_summary, run as run_loadgen, validate_json, write_json, LoadgenConfig,
    LoadgenReport,
};
pub use pool::{PoolConfig, SessionPool, ShardSnapshot, Submit};
pub use server::{install_sigint_handler, ServeConfig, ServeReport, Server};

use crate::cli::Args;
use crate::error::{Error, Result};

/// One CLI option of a serve-layer subcommand: the single source of truth
/// for both the generated help text ([`crate::cli::usage`] renders these
/// tables) and the parsed defaults ([`ServeConfig::from_args`] /
/// [`LoadgenConfig::from_args`] read defaults from the same rows via
/// `spec_default`) — there is no hand-synced `USAGE` string to drift.
pub struct OptSpec {
    /// `--key`.
    pub key: &'static str,
    /// Metavar for valued options (`""` for boolean flags).
    pub metavar: &'static str,
    /// Default value as it parses (`""` for flags; flags default to off).
    pub default: &'static str,
    /// One-line help.
    pub help: &'static str,
}

/// `nekbone serve` options.
pub const SERVE_OPTS: &[OptSpec] = &[
    OptSpec {
        key: "addr",
        metavar: "HOST:PORT",
        default: "127.0.0.1:5571",
        help: "listen address (port 0 picks a free port)",
    },
    OptSpec {
        key: "shards",
        metavar: "K",
        default: "4",
        help: "session-pool shards (worker threads)",
    },
    OptSpec {
        key: "queue",
        metavar: "N",
        default: "64",
        help: "bounded per-shard queue; full => overloaded",
    },
    OptSpec {
        key: "batch",
        metavar: "N",
        default: "8",
        help: "max requests a worker drains per wakeup",
    },
    OptSpec {
        key: "niter",
        metavar: "N",
        default: "20",
        help: "CG iterations when a request names none",
    },
];

/// `nekbone loadgen` options.
pub const LOADGEN_OPTS: &[OptSpec] = &[
    OptSpec {
        key: "addr",
        metavar: "HOST:PORT",
        default: "127.0.0.1:5571",
        help: "server address to drive",
    },
    OptSpec { key: "clients", metavar: "C", default: "4", help: "concurrent client threads" },
    OptSpec { key: "requests", metavar: "R", default: "16", help: "solve requests per client" },
    OptSpec {
        key: "backend",
        metavar: "NAME",
        default: "cpu-layered",
        help: "operator the requests name (registry name)",
    },
    OptSpec { key: "n", metavar: "N", default: "4", help: "base GLL points per dim" },
    OptSpec { key: "nelt", metavar: "N", default: "8", help: "base element count" },
    OptSpec { key: "niter", metavar: "N", default: "20", help: "CG iterations per solve" },
    OptSpec {
        key: "bench-json",
        metavar: "PATH",
        default: "",
        help: "write a nekbone-serve/1 BENCH_serve.json",
    },
    OptSpec { key: "quick", metavar: "", default: "", help: "smoke scale (2 clients x 4)" },
    OptSpec {
        key: "shutdown",
        metavar: "",
        default: "",
        help: "send a shutdown request when done",
    },
];

/// Default of `key` in an option table. Panics when the key is not in the
/// table — a config field reading an option that the help does not list
/// is a bug, caught by every test that touches `from_args`.
pub(crate) fn spec_default(opts: &[OptSpec], key: &str) -> &'static str {
    opts.iter()
        .find(|o| o.key == key)
        .unwrap_or_else(|| panic!("option --{key} missing from its OptSpec table"))
        .default
}

/// `--key` as usize, defaulting from the spec table.
pub(crate) fn spec_usize(args: &Args, opts: &[OptSpec], key: &str) -> Result<usize> {
    let dflt = spec_default(opts, key)
        .parse::<usize>()
        .map_err(|_| Error::Config(format!("spec default for --{key} is not an integer")))?;
    args.get_usize(key, dflt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn configs_default_from_the_spec_tables() {
        // `from_args` on a bare subcommand must reproduce exactly the
        // defaults the help text advertises — same rows, one source.
        let s = ServeConfig::from_args(&args(&["serve"])).unwrap();
        assert_eq!(s.addr, spec_default(SERVE_OPTS, "addr"));
        assert_eq!(s.shards.to_string(), spec_default(SERVE_OPTS, "shards"));
        assert_eq!(s.queue.to_string(), spec_default(SERVE_OPTS, "queue"));
        assert_eq!(s.batch.to_string(), spec_default(SERVE_OPTS, "batch"));
        assert_eq!(s.niter.to_string(), spec_default(SERVE_OPTS, "niter"));

        let l = LoadgenConfig::from_args(&args(&["loadgen"])).unwrap();
        assert_eq!(l.addr, spec_default(LOADGEN_OPTS, "addr"));
        assert_eq!(l.clients.to_string(), spec_default(LOADGEN_OPTS, "clients"));
        assert_eq!(l.requests.to_string(), spec_default(LOADGEN_OPTS, "requests"));
        assert_eq!(l.operator, spec_default(LOADGEN_OPTS, "backend"));
        assert_eq!(l.n.to_string(), spec_default(LOADGEN_OPTS, "n"));
        assert_eq!(l.nelt.to_string(), spec_default(LOADGEN_OPTS, "nelt"));
        assert_eq!(l.bench_json, None);
        assert!(!l.shutdown);
    }

    #[test]
    fn quick_flag_shrinks_the_load() {
        let l = LoadgenConfig::from_args(&args(&["loadgen", "--quick"])).unwrap();
        let full = LoadgenConfig::from_args(&args(&["loadgen"])).unwrap();
        assert!(l.clients < full.clients || l.requests < full.requests);
        assert!(l.n <= full.n && l.nelt <= full.nelt);
        // Explicit options still win over the quick scale.
        let l = LoadgenConfig::from_args(&args(&["loadgen", "--quick", "--clients", "7"]))
            .unwrap();
        assert_eq!(l.clients, 7);
    }

    #[test]
    fn overrides_parse() {
        let s = ServeConfig::from_args(&args(&[
            "serve",
            "--addr",
            "0.0.0.0:9000",
            "--shards=2",
            "--queue",
            "5",
        ]))
        .unwrap();
        assert_eq!(s.addr, "0.0.0.0:9000");
        assert_eq!(s.shards, 2);
        assert_eq!(s.queue, 5);
        assert!(ServeConfig::from_args(&args(&["serve", "--shards", "0"])).is_err());
        assert!(ServeConfig::from_args(&args(&["serve", "--queue", "zero"])).is_err());
    }

    #[test]
    fn every_spec_key_is_unique_and_help_fits() {
        for opts in [SERVE_OPTS, LOADGEN_OPTS] {
            for (i, o) in opts.iter().enumerate() {
                assert!(
                    !opts[..i].iter().any(|p| p.key == o.key),
                    "duplicate option --{}",
                    o.key
                );
                assert!(!o.help.is_empty());
            }
        }
    }
}
