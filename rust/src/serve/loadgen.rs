//! The built-in load generator: `nekbone loadgen` drives a running server
//! with concurrent clients over real TCP and reports latency/throughput in
//! the schema-stable `nekbone-serve/1` JSON (the serve-side twin of the
//! roofline bench's `nekbone-roofline/1`).
//!
//! The request mix cycles three distinct meshes per operator so the run
//! exercises shard routing and session caching, not just one warm key.
//! Request payloads are deterministic (seeded per client/request), so two
//! runs against the same server issue identical solves.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::json::{parse, Value};
use crate::rng::Rng;

use super::pool::ShardSnapshot;
use super::protocol::ERR_OVERLOADED;
use super::{spec_default, spec_usize, LOADGEN_OPTS};

/// Schema tag written into every report.
pub const SCHEMA: &str = "nekbone-serve/1";

/// `nekbone loadgen` configuration; defaults come from [`LOADGEN_OPTS`].
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    pub clients: usize,
    /// Solve requests per client.
    pub requests: usize,
    /// Operator every request names.
    pub operator: String,
    /// Base GLL points per dimension (the mesh mix varies around this).
    pub n: usize,
    /// Base element count.
    pub nelt: usize,
    pub niter: usize,
    /// Where to write the `nekbone-serve/1` report (`None`: stdout only).
    pub bench_json: Option<String>,
    /// Send a `shutdown` request after the run (CI smoke uses this).
    pub shutdown: bool,
}

impl LoadgenConfig {
    pub fn from_args(args: &Args) -> Result<LoadgenConfig> {
        let quick = args.flag("quick");
        // `--quick` shrinks every knob the user did not set explicitly.
        let pick = |key: &str, quick_val: usize| -> Result<usize> {
            if quick && args.get(key).is_none() {
                Ok(quick_val)
            } else {
                spec_usize(args, LOADGEN_OPTS, key)
            }
        };
        let cfg = LoadgenConfig {
            addr: args.get("addr").unwrap_or(spec_default(LOADGEN_OPTS, "addr")).to_string(),
            clients: pick("clients", 2)?,
            requests: pick("requests", 4)?,
            operator: args
                .get("backend")
                .unwrap_or(spec_default(LOADGEN_OPTS, "backend"))
                .to_string(),
            n: pick("n", 3)?,
            nelt: pick("nelt", 2)?,
            niter: pick("niter", 8)?,
            bench_json: args.get("bench-json").filter(|s| !s.is_empty()).map(str::to_string),
            shutdown: args.flag("shutdown"),
        };
        for (what, v) in [
            ("clients", cfg.clients),
            ("requests", cfg.requests),
            ("n", cfg.n),
            ("nelt", cfg.nelt),
            ("niter", cfg.niter),
        ] {
            if v == 0 {
                return Err(Error::Config(format!("loadgen: --{what} must be positive")));
            }
        }
        if cfg.n < 2 {
            return Err(Error::Config("loadgen: --n must be at least 2".into()));
        }
        Ok(cfg)
    }

    /// The mesh mix a run cycles through: three distinct shard keys off
    /// the base `(n, nelt)`, so routing and caching both get exercised.
    pub fn meshes(&self) -> [(usize, usize); 3] {
        [(self.n, self.nelt), (self.n + 1, self.nelt), (self.n, self.nelt * 2)]
    }
}

/// What one run measured.
pub struct LoadgenReport {
    pub clients: usize,
    pub requests_per_client: usize,
    pub ok: usize,
    pub overloaded: usize,
    pub errors: usize,
    pub seconds: f64,
    /// Per-request round-trip latencies, milliseconds, unsorted.
    pub latencies_ms: Vec<f64>,
    /// Server-reported queue capacity (from `info`; 0 if unavailable).
    pub queue_capacity: usize,
    /// Server-reported per-shard statistics (empty if `info` failed).
    pub shards: Vec<ShardSnapshot>,
}

impl LoadgenReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.seconds > 0.0 {
            self.ok as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// `p` in 0..=100 over unsorted samples (nearest-rank on a sorted copy).
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct ClientTally {
    ok: usize,
    overloaded: usize,
    errors: usize,
    latencies_ms: Vec<f64>,
}

/// One NDJSON exchange: write the line, read one response line back.
fn exchange(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> Result<Value> {
    writeln!(writer, "{line}")
        .and_then(|()| writer.flush())
        .map_err(|e| Error::Config(format!("loadgen: send failed: {e}")))?;
    let mut resp = String::new();
    let bytes = reader
        .read_line(&mut resp)
        .map_err(|e| Error::Config(format!("loadgen: recv failed: {e}")))?;
    if bytes == 0 {
        return Err(Error::Config("loadgen: server closed the connection".into()));
    }
    parse(resp.trim())
}

fn connect(addr: &str) -> Result<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::Config(format!("loadgen: cannot connect to {addr}: {e}")))?;
    let reader = BufReader::new(
        stream.try_clone().map_err(|e| Error::Config(format!("loadgen: clone: {e}")))?,
    );
    Ok((stream, reader))
}

fn solve_line(id: u64, operator: &str, n: usize, nelt: usize, niter: usize, rhs: &[f64]) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".to_string(), Value::String("solve".into()));
    m.insert("id".to_string(), Value::Number(id as f64));
    m.insert("operator".to_string(), Value::String(operator.to_string()));
    m.insert("n".to_string(), Value::Number(n as f64));
    m.insert("nelt".to_string(), Value::Number(nelt as f64));
    m.insert("niter".to_string(), Value::Number(niter as f64));
    m.insert("rhs".to_string(), Value::Array(rhs.iter().map(|&x| Value::Number(x)).collect()));
    Value::Object(m).dump()
}

fn run_client(cfg: &LoadgenConfig, client: usize) -> Result<ClientTally> {
    let (mut writer, mut reader) = connect(&cfg.addr)?;
    let meshes = cfg.meshes();
    let mut tally =
        ClientTally { ok: 0, overloaded: 0, errors: 0, latencies_ms: Vec::with_capacity(cfg.requests) };
    for req in 0..cfg.requests {
        let (n, nelt) = meshes[(client + req) % meshes.len()];
        let seed = crate::rng::rhs_seed(0xC11E_4700 + client as u64, req as u64);
        let rhs = Rng::new(seed).normal_vec(nelt * n * n * n);
        let id = (client * cfg.requests + req) as u64 + 1;
        let line = solve_line(id, &cfg.operator, n, nelt, cfg.niter, &rhs);
        let t0 = Instant::now();
        let resp = exchange(&mut writer, &mut reader, &line)?;
        tally.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        match resp.get("ok") {
            Some(Value::Bool(true)) => tally.ok += 1,
            _ => {
                if resp.get("error").and_then(Value::as_str) == Some(ERR_OVERLOADED) {
                    tally.overloaded += 1;
                } else {
                    tally.errors += 1;
                }
            }
        }
    }
    Ok(tally)
}

/// Drive the server at `cfg.addr`: `clients` threads x `requests` solves,
/// then one control connection for `info` (and `shutdown` if asked).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(cfg.clients);
    for client in 0..cfg.clients {
        let cfg = cfg.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("nekbone-loadgen-{client}"))
                .spawn(move || run_client(&cfg, client))
                .map_err(|e| Error::Config(format!("loadgen: spawn client: {e}")))?,
        );
    }
    let mut report = LoadgenReport {
        clients: cfg.clients,
        requests_per_client: cfg.requests,
        ok: 0,
        overloaded: 0,
        errors: 0,
        seconds: 0.0,
        latencies_ms: Vec::new(),
        queue_capacity: 0,
        shards: Vec::new(),
    };
    let mut first_err: Option<Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(t)) => {
                report.ok += t.ok;
                report.overloaded += t.overloaded;
                report.errors += t.errors;
                report.latencies_ms.extend(t.latencies_ms);
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err =
                    first_err.or(Some(Error::Config("loadgen: client thread panicked".into())))
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    report.seconds = t0.elapsed().as_secs_f64();

    // Control connection: final statistics, then (optionally) shutdown.
    let (mut writer, mut reader) = connect(&cfg.addr)?;
    let info = exchange(&mut writer, &mut reader, r#"{"op":"info","id":9001}"#)?;
    report.queue_capacity =
        info.get("queue_capacity").and_then(Value::as_usize).unwrap_or(0);
    if let Some(rows) = info.get("shard_stats").and_then(Value::as_array) {
        report.shards = rows.iter().filter_map(ShardSnapshot::from_value).collect();
    }
    if cfg.shutdown {
        let ack = exchange(&mut writer, &mut reader, r#"{"op":"shutdown","id":9002}"#)?;
        if ack.get("draining") != Some(&Value::Bool(true)) {
            return Err(Error::Config("loadgen: shutdown was not acknowledged".into()));
        }
    }
    Ok(report)
}

/// Serialize a report in the `nekbone-serve/1` schema.
pub fn to_json(report: &LoadgenReport) -> String {
    let mut m = BTreeMap::new();
    let mut put = |k: &str, v: Value| {
        m.insert(k.to_string(), v);
    };
    put("schema", Value::String(SCHEMA.into()));
    put("clients", Value::Number(report.clients as f64));
    put("requests", Value::Number((report.clients * report.requests_per_client) as f64));
    put("ok", Value::Number(report.ok as f64));
    put("overloaded", Value::Number(report.overloaded as f64));
    put("errors", Value::Number(report.errors as f64));
    put("seconds", Value::Number(report.seconds));
    put("throughput_rps", Value::Number(report.throughput_rps()));
    let mut lat = BTreeMap::new();
    let mean = if report.latencies_ms.is_empty() {
        0.0
    } else {
        report.latencies_ms.iter().sum::<f64>() / report.latencies_ms.len() as f64
    };
    lat.insert("p50".to_string(), Value::Number(percentile(&report.latencies_ms, 50.0)));
    lat.insert("p99".to_string(), Value::Number(percentile(&report.latencies_ms, 99.0)));
    lat.insert("mean".to_string(), Value::Number(mean));
    lat.insert(
        "max".to_string(),
        Value::Number(report.latencies_ms.iter().cloned().fold(0.0, f64::max)),
    );
    put("latency_ms", Value::Object(lat));
    let mut q = BTreeMap::new();
    q.insert("capacity".to_string(), Value::Number(report.queue_capacity as f64));
    q.insert(
        "max_depth".to_string(),
        Value::Number(report.shards.iter().map(|s| s.max_depth).max().unwrap_or(0) as f64),
    );
    put("queue", Value::Object(q));
    put("shards", Value::Array(report.shards.iter().map(ShardSnapshot::to_value).collect()));
    let mut text = Value::Object(m).dump();
    text.push('\n');
    text
}

/// Validate serialized text against the `nekbone-serve/1` schema (the
/// loadgen validates its own output before writing; CI smoke re-checks).
pub fn validate_json(text: &str) -> Result<()> {
    let doc = parse(text)?;
    let bad = |msg: &str| Error::Config(format!("serve json: {msg}"));
    if doc.get("schema").and_then(Value::as_str) != Some(SCHEMA) {
        return Err(bad(&format!("\"schema\" must be {SCHEMA:?}")));
    }
    for key in ["clients", "requests", "ok", "overloaded", "errors"] {
        doc.get(key).and_then(Value::as_usize).ok_or_else(|| bad(&format!("missing {key}")))?;
    }
    for key in ["seconds", "throughput_rps"] {
        doc.get(key).and_then(Value::as_f64).ok_or_else(|| bad(&format!("missing {key}")))?;
    }
    let lat = doc.get("latency_ms").ok_or_else(|| bad("missing latency_ms"))?;
    for key in ["p50", "p99", "mean", "max"] {
        lat.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| bad(&format!("missing latency_ms.{key}")))?;
    }
    let q = doc.get("queue").ok_or_else(|| bad("missing queue"))?;
    for key in ["capacity", "max_depth"] {
        q.get(key).and_then(Value::as_usize).ok_or_else(|| bad(&format!("missing queue.{key}")))?;
    }
    let shards =
        doc.get("shards").and_then(Value::as_array).ok_or_else(|| bad("missing shards"))?;
    for row in shards {
        ShardSnapshot::from_value(row).ok_or_else(|| bad("malformed shard row"))?;
    }
    let total = doc.get("requests").and_then(Value::as_usize).unwrap_or(0);
    let accounted = ["ok", "overloaded", "errors"]
        .iter()
        .map(|k| doc.get(k).and_then(Value::as_usize).unwrap_or(0))
        .sum::<usize>();
    if accounted != total {
        return Err(bad(&format!("ok+overloaded+errors = {accounted}, requests = {total}")));
    }
    Ok(())
}

/// Write a report to `path` (schema-validated round trip).
pub fn write_json(report: &LoadgenReport, path: &str) -> Result<()> {
    let text = to_json(report);
    validate_json(&text)?;
    std::fs::write(path, &text).map_err(|source| Error::Io { path: path.to_string(), source })
}

/// Human-readable one-screen summary for the CLI.
pub fn render_summary(report: &LoadgenReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "loadgen: {} clients x {} requests in {:.3}s  ({:.1} solves/s)\n",
        report.clients,
        report.requests_per_client,
        report.seconds,
        report.throughput_rps()
    ));
    out.push_str(&format!(
        "  ok {}  overloaded {}  errors {}\n",
        report.ok, report.overloaded, report.errors
    ));
    out.push_str(&format!(
        "  latency ms: p50 {:.3}  p99 {:.3}  max {:.3}\n",
        percentile(&report.latencies_ms, 50.0),
        percentile(&report.latencies_ms, 99.0),
        report.latencies_ms.iter().cloned().fold(0.0, f64::max)
    ));
    for s in &report.shards {
        out.push_str(&format!(
            "  shard {}: {} reqs, {} batches, cache {}/{} hit/miss, {} keys, peak depth {}\n",
            s.shard, s.requests, s.batches, s.cache_hits, s.cache_misses, s.keys, s.max_depth
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> LoadgenReport {
        LoadgenReport {
            clients: 2,
            requests_per_client: 4,
            ok: 7,
            overloaded: 1,
            errors: 0,
            seconds: 0.25,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
            queue_capacity: 64,
            shards: vec![ShardSnapshot {
                shard: 0,
                requests: 8,
                batches: 3,
                cache_hits: 5,
                cache_misses: 3,
                keys: 3,
                overloaded: 1,
                max_depth: 4,
            }],
        }
    }

    #[test]
    fn report_json_round_trips_and_validates() {
        let text = to_json(&sample_report());
        validate_json(&text).unwrap();
        let doc = parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(doc.get("requests").unwrap().as_usize(), Some(8));
        assert_eq!(doc.get("ok").unwrap().as_usize(), Some(7));
        let row = &doc.get("shards").unwrap().as_array().unwrap()[0];
        assert_eq!(row.get("cache_misses").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn validation_rejects_drifted_schemas() {
        let good = to_json(&sample_report());
        // Tampering with any required field must fail validation.
        for (from, to) in [
            (r#""schema":"nekbone-serve/1""#, r#""schema":"nekbone-serve/2""#),
            (r#""p99":"#, r#""p98":"#),
            (r#""capacity":"#, r#""cap":"#),
            (r#""ok":7"#, r#""ok":5"#), // breaks the ok+overloaded+errors sum
        ] {
            let bad = good.replace(from, to);
            assert_ne!(bad, good, "tamper pattern {from:?} did not apply");
            assert!(validate_json(&bad).is_err(), "tamper {from:?} -> {to:?} passed");
        }
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn summary_mentions_the_headline_numbers() {
        let s = render_summary(&sample_report());
        assert!(s.contains("2 clients x 4 requests"));
        assert!(s.contains("ok 7"));
        assert!(s.contains("shard 0"));
    }

    #[test]
    fn mesh_mix_has_three_distinct_keys() {
        let cfg = LoadgenConfig {
            addr: String::new(),
            clients: 1,
            requests: 1,
            operator: "cpu-layered".into(),
            n: 4,
            nelt: 8,
            niter: 10,
            bench_json: None,
            shutdown: false,
        };
        let m = cfg.meshes();
        assert_ne!(m[0], m[1]);
        assert_ne!(m[0], m[2]);
        assert_ne!(m[1], m[2]);
    }
}
