//! Element-count factorization: pick a near-cubic `ex x ey x ez` grid for a
//! requested element count (what Nekbone's setup does from `nelt`).

use crate::error::{Error, Result};

/// Factor `nelt` into `(ex, ey, ez)` with `ex*ey*ez == nelt`, minimizing the
/// surface-to-volume ratio (ties broken toward `ex >= ey >= ez`).
///
/// The mesh surface area in element faces is
/// `2 (ex ey + ey ez + ez ex)`; minimizing it gives the most compact box and
/// hence the fewest shared dofs — the same objective as MPI rank placement
/// in the real code.
pub fn box_dims(nelt: usize) -> Result<(usize, usize, usize)> {
    if nelt == 0 {
        return Err(Error::Config("nelt must be positive".into()));
    }
    let mut best: Option<(usize, usize, usize)> = None;
    let mut best_surface = usize::MAX;
    // ez <= ey <= ex, so ez <= cbrt(nelt).
    let mut ez = 1;
    while ez * ez * ez <= nelt {
        if nelt % ez == 0 {
            let rest = nelt / ez;
            let mut ey = ez;
            while ey * ey <= rest {
                if rest % ey == 0 {
                    let ex = rest / ey;
                    let surface = ex * ey + ey * ez + ez * ex;
                    if surface < best_surface {
                        best_surface = surface;
                        best = Some((ex, ey, ez));
                    }
                }
                ey += 1;
            }
        }
        ez += 1;
    }
    best.ok_or_else(|| Error::Config(format!("cannot factor nelt={nelt}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_products() {
        for nelt in 1..=512 {
            let (ex, ey, ez) = box_dims(nelt).unwrap();
            assert_eq!(ex * ey * ez, nelt, "nelt={nelt}");
            assert!(ex >= ey && ey >= ez);
        }
    }

    #[test]
    fn cubes_become_cubes() {
        assert_eq!(box_dims(64).unwrap(), (4, 4, 4));
        assert_eq!(box_dims(512).unwrap(), (8, 8, 8));
        assert_eq!(box_dims(4096).unwrap(), (16, 16, 16));
    }

    #[test]
    fn paper_sweep_sizes() {
        // The paper's element counts must all decompose reasonably.
        for nelt in [64, 128, 256, 448, 512, 896, 1024, 1792, 2048, 3584, 4096] {
            let (ex, ey, ez) = box_dims(nelt).unwrap();
            assert_eq!(ex * ey * ez, nelt);
            // Not absurdly elongated: aspect ratio below 8 for these counts.
            assert!(ex / ez <= 8, "nelt={nelt} -> {ex}x{ey}x{ez}");
        }
    }

    #[test]
    fn primes_degenerate_gracefully() {
        assert_eq!(box_dims(7).unwrap(), (7, 1, 1));
        assert_eq!(box_dims(1).unwrap(), (1, 1, 1));
    }

    #[test]
    fn zero_rejected() {
        assert!(box_dims(0).is_err());
    }
}
