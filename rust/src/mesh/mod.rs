//! Structured spectral-element mesh of the cubic domain (Nekbone's proxy
//! setup: `genbox` + global numbering + boundary masks).
//!
//! The domain `[0,1]^3` is split into `ex x ey x ez` hexahedral elements,
//! each carrying `n^3` GLL points. Neighboring elements share the points on
//! their common face/edge/corner; the *global* point grid therefore has
//! `(ex(n-1)+1) x (ey(n-1)+1) x (ez(n-1)+1)` distinct points, and the
//! local→global map drives the gather–scatter (`crate::gs`).
//!
//! Local storage convention matches the kernels: a local field is
//! `f64[nelt][n][n][n]` flattened row-major with axes `(e, k, j, i)` where
//! `i` runs along x, `j` along y, `k` along z.

mod decompose;

pub use decompose::box_dims;

use crate::error::{Error, Result};

/// A structured box mesh.
#[derive(Clone, Debug)]
pub struct Mesh {
    /// GLL points per dimension per element.
    pub n: usize,
    /// Elements along x, y, z.
    pub ex: usize,
    pub ey: usize,
    pub ez: usize,
    /// Global point-grid dimensions.
    pub gx: usize,
    pub gy: usize,
    pub gz: usize,
}

impl Mesh {
    /// Mesh with an explicit element grid.
    pub fn new(ex: usize, ey: usize, ez: usize, n: usize) -> Result<Self> {
        if ex == 0 || ey == 0 || ez == 0 {
            return Err(Error::Config(format!(
                "element grid must be non-empty, got {ex}x{ey}x{ez}"
            )));
        }
        if n < 2 {
            return Err(Error::Config(format!("mesh needs n >= 2 GLL points, got {n}")));
        }
        Ok(Mesh {
            n,
            ex,
            ey,
            ez,
            gx: ex * (n - 1) + 1,
            gy: ey * (n - 1) + 1,
            gz: ez * (n - 1) + 1,
        })
    }

    /// Near-cubic mesh with exactly `nelt` elements (Nekbone picks the
    /// element grid automatically from the requested element count).
    pub fn for_nelt(nelt: usize, n: usize) -> Result<Self> {
        let (ex, ey, ez) = box_dims(nelt)?;
        Mesh::new(ex, ey, ez, n)
    }

    /// Total number of elements.
    pub fn nelt(&self) -> usize {
        self.ex * self.ey * self.ez
    }

    /// Local degrees of freedom (with duplicates): `nelt * n^3`.
    pub fn ndof_local(&self) -> usize {
        self.nelt() * self.n * self.n * self.n
    }

    /// Distinct global points.
    pub fn ndof_global(&self) -> usize {
        self.gx * self.gy * self.gz
    }

    /// Element index from its (x, y, z) position in the element grid.
    #[inline]
    pub fn elem_id(&self, ei: usize, ej: usize, ek: usize) -> usize {
        (ek * self.ey + ej) * self.ex + ei
    }

    /// Inverse of [`elem_id`].
    #[inline]
    pub fn elem_pos(&self, e: usize) -> (usize, usize, usize) {
        let ei = e % self.ex;
        let ej = (e / self.ex) % self.ey;
        let ek = e / (self.ex * self.ey);
        (ei, ej, ek)
    }

    /// Flat local index of point `(i, j, k)` in element `e`.
    #[inline]
    pub fn local_id(&self, e: usize, k: usize, j: usize, i: usize) -> usize {
        ((e * self.n + k) * self.n + j) * self.n + i
    }

    /// Global point id of local point `(i, j, k)` in element `e`.
    #[inline]
    pub fn global_id(&self, e: usize, k: usize, j: usize, i: usize) -> usize {
        let (ei, ej, ek) = self.elem_pos(e);
        let px = ei * (self.n - 1) + i;
        let py = ej * (self.n - 1) + j;
        let pz = ek * (self.n - 1) + k;
        (pz * self.gy + py) * self.gx + px
    }

    /// The full local→global map, one entry per local dof.
    pub fn global_ids(&self) -> Vec<usize> {
        let n = self.n;
        let mut ids = Vec::with_capacity(self.ndof_local());
        for e in 0..self.nelt() {
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        ids.push(self.global_id(e, k, j, i));
                    }
                }
            }
        }
        ids
    }

    /// Multiplicity of every *local* dof: how many local copies its global
    /// point has (1 interior, 2 on faces, 4 on edges, 8 on corners of the
    /// element grid).
    pub fn multiplicity(&self) -> Vec<f64> {
        let mut count = vec![0u32; self.ndof_global()];
        let ids = self.global_ids();
        for &g in &ids {
            count[g] += 1;
        }
        ids.iter().map(|&g| count[g] as f64).collect()
    }

    /// Nekbone's `c` vector: inverse multiplicity, used to weight the CG
    /// inner products so each global dof counts once.
    pub fn inv_multiplicity(&self) -> Vec<f64> {
        self.multiplicity().iter().map(|&m| 1.0 / m).collect()
    }

    /// Homogeneous-Dirichlet mask: 0.0 at every local dof on the domain
    /// boundary, 1.0 elsewhere.
    pub fn boundary_mask(&self) -> Vec<f64> {
        let n = self.n;
        let mut mask = Vec::with_capacity(self.ndof_local());
        for e in 0..self.nelt() {
            let (ei, ej, ek) = self.elem_pos(e);
            for k in 0..n {
                let bz = (ek == 0 && k == 0) || (ek == self.ez - 1 && k == n - 1);
                for j in 0..n {
                    let by = (ej == 0 && j == 0) || (ej == self.ey - 1 && j == n - 1);
                    for i in 0..n {
                        let bx = (ei == 0 && i == 0) || (ei == self.ex - 1 && i == n - 1);
                        mask.push(if bx || by || bz { 0.0 } else { 1.0 });
                    }
                }
            }
        }
        mask
    }

    /// Physical extent of element `e` in the unit cube:
    /// `([x0, y0, z0], [x1, y1, z1])`.
    pub fn element_bounds(&self, e: usize) -> ([f64; 3], [f64; 3]) {
        let (ei, ej, ek) = self.elem_pos(e);
        let hx = 1.0 / self.ex as f64;
        let hy = 1.0 / self.ey as f64;
        let hz = 1.0 / self.ez as f64;
        (
            [ei as f64 * hx, ej as f64 * hy, ek as f64 * hz],
            [(ei + 1) as f64 * hx, (ej + 1) as f64 * hy, (ek + 1) as f64 * hz],
        )
    }

    /// Physical coordinates of every local dof, as three local fields
    /// `(x, y, z)` (used by manufactured-solution examples and the general
    /// geometry path).
    pub fn coordinates(&self, gll: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        assert_eq!(gll.len(), self.n, "GLL point count mismatch");
        let n = self.n;
        let ndof = self.ndof_local();
        let (mut xs, mut ys, mut zs) =
            (Vec::with_capacity(ndof), Vec::with_capacity(ndof), Vec::with_capacity(ndof));
        for e in 0..self.nelt() {
            let (lo, hi) = self.element_bounds(e);
            for k in 0..n {
                let z = lo[2] + (gll[k] + 1.0) * 0.5 * (hi[2] - lo[2]);
                for j in 0..n {
                    let y = lo[1] + (gll[j] + 1.0) * 0.5 * (hi[1] - lo[1]);
                    for i in 0..n {
                        let x = lo[0] + (gll[i] + 1.0) * 0.5 * (hi[0] - lo[0]);
                        xs.push(x);
                        ys.push(y);
                        zs.push(z);
                    }
                }
            }
        }
        (xs, ys, zs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let m = Mesh::new(2, 3, 4, 5).unwrap();
        assert_eq!(m.nelt(), 24);
        assert_eq!(m.ndof_local(), 24 * 125);
        assert_eq!(m.ndof_global(), 9 * 13 * 17);
    }

    #[test]
    fn rejects_degenerate() {
        assert!(Mesh::new(0, 1, 1, 5).is_err());
        assert!(Mesh::new(1, 1, 1, 1).is_err());
    }

    #[test]
    fn elem_id_roundtrip() {
        let m = Mesh::new(3, 4, 5, 3).unwrap();
        for e in 0..m.nelt() {
            let (i, j, k) = m.elem_pos(e);
            assert_eq!(m.elem_id(i, j, k), e);
        }
    }

    #[test]
    fn shared_face_points_have_same_global_id() {
        let m = Mesh::new(2, 1, 1, 4).unwrap();
        let n = m.n;
        // right face of element 0 == left face of element 1
        for k in 0..n {
            for j in 0..n {
                assert_eq!(m.global_id(0, k, j, n - 1), m.global_id(1, k, j, 0));
            }
        }
    }

    #[test]
    fn global_ids_cover_grid() {
        let m = Mesh::new(2, 2, 2, 3).unwrap();
        let mut seen = vec![false; m.ndof_global()];
        for &g in &m.global_ids() {
            seen[g] = true;
        }
        assert!(seen.iter().all(|&s| s), "every global point appears locally");
    }

    #[test]
    fn multiplicity_values() {
        let m = Mesh::new(2, 2, 2, 3).unwrap();
        let mult = m.multiplicity();
        // Center of the box is shared by all 8 elements.
        let center = m.local_id(0, 2, 2, 2); // top corner of element 0
        assert_eq!(mult[center], 8.0);
        // Element-interior point belongs to exactly one element.
        let interior = m.local_id(0, 1, 1, 1);
        assert_eq!(mult[interior], 1.0);
    }

    #[test]
    fn inv_multiplicity_sums_to_global_count() {
        // sum of 1/mult over local dofs == number of distinct global dofs
        let m = Mesh::new(3, 2, 2, 4).unwrap();
        let s: f64 = m.inv_multiplicity().iter().sum();
        assert!((s - m.ndof_global() as f64).abs() < 1e-9);
    }

    #[test]
    fn boundary_mask_counts() {
        let m = Mesh::new(2, 2, 2, 3).unwrap();
        let mask = m.boundary_mask();
        let ids = m.global_ids();
        // A global boundary point must be masked in every local copy.
        let (gx, gy, gz) = (m.gx, m.gy, m.gz);
        for (l, &g) in ids.iter().enumerate() {
            let px = g % gx;
            let py = (g / gx) % gy;
            let pz = g / (gx * gy);
            let boundary = px == 0 || px == gx - 1 || py == 0 || py == gy - 1 || pz == 0 || pz == gz - 1;
            assert_eq!(mask[l] == 0.0, boundary, "local {l} global {g}");
        }
    }

    #[test]
    fn coordinates_match_bounds() {
        let m = Mesh::new(2, 1, 1, 3).unwrap();
        let gll = crate::basis::gll_points(3);
        let (xs, ys, zs) = m.coordinates(&gll);
        assert_eq!(xs.len(), m.ndof_local());
        // First element spans x in [0, 0.5]; first point is its corner.
        assert!((xs[0] - 0.0).abs() < 1e-15);
        assert!((ys[0] - 0.0).abs() < 1e-15);
        assert!((zs[0] - 0.0).abs() < 1e-15);
        // Last point of element 1 is the far corner (1, 1, 1).
        let last = m.local_id(1, 2, 2, 2);
        assert!((xs[last] - 1.0).abs() < 1e-15);
        assert!((ys[last] - 1.0).abs() < 1e-15);
        assert!((zs[last] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn for_nelt_produces_exact_count() {
        for nelt in [1, 8, 64, 448, 1024, 3584] {
            let m = Mesh::for_nelt(nelt, 4).unwrap();
            assert_eq!(m.nelt(), nelt, "nelt {nelt}");
        }
    }
}
