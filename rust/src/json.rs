//! Minimal JSON parser + serializer.
//!
//! The offline crate set has no `serde_json`; the artifact manifest
//! (`artifacts/manifest.json`) and the serve layer's wire protocol are the
//! only JSON the crate consumes, so we carry a small recursive-descent
//! parser — objects, arrays, strings (with escapes), numbers, booleans,
//! null — strict enough for our producers (Python's `json.dump`, our own
//! [`Value::dump`]) and rejecting trailing garbage, plus the matching
//! single-line serializer.
//!
//! Serialize→parse round-trips **bitwise** for finite numbers: `dump`
//! prints `f64` with Rust's shortest-round-trip `Display`, and `parse`
//! reads numbers back with `str::parse::<f64>` — the serve layer's
//! conformance suite relies on this to compare served solution vectors
//! against serial solves bit for bit.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Typed accessors (return `None` on kind mismatch).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Serialize to a single line (no trailing newline) that [`parse`]
    /// reads back to an equal `Value` — bitwise-equal for finite numbers
    /// (shortest-round-trip `Display` out, `str::parse::<f64>` back in).
    /// JSON has no NaN/Infinity; non-finite numbers serialize as `null`
    /// (the protocol never produces them from a successful solve).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(x) if !x.is_finite() => out.push_str("null"),
            Value::Number(x) => {
                use std::fmt::Write;
                write!(out, "{x}").expect("write to String cannot fail");
            }
            Value::String(s) => write_json_string(s, out),
            Value::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape and quote a string for JSON output.
fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                write!(out, "\\u{:04x}", c as u32).expect("write to String cannot fail");
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: manifest content is ASCII, but be
                        // correct anyway.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("invalid number {s:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Number(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = parse("[3]").unwrap();
        let n = &v.as_array().unwrap()[0];
        assert_eq!(n.as_u64(), Some(3));
        assert_eq!(n.as_usize(), Some(3));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn dump_round_trips() {
        for doc in [
            "null",
            "true",
            "[1,2.5,-3]",
            r#"{"a":[1,{"b":"c"}],"d":null,"e":false}"#,
            r#""quote \" backslash \\ newline \n tab \t""#,
        ] {
            let v = parse(doc).unwrap();
            assert_eq!(parse(&v.dump()).unwrap(), v, "{doc}");
        }
        // Control characters survive via \u escapes.
        let v = Value::String("bell\u{7}end".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn dump_numbers_round_trip_bitwise() {
        // The serve conformance suite compares echoed solution vectors
        // bit for bit; shortest-round-trip Display guarantees it.
        for x in [0.1 + 0.2, 1.0 / 3.0, -0.0, 1e-300, 6.02214076e23, f64::MIN_POSITIVE] {
            let dumped = Value::Number(x).dump();
            match parse(&dumped).unwrap() {
                Value::Number(y) => assert_eq!(y.to_bits(), x.to_bits(), "{dumped}"),
                other => panic!("{other:?}"),
            }
        }
        // Non-finite numbers have no JSON spelling: they emit null.
        assert_eq!(Value::Number(f64::NAN).dump(), "null");
        assert_eq!(Value::Number(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn dump_is_single_line_with_sorted_keys() {
        let v = parse(r#"{"zeta": 1, "alpha": [true, "x"]}"#).unwrap();
        let dumped = v.dump();
        assert!(!dumped.contains('\n'));
        // BTreeMap ordering makes output deterministic (alpha before zeta).
        assert_eq!(dumped, r#"{"alpha":[true,"x"],"zeta":1}"#);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "format": 1,
          "artifacts": [
            {"name": "ax_layered_n10_e64", "kind": "ax", "n": 10, "chunk": 64,
             "file": "ax_layered_n10_e64.hlo.txt",
             "arg_shapes": [[64,10,10,10],[10,10],[64,6,10,10,10]]}
          ]
        }"#;
        let v = parse(doc).unwrap();
        let a = &v.get("artifacts").unwrap().as_array().unwrap()[0];
        assert_eq!(a.get("n").unwrap().as_usize(), Some(10));
        let shapes = a.get("arg_shapes").unwrap().as_array().unwrap();
        assert_eq!(shapes[0].as_array().unwrap().len(), 4);
    }
}
