//! Measured roofline (paper section V / Fig. 4).
//!
//! The paper measures achievable bandwidth by replacing every load and
//! store of a CG iteration with a `cudaMemcpy` of the same bytes — "exactly
//! double the amount of data movement necessary" — and derives the roofline
//! `P = I(n) * BW(size)`. We do the same with `memcpy` over buffers sized to
//! the problem: 24 D reads + 6 D writes per iteration, copied (each copy is
//! a read + a write, hence the paper's doubling).
//!
//! The *kernel-level* measured-roofline harness (STREAM-triad + peak
//! multiply-add ceilings, per-operator `flops()/bytes_moved()` intensity,
//! `BENCH_roofline.json` emission) lives in [`crate::bench::roofline`];
//! this module stays the solve-level, Eq. (2) methodology of Fig. 4. Keep
//! ceiling-measurement fixes in sync between the two.

use crate::metrics::{CostModel, Measurement};
use crate::metrics::Stopwatch;

/// One point of the measured-bandwidth curve.
#[derive(Clone, Copy, Debug)]
pub struct BandwidthPoint {
    /// Local degrees of freedom of the problem this emulates.
    pub dof: usize,
    /// Sustained copy bandwidth in GB/s, counting bytes-read + bytes-written.
    pub bandwidth_gbs: f64,
}

/// Measure sustained copy bandwidth for the data volume of one CG iteration
/// over `dof` degrees of freedom (24 reads + 6 writes per dof), repeated
/// `iters` times — the `cudaMemcpy` methodology of the paper on the CPU
/// substrate.
pub fn measure_bandwidth(dof: usize, iters: usize) -> BandwidthPoint {
    // One iteration moves 30 dof values; a memcpy of L values moves 2 L
    // (read + write), so copy 15 dof values per emulated iteration.
    let copy_len = (15 * dof).max(1);
    let src = vec![1.0f64; copy_len];
    let mut dst = vec![0.0f64; copy_len];

    // Warmup: fault pages in and warm whatever cache level fits.
    dst.copy_from_slice(&src);

    let sw = Stopwatch::start();
    for _ in 0..iters.max(1) {
        dst.copy_from_slice(&src);
        std::hint::black_box(&mut dst);
    }
    let secs = sw.elapsed_s();
    let bytes = (2 * copy_len * 8 * iters.max(1)) as u64;
    BandwidthPoint { dof, bandwidth_gbs: bytes as f64 / secs / 1e9 }
}

/// The measured roofline for a problem size: achievable GFlop/s given the
/// measured bandwidth and the paper's intensity (Eq. 2).
pub fn roofline_for(n: usize, nelt: usize, iters: usize) -> (BandwidthPoint, f64) {
    let cm = CostModel::new(n, nelt);
    let bw = measure_bandwidth(cm.dof, iters);
    (bw, cm.roofline_gflops(bw.bandwidth_gbs))
}

/// Measured *compute* ceiling: the Ax kernel on a cache-resident problem
/// (nothing leaves L2), in GFlop/s of the paper's per-iteration flop model.
///
/// On the paper's GPUs the memory roof binds (f64 peak ≫ I·BW); on a
/// single CPU core the balance inverts — the scalar/SIMD f64 pipeline is
/// the binding roof — so Fig. 4's fraction must be taken against
/// `min(memory roof, compute ceiling)`. See EXPERIMENTS.md E3.
pub fn measure_compute_ceiling(n: usize, reps: usize) -> f64 {
    let nelt = 2; // ~110 KB working set at n = 10: L2-resident
    let np = n * n * n;
    let d = crate::basis::derivative_matrix(n);
    let mut rng = crate::rng::Rng::new(0xA0);
    let u = rng.normal_vec(nelt * np);
    let g = rng.normal_vec(nelt * 6 * np);
    let mut w = vec![0.0; nelt * np];
    // Warm.
    crate::operators::ax_layered(n, nelt, &u, &d, &g, &mut w);
    let sw = Stopwatch::start();
    for _ in 0..reps.max(1) {
        crate::operators::ax_layered(n, nelt, &u, &d, &g, &mut w);
        std::hint::black_box(&mut w);
    }
    let secs = sw.elapsed_s();
    let flops = crate::operators::ax_flops(n, nelt) * reps.max(1) as u64;
    flops as f64 / secs / 1e9
}

/// Fraction of the measured roofline a measurement achieved.
pub fn roofline_fraction(measured: &Measurement, roofline_gflops: f64) -> f64 {
    measured.gflops() / roofline_gflops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_positive_and_sane() {
        let bp = measure_bandwidth(64 * 1000, 3);
        assert!(bp.bandwidth_gbs > 0.1, "bw {}", bp.bandwidth_gbs);
        assert!(bp.bandwidth_gbs < 10_000.0, "bw {}", bp.bandwidth_gbs);
    }

    #[test]
    fn roofline_scales_with_intensity() {
        // Same bandwidth, higher degree => higher roofline.
        let cm8 = CostModel::new(8, 64);
        let cm12 = CostModel::new(12, 64);
        assert!(cm12.roofline_gflops(100.0) > cm8.roofline_gflops(100.0));
    }

    #[test]
    fn fraction_math() {
        let m = Measurement { seconds: 1.0, flops: 50_000_000_000, bytes: 0 };
        assert!((roofline_fraction(&m, 100.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tiny_dof_does_not_panic() {
        let bp = measure_bandwidth(0, 1);
        assert!(bp.bandwidth_gbs >= 0.0);
    }
}
