//! Scaling-scenario lab: strong/weak-scaling campaigns over the ranked
//! runtime, emitted as `BENCH_scaling.json`.
//!
//! A *scenario* fixes how the problem grows with the rank count:
//!
//! * **strong** — the global element count is fixed; more ranks split the
//!   same problem into smaller bricks (the paper's strong-scaling walls).
//! * **weak** — each rank keeps a fixed local element count; the global
//!   problem grows with the machine (`nelt = elements × ranks`).
//!
//! The campaign sweeps (scenario × degree × element count × decomposition
//! shape × rank count) through [`run_ranked_with`] — the same entry point
//! `nekbone run --ranks` uses, so every measured point is a real
//! distributed solve whose report is bitwise identical to the serial one.
//! Combinations a shape cannot decompose (say, 8 slab ranks on a 2-layer
//! element grid) are counted as `skipped` diagnostics, not errors: the
//! campaign reports the feasible frontier instead of refusing to run.
//!
//! The JSON schema (`nekbone-scaling/1`, documented in `ROADMAP.md`) is
//! append-friendly: each point carries the stable key set (`scenario`,
//! `decomp`, `operator`, `degree`, `ranks`, `elements`) plus the measured
//! `throughput_mdofs` (assembled dofs × iterations / second / 1e6), so
//! successive PRs emit comparable trajectories and CI's trajectory gate
//! can diff fresh quick-mode points against the committed baseline. Run
//! it via `cargo bench --bench scaling` or `nekbone scenarios`.

use crate::bench::Table;
use crate::cli::Args;
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::mesh::Mesh;
use crate::rank::{run_ranked_with, DecompShape};
use crate::serve::{spec_default, spec_usize, OptSpec};

/// Schema identifier written into (and asserted on) every emitted file.
pub const SCHEMA: &str = "nekbone-scaling/1";

/// `nekbone scenarios` options. The help text renders this table and
/// [`ScenarioConfig::from_args`] reads its defaults from the same rows,
/// so the two cannot drift.
pub const SCENARIO_OPTS: &[OptSpec] = &[
    OptSpec {
        key: "backend",
        metavar: "NAME",
        default: "cpu-layered",
        help: "per-rank operator-registry name",
    },
    OptSpec {
        key: "decomps",
        metavar: "LIST",
        default: "slab,pencil,box",
        help: "decomposition shapes to sweep",
    },
    OptSpec { key: "ranks", metavar: "LIST", default: "1,2,4,8", help: "rank counts to sweep" },
    OptSpec {
        key: "elements",
        metavar: "LIST",
        default: "32,64",
        help: "elements: global (strong) / per rank (weak)",
    },
    OptSpec {
        key: "degrees",
        metavar: "LIST",
        default: "5,9",
        help: "GLL points per dim to sweep",
    },
    OptSpec { key: "niter", metavar: "N", default: "30", help: "CG iterations per point" },
    OptSpec {
        key: "block-dofs",
        metavar: "B",
        default: "auto",
        help: "cache-block the CG vector pipeline (auto|off|N)",
    },
    OptSpec {
        key: "json",
        metavar: "PATH",
        default: "",
        help: "write nekbone-scaling/1 JSON to PATH",
    },
    OptSpec { key: "quick", metavar: "", default: "", help: "smoke-test scale (CI)" },
];

/// Campaign configuration.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Per-rank operator-registry name.
    pub operator: String,
    /// Decomposition shapes to sweep.
    pub decomps: Vec<DecompShape>,
    /// Rank counts to sweep.
    pub ranks: Vec<usize>,
    /// Element counts: the global problem size for strong scaling, the
    /// per-rank size for weak scaling.
    pub elements: Vec<usize>,
    /// Degrees (`n`, GLL points per dimension) to sweep.
    pub degrees: Vec<usize>,
    /// CG iterations per point.
    pub niter: usize,
    /// `--block-dofs` value passed through to every point's [`RunConfig`]
    /// (`auto|off|N`): the ranked solves run the cache-blocked vector
    /// pipeline, whose trajectory is bitwise identical to the unblocked
    /// one, so throughput deltas are pure memory traffic.
    pub block_dofs: String,
    /// Write the JSON report here (in addition to the printed table).
    pub json: Option<String>,
}

/// Parse `1,2,4`-style positive-integer lists.
fn parse_list(opt: &str, s: &str) -> Result<Vec<usize>> {
    let vals: Vec<usize> = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|_| Error::Config(format!("bad value {t:?} in --{opt}")))
        })
        .collect::<Result<_>>()?;
    if vals.is_empty() || vals.contains(&0) {
        return Err(Error::Config(format!("--{opt} needs positive values, got {s:?}")));
    }
    Ok(vals)
}

impl ScenarioConfig {
    /// Build from parsed CLI arguments; `--quick` shrinks the sweep to
    /// smoke-test scale (explicit options still win over the quick scale).
    pub fn from_args(args: &Args) -> Result<Self> {
        let quick = args.flag("quick");
        let list = |key: &'static str, quick_dflt: &'static str| -> Result<Vec<usize>> {
            let dflt = if quick { quick_dflt } else { spec_default(SCENARIO_OPTS, key) };
            parse_list(key, args.get(key).unwrap_or(dflt))
        };
        let decomps_raw =
            args.get("decomps").unwrap_or_else(|| spec_default(SCENARIO_OPTS, "decomps"));
        let decomps = decomps_raw
            .split(',')
            .map(|t| DecompShape::parse(t.trim()))
            .collect::<Result<Vec<_>>>()?;
        let niter = if quick && args.get("niter").is_none() {
            8
        } else {
            spec_usize(args, SCENARIO_OPTS, "niter")?
        };
        Ok(ScenarioConfig {
            operator: args
                .get("backend")
                .unwrap_or_else(|| spec_default(SCENARIO_OPTS, "backend"))
                .to_string(),
            decomps,
            ranks: list("ranks", "1,2,4")?,
            elements: list("elements", "8")?,
            degrees: list("degrees", "3")?,
            niter,
            block_dofs: args
                .get("block-dofs")
                .unwrap_or_else(|| spec_default(SCENARIO_OPTS, "block-dofs"))
                .to_string(),
            json: args.get("json").map(str::to_string),
        })
    }

    /// The smoke-test campaign CI runs (also the trajectory-gate grid).
    pub fn quick() -> Self {
        ScenarioConfig {
            operator: "cpu-layered".into(),
            decomps: vec![DecompShape::Slab, DecompShape::Pencil, DecompShape::Box],
            ranks: vec![1, 2, 4],
            elements: vec![8],
            degrees: vec![3],
            niter: 8,
            block_dofs: "auto".into(),
            json: None,
        }
    }
}

/// One measured scaling point.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// `"strong"` or `"weak"`.
    pub scenario: &'static str,
    /// Decomposition shape name.
    pub decomp: &'static str,
    /// Canonical operator-registry name.
    pub operator: String,
    /// GLL points per dimension.
    pub degree: usize,
    /// Simulated MPI ranks.
    pub ranks: usize,
    /// Global element count actually solved (for weak scaling this is
    /// the per-rank count × ranks).
    pub elements: usize,
    /// CG iterations performed.
    pub iterations: usize,
    /// Wall time of the whole ranked solve.
    pub seconds: f64,
    /// Assembled (unique) dofs × iterations / seconds / 1e6.
    pub throughput_mdofs: f64,
}

/// A full campaign: every feasible point plus the infeasible-combination
/// count (a diagnostic, not an error — shapes differ in how far they
/// subdivide a given element grid).
#[derive(Clone, Debug)]
pub struct ScalingReport {
    pub operator: String,
    pub points: Vec<ScalingPoint>,
    pub skipped: usize,
}

/// Run the campaign: every (scenario × degree × elements × shape × ranks)
/// combination through the ranked runtime. Infeasible decompositions are
/// counted as skips; any other failure aborts the campaign.
pub fn run(cfg: &ScenarioConfig) -> Result<ScalingReport> {
    // Fail fast on unknown operators so a typo is an error, not a
    // campaign full of silent skips.
    crate::operators::registry().resolve(&cfg.operator)?;
    // Fail fast on a degenerate --block-dofs (zero, garbage, or larger
    // than even the campaign's biggest point) before spending time on the
    // sweep. Per-point ndof caps below that are feasibility, handled like
    // any other infeasible combination (a skip, not an abort).
    let probe = RunConfig {
        nelt: cfg.elements.iter().copied().max().unwrap_or(1)
            * cfg.ranks.iter().copied().max().unwrap_or(1),
        n: cfg.degrees.iter().copied().max().unwrap_or(3),
        block_dofs: cfg.block_dofs.clone(),
        ..RunConfig::default()
    };
    probe.resolved_block_dofs()?;
    let mut points = Vec::new();
    let mut skipped = 0usize;
    for scenario in ["strong", "weak"] {
        for &degree in &cfg.degrees {
            for &base in &cfg.elements {
                for &shape in &cfg.decomps {
                    for &ranks in &cfg.ranks {
                        let nelt = if scenario == "strong" { base } else { base * ranks };
                        let rc = RunConfig {
                            nelt,
                            n: degree,
                            niter: cfg.niter,
                            ranks,
                            decomp: shape.as_str().into(),
                            block_dofs: cfg.block_dofs.clone(),
                            ..RunConfig::default()
                        };
                        let rep = match run_ranked_with(&rc, &cfg.operator) {
                            Ok(rep) => rep,
                            // The operator resolved above, so a Config
                            // error here is an infeasible decomposition
                            // (axis over-split / ranks > nelt).
                            Err(Error::Config(_)) => {
                                skipped += 1;
                                continue;
                            }
                            Err(e) => return Err(e),
                        };
                        let ndof_global = Mesh::for_nelt(nelt, degree)?.ndof_global();
                        points.push(ScalingPoint {
                            scenario,
                            decomp: shape.as_str(),
                            operator: cfg.operator.clone(),
                            degree,
                            ranks,
                            elements: nelt,
                            iterations: rep.iterations,
                            seconds: rep.seconds,
                            throughput_mdofs: ndof_global as f64 * rep.iterations as f64
                                / rep.seconds
                                / 1e6,
                        });
                    }
                }
            }
        }
    }
    if points.is_empty() {
        return Err(Error::Config(
            "scaling campaign produced no feasible points; loosen --ranks/--decomps".into(),
        ));
    }
    Ok(ScalingReport { operator: cfg.operator.clone(), points, skipped })
}

/// Render the report as the aligned table the bench and CLI print.
pub fn render_table(report: &ScalingReport) -> String {
    let mut table = Table::new(&[
        "scenario",
        "decomp",
        "n",
        "ranks",
        "elems",
        "iters",
        "seconds",
        "Mdof/s",
    ]);
    for p in &report.points {
        table.row(&[
            p.scenario.to_string(),
            p.decomp.to_string(),
            p.degree.to_string(),
            p.ranks.to_string(),
            p.elements.to_string(),
            p.iterations.to_string(),
            format!("{:.4}", p.seconds),
            format!("{:.3}", p.throughput_mdofs),
        ]);
    }
    table.render()
}

/// A JSON number that is always valid JSON (non-finite values, which JSON
/// cannot represent, become 0).
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9}")
    } else {
        "0.0".into()
    }
}

fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Serialize a report in the `nekbone-scaling/1` schema.
pub fn to_json(report: &ScalingReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": {},\n", jstr(SCHEMA)));
    out.push_str(&format!("  \"operator\": {},\n", jstr(&report.operator)));
    out.push_str(&format!("  \"skipped\": {},\n", report.skipped));
    out.push_str("  \"points\": [\n");
    for (i, p) in report.points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": {}, \"decomp\": {}, \"operator\": {}, \
             \"degree\": {}, \"ranks\": {}, \"elements\": {}, \
             \"iterations\": {}, \"seconds\": {}, \"throughput_mdofs\": {}}}{}\n",
            jstr(p.scenario),
            jstr(p.decomp),
            jstr(&p.operator),
            p.degree,
            p.ranks,
            p.elements,
            p.iterations,
            jnum(p.seconds),
            jnum(p.throughput_mdofs),
            if i + 1 < report.points.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Validate a serialized report against the `nekbone-scaling/1` schema
/// (used by the bench after writing, by CI's smoke job, and by the
/// trajectory gate before trusting a committed baseline).
pub fn validate_json(text: &str) -> Result<()> {
    let doc = crate::json::parse(text)?;
    let bad = |msg: &str| Error::Config(format!("scaling json: {msg}"));
    if doc.get("schema").and_then(|v| v.as_str()) != Some(SCHEMA) {
        return Err(bad(&format!("\"schema\" must be {SCHEMA:?}")));
    }
    doc.get("operator").and_then(|v| v.as_str()).ok_or_else(|| bad("missing operator"))?;
    doc.get("skipped").and_then(|v| v.as_usize()).ok_or_else(|| bad("missing skipped"))?;
    let points =
        doc.get("points").and_then(|v| v.as_array()).ok_or_else(|| bad("missing points"))?;
    if points.is_empty() {
        return Err(bad("points must be non-empty"));
    }
    for p in points {
        let scenario =
            p.get("scenario").and_then(|v| v.as_str()).ok_or_else(|| bad("point scenario"))?;
        if scenario != "strong" && scenario != "weak" {
            return Err(bad(&format!("scenario must be strong|weak, got {scenario:?}")));
        }
        let decomp =
            p.get("decomp").and_then(|v| v.as_str()).ok_or_else(|| bad("point decomp"))?;
        DecompShape::parse(decomp).map_err(|_| bad(&format!("bad decomp {decomp:?}")))?;
        p.get("operator").and_then(|v| v.as_str()).ok_or_else(|| bad("point operator"))?;
        for key in ["degree", "ranks", "elements", "iterations"] {
            p.get(key).and_then(|v| v.as_usize()).ok_or_else(|| bad(&format!("point {key}")))?;
        }
        for key in ["seconds", "throughput_mdofs"] {
            p.get(key).and_then(|v| v.as_f64()).ok_or_else(|| bad(&format!("point {key}")))?;
        }
    }
    Ok(())
}

/// Write a report to `path` (schema-validated round trip).
pub fn write_json(report: &ScalingReport, path: &str) -> Result<()> {
    let text = to_json(report);
    validate_json(&text)?;
    std::fs::write(path, &text).map_err(|source| Error::Io { path: path.to_string(), source })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn config_defaults_from_spec_table() {
        let c = ScenarioConfig::from_args(&args(&["scenarios"])).unwrap();
        assert_eq!(c.operator, spec_default(SCENARIO_OPTS, "backend"));
        assert_eq!(c.ranks, vec![1, 2, 4, 8]);
        assert_eq!(c.elements, vec![32, 64]);
        assert_eq!(c.degrees, vec![5, 9]);
        assert_eq!(c.niter.to_string(), spec_default(SCENARIO_OPTS, "niter"));
        assert_eq!(
            c.decomps,
            vec![DecompShape::Slab, DecompShape::Pencil, DecompShape::Box]
        );
        assert_eq!(c.json, None);
        assert_eq!(c.block_dofs, spec_default(SCENARIO_OPTS, "block-dofs"));
    }

    #[test]
    fn block_dofs_passes_through_and_fails_loud() {
        let c = ScenarioConfig::from_args(&args(&["scenarios", "--block-dofs", "off"]))
            .unwrap();
        assert_eq!(c.block_dofs, "off");
        // Degenerate values abort the campaign before the sweep.
        for bad in ["0", "grid", "9999999"] {
            let cfg = ScenarioConfig {
                block_dofs: bad.into(),
                ..ScenarioConfig::quick()
            };
            let err = run(&cfg).unwrap_err().to_string();
            assert!(err.contains("block-dofs"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn blocked_campaign_matches_unblocked_iteration_trajectory() {
        // The blocked vector pipeline is bitwise identical to the flat
        // one, so the two campaigns must agree point-for-point on
        // everything but wall time.
        let flat = run(&ScenarioConfig {
            block_dofs: "off".into(),
            ..ScenarioConfig::quick()
        })
        .unwrap();
        let blocked = run(&ScenarioConfig {
            block_dofs: "64".into(),
            ..ScenarioConfig::quick()
        })
        .unwrap();
        assert_eq!(flat.skipped, blocked.skipped);
        assert_eq!(flat.points.len(), blocked.points.len());
        for (p, q) in flat.points.iter().zip(&blocked.points) {
            assert_eq!(p.iterations, q.iterations, "{p:?} vs {q:?}");
            assert_eq!(
                (p.scenario, p.decomp, p.degree, p.ranks, p.elements),
                (q.scenario, q.decomp, q.degree, q.ranks, q.elements)
            );
        }
    }

    #[test]
    fn quick_flag_shrinks_the_sweep() {
        let q = ScenarioConfig::from_args(&args(&["scenarios", "--quick"])).unwrap();
        let full = ScenarioConfig::from_args(&args(&["scenarios"])).unwrap();
        assert!(q.ranks.len() < full.ranks.len());
        assert!(q.elements[0] < full.elements[0]);
        assert!(q.degrees[0] < full.degrees[0]);
        assert!(q.niter < full.niter);
        // The CLI quick scale is exactly the committed-baseline grid.
        let canned = ScenarioConfig::quick();
        assert_eq!(q.ranks, canned.ranks);
        assert_eq!(q.elements, canned.elements);
        assert_eq!(q.degrees, canned.degrees);
        assert_eq!(q.niter, canned.niter);
        // Explicit options still win over the quick scale.
        let q = ScenarioConfig::from_args(&args(&["scenarios", "--quick", "--niter", "5"]))
            .unwrap();
        assert_eq!(q.niter, 5);
    }

    #[test]
    fn config_rejects_bad_lists() {
        assert!(ScenarioConfig::from_args(&args(&["scenarios", "--ranks", "1,x"])).is_err());
        assert!(ScenarioConfig::from_args(&args(&["scenarios", "--ranks", "0"])).is_err());
        assert!(
            ScenarioConfig::from_args(&args(&["scenarios", "--decomps", "diag"])).is_err()
        );
    }

    #[test]
    fn campaign_covers_the_feasible_grid_and_counts_skips() {
        let report = run(&ScenarioConfig::quick()).unwrap();
        // Both scenarios and at least two shapes must survive on the
        // quick grid; the combinations a shape cannot decompose are
        // counted, not dropped silently.
        assert!(report.points.iter().any(|p| p.scenario == "strong"));
        assert!(report.points.iter().any(|p| p.scenario == "weak"));
        assert!(report.points.iter().any(|p| p.decomp == "pencil"));
        for p in &report.points {
            assert!(p.throughput_mdofs > 0.0 && p.throughput_mdofs.is_finite(), "{p:?}");
            assert!(p.seconds > 0.0, "{p:?}");
            assert!(p.iterations > 0, "{p:?}");
            match p.scenario {
                "strong" => assert_eq!(p.elements, 8, "{p:?}"),
                _ => assert_eq!(p.elements, 8 * p.ranks, "{p:?}"),
            }
        }
        // 2 scenarios × 3 shapes × 3 rank counts × 1 elem × 1 degree.
        assert_eq!(report.points.len() + report.skipped, 18);
        let table = render_table(&report);
        assert!(table.contains("pencil"), "{table}");
    }

    #[test]
    fn unknown_operator_is_an_error_not_a_skip() {
        let cfg = ScenarioConfig { operator: "no-such-op".into(), ..ScenarioConfig::quick() };
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("no-such-op"), "{err}");
    }

    #[test]
    fn json_round_trips_schema() {
        let report = run(&ScenarioConfig::quick()).unwrap();
        let text = to_json(&report);
        validate_json(&text).unwrap();
        let doc = crate::json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(doc.get("skipped").unwrap().as_usize().unwrap(), report.skipped);
        let points = doc.get("points").unwrap().as_array().unwrap();
        assert_eq!(points.len(), report.points.len());
        assert_eq!(
            points[0].get("scenario").unwrap().as_str().unwrap(),
            report.points[0].scenario
        );
        assert_eq!(
            points[0].get("ranks").unwrap().as_usize().unwrap(),
            report.points[0].ranks
        );
    }

    #[test]
    fn validation_rejects_missing_and_malformed() {
        assert!(validate_json("{}").is_err());
        assert!(validate_json("not json").is_err());
        let no_points = format!(
            "{{\"schema\": \"{SCHEMA}\", \"operator\": \"x\", \"skipped\": 0, \
             \"points\": []}}"
        );
        assert!(validate_json(&no_points).is_err());
        let bad_scenario = format!(
            "{{\"schema\": \"{SCHEMA}\", \"operator\": \"x\", \"skipped\": 0, \
             \"points\": [{{\"scenario\": \"diagonal\", \"decomp\": \"slab\", \
             \"operator\": \"x\", \"degree\": 3, \"ranks\": 1, \"elements\": 8, \
             \"iterations\": 8, \"seconds\": 0.1, \"throughput_mdofs\": 1.0}}]}}"
        );
        assert!(validate_json(&bad_scenario).is_err());
        let bad_decomp = bad_scenario.replace("diagonal", "strong").replace(
            "\"decomp\": \"slab\"",
            "\"decomp\": \"diag\"",
        );
        assert!(validate_json(&bad_decomp).is_err());
        let good = bad_scenario.replace("diagonal", "strong");
        validate_json(&good).unwrap();
    }
}
