//! Run reports: what a Nekbone run measured.

use crate::metrics::CostModel;

/// Outcome and measurements of one Nekbone run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Backend label.
    pub backend: String,
    /// Elements, GLL points per dim, iterations executed.
    pub nelt: usize,
    pub n: usize,
    pub iterations: usize,
    /// c-weighted residual norm at exit.
    pub final_residual: f64,
    /// End-to-end solve wall time (seconds), excluding setup.
    pub seconds: f64,
    /// Wall time inside the local Ax (accumulated around the backend call).
    pub ax_seconds: f64,
    /// Flops by the paper's cost model: `iterations * D (12n + 34)`.
    pub flops: u64,
    /// Did the operator fuse the pap reduction into Ax? Kernel-level
    /// accounting ([`RunReport::ax_gflops`]) must then count the in-kernel
    /// multiply-adds, matching the operator's own `flops()` hook.
    pub fused: bool,
    /// Residual history if recorded.
    pub rnorms: Vec<f64>,
}

impl RunReport {
    /// Paper-model GFlop/s of the whole CG solve.
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.seconds / 1e9
    }

    /// GFlop/s attributing only the in-kernel flops to the Ax time
    /// (kernel-level number, comparable to Świrydowicz et al.). Fused
    /// operators count the in-kernel pap reduction too — the same
    /// per-apply count the operator's `flops()` hook reports.
    pub fn ax_gflops(&self) -> f64 {
        let per_apply = if self.fused {
            crate::operators::fused_ax_flops(self.n, self.nelt)
        } else {
            crate::operators::ax_flops(self.n, self.nelt)
        };
        let ax_flops = per_apply * self.iterations as u64;
        ax_flops as f64 / self.ax_seconds / 1e9
    }

    /// The cost model used for the accounting.
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.n, self.nelt)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<22} nelt={:<5} n={:<3} iters={:<4} time={:>8.3}s  {:>8.2} GFlop/s  |r|={:.3e}",
            self.backend,
            self.nelt,
            self.n,
            self.iterations,
            self.seconds,
            self.gflops(),
            self.final_residual
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RunReport {
        RunReport {
            backend: "cpu-layered".into(),
            nelt: 64,
            n: 10,
            iterations: 100,
            final_residual: 1e-6,
            seconds: 2.0,
            ax_seconds: 1.5,
            flops: 64 * 1000 * 154 * 100,
            fused: false,
            rnorms: vec![],
        }
    }

    #[test]
    fn gflops_math() {
        let r = report();
        let want = (64_000.0 * 154.0 * 100.0) / 2.0 / 1e9;
        assert!((r.gflops() - want).abs() < 1e-12);
    }

    #[test]
    fn fused_reports_count_in_kernel_pap_flops() {
        let plain = report();
        let fused = RunReport { fused: true, ..report() };
        // Same shape and timing: the fused kernel did strictly more work
        // per apply (the in-kernel pap multiply-adds), by exactly the
        // 3-flops-per-point ratio.
        let ratio = fused.ax_gflops() / plain.ax_gflops();
        let want = (12.0 * 10.0 + 18.0) / (12.0 * 10.0 + 15.0);
        assert!((ratio - want).abs() < 1e-12, "ratio {ratio} want {want}");
    }

    #[test]
    fn summary_contains_fields() {
        let s = report().summary();
        assert!(s.contains("cpu-layered"));
        assert!(s.contains("nelt=64"));
    }
}
