//! Backend selection: which implementation computes the local Ax, and
//! which computes the CG vector algebra.

use crate::error::{Error, Result};

/// Where the tensor-product operator runs.
///
/// The five `Xla` variants are the paper's five GPU versions (section IV);
/// the CPU variants provide the Fig. 3 CPU baseline and the parity oracle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Listing-1 structure with full-size intermediates, single thread.
    CpuNaive,
    /// The paper's layered schedule on one CPU thread.
    CpuLayered,
    /// Layered schedule across all cores (the paper's CPU/MPI baseline).
    CpuThreaded,
    /// An AOT-compiled kernel variant run via PJRT:
    /// "jnp" (OpenACC analog), "original", "shared", "layered" (the paper's
    /// contribution), "layered_unroll2" (CUDA-Fortran analog).
    Xla(String),
    /// The fused Ax+pap executable (perf-pass hot path; layered schedule).
    XlaFused(String),
}

impl Backend {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "cpu-naive" => Ok(Backend::CpuNaive),
            "cpu-layered" => Ok(Backend::CpuLayered),
            "cpu-threaded" => Ok(Backend::CpuThreaded),
            "xla-jnp" | "xla-openacc" => Ok(Backend::Xla("jnp".into())),
            "xla-original" => Ok(Backend::Xla("original".into())),
            "xla-shared" => Ok(Backend::Xla("shared".into())),
            "xla-layered" => Ok(Backend::Xla("layered".into())),
            "xla-layered-unroll2" => Ok(Backend::Xla("layered_unroll2".into())),
            "xla-fused" => Ok(Backend::XlaFused("layered".into())),
            other => Err(Error::Config(format!(
                "unknown backend {other:?}; expected one of cpu-naive, cpu-layered, \
                 cpu-threaded, xla-jnp, xla-original, xla-shared, xla-layered, \
                 xla-layered-unroll2, xla-fused"
            ))),
        }
    }

    /// Does this backend need the PJRT runtime + artifacts?
    pub fn needs_artifacts(&self) -> bool {
        matches!(self, Backend::Xla(_) | Backend::XlaFused(_))
    }

    /// Stable display name (used in bench tables).
    pub fn label(&self) -> String {
        match self {
            Backend::CpuNaive => "cpu-naive".into(),
            Backend::CpuLayered => "cpu-layered".into(),
            Backend::CpuThreaded => "cpu-threaded".into(),
            Backend::Xla(v) => format!("xla-{}", v.replace('_', "-")),
            Backend::XlaFused(v) => format!("xla-fused-{}", v.replace('_', "-")),
        }
    }
}

/// Where the CG vector algebra runs (experiment E6: the paper's
/// "OpenACC for simple operations costs a few percent" ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum VectorBackend {
    /// Native Rust loops (default; the role OpenACC plays in the paper).
    #[default]
    Rust,
    /// Chunked XLA vector-op executables.
    Xla,
}

impl VectorBackend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rust" => Ok(VectorBackend::Rust),
            "xla" => Ok(VectorBackend::Xla),
            other => Err(Error::Config(format!(
                "unknown vector backend {other:?}; expected rust or xla"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for name in [
            "cpu-naive",
            "cpu-layered",
            "cpu-threaded",
            "xla-jnp",
            "xla-original",
            "xla-shared",
            "xla-layered",
            "xla-layered-unroll2",
            "xla-fused",
        ] {
            let b = Backend::parse(name).unwrap();
            if name != "xla-fused" {
                assert_eq!(b.label(), name.replace("openacc", "jnp"));
            }
        }
        assert!(Backend::parse("cuda").is_err());
    }

    #[test]
    fn artifact_need() {
        assert!(!Backend::CpuLayered.needs_artifacts());
        assert!(Backend::Xla("layered".into()).needs_artifacts());
        assert!(Backend::XlaFused("layered".into()).needs_artifacts());
    }

    #[test]
    fn vector_backend_parse() {
        assert_eq!(VectorBackend::parse("rust").unwrap(), VectorBackend::Rust);
        assert_eq!(VectorBackend::parse("xla").unwrap(), VectorBackend::Xla);
        assert!(VectorBackend::parse("acc").is_err());
    }
}
