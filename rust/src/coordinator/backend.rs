//! Vector-backend selection for the CG algebra.
//!
//! Operator ("backend") selection has no type of its own anymore: an
//! operator is a **registry name**, validated by
//! [`OperatorRegistry::resolve`](crate::operators::OperatorRegistry::resolve)
//! and carried as the canonical `String` it returns. The legacy `Backend`
//! wrapper (a parsed-name shim predating the registry) was folded into the
//! registry path so the crate has exactly one dispatch surface — the CLI,
//! the builder, the rank runtime, and the benches all resolve names
//! directly.

use crate::error::Result;

/// Where the CG vector algebra runs (experiment E6: the paper's
/// "OpenACC for simple operations costs a few percent" ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum VectorBackend {
    /// Native Rust loops (default; the role OpenACC plays in the paper).
    #[default]
    Rust,
    /// Chunked XLA vector-op executables.
    Xla,
}

impl VectorBackend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rust" => Ok(VectorBackend::Rust),
            "xla" => Ok(VectorBackend::Xla),
            other => Err(crate::error::Error::Config(format!(
                "unknown vector backend {other:?}; expected rust or xla"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::OperatorRegistry;

    #[test]
    fn registry_is_the_backend_parser() {
        // What Backend::parse used to guarantee, stated against the
        // registry directly: canonical names resolve to themselves,
        // aliases resolve to re-resolvable canonical names, unknown names
        // error listing the options.
        let reg = OperatorRegistry::with_builtins();
        for name in reg.names() {
            assert_eq!(reg.resolve(&name).unwrap().name, name);
        }
        for alias in ["xla-openacc", "xla-fused"] {
            let canonical = reg.resolve(alias).unwrap().name.clone();
            assert_ne!(canonical, alias, "alias must resolve to canonical");
            assert_eq!(reg.resolve(&canonical).unwrap().name, canonical);
        }
        // The historical asymmetry stays fixed: "xla-fused" resolves to
        // the canonical "xla-fused-layered", which resolves to itself.
        assert_eq!(reg.resolve("xla-fused").unwrap().name, "xla-fused-layered");
        assert!(reg.resolve("cuda").is_err());
    }

    #[test]
    fn artifact_need_comes_from_the_spec() {
        let reg = OperatorRegistry::with_builtins();
        assert!(!reg.resolve("cpu-layered").unwrap().needs_artifacts);
        assert!(reg.resolve("xla-layered").unwrap().needs_artifacts);
        assert!(reg.resolve("xla-fused").unwrap().needs_artifacts);
    }

    #[test]
    fn unknown_backend_error_lists_options() {
        let err = OperatorRegistry::with_builtins().resolve("cuda").unwrap_err().to_string();
        assert!(err.contains("cpu-layered"), "{err}");
        assert!(err.contains("xla-layered"), "{err}");
    }

    #[test]
    fn vector_backend_parse() {
        assert_eq!(VectorBackend::parse("rust").unwrap(), VectorBackend::Rust);
        assert_eq!(VectorBackend::parse("xla").unwrap(), VectorBackend::Xla);
        assert!(VectorBackend::parse("acc").is_err());
    }
}
