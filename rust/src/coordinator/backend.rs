//! Backend selection: which implementation computes the local Ax, and
//! which computes the CG vector algebra.
//!
//! [`Backend`] is a validated operator name — parsing is a lookup in the
//! [`OperatorRegistry`](crate::operators::OperatorRegistry), not a `match`,
//! so registered variants (including aliases like `xla-openacc` and
//! `xla-fused`) resolve here without this module knowing about them.

use crate::error::Result;
use crate::operators::OperatorRegistry;

/// A validated, canonical operator name. `label()` always round-trips
/// through `parse` back to the same backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Backend {
    name: String,
    needs_artifacts: bool,
}

impl Backend {
    /// Parse a CLI name against the built-in registry. Aliases resolve to
    /// their canonical entry; unknown names error with the full list.
    pub fn parse(s: &str) -> Result<Self> {
        Self::parse_with(s, &OperatorRegistry::with_builtins())
    }

    /// Parse against a caller-supplied registry (custom operators).
    pub fn parse_with(s: &str, registry: &OperatorRegistry) -> Result<Self> {
        let spec = registry.resolve(s)?;
        Ok(Backend { name: spec.name.clone(), needs_artifacts: spec.needs_artifacts })
    }

    /// Does this backend need the PJRT runtime + artifacts?
    pub fn needs_artifacts(&self) -> bool {
        self.needs_artifacts
    }

    /// Canonical registry name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stable display name (used in bench tables). Identical to the
    /// canonical registry name, so it is always re-parseable.
    pub fn label(&self) -> String {
        self.name.clone()
    }
}

/// Where the CG vector algebra runs (experiment E6: the paper's
/// "OpenACC for simple operations costs a few percent" ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum VectorBackend {
    /// Native Rust loops (default; the role OpenACC plays in the paper).
    #[default]
    Rust,
    /// Chunked XLA vector-op executables.
    Xla,
}

impl VectorBackend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rust" => Ok(VectorBackend::Rust),
            "xla" => Ok(VectorBackend::Xla),
            other => Err(crate::error::Error::Config(format!(
                "unknown vector backend {other:?}; expected rust or xla"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        // Every canonical name labels as itself, and every label (canonical
        // or produced from an alias) re-parses to an equal backend.
        let reg = OperatorRegistry::with_builtins();
        for name in reg.names() {
            let b = Backend::parse(&name).unwrap();
            assert_eq!(b.label(), name, "canonical name must round-trip");
            assert_eq!(Backend::parse(&b.label()).unwrap(), b);
        }
        for alias in ["xla-openacc", "xla-fused"] {
            let b = Backend::parse(alias).unwrap();
            assert_ne!(b.label(), alias, "alias must resolve to canonical");
            assert_eq!(Backend::parse(&b.label()).unwrap(), b);
        }
        // The historical asymmetry: "xla-fused" labels as the canonical
        // "xla-fused-layered", which parses back to the same backend.
        assert_eq!(Backend::parse("xla-fused").unwrap().label(), "xla-fused-layered");
        assert!(Backend::parse("cuda").is_err());
    }

    #[test]
    fn artifact_need() {
        assert!(!Backend::parse("cpu-layered").unwrap().needs_artifacts());
        assert!(Backend::parse("xla-layered").unwrap().needs_artifacts());
        assert!(Backend::parse("xla-fused").unwrap().needs_artifacts());
    }

    #[test]
    fn unknown_backend_error_lists_options() {
        let err = Backend::parse("cuda").unwrap_err().to_string();
        assert!(err.contains("cpu-layered"), "{err}");
        assert!(err.contains("xla-layered"), "{err}");
    }

    #[test]
    fn vector_backend_parse() {
        assert_eq!(VectorBackend::parse("rust").unwrap(), VectorBackend::Rust);
        assert_eq!(VectorBackend::parse("xla").unwrap(), VectorBackend::Xla);
        assert!(VectorBackend::parse("acc").is_err());
    }
}
