//! Layer-3 coordinator: wires mesh, basis, geometry, gather–scatter, the
//! CG solver, and the selected Ax backend (CPU or AOT-compiled XLA) into
//! the Nekbone application.

mod backend;
mod pipeline;
mod report;

pub use backend::{Backend, VectorBackend};
pub use pipeline::Nekbone;
pub use report::RunReport;
