//! Layer-3 coordinator: wires mesh, basis, geometry, gather–scatter, the
//! CG solver, and the selected Ax operator (resolved by name from the
//! operator registry) into the Nekbone application — plus the multi-RHS
//! [`SolveSession`] serving layer on top.

mod backend;
mod pipeline;
mod report;
mod session;

pub use backend::VectorBackend;
pub use pipeline::{Nekbone, NekboneBuilder};
pub use report::RunReport;
pub use session::{OwnedSession, SolveSession};
