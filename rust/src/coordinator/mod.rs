//! Layer-3 coordinator: wires mesh, basis, geometry, gather–scatter, the
//! CG solver, and the selected Ax operator (resolved by name from the
//! operator registry) into the Nekbone application.

mod backend;
mod pipeline;
mod report;

pub use backend::{Backend, VectorBackend};
pub use pipeline::{Nekbone, NekboneBuilder};
pub use report::RunReport;
