//! The Nekbone application object: setup once, run CG many times.
//!
//! Built through [`NekboneBuilder`]: the operator is resolved by name from
//! an [`OperatorRegistry`] and held as a `Box<dyn AxOperator>` — the
//! application has no knowledge of which implementations exist.

use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::{RunReport, VectorBackend};
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::{AxOperator, OperatorCtx, OperatorRegistry};
use crate::runtime::XlaRuntime;
use crate::solver::{cg_solve, glsc3, mask_apply, AxApply, CgOptions, CgWorkspace};

/// Everything needed to run Nekbone with one operator on one mesh.
pub struct Nekbone {
    pub cfg: RunConfig,
    /// The local Ax, dispatched purely through the trait object.
    op: Box<dyn AxOperator>,
    vector_backend: VectorBackend,
    mesh: Mesh,
    basis: Basis,
    gs: GatherScatter,
    mask: Vec<f64>,
    /// Inverse multiplicity (Nekbone's `c`).
    c: Vec<f64>,
    /// Right-hand side (dssum-consistent, masked).
    f: Vec<f64>,
    ws: CgWorkspace,
}

/// Builder for [`Nekbone`]: pick the operator by registry name, optionally
/// a custom registry and the vector-algebra backend, then `build()`.
///
/// ```no_run
/// use nekbone::config::RunConfig;
/// use nekbone::coordinator::Nekbone;
///
/// let cfg = RunConfig { nelt: 64, n: 10, ..RunConfig::default() };
/// let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
/// let report = app.run().unwrap();
/// ```
pub struct NekboneBuilder {
    cfg: RunConfig,
    operator: String,
    vector_backend: VectorBackend,
    registry: Option<OperatorRegistry>,
}

impl NekboneBuilder {
    /// Select the local-Ax operator by registry name (canonical or alias).
    pub fn operator(mut self, name: impl Into<String>) -> Self {
        self.operator = name.into();
        self
    }

    /// Select where the CG vector algebra runs (default: native Rust).
    pub fn vector_backend(mut self, vb: VectorBackend) -> Self {
        self.vector_backend = vb;
        self
    }

    /// Use a custom operator registry (e.g. with runtime-registered
    /// variants) instead of the built-ins.
    pub fn registry(mut self, registry: OperatorRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Build the application: mesh, basis, geometry, gather–scatter, RHS,
    /// and the operator (set up against this problem's data).
    pub fn build(self) -> Result<Nekbone> {
        let cfg = self.cfg;
        cfg.validate()?;
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
        let basis = Basis::new(cfg.n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let c = mesh.inv_multiplicity();

        // RHS: deterministic pseudo-random field, made dssum-consistent and
        // masked (Nekbone's set-up of `f`).
        let mut rng = crate::rng::Rng::new(cfg.seed);
        let mut f = rng.normal_vec(mesh.ndof_local());
        gs.dssum(&mut f);
        mask_apply(&mut f, &mask);

        let registry = self.registry.unwrap_or_else(OperatorRegistry::with_builtins);
        let ctx = OperatorCtx {
            n: cfg.n,
            nelt: mesh.nelt(),
            chunk: cfg.chunk,
            threads: cfg.cpu_threads,
            artifacts_dir: &cfg.artifacts_dir,
            d: &basis.d,
            g: &geom.g,
            c: &c,
        };
        let op = registry.build(&self.operator, &ctx)?;
        // The operator owns whatever it cloned/uploaded from `geom`; the
        // application itself never needs the geometric factors again.

        let ndof = mesh.ndof_local();
        Ok(Nekbone {
            cfg,
            op,
            vector_backend: self.vector_backend,
            mesh,
            basis,
            gs,
            mask,
            c,
            f,
            ws: CgWorkspace::new(ndof),
        })
    }
}

/// [`AxApply`] adapter that times each operator application and forwards
/// the fused-pap hooks, so one [`cg_solve`] call serves fused and unfused
/// operators alike.
struct TimedAx<'a> {
    op: &'a mut dyn AxOperator,
    seconds: f64,
}

impl AxApply for TimedAx<'_> {
    fn apply(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        let t0 = Instant::now();
        self.op.apply(p, w)?;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn is_fused(&self) -> bool {
        self.op.is_fused()
    }

    fn fused_pap(&self) -> Option<f64> {
        self.op.last_pap()
    }
}

impl Nekbone {
    /// Start building an application for this configuration. The default
    /// operator is `cpu-layered` (always available, no artifacts).
    pub fn builder(cfg: RunConfig) -> NekboneBuilder {
        NekboneBuilder {
            cfg,
            operator: "cpu-layered".into(),
            vector_backend: VectorBackend::default(),
            registry: None,
        }
    }

    /// Convenience: build with a parsed [`Backend`](crate::coordinator::Backend).
    ///
    /// Resolves against the **built-in** registry only; for a backend
    /// validated against a custom registry
    /// ([`Backend::parse_with`](crate::coordinator::Backend::parse_with)),
    /// use the builder and pass the same registry via
    /// [`NekboneBuilder::registry`].
    pub fn new(cfg: RunConfig, backend: crate::coordinator::Backend) -> Result<Self> {
        Self::builder(cfg).operator(backend.name()).build()
    }

    /// The mesh in use.
    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }

    /// The basis in use.
    pub fn basis(&self) -> &Basis {
        &self.basis
    }

    /// The operator's display label (canonical registry name).
    pub fn operator_label(&self) -> String {
        self.op.label()
    }

    /// Replace the right-hand side (e.g. a manufactured solution's load).
    /// The field is made dssum-consistent and masked.
    pub fn set_rhs(&mut self, f: &[f64]) -> Result<()> {
        if f.len() != self.mesh.ndof_local() {
            return Err(Error::Config("set_rhs: length mismatch".into()));
        }
        self.f.copy_from_slice(f);
        self.gs.dssum(&mut self.f);
        mask_apply(&mut self.f, &self.mask);
        Ok(())
    }

    /// Run the configured number of CG iterations; returns the report.
    /// `x_out`, when given, receives the solution field.
    pub fn run_into(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        if self.vector_backend == VectorBackend::Xla {
            return self.run_vector_xla(x_out);
        }
        self.run_rust_vectors(x_out)
    }

    /// The native-Rust vector-algebra CG (the default path), regardless of
    /// the configured vector backend. Fused operators take the same route:
    /// [`cg_solve`] consults the operator's fused-pap hooks (via
    /// [`TimedAx`]) and skips its own pap sweep.
    fn run_rust_vectors(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        let n = self.cfg.n;
        let nelt = self.cfg.nelt;
        let ndof = self.mesh.ndof_local();
        let mut x = vec![0.0; ndof];

        let opts = CgOptions {
            niter: self.cfg.niter,
            rtol: None,
            record_residuals: false,
        };

        let mut ax = TimedAx { op: self.op.as_mut(), seconds: 0.0 };
        let gs_opt = if self.cfg.no_comm { None } else { Some(&mut self.gs) };
        let mask_opt = if self.cfg.no_mask { None } else { Some(self.mask.as_slice()) };

        let sw = Instant::now();
        let rep = cg_solve(
            &mut ax,
            gs_opt,
            mask_opt,
            &self.c,
            &self.f,
            &mut x,
            &opts,
            &mut self.ws,
        )?;
        let seconds = sw.elapsed().as_secs_f64();
        let ax_seconds = ax.seconds;

        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: self.op.label(),
            nelt,
            n,
            iterations: rep.iterations,
            final_residual: rep.final_rnorm,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * rep.iterations as u64,
            rnorms: rep.rnorms,
        })
    }

    /// Convenience: run and discard the solution.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_into(None)
    }

    /// Apply the local operator once (used by parity tests and
    /// kernel-level benches; no dssum, no mask).
    pub fn apply_ax_once(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        self.op.apply(p, w)
    }

    /// Run CG with the vector algebra on the given backend for this run
    /// only (experiment E6's rust-vs-xla comparison), overriding whatever
    /// the builder configured.
    pub fn run_vector_backend(&mut self, vb: VectorBackend) -> Result<RunReport> {
        match vb {
            VectorBackend::Rust => self.run_rust_vectors(None),
            VectorBackend::Xla => self.run_vector_xla(None),
        }
    }

    /// XLA vector path: chunked executables for glsc3 / add2s1 / add2s2,
    /// sharing the operator's PJRT runtime.
    fn run_vector_xla(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        let rt = self.op.xla_runtime().ok_or_else(|| {
            Error::Config("vector-backend xla requires an XLA Ax backend".into())
        })?;
        if self.op.is_fused() {
            return Err(Error::Config(
                "vector-backend xla requires a (non-fused) XLA Ax backend".into(),
            ));
        }
        let size = self.cfg.chunk * self.cfg.n.pow(3);
        let glsc3_e = crate::runtime::VectorEngine::new(&rt, "glsc3", size)?;
        let add2s1_e = crate::runtime::VectorEngine::new(&rt, "add2s1", size)?;
        let add2s2_e = crate::runtime::VectorEngine::new(&rt, "add2s2", size)?;

        let ndof = self.mesh.ndof_local();
        let (n, nelt) = (self.cfg.n, self.cfg.nelt);
        let chunked_glsc3 = |rt: &XlaRuntime, a: &[f64], b: &[f64], c: &[f64]| -> Result<f64> {
            let mut acc = 0.0;
            let mut i = 0;
            while i + size <= a.len() {
                acc += glsc3_e.glsc3(rt, &a[i..i + size], &b[i..i + size], &c[i..i + size])?;
                i += size;
            }
            if i < a.len() {
                acc += glsc3(&a[i..], &b[i..], &c[i..]); // rust tail
            }
            Ok(acc)
        };
        let chunked_axpy = |rt: &XlaRuntime,
                            e: &crate::runtime::VectorEngine,
                            a: &mut [f64],
                            b: &[f64],
                            s: f64,
                            s1: bool|
         -> Result<()> {
            let mut i = 0;
            while i + size <= a.len() {
                e.axpy(rt, &mut a[i..i + size], &b[i..i + size], s)?;
                i += size;
            }
            if i < a.len() {
                if s1 {
                    crate::solver::add2s1(&mut a[i..], &b[i..], s);
                } else {
                    crate::solver::add2s2(&mut a[i..], &b[i..], s);
                }
            }
            Ok(())
        };

        let mut x = vec![0.0; ndof];
        let mut r = self.f.clone();
        mask_apply(&mut r, &self.mask);
        let mut p = vec![0.0; ndof];
        let mut w = vec![0.0; ndof];
        let mut rtz1 = 1.0f64;
        let mut ax_seconds = 0.0;
        let sw = Instant::now();
        let mut iterations = 0;
        for iter in 0..self.cfg.niter {
            let rtz2 = rtz1;
            rtz1 = chunked_glsc3(&rt, &r, &self.c, &r)?;
            let beta = if iter == 0 { 0.0 } else { rtz1 / rtz2 };
            chunked_axpy(&rt, &add2s1_e, &mut p, &r, beta, true)?;
            let t0 = Instant::now();
            self.op.apply(&p, &mut w)?;
            ax_seconds += t0.elapsed().as_secs_f64();
            if !self.cfg.no_comm {
                self.gs.dssum(&mut w);
            }
            mask_apply(&mut w, &self.mask);
            let pap = chunked_glsc3(&rt, &w, &self.c, &p)?;
            if pap <= 0.0 || !pap.is_finite() {
                return Err(Error::Numerical(format!("CG breakdown at iter {iter}: pap {pap}")));
            }
            let alpha = rtz1 / pap;
            chunked_axpy(&rt, &add2s2_e, &mut x, &p, alpha, false)?;
            chunked_axpy(&rt, &add2s2_e, &mut r, &w, -alpha, false)?;
            iterations = iter + 1;
        }
        let seconds = sw.elapsed().as_secs_f64();
        let final_residual = glsc3(&r, &self.c, &r).max(0.0).sqrt();
        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: format!("{}+vec-xla", self.op.label()),
            nelt,
            n,
            iterations,
            final_residual,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * iterations as u64,
            rnorms: vec![],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RunConfig {
        RunConfig { nelt: 8, n: 4, niter: 30, chunk: 64, ..Default::default() }
    }

    fn app(operator: &str, cfg: RunConfig) -> Nekbone {
        Nekbone::builder(cfg).operator(operator).build().unwrap()
    }

    #[test]
    fn cpu_backends_agree() {
        let mut reports = Vec::new();
        let mut xs = Vec::new();
        for name in [
            "cpu-naive",
            "cpu-layered",
            "cpu-threaded",
            "cpu-layered-fused",
            "cpu-threaded-fused",
        ] {
            let mut app = app(name, small_cfg());
            let mut x = vec![0.0; app.mesh().ndof_local()];
            let rep = app.run_into(Some(&mut x)).unwrap();
            assert_eq!(rep.backend, name, "report label must be the registry name");
            reports.push(rep);
            xs.push(x);
        }
        for r in &reports[1..] {
            assert!(
                (r.final_residual - reports[0].final_residual).abs()
                    <= 1e-9 * reports[0].final_residual.abs().max(1e-30),
                "residuals diverge: {} vs {}",
                r.final_residual,
                reports[0].final_residual
            );
        }
        for x in &xs[1..] {
            crate::proputil::assert_allclose(x, &xs[0], 1e-9, 1e-12);
        }
    }

    #[test]
    fn residual_decreases() {
        let cfg = RunConfig { niter: 50, ..small_cfg() };
        let mut app = app("cpu-layered", cfg);
        let rep = app.run().unwrap();
        // The first residual equals |masked f|_c; after 50 iterations on a
        // 512-dof system CG should be well converged.
        let f_norm = glsc3(&app.f, &app.c, &app.f).sqrt();
        assert!(
            rep.final_residual < 1e-6 * f_norm,
            "residual {} vs f {}",
            rep.final_residual,
            f_norm
        );
    }

    #[test]
    fn fused_no_comm_matches_unfused_no_comm() {
        // In no-comm mode the fused pap is consumed with no correction at
        // all; the trajectory must still track the unfused operator.
        let mk = || RunConfig { no_comm: true, ..small_cfg() };
        let a = app("cpu-layered", mk()).run().unwrap();
        let b = app("cpu-layered-fused", mk()).run().unwrap();
        let denom = a.final_residual.abs().max(1e-30);
        assert!(
            (a.final_residual - b.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            a.final_residual,
            b.final_residual
        );
    }

    #[test]
    fn no_comm_differs_from_comm() {
        // Without dssum the operator is block-diagonal — different system,
        // different residual trajectory (sanity that the switch acts).
        let mut with = app("cpu-layered", small_cfg());
        let cfg_nc = RunConfig { no_comm: true, ..small_cfg() };
        let mut without = app("cpu-layered", cfg_nc);
        let a = with.run().unwrap();
        let b = without.run().unwrap();
        assert!((a.final_residual - b.final_residual).abs() > 1e-12);
    }

    #[test]
    fn report_flops_use_cost_model() {
        let mut app = app("cpu-layered", small_cfg());
        let rep = app.run().unwrap();
        let per_iter = CostModel::new(4, 8).flops_per_iter();
        assert_eq!(rep.flops, per_iter * rep.iterations as u64);
    }

    #[test]
    fn set_rhs_changes_solution() {
        let mut app = app("cpu-layered", small_cfg());
        let r1 = app.run().unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![1.0; ndof]).unwrap();
        let r2 = app.run().unwrap();
        assert!((r1.final_residual - r2.final_residual).abs() > 0.0);
    }

    #[test]
    fn builder_rejects_unknown_operator() {
        let err = Nekbone::builder(small_cfg()).operator("gpu-magic").build().err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("gpu-magic"), "{msg}");
        assert!(msg.contains("cpu-layered"), "error must list registered names: {msg}");
    }

    #[test]
    fn builder_accepts_custom_registry() {
        use crate::operators::{ax_layered, AxOperator, OperatorCtx};

        /// Test-only operator delegating to the layered kernel.
        #[derive(Default)]
        struct Custom {
            st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
        }
        impl AxOperator for Custom {
            fn label(&self) -> String {
                "test-custom".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                let (n, nelt, d, g) = self.st.as_ref().unwrap();
                ax_layered(*n, *nelt, u, d, g, w);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut reg = OperatorRegistry::with_builtins();
        reg.register("test-custom", false, || Box::<Custom>::default()).unwrap();
        let mut custom = Nekbone::builder(small_cfg())
            .registry(reg)
            .operator("test-custom")
            .build()
            .unwrap();
        let got = custom.run().unwrap();
        let want = app("cpu-layered", small_cfg()).run().unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-12,
            "custom operator must match the kernel it wraps"
        );
        assert_eq!(got.backend, "test-custom");
    }

    #[test]
    fn vector_xla_requires_xla_operator() {
        let mut app = app("cpu-layered", small_cfg());
        let err = app.run_vector_backend(VectorBackend::Xla).err().unwrap();
        assert!(err.to_string().contains("XLA"), "{err}");
    }
}
