//! The Nekbone application object: setup once, run CG many times.
//!
//! Built through [`NekboneBuilder`]: the operator is resolved by name from
//! an [`OperatorRegistry`] and held as a `Box<dyn AxOperator>` — the
//! application has no knowledge of which implementations exist. Every
//! solve — the default native path, the chunked-XLA vector path, and
//! [`SolveSession`](crate::coordinator::SolveSession) solves — funnels
//! through one private `solve_once` into the crate's single CG loop
//! ([`cg_solve_with`]), with [`NullComm`] as the communicator and the
//! application's [`GatherScatter`] (or [`NoExchange`] under `--no-comm`)
//! as the domain exchange.

use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::{RunReport, VectorBackend};
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::{AxOperator, OperatorCtx, OperatorRegistry};
use crate::runtime::{VectorEngine, XlaRuntime};
use crate::solver::{
    add2s1, add2s2, cg_solve_with, glsc3, mask_apply, CgOptions, CgReport, CgWorkspace,
    DomainExchange, NativeVectors, NoExchange, NullComm, TimedAx, VectorOps,
};

/// Everything needed to run Nekbone with one operator on one mesh.
///
/// Internally split into build-time state (the mesh numbering and basis
/// tables, kept for inspection and re-setup) and the serve-time
/// [`SolveState`] (what a solve actually touches). A serving process that
/// only needs to answer solves converts with [`Nekbone::into_session`],
/// dropping the build-time half.
pub struct Nekbone {
    pub cfg: RunConfig,
    vector_backend: VectorBackend,
    mesh: Mesh,
    basis: Basis,
    state: SolveState,
}

/// The serve-time half of an application: exactly what one CG solve
/// touches — the operator, the gather–scatter assembly, the boundary
/// mask, the inverse-multiplicity weights, the staged RHS, and the
/// reusable CG workspace. Split out of [`Nekbone`] so a long-lived
/// serving process can cache many of these (one per warmed mesh) without
/// also holding every mesh's build-time numbering and basis tables, and
/// so an owned session ([`crate::coordinator::OwnedSession`]) can cross
/// into a shard worker: `SolveState` is `Send` end to end (the operator
/// trait requires it, `GatherScatter` and the vectors are plain data).
pub(crate) struct SolveState {
    /// The local Ax, dispatched purely through the trait object.
    op: Box<dyn AxOperator>,
    gs: GatherScatter,
    mask: Vec<f64>,
    /// Inverse multiplicity (Nekbone's `c`).
    c: Vec<f64>,
    /// Right-hand side (dssum-consistent, masked).
    f: Vec<f64>,
    /// Optional preconditioner (assembled at build from the same mesh
    /// data the operator saw; `None` mirrors Nekbone's plain CG).
    precond: Option<crate::solver::Precond>,
    ws: CgWorkspace,
}

impl SolveState {
    /// Local dofs this state solves over.
    pub(crate) fn ndof(&self) -> usize {
        self.f.len()
    }

    /// The operator's display label (canonical registry name).
    pub(crate) fn label(&self) -> String {
        self.op.label()
    }

    /// Stage a right-hand side: copy, make dssum-consistent, mask. The
    /// caller has already length-checked `f` (each owner fronts this with
    /// its own `Error::Config` naming its boundary).
    pub(crate) fn stage_rhs(&mut self, f: &[f64]) {
        debug_assert_eq!(f.len(), self.f.len());
        self.f.copy_from_slice(f);
        self.gs.dssum(&mut self.f);
        mask_apply(&mut self.f, &self.mask);
    }

    /// Drive the crate's one CG loop against this state's operator,
    /// exchange, and (reused) workspace, solving the staged RHS. Returns
    /// the solver report and the wall time spent inside the local
    /// operator. Every solve path — [`Nekbone::run_into`], the borrowing
    /// [`crate::coordinator::SolveSession`], and the serve layer's owned
    /// sessions — funnels through here.
    pub(crate) fn solve(
        &mut self,
        cfg: &RunConfig,
        x: &mut [f64],
        vectors: &mut dyn VectorOps,
    ) -> Result<(CgReport, f64)> {
        let SolveState { op, gs, mask, c, f, precond, ws } = self;
        let rhs: &[f64] = f;
        let opts = CgOptions {
            niter: cfg.niter,
            rtol: cfg.rtol,
            record_residuals: cfg.record_residuals,
        };
        let mut ax = TimedAx::new(op.as_mut());
        let mut no_exchange = NoExchange;
        let exchange: &mut dyn DomainExchange =
            if cfg.no_comm { &mut no_exchange } else { gs };
        let mask_opt = (!cfg.no_mask).then_some(mask.as_slice());
        let rep = cg_solve_with(
            &mut ax,
            exchange,
            &mut NullComm,
            vectors,
            mask_opt,
            c,
            rhs,
            x,
            &opts,
            ws,
            precond.as_ref(),
        )?;
        Ok((rep, ax.seconds))
    }
}

/// Builder for [`Nekbone`]: pick the operator by registry name, optionally
/// a custom registry and the vector-algebra backend, then `build()`.
///
/// The `cpu-*` operators need no artifacts, so this runs anywhere
/// (`cargo test` executes it):
///
/// ```
/// use nekbone::config::RunConfig;
/// use nekbone::coordinator::Nekbone;
///
/// let cfg = RunConfig { nelt: 2, n: 3, niter: 5, ..RunConfig::default() };
/// let mut app = Nekbone::builder(cfg)
///     .operator("cpu-spec") // any operator-registry name; aliases resolve too
///     .build()
///     .unwrap();
/// let report = app.run().unwrap();
/// assert_eq!(report.backend, "cpu-spec");
/// assert_eq!(report.iterations, 5);
/// ```
///
/// An unknown operator name fails at `build()` with an error listing
/// every registered name:
///
/// ```
/// use nekbone::config::RunConfig;
/// use nekbone::coordinator::Nekbone;
///
/// let err = Nekbone::builder(RunConfig::default()).operator("gpu-magic").build();
/// assert!(err.err().unwrap().to_string().contains("cpu-layered"));
/// ```
pub struct NekboneBuilder {
    cfg: RunConfig,
    operator: String,
    vector_backend: VectorBackend,
    registry: Option<OperatorRegistry>,
}

impl NekboneBuilder {
    /// Select the local-Ax operator by registry name (canonical or alias).
    pub fn operator(mut self, name: impl Into<String>) -> Self {
        self.operator = name.into();
        self
    }

    /// Select where the CG vector algebra runs (default: native Rust).
    pub fn vector_backend(mut self, vb: VectorBackend) -> Self {
        self.vector_backend = vb;
        self
    }

    /// Use a custom operator registry (e.g. with runtime-registered
    /// variants) instead of the built-ins.
    pub fn registry(mut self, registry: OperatorRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Build the application: mesh, basis, geometry, gather–scatter, RHS,
    /// and the operator (set up against this problem's data).
    pub fn build(self) -> Result<Nekbone> {
        let cfg = self.cfg;
        cfg.validate()?;
        // A supplied registry wins; otherwise every build shares the
        // process-wide instance (built once, not per call site).
        let registry: &OperatorRegistry = match &self.registry {
            Some(r) => r,
            None => crate::operators::registry(),
        };
        // Fail fast on an unknown operator name, before the expensive
        // mesh / gather-scatter / geometry construction below.
        registry.resolve(&self.operator)?;
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
        let basis = Basis::new(cfg.n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let c = mesh.inv_multiplicity();

        // RHS: deterministic pseudo-random field, made dssum-consistent and
        // masked (Nekbone's set-up of `f`).
        let mut rng = crate::rng::Rng::new(cfg.seed);
        let mut f = rng.normal_vec(mesh.ndof_local());
        gs.dssum(&mut f);
        mask_apply(&mut f, &mask);

        // Preconditioner (if requested): assembled from the same basis /
        // geometry / gather-scatter / mask the operator is set up with,
        // honoring --no-mask the way the solve itself does.
        let pc_mask = (!cfg.no_mask).then_some(mask.as_slice());
        let precond = match cfg.precond.as_str() {
            "jacobi" => Some(crate::solver::Precond::Jacobi(crate::solver::Jacobi::assemble(
                cfg.n,
                mesh.nelt(),
                &basis.d,
                &geom.g,
                &mut gs,
                pc_mask,
            )?)),
            "cheb" => {
                Some(crate::solver::Precond::Chebyshev(crate::solver::Chebyshev::assemble(
                    cfg.n,
                    mesh.nelt(),
                    &basis.d,
                    &geom.g,
                    &mut gs,
                    pc_mask,
                    cfg.cheb_order,
                )?))
            }
            _ => None, // validate() restricts this to "none"
        };

        // Fold plan for assembly-fused operators: only built when the
        // solve itself would run dssum (+mask), so an assembling operator
        // reproduces exactly what the standalone passes would have done.
        // Under --no-comm there is no assembly to fold and the plan stays
        // absent — `cpu-asm*` then degrade to their plain layered sweep.
        let plan = if cfg.no_comm {
            None
        } else {
            Some(gs.assembly_plan(cfg.n * cfg.n * cfg.n, pc_mask)?)
        };
        let ctx = OperatorCtx {
            n: cfg.n,
            nelt: mesh.nelt(),
            chunk: cfg.chunk,
            threads: cfg.cpu_threads,
            artifacts_dir: &cfg.artifacts_dir,
            d: &basis.d,
            g: &geom.g,
            c: &c,
            assemble: plan.as_ref(),
        };
        let op = registry.build(&self.operator, &ctx)?;
        // The operator owns whatever it cloned/uploaded from `geom`; the
        // application itself never needs the geometric factors again.

        let ndof = mesh.ndof_local();
        // Element-blocked reductions, folded in global element order: the
        // same plan the ranked path installs per brick, so serial and
        // ranked dot products evaluate one fold expression bit for bit.
        let mut ws = CgWorkspace::new(ndof);
        ws.set_reduce_plan(cfg.n * cfg.n * cfg.n, (0..mesh.nelt() as u64).collect())?;
        // Cache-blocked iteration pipeline (bitwise identical to the
        // unblocked walk — see CgWorkspace::set_iteration_plan): resolved
        // from `--block-dofs`, skipped only for "off".
        if let Some(block_dofs) = cfg.resolved_block_dofs()? {
            ws.set_iteration_plan(block_dofs)?;
        }
        Ok(Nekbone {
            cfg,
            vector_backend: self.vector_backend,
            mesh,
            basis,
            state: SolveState { op, gs, mask, c, f, precond, ws },
        })
    }
}

impl Nekbone {
    /// Start building an application for this configuration. The default
    /// operator is `cpu-layered` (always available, no artifacts).
    pub fn builder(cfg: RunConfig) -> NekboneBuilder {
        NekboneBuilder {
            cfg,
            operator: "cpu-layered".into(),
            vector_backend: VectorBackend::default(),
            registry: None,
        }
    }

    /// The mesh in use.
    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }

    /// The basis in use.
    pub fn basis(&self) -> &Basis {
        &self.basis
    }

    /// The operator's display label (canonical registry name).
    pub fn operator_label(&self) -> String {
        self.state.label()
    }

    /// Replace the right-hand side (e.g. a manufactured solution's load).
    /// The field is made dssum-consistent and masked.
    pub fn set_rhs(&mut self, f: &[f64]) -> Result<()> {
        if f.len() != self.mesh.ndof_local() {
            return Err(Error::Config("set_rhs: length mismatch".into()));
        }
        self.state.stage_rhs(f);
        Ok(())
    }

    /// Drive the crate's one CG loop, solving the staged RHS `f` (set it
    /// with [`Nekbone::set_rhs`] — staging performs the dssum + mask every
    /// RHS needs); the caller picks the vector backend. Returns the solver
    /// report and the wall time spent inside the local operator. Shared by
    /// [`Nekbone::run_into`] and
    /// [`SolveSession`](crate::coordinator::SolveSession); delegates to
    /// [`SolveState::solve`].
    pub(crate) fn solve_once(
        &mut self,
        x: &mut [f64],
        vectors: &mut dyn VectorOps,
    ) -> Result<(CgReport, f64)> {
        self.state.solve(&self.cfg, x, vectors)
    }

    /// Split off the serve-time state as an owned, `Send` session,
    /// dropping the build-time mesh numbering and basis tables. This is
    /// the serve layer's cache entry: dozens of warmed meshes can be held
    /// per shard at the cost of their solve state alone.
    pub fn into_session(self) -> crate::coordinator::OwnedSession {
        crate::coordinator::OwnedSession::from_parts(self.cfg, self.state)
    }

    /// Run the configured number of CG iterations; returns the report.
    /// `x_out`, when given, receives the solution field.
    pub fn run_into(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        if self.vector_backend == VectorBackend::Xla {
            return self.run_vector_xla(x_out);
        }
        self.run_rust_vectors(x_out)
    }

    /// The native-Rust vector-algebra CG (the default path), regardless of
    /// the configured vector backend. Fused operators take the same route:
    /// the shared solver consults the operator's fused-pap hooks (via
    /// [`TimedAx`]) and skips its own pap sweep.
    fn run_rust_vectors(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        let n = self.cfg.n;
        let nelt = self.cfg.nelt;
        let ndof = self.mesh.ndof_local();
        let mut x = vec![0.0; ndof];

        let sw = Instant::now();
        let (rep, ax_seconds) = self.solve_once(&mut x, &mut NativeVectors)?;
        let seconds = sw.elapsed().as_secs_f64();

        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: self.state.op.label(),
            nelt,
            n,
            iterations: rep.iterations,
            final_residual: rep.final_rnorm,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * rep.iterations as u64,
            fused: self.state.op.is_fused(),
            rnorms: rep.rnorms,
        })
    }

    /// Convenience: run and discard the solution.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_into(None)
    }

    /// Apply the local operator once (used by parity tests and
    /// kernel-level benches; no dssum, no mask).
    pub fn apply_ax_once(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        self.state.op.apply(p, w)
    }

    /// Run CG with the vector algebra on the given backend for this run
    /// only (experiment E6's rust-vs-xla comparison), overriding whatever
    /// the builder configured.
    pub fn run_vector_backend(&mut self, vb: VectorBackend) -> Result<RunReport> {
        match vb {
            VectorBackend::Rust => self.run_rust_vectors(None),
            VectorBackend::Xla => self.run_vector_xla(None),
        }
    }

    /// XLA vector path: chunked executables for glsc3 / add2s1 / add2s2,
    /// sharing the operator's PJRT runtime — the same CG loop as every
    /// other path, with [`XlaVectors`] in the vector-algebra slot.
    fn run_vector_xla(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        let rt = self.state.op.xla_runtime().ok_or_else(|| {
            Error::Config("vector-backend xla requires an XLA Ax backend".into())
        })?;
        if self.state.op.is_fused() {
            return Err(Error::Config(
                "vector-backend xla requires a (non-fused) XLA Ax backend".into(),
            ));
        }
        let size = self.cfg.chunk * self.cfg.n.pow(3);
        let mut vectors = XlaVectors::new(rt, size)?;
        let label = self.state.op.label();
        let (n, nelt) = (self.cfg.n, self.cfg.nelt);
        let ndof = self.mesh.ndof_local();
        let mut x = vec![0.0; ndof];

        let sw = Instant::now();
        let (rep, ax_seconds) = self.solve_once(&mut x, &mut vectors)?;
        let seconds = sw.elapsed().as_secs_f64();

        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: format!("{label}+vec-xla"),
            nelt,
            n,
            iterations: rep.iterations,
            final_residual: rep.final_rnorm,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * rep.iterations as u64,
            fused: self.state.op.is_fused(),
            rnorms: rep.rnorms,
        })
    }
}

/// [`VectorOps`] over chunked XLA executables (experiment E6): full chunks
/// run through PJRT, the sub-chunk tail runs native. Plugged into the
/// shared CG loop by [`Nekbone::run_vector_backend`].
struct XlaVectors {
    rt: std::sync::Arc<XlaRuntime>,
    glsc3_e: VectorEngine,
    add2s1_e: VectorEngine,
    add2s2_e: VectorEngine,
    /// Dofs per executable launch.
    size: usize,
}

impl XlaVectors {
    fn new(rt: std::sync::Arc<XlaRuntime>, size: usize) -> Result<Self> {
        Ok(XlaVectors {
            glsc3_e: VectorEngine::new(&rt, "glsc3", size)?,
            add2s1_e: VectorEngine::new(&rt, "add2s1", size)?,
            add2s2_e: VectorEngine::new(&rt, "add2s2", size)?,
            rt,
            size,
        })
    }

    /// Chunked `axpy` through one of the engines, native tail.
    fn chunked_axpy(
        &self,
        engine: &VectorEngine,
        a: &mut [f64],
        b: &[f64],
        s: f64,
        s1: bool,
    ) -> Result<()> {
        let size = self.size;
        let mut i = 0;
        while i + size <= a.len() {
            engine.axpy(&self.rt, &mut a[i..i + size], &b[i..i + size], s)?;
            i += size;
        }
        if i < a.len() {
            if s1 {
                add2s1(&mut a[i..], &b[i..], s);
            } else {
                add2s2(&mut a[i..], &b[i..], s);
            }
        }
        Ok(())
    }
}

impl VectorOps for XlaVectors {
    fn glsc3(&mut self, a: &[f64], b: &[f64], c: &[f64]) -> Result<f64> {
        let size = self.size;
        let mut acc = 0.0;
        let mut i = 0;
        while i + size <= a.len() {
            acc += self.glsc3_e.glsc3(
                &self.rt,
                &a[i..i + size],
                &b[i..i + size],
                &c[i..i + size],
            )?;
            i += size;
        }
        if i < a.len() {
            acc += glsc3(&a[i..], &b[i..], &c[i..]); // rust tail
        }
        Ok(acc)
    }

    fn add2s1(&mut self, a: &mut [f64], b: &[f64], c1: f64) -> Result<()> {
        self.chunked_axpy(&self.add2s1_e, a, b, c1, true)
    }

    fn add2s2(&mut self, a: &mut [f64], b: &[f64], c2: f64) -> Result<()> {
        self.chunked_axpy(&self.add2s2_e, a, b, c2, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RunConfig {
        RunConfig { nelt: 8, n: 4, niter: 30, chunk: 64, ..Default::default() }
    }

    fn app(operator: &str, cfg: RunConfig) -> Nekbone {
        Nekbone::builder(cfg).operator(operator).build().unwrap()
    }

    #[test]
    fn cpu_backends_agree() {
        // Enumerated from the registry (every artifact-free operator), so
        // a new CPU registration is covered here without a list edit. The
        // f32-storage family solves a slightly perturbed system (the
        // factors round once), so it forms its own tight agreement group;
        // across the groups the solutions must still agree within the
        // reduced-storage band.
        let registry = crate::operators::OperatorRegistry::with_builtins();
        let names: Vec<String> = registry
            .names()
            .into_iter()
            .filter(|name| !registry.resolve(name).unwrap().needs_artifacts)
            .collect();
        assert!(names.len() >= 21, "registry lost CPU operators ({} left)", names.len());
        let mut groups: [Vec<(String, RunReport, Vec<f64>)>; 2] = [Vec::new(), Vec::new()];
        for name in &names {
            let mut app = app(name, small_cfg());
            let mut x = vec![0.0; app.mesh().ndof_local()];
            let rep = app.run_into(Some(&mut x)).unwrap();
            assert_eq!(&rep.backend, name, "report label must be the registry name");
            let g = usize::from(name.ends_with("-f32"));
            groups[g].push((name.clone(), rep, x));
        }
        assert!(groups[1].len() >= 10, "registry lost f32 operators");
        for group in &groups {
            let (_, rep0, x0) = &group[0];
            for (name, rep, x) in &group[1..] {
                assert!(
                    (rep.final_residual - rep0.final_residual).abs()
                        <= 1e-9 * rep0.final_residual.abs().max(1e-30),
                    "{name}: residuals diverge: {} vs {}",
                    rep.final_residual,
                    rep0.final_residual
                );
                crate::proputil::assert_allclose(x, x0, 1e-9, 1e-12);
            }
        }
        // Cross-group: same solve to reduced-storage accuracy.
        crate::proputil::assert_allclose(&groups[1][0].2, &groups[0][0].2, 1e-3, 1e-6);
    }

    #[test]
    fn preconditioned_runs_solve_the_same_system() {
        // --precond plumbs through build() into the shared CG loop. Run
        // long enough that every variant fully converges: precondition-
        // ing changes the path, not the solution.
        let mk = |precond: &str, niter: usize| RunConfig {
            niter,
            precond: precond.into(),
            ..small_cfg()
        };
        let mut xs = Vec::new();
        for p in ["none", "jacobi", "cheb"] {
            let mut app = app("cpu-layered", mk(p, 100));
            let mut x = vec![0.0; app.mesh().ndof_local()];
            app.run_into(Some(&mut x)).unwrap();
            xs.push(x);
        }
        for x in &xs[1..] {
            crate::proputil::assert_allclose(x, &xs[0], 1e-6, 1e-9);
        }
        // Truncated runs expose the acceleration: after the same few
        // iterations the Chebyshev-preconditioned true residual (the
        // unpreconditioned norm the report computes when rtol is off)
        // must sit well below plain CG's.
        let none = app("cpu-layered", mk("none", 12)).run().unwrap();
        let cheb = app("cpu-layered", mk("cheb", 12)).run().unwrap();
        assert!(
            cheb.final_residual < 0.5 * none.final_residual,
            "Chebyshev should accelerate: {} vs plain {}",
            cheb.final_residual,
            none.final_residual
        );
    }

    #[test]
    fn residual_decreases() {
        let cfg = RunConfig { niter: 50, ..small_cfg() };
        let mut app = app("cpu-layered", cfg);
        let rep = app.run().unwrap();
        // The first residual equals |masked f|_c; after 50 iterations on a
        // 512-dof system CG should be well converged.
        let f_norm = glsc3(&app.state.f, &app.state.c, &app.state.f).sqrt();
        assert!(
            rep.final_residual < 1e-6 * f_norm,
            "residual {} vs f {}",
            rep.final_residual,
            f_norm
        );
    }

    #[test]
    fn run_honors_config_rtol_and_history() {
        // The pipeline passes the config's solver options through to the
        // shared solver: record_residuals fills the report history, rtol
        // exits early.
        let cfg = RunConfig { record_residuals: true, ..small_cfg() };
        let mut app = app("cpu-layered", cfg);
        let rep = app.run().unwrap();
        assert_eq!(rep.rnorms.len(), rep.iterations);
        let tol = (rep.rnorms[4] * rep.rnorms[5]).sqrt();
        let tcfg = RunConfig { rtol: Some(tol), ..small_cfg() };
        let mut tapp = app("cpu-layered", tcfg);
        let trep = tapp.run().unwrap();
        assert!(trep.iterations < 30, "rtol must exit early: {}", trep.iterations);
        assert!(trep.final_residual <= tol);
    }

    #[test]
    fn fused_no_comm_matches_unfused_no_comm() {
        // In no-comm mode the fused pap is consumed with no correction at
        // all; the trajectory must still track the unfused operator.
        let mk = || RunConfig { no_comm: true, ..small_cfg() };
        let a = app("cpu-layered", mk()).run().unwrap();
        let b = app("cpu-layered-fused", mk()).run().unwrap();
        let denom = a.final_residual.abs().max(1e-30);
        assert!(
            (a.final_residual - b.final_residual).abs() / denom < 1e-9,
            "{} vs {}",
            a.final_residual,
            b.final_residual
        );
    }

    #[test]
    fn no_comm_differs_from_comm() {
        // Without dssum the operator is block-diagonal — different system,
        // different residual trajectory (sanity that the switch acts).
        let mut with = app("cpu-layered", small_cfg());
        let cfg_nc = RunConfig { no_comm: true, ..small_cfg() };
        let mut without = app("cpu-layered", cfg_nc);
        let a = with.run().unwrap();
        let b = without.run().unwrap();
        assert!((a.final_residual - b.final_residual).abs() > 1e-12);
    }

    #[test]
    fn report_flops_use_cost_model() {
        let mut app = app("cpu-layered", small_cfg());
        let rep = app.run().unwrap();
        let per_iter = CostModel::new(4, 8).flops_per_iter();
        assert_eq!(rep.flops, per_iter * rep.iterations as u64);
    }

    #[test]
    fn set_rhs_changes_solution() {
        let mut app = app("cpu-layered", small_cfg());
        let r1 = app.run().unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![1.0; ndof]).unwrap();
        let r2 = app.run().unwrap();
        assert!((r1.final_residual - r2.final_residual).abs() > 0.0);
    }

    #[test]
    fn builder_rejects_unknown_operator() {
        let err = Nekbone::builder(small_cfg()).operator("gpu-magic").build().err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("gpu-magic"), "{msg}");
        assert!(msg.contains("cpu-layered"), "error must list registered names: {msg}");
    }

    #[test]
    fn builder_accepts_custom_registry() {
        use crate::operators::{ax_layered, AxOperator, OperatorCtx};

        /// Test-only operator delegating to the layered kernel.
        #[derive(Default)]
        struct Custom {
            st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
        }
        impl AxOperator for Custom {
            fn label(&self) -> String {
                "test-custom".into()
            }
            fn setup(&mut self, ctx: &OperatorCtx) -> Result<()> {
                self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
                Ok(())
            }
            fn apply(&mut self, u: &[f64], w: &mut [f64]) -> Result<()> {
                let (n, nelt, d, g) = self.st.as_ref().unwrap();
                ax_layered(*n, *nelt, u, d, g, w);
                Ok(())
            }
            fn flops(&self) -> u64 {
                0
            }
        }

        let mut reg = OperatorRegistry::with_builtins();
        reg.register("test-custom", false, || Box::<Custom>::default()).unwrap();
        let mut custom = Nekbone::builder(small_cfg())
            .registry(reg)
            .operator("test-custom")
            .build()
            .unwrap();
        let got = custom.run().unwrap();
        let want = app("cpu-layered", small_cfg()).run().unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-12,
            "custom operator must match the kernel it wraps"
        );
        assert_eq!(got.backend, "test-custom");
    }

    #[test]
    fn vector_xla_requires_xla_operator() {
        let mut app = app("cpu-layered", small_cfg());
        let err = app.run_vector_backend(VectorBackend::Xla).err().unwrap();
        assert!(err.to_string().contains("XLA"), "{err}");
    }
}
