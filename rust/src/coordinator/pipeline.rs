//! The Nekbone application object: setup once, run CG many times.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::basis::Basis;
use crate::config::RunConfig;
use crate::coordinator::{Backend, RunReport, VectorBackend};
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::gs::GatherScatter;
use crate::mesh::Mesh;
use crate::metrics::CostModel;
use crate::operators::CpuVariant;
use crate::runtime::{AxEngine, CgIterEngine, XlaRuntime};
use crate::solver::{cg_solve, glsc3, mask_apply, CgOptions, CgWorkspace};

/// Everything needed to run Nekbone with one backend on one mesh.
pub struct Nekbone {
    pub cfg: RunConfig,
    backend: Backend,
    mesh: Mesh,
    basis: Basis,
    geom: GeomFactors,
    gs: GatherScatter,
    mask: Vec<f64>,
    /// Inverse multiplicity (Nekbone's `c`).
    c: Vec<f64>,
    /// Right-hand side (dssum-consistent, masked).
    f: Vec<f64>,
    /// XLA state when the backend needs it.
    xla: Option<XlaState>,
    ws: CgWorkspace,
}

struct XlaState {
    rt: XlaRuntime,
    ax: Option<AxEngine>,
    fused: Option<CgIterEngine>,
}

impl Nekbone {
    /// Build the application: mesh, basis, geometry, gather–scatter, RHS,
    /// and (for XLA backends) the PJRT engines with resident buffers.
    pub fn new(cfg: RunConfig, backend: Backend) -> Result<Self> {
        cfg.validate()?;
        let mesh = Mesh::for_nelt(cfg.nelt, cfg.n)?;
        let basis = Basis::new(cfg.n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let c = mesh.inv_multiplicity();

        // RHS: deterministic pseudo-random field, made dssum-consistent and
        // masked (Nekbone's set-up of `f`).
        let mut rng = crate::rng::Rng::new(cfg.seed);
        let mut f = rng.normal_vec(mesh.ndof_local());
        gs.dssum(&mut f);
        mask_apply(&mut f, &mask);

        let xla = if backend.needs_artifacts() {
            let rt = XlaRuntime::new(&cfg.artifacts_dir)?;
            let (ax, fused) = match &backend {
                Backend::Xla(variant) => (
                    Some(AxEngine::new(
                        &rt,
                        variant,
                        cfg.n,
                        cfg.chunk,
                        mesh.nelt(),
                        &basis.d,
                        &geom.g,
                    )?),
                    None,
                ),
                Backend::XlaFused(variant) => (
                    None,
                    Some(CgIterEngine::new(
                        &rt,
                        variant,
                        cfg.n,
                        cfg.chunk,
                        mesh.nelt(),
                        &basis.d,
                        &geom.g,
                        &c,
                    )?),
                ),
                _ => unreachable!(),
            };
            Some(XlaState { rt, ax, fused })
        } else {
            None
        };

        let ndof = mesh.ndof_local();
        Ok(Nekbone {
            cfg,
            backend,
            mesh,
            basis,
            geom,
            gs,
            mask,
            c,
            f,
            xla,
            ws: CgWorkspace::new(ndof),
        })
    }

    /// The mesh in use.
    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }

    /// The basis in use.
    pub fn basis(&self) -> &Basis {
        &self.basis
    }

    /// Replace the right-hand side (e.g. a manufactured solution's load).
    /// The field is made dssum-consistent and masked.
    pub fn set_rhs(&mut self, f: &[f64]) -> Result<()> {
        if f.len() != self.mesh.ndof_local() {
            return Err(Error::Config("set_rhs: length mismatch".into()));
        }
        self.f.copy_from_slice(f);
        self.gs.dssum(&mut self.f);
        mask_apply(&mut self.f, &self.mask);
        Ok(())
    }

    /// Run the configured number of CG iterations; returns the report.
    /// `x_out`, when given, receives the solution field.
    pub fn run_into(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        if matches!(self.backend, Backend::XlaFused(_)) {
            return self.run_fused(x_out);
        }
        let n = self.cfg.n;
        let nelt = self.cfg.nelt;
        let ndof = self.mesh.ndof_local();
        let mut x = vec![0.0; ndof];

        let ax_time = Rc::new(RefCell::new(0.0f64));
        let opts = CgOptions {
            niter: self.cfg.niter,
            rtol: None,
            record_residuals: false,
        };

        // Assemble the AxApply closure for the selected backend.
        let d = self.basis.d.clone();
        let g = &self.geom.g;
        let cpu_threads = self.cfg.cpu_threads;
        let backend = self.backend.clone();
        let xla = &mut self.xla;
        let ax_time_c = Rc::clone(&ax_time);
        let mut ax_fn = move |p: &[f64], w: &mut [f64]| -> Result<()> {
            let t0 = Instant::now();
            match &backend {
                Backend::CpuNaive => CpuVariant::Naive.apply(n, nelt, p, &d, g, w),
                Backend::CpuLayered => CpuVariant::Layered.apply(n, nelt, p, &d, g, w),
                Backend::CpuThreaded => {
                    crate::operators::ax_threaded(n, nelt, p, &d, g, w, cpu_threads)
                }
                Backend::Xla(_) => {
                    let st = xla.as_mut().expect("xla state");
                    let engine = st.ax.as_mut().expect("ax engine");
                    engine.apply(&st.rt, p, w)?;
                }
                Backend::XlaFused(_) => unreachable!(),
            }
            *ax_time_c.borrow_mut() += t0.elapsed().as_secs_f64();
            Ok(())
        };

        let gs_opt = if self.cfg.no_comm { None } else { Some(&mut self.gs) };
        let mask_opt = if self.cfg.no_mask { None } else { Some(self.mask.as_slice()) };

        let sw = Instant::now();
        let rep = cg_solve(
            &mut ax_fn,
            gs_opt,
            mask_opt,
            &self.c,
            &self.f,
            &mut x,
            &opts,
            &mut self.ws,
        )?;
        let seconds = sw.elapsed().as_secs_f64();

        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        let ax_seconds = *ax_time.borrow();
        Ok(RunReport {
            backend: self.backend.label(),
            nelt,
            n,
            iterations: rep.iterations,
            final_residual: rep.final_rnorm,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * rep.iterations as u64,
            rnorms: rep.rnorms,
        })
    }

    /// Convenience: run and discard the solution.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_into(None)
    }

    /// The fused hot path: Ax and the pap reduction in one XLA launch per
    /// chunk (perf pass). The CG logic is inlined here because the fused
    /// executable returns pap itself.
    fn run_fused(&mut self, x_out: Option<&mut [f64]>) -> Result<RunReport> {
        let st = self.xla.as_mut().expect("xla state");
        let engine = st.fused.as_ref().expect("fused engine");
        let ndof = self.mesh.ndof_local();
        let (n, nelt) = (self.cfg.n, self.cfg.nelt);
        let mut x = vec![0.0; ndof];
        let mut r = self.f.clone();
        if !self.cfg.no_mask {
            mask_apply(&mut r, &self.mask);
        }
        let mut p = vec![0.0; ndof];
        let mut w = vec![0.0; ndof];
        let mut rtz1 = 1.0f64;
        let mut ax_seconds = 0.0;
        let sw = Instant::now();
        let mut iterations = 0;
        for iter in 0..self.cfg.niter {
            let rtz2 = rtz1;
            rtz1 = glsc3(&r, &self.c, &r);
            let beta = if iter == 0 { 0.0 } else { rtz1 / rtz2 };
            crate::solver::add2s1(&mut p, &r, beta);

            let t0 = Instant::now();
            // Fused pap is only exact when no dssum/mask intervenes between
            // Ax and the reduction; with comm on we recompute pap after.
            let mut pap = engine.apply(&st.rt, &p, &mut w)?;
            ax_seconds += t0.elapsed().as_secs_f64();

            if !self.cfg.no_comm {
                self.gs.dssum(&mut w);
            }
            if !self.cfg.no_mask {
                mask_apply(&mut w, &self.mask);
            }
            if !self.cfg.no_comm || !self.cfg.no_mask {
                pap = glsc3(&w, &self.c, &p);
            }
            if pap <= 0.0 || !pap.is_finite() {
                return Err(Error::Numerical(format!(
                    "fused CG breakdown at iter {iter}: pap = {pap}"
                )));
            }
            let alpha = rtz1 / pap;
            crate::solver::add2s2(&mut x, &p, alpha);
            crate::solver::add2s2(&mut r, &w, -alpha);
            iterations = iter + 1;
        }
        let seconds = sw.elapsed().as_secs_f64();
        let final_residual = glsc3(&r, &self.c, &r).max(0.0).sqrt();
        if let Some(out) = x_out {
            out.copy_from_slice(&x);
        }
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: self.backend.label(),
            nelt,
            n,
            iterations,
            final_residual,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * iterations as u64,
            rnorms: vec![],
        })
    }

    /// Apply the local operator once with the configured backend (used by
    /// parity tests and kernel-level benches; no dssum, no mask).
    pub fn apply_ax_once(&mut self, p: &[f64], w: &mut [f64]) -> Result<()> {
        let (n, nelt) = (self.cfg.n, self.cfg.nelt);
        match &self.backend {
            Backend::CpuNaive => CpuVariant::Naive.apply(n, nelt, p, &self.basis.d, &self.geom.g, w),
            Backend::CpuLayered => {
                CpuVariant::Layered.apply(n, nelt, p, &self.basis.d, &self.geom.g, w)
            }
            Backend::CpuThreaded => crate::operators::ax_threaded(
                n,
                nelt,
                p,
                &self.basis.d,
                &self.geom.g,
                w,
                self.cfg.cpu_threads,
            ),
            Backend::Xla(_) => {
                let st = self.xla.as_mut().expect("xla state");
                st.ax.as_mut().expect("ax engine").apply(&st.rt, p, w)?;
            }
            Backend::XlaFused(_) => {
                let st = self.xla.as_mut().expect("xla state");
                st.fused.as_ref().expect("fused engine").apply(&st.rt, p, w)?;
            }
        }
        Ok(())
    }

    /// Run CG with the vector algebra offloaded to XLA executables
    /// (experiment E6). Only the Rust path is otherwise exercised, so this
    /// lives beside `run` rather than inside it.
    pub fn run_vector_backend(&mut self, vb: VectorBackend) -> Result<RunReport> {
        if vb == VectorBackend::Rust {
            return self.run();
        }
        // XLA vector path: chunked executables for glsc3 / add2s1 / add2s2.
        let st = self
            .xla
            .as_mut()
            .ok_or_else(|| Error::Config("vector-backend xla requires an XLA Ax backend".into()))?;
        let size = self.cfg.chunk * self.cfg.n.pow(3);
        let glsc3_e = crate::runtime::VectorEngine::new(&st.rt, "glsc3", size)?;
        let add2s1_e = crate::runtime::VectorEngine::new(&st.rt, "add2s1", size)?;
        let add2s2_e = crate::runtime::VectorEngine::new(&st.rt, "add2s2", size)?;

        let ndof = self.mesh.ndof_local();
        let (n, nelt) = (self.cfg.n, self.cfg.nelt);
        let chunked_glsc3 = |rt: &XlaRuntime, a: &[f64], b: &[f64], c: &[f64]| -> Result<f64> {
            let mut acc = 0.0;
            let mut i = 0;
            while i + size <= a.len() {
                acc += glsc3_e.glsc3(rt, &a[i..i + size], &b[i..i + size], &c[i..i + size])?;
                i += size;
            }
            if i < a.len() {
                acc += glsc3(&a[i..], &b[i..], &c[i..]); // rust tail
            }
            Ok(acc)
        };
        let chunked_axpy = |rt: &XlaRuntime,
                            e: &crate::runtime::VectorEngine,
                            a: &mut [f64],
                            b: &[f64],
                            s: f64,
                            s1: bool|
         -> Result<()> {
            let mut i = 0;
            while i + size <= a.len() {
                e.axpy(rt, &mut a[i..i + size], &b[i..i + size], s)?;
                i += size;
            }
            if i < a.len() {
                if s1 {
                    crate::solver::add2s1(&mut a[i..], &b[i..], s);
                } else {
                    crate::solver::add2s2(&mut a[i..], &b[i..], s);
                }
            }
            Ok(())
        };

        let engine = st.ax.as_mut().ok_or_else(|| {
            Error::Config("vector-backend xla requires a (non-fused) XLA Ax backend".into())
        })?;
        let mut x = vec![0.0; ndof];
        let mut r = self.f.clone();
        mask_apply(&mut r, &self.mask);
        let mut p = vec![0.0; ndof];
        let mut w = vec![0.0; ndof];
        let mut rtz1 = 1.0f64;
        let mut ax_seconds = 0.0;
        let sw = Instant::now();
        let mut iterations = 0;
        for iter in 0..self.cfg.niter {
            let rtz2 = rtz1;
            rtz1 = chunked_glsc3(&st.rt, &r, &self.c, &r)?;
            let beta = if iter == 0 { 0.0 } else { rtz1 / rtz2 };
            chunked_axpy(&st.rt, &add2s1_e, &mut p, &r, beta, true)?;
            let t0 = Instant::now();
            engine.apply(&st.rt, &p, &mut w)?;
            ax_seconds += t0.elapsed().as_secs_f64();
            if !self.cfg.no_comm {
                self.gs.dssum(&mut w);
            }
            mask_apply(&mut w, &self.mask);
            let pap = chunked_glsc3(&st.rt, &w, &self.c, &p)?;
            if pap <= 0.0 || !pap.is_finite() {
                return Err(Error::Numerical(format!("CG breakdown at iter {iter}: pap {pap}")));
            }
            let alpha = rtz1 / pap;
            chunked_axpy(&st.rt, &add2s2_e, &mut x, &p, alpha, false)?;
            chunked_axpy(&st.rt, &add2s2_e, &mut r, &w, -alpha, false)?;
            iterations = iter + 1;
        }
        let seconds = sw.elapsed().as_secs_f64();
        let final_residual = glsc3(&r, &self.c, &r).max(0.0).sqrt();
        let cm = CostModel::new(n, nelt);
        Ok(RunReport {
            backend: format!("{}+vec-xla", self.backend.label()),
            nelt,
            n,
            iterations,
            final_residual,
            seconds,
            ax_seconds,
            flops: cm.flops_per_iter() * iterations as u64,
            rnorms: vec![],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RunConfig {
        RunConfig { nelt: 8, n: 4, niter: 30, chunk: 64, ..Default::default() }
    }

    #[test]
    fn cpu_backends_agree() {
        let mut reports = Vec::new();
        let mut xs = Vec::new();
        for b in [Backend::CpuNaive, Backend::CpuLayered, Backend::CpuThreaded] {
            let mut app = Nekbone::new(small_cfg(), b).unwrap();
            let mut x = vec![0.0; app.mesh().ndof_local()];
            let rep = app.run_into(Some(&mut x)).unwrap();
            reports.push(rep);
            xs.push(x);
        }
        for r in &reports[1..] {
            assert!(
                (r.final_residual - reports[0].final_residual).abs()
                    <= 1e-9 * reports[0].final_residual.abs().max(1e-30),
                "residuals diverge: {} vs {}",
                r.final_residual,
                reports[0].final_residual
            );
        }
        for x in &xs[1..] {
            crate::proputil::assert_allclose(x, &xs[0], 1e-9, 1e-12);
        }
    }

    #[test]
    fn residual_decreases() {
        let cfg = RunConfig { niter: 50, ..small_cfg() };
        let mut app = Nekbone::new(cfg, Backend::CpuLayered).unwrap();
        let rep = app.run().unwrap();
        // The first residual equals |masked f|_c; after 50 iterations on a
        // 512-dof system CG should be well converged.
        let f_norm = glsc3(&app.f, &app.c, &app.f).sqrt();
        assert!(
            rep.final_residual < 1e-6 * f_norm,
            "residual {} vs f {}",
            rep.final_residual,
            f_norm
        );
    }

    #[test]
    fn no_comm_differs_from_comm() {
        // Without dssum the operator is block-diagonal — different system,
        // different residual trajectory (sanity that the switch acts).
        let mut with = Nekbone::new(small_cfg(), Backend::CpuLayered).unwrap();
        let cfg_nc = RunConfig { no_comm: true, ..small_cfg() };
        let mut without = Nekbone::new(cfg_nc, Backend::CpuLayered).unwrap();
        let a = with.run().unwrap();
        let b = without.run().unwrap();
        assert!((a.final_residual - b.final_residual).abs() > 1e-12);
    }

    #[test]
    fn report_flops_use_cost_model() {
        let mut app = Nekbone::new(small_cfg(), Backend::CpuLayered).unwrap();
        let rep = app.run().unwrap();
        let per_iter = CostModel::new(4, 8).flops_per_iter();
        assert_eq!(rep.flops, per_iter * rep.iterations as u64);
    }

    #[test]
    fn set_rhs_changes_solution() {
        let mut app = Nekbone::new(small_cfg(), Backend::CpuLayered).unwrap();
        let r1 = app.run().unwrap();
        let ndof = app.mesh().ndof_local();
        app.set_rhs(&vec![1.0; ndof]).unwrap();
        let r2 = app.run().unwrap();
        assert!((r1.final_residual - r2.final_residual).abs() > 0.0);
    }
}
