//! Solve sessions: serve many right-hand sides against one setup.
//!
//! Building a [`Nekbone`] application is the expensive part — mesh
//! numbering, geometric factors, gather–scatter tables, operator setup
//! (thread-pool spawn, artifact load/upload). A [`SolveSession`] borrows a
//! built application and runs repeated solves against it with **zero
//! per-solve allocation or re-setup**: the operator, the gather–scatter,
//! the CG workspace, and the session's solution buffer are all created
//! once and reused. This is the multi-RHS serving entry point — the
//! "one setup, many requests" shape a production deployment needs.
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::Nekbone;
//!
//! let cfg = RunConfig { nelt: 64, n: 10, niter: 100, ..RunConfig::default() };
//! let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
//! let ndof = app.mesh().ndof_local();
//! let mut session = app.session();
//! let reports = session
//!     .solve_batch(&[vec![1.0; ndof], vec![2.0; ndof]])
//!     .unwrap();
//! println!("batch of {} solves, last |r| = {:e}",
//!          reports.len(), reports.last().unwrap().final_rnorm);
//! ```

use crate::coordinator::Nekbone;
use crate::error::{Error, Result};
use crate::solver::{CgReport, NativeVectors};

/// A multi-RHS solve session over one built [`Nekbone`] application (see
/// the module docs). Create with [`Nekbone::session`].
///
/// Each [`SolveSession::solve`] stages the given right-hand side through
/// the application (dssum-consistent, masked — exactly like
/// [`Nekbone::set_rhs`]) and runs the crate's one CG loop against the
/// application's operator and reused workspace. Solver options
/// (`niter`, `rtol`, `record_residuals`) come from the application's
/// [`RunConfig`](crate::config::RunConfig). Sessions always run the
/// native vector path.
pub struct SolveSession<'a> {
    app: &'a mut Nekbone,
    /// Reused solution buffer (allocated once at session creation).
    x: Vec<f64>,
    solves: usize,
}

impl Nekbone {
    /// Open a solve session: repeated [`SolveSession::solve`] /
    /// [`SolveSession::solve_batch`] calls reuse this application's
    /// operator state and CG workspace without allocating.
    ///
    /// # Examples
    ///
    /// ```
    /// use nekbone::config::RunConfig;
    /// use nekbone::coordinator::Nekbone;
    ///
    /// let cfg = RunConfig { nelt: 2, n: 3, niter: 5, ..RunConfig::default() };
    /// let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    /// let ndof = app.mesh().ndof_local();
    /// let mut session = app.session();
    /// for seed in 0..3u64 {
    ///     let rhs = nekbone::rng::Rng::new(seed).normal_vec(ndof);
    ///     let report = session.solve(&rhs).unwrap();
    ///     assert_eq!(report.iterations, 5);
    /// }
    /// assert_eq!(session.solves(), 3);
    /// assert_eq!(session.solution().len(), ndof);
    /// ```
    pub fn session(&mut self) -> SolveSession<'_> {
        let ndof = self.mesh().ndof_local();
        SolveSession { app: self, x: vec![0.0; ndof], solves: 0 }
    }
}

impl SolveSession<'_> {
    /// Solve `A x = rhs`; the solution is retained in
    /// [`SolveSession::solution`] until the next solve. The rhs is staged
    /// the way the application stages its built-in one (dssum + mask), so
    /// a session solve of RHS `b` is identical to
    /// `app.set_rhs(b); app.run()` — minus the per-call allocations.
    pub fn solve(&mut self, rhs: &[f64]) -> Result<CgReport> {
        self.app.set_rhs(rhs)?;
        let (report, _ax_seconds) =
            self.app.solve_once(&mut self.x, &mut NativeVectors)?;
        self.solves += 1;
        Ok(report)
    }

    /// [`SolveSession::solve`], additionally copying the solution into
    /// `x_out`.
    pub fn solve_into(&mut self, rhs: &[f64], x_out: &mut [f64]) -> Result<CgReport> {
        let report = self.solve(rhs)?;
        if x_out.len() != self.x.len() {
            return Err(Error::Config(format!(
                "solve_into: x_out has {} dofs, problem has {}",
                x_out.len(),
                self.x.len()
            )));
        }
        x_out.copy_from_slice(&self.x);
        Ok(report)
    }

    /// Solve a batch of right-hand sides in order, reusing all state
    /// between entries; returns one report per entry. Equivalent to (and
    /// tested against) N independent solves — a fused operator's
    /// per-apply state cannot leak between entries because every solve
    /// runs the full CG loop from a fresh `x = 0`.
    pub fn solve_batch<R: AsRef<[f64]>>(&mut self, rhss: &[R]) -> Result<Vec<CgReport>> {
        rhss.iter().map(|rhs| self.solve(rhs.as_ref())).collect()
    }

    /// The solution field of the most recent solve (zeros before the
    /// first). The buffer is allocated once per session — its address is
    /// stable across solves.
    pub fn solution(&self) -> &[f64] {
        &self.x
    }

    /// Number of solves completed in this session.
    pub fn solves(&self) -> usize {
        self.solves
    }

    /// The underlying application's operator label.
    pub fn operator_label(&self) -> String {
        self.app.operator_label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cfg() -> RunConfig {
        RunConfig { nelt: 8, n: 4, niter: 20, ..Default::default() }
    }

    #[test]
    fn session_solve_matches_set_rhs_run() {
        let mut a = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = a.mesh().ndof_local();
        let rhs = crate::rng::Rng::new(11).normal_vec(ndof);

        let mut b = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        b.set_rhs(&rhs).unwrap();
        let mut x_run = vec![0.0; ndof];
        let want = b.run_into(Some(&mut x_run)).unwrap();

        let mut session = a.session();
        let mut x_session = vec![0.0; ndof];
        let rep = session.solve_into(&rhs, &mut x_session).unwrap();
        assert_eq!(rep.iterations, want.iterations);
        assert_eq!(rep.final_rnorm, want.final_residual);
        crate::proputil::assert_allclose(&x_session, &x_run, 1e-15, 1e-15);
        assert_eq!(session.solves(), 1);
    }

    #[test]
    fn solution_buffer_is_stable_across_solves() {
        // The no-allocation contract, probed by address: the session's
        // solution buffer must never reallocate between solves.
        let mut app = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = app.mesh().ndof_local();
        let rhs_a = crate::rng::Rng::new(1).normal_vec(ndof);
        let rhs_b = crate::rng::Rng::new(2).normal_vec(ndof);
        let mut session = app.session();
        let ptr0 = session.solution().as_ptr();
        session.solve(&rhs_a).unwrap();
        assert_eq!(session.solution().as_ptr(), ptr0);
        session.solve(&rhs_b).unwrap();
        assert_eq!(session.solution().as_ptr(), ptr0);
        assert_eq!(session.solves(), 2);
    }

    #[test]
    fn session_rejects_mis_sized_inputs() {
        let mut app = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = app.mesh().ndof_local();
        let mut session = app.session();
        assert!(session.solve(&vec![0.0; ndof + 1]).is_err());
        let rhs = vec![1.0; ndof];
        let mut short = vec![0.0; ndof - 1];
        assert!(session.solve_into(&rhs, &mut short).is_err());
    }
}
