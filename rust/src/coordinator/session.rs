//! Solve sessions: serve many right-hand sides against one setup.
//!
//! Building a [`Nekbone`] application is the expensive part — mesh
//! numbering, geometric factors, gather–scatter tables, operator setup
//! (thread-pool spawn, artifact load/upload). A [`SolveSession`] borrows a
//! built application and runs repeated solves against it with **zero
//! per-solve allocation or re-setup**: the operator, the gather–scatter,
//! the CG workspace, and the session's solution buffer are all created
//! once and reused. This is the multi-RHS serving entry point — the
//! "one setup, many requests" shape a production deployment needs.
//!
//! ```no_run
//! use nekbone::config::RunConfig;
//! use nekbone::coordinator::Nekbone;
//!
//! let cfg = RunConfig { nelt: 64, n: 10, niter: 100, ..RunConfig::default() };
//! let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
//! let ndof = app.mesh().ndof_local();
//! let mut session = app.session();
//! let reports = session
//!     .solve_batch(&[vec![1.0; ndof], vec![2.0; ndof]])
//!     .unwrap();
//! println!("batch of {} solves, last |r| = {:e}",
//!          reports.len(), reports.last().unwrap().final_rnorm);
//! ```

use super::pipeline::SolveState;
use crate::config::RunConfig;
use crate::coordinator::Nekbone;
use crate::error::{Error, Result};
use crate::solver::{CgReport, NativeVectors};

/// The session-boundary shape check shared by both session types: a
/// `Config` error that names both dof counts, so a network client (or a
/// batch caller) learns what it sent and what the mesh wanted.
fn check_rhs_len(got: usize, want: usize) -> Result<()> {
    if got != want {
        return Err(Error::Config(format!(
            "session solve: rhs has {got} dofs, this session solves {want}"
        )));
    }
    Ok(())
}

/// Prefix a batch entry's error with its index (batch callers otherwise
/// cannot tell which RHS was rejected).
fn tag_batch_entry(i: usize, e: Error) -> Error {
    match e {
        Error::Config(msg) => Error::Config(format!("batch entry {i}: {msg}")),
        other => other,
    }
}

/// A multi-RHS solve session over one built [`Nekbone`] application (see
/// the module docs). Create with [`Nekbone::session`].
///
/// Each [`SolveSession::solve`] stages the given right-hand side through
/// the application (dssum-consistent, masked — exactly like
/// [`Nekbone::set_rhs`]) and runs the crate's one CG loop against the
/// application's operator and reused workspace. Solver options
/// (`niter`, `rtol`, `record_residuals`) come from the application's
/// [`RunConfig`](crate::config::RunConfig). Sessions always run the
/// native vector path.
pub struct SolveSession<'a> {
    app: &'a mut Nekbone,
    /// Reused solution buffer (allocated once at session creation).
    x: Vec<f64>,
    solves: usize,
}

impl Nekbone {
    /// Open a solve session: repeated [`SolveSession::solve`] /
    /// [`SolveSession::solve_batch`] calls reuse this application's
    /// operator state and CG workspace without allocating.
    ///
    /// # Examples
    ///
    /// ```
    /// use nekbone::config::RunConfig;
    /// use nekbone::coordinator::Nekbone;
    ///
    /// let cfg = RunConfig { nelt: 2, n: 3, niter: 5, ..RunConfig::default() };
    /// let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    /// let ndof = app.mesh().ndof_local();
    /// let mut session = app.session();
    /// for seed in 0..3u64 {
    ///     let rhs = nekbone::rng::Rng::new(seed).normal_vec(ndof);
    ///     let report = session.solve(&rhs).unwrap();
    ///     assert_eq!(report.iterations, 5);
    /// }
    /// assert_eq!(session.solves(), 3);
    /// assert_eq!(session.solution().len(), ndof);
    /// ```
    pub fn session(&mut self) -> SolveSession<'_> {
        let ndof = self.mesh().ndof_local();
        SolveSession { app: self, x: vec![0.0; ndof], solves: 0 }
    }
}

impl SolveSession<'_> {
    /// Solve `A x = rhs`; the solution is retained in
    /// [`SolveSession::solution`] until the next solve. The rhs is staged
    /// the way the application stages its built-in one (dssum + mask), so
    /// a session solve of RHS `b` is identical to
    /// `app.set_rhs(b); app.run()` — minus the per-call allocations.
    pub fn solve(&mut self, rhs: &[f64]) -> Result<CgReport> {
        check_rhs_len(rhs.len(), self.x.len())?;
        self.app.set_rhs(rhs)?;
        let (report, _ax_seconds) =
            self.app.solve_once(&mut self.x, &mut NativeVectors)?;
        self.solves += 1;
        Ok(report)
    }

    /// [`SolveSession::solve`], additionally copying the solution into
    /// `x_out`.
    pub fn solve_into(&mut self, rhs: &[f64], x_out: &mut [f64]) -> Result<CgReport> {
        let report = self.solve(rhs)?;
        if x_out.len() != self.x.len() {
            return Err(Error::Config(format!(
                "solve_into: x_out has {} dofs, problem has {}",
                x_out.len(),
                self.x.len()
            )));
        }
        x_out.copy_from_slice(&self.x);
        Ok(report)
    }

    /// Solve a batch of right-hand sides in order, reusing all state
    /// between entries; returns one [`CgReport`] per entry (iterations,
    /// final rnorm — everything a serving protocol echoes back per RHS).
    /// Equivalent to (and tested against) N independent solves — a fused
    /// operator's per-apply state cannot leak between entries because
    /// every solve runs the full CG loop from a fresh `x = 0`. A
    /// mis-sized entry fails with a `Config` error naming its index.
    pub fn solve_batch<R: AsRef<[f64]>>(&mut self, rhss: &[R]) -> Result<Vec<CgReport>> {
        rhss.iter()
            .enumerate()
            .map(|(i, rhs)| self.solve(rhs.as_ref()).map_err(|e| tag_batch_entry(i, e)))
            .collect()
    }

    /// The solution field of the most recent solve (zeros before the
    /// first). The buffer is allocated once per session — its address is
    /// stable across solves.
    pub fn solution(&self) -> &[f64] {
        &self.x
    }

    /// Number of solves completed in this session.
    pub fn solves(&self) -> usize {
        self.solves
    }

    /// The underlying application's operator label.
    pub fn operator_label(&self) -> String {
        self.app.operator_label()
    }
}

/// An owning, `Send` solve session: the serve-time half of a built
/// [`Nekbone`] (its [`SolveState`]) plus the session buffers, with the
/// build-time mesh numbering and basis tables dropped. Create with
/// [`Nekbone::into_session`].
///
/// This is the session shape a serving process caches and moves between
/// threads: build the application wherever convenient (an acceptor
/// thread, a warm-up pass), convert, and hand the session to the shard
/// worker that owns its mesh. Semantics are identical to the borrowing
/// [`SolveSession`] — same staging, same single CG loop, same
/// zero-per-solve-allocation contract — and the conformance suite holds
/// the two bitwise-equal.
///
/// ```
/// use nekbone::config::RunConfig;
/// use nekbone::coordinator::Nekbone;
///
/// let cfg = RunConfig { nelt: 2, n: 3, niter: 5, ..RunConfig::default() };
/// let app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
/// let mut session = app.into_session(); // mesh/basis tables dropped here
/// let rhs = vec![1.0; session.ndof()];
/// let report = session.solve(&rhs).unwrap();
/// assert_eq!(report.iterations, 5);
/// ```
pub struct OwnedSession {
    cfg: RunConfig,
    state: SolveState,
    /// Reused solution buffer (allocated once at session creation).
    x: Vec<f64>,
    solves: usize,
}

impl OwnedSession {
    /// Assemble from a split application (see [`Nekbone::into_session`]).
    pub(crate) fn from_parts(cfg: RunConfig, state: SolveState) -> Self {
        let ndof = state.ndof();
        OwnedSession { cfg, state, x: vec![0.0; ndof], solves: 0 }
    }

    /// Local dofs this session solves over (`nelt * n^3`).
    pub fn ndof(&self) -> usize {
        self.state.ndof()
    }

    /// The configuration the session was built with (solver options,
    /// problem shape).
    pub fn cfg(&self) -> &RunConfig {
        &self.cfg
    }

    /// Solve `A x = rhs`; the solution is retained in
    /// [`OwnedSession::solution`] until the next solve. Identical staging
    /// and solve path to [`SolveSession::solve`].
    pub fn solve(&mut self, rhs: &[f64]) -> Result<CgReport> {
        check_rhs_len(rhs.len(), self.x.len())?;
        self.state.stage_rhs(rhs);
        let (report, _ax_seconds) =
            self.state.solve(&self.cfg, &mut self.x, &mut NativeVectors)?;
        self.solves += 1;
        Ok(report)
    }

    /// [`OwnedSession::solve`], additionally copying the solution into
    /// `x_out`.
    pub fn solve_into(&mut self, rhs: &[f64], x_out: &mut [f64]) -> Result<CgReport> {
        let report = self.solve(rhs)?;
        if x_out.len() != self.x.len() {
            return Err(Error::Config(format!(
                "solve_into: x_out has {} dofs, problem has {}",
                x_out.len(),
                self.x.len()
            )));
        }
        x_out.copy_from_slice(&self.x);
        Ok(report)
    }

    /// Solve a batch of right-hand sides in order; one report per entry,
    /// mis-sized entries rejected with their index (see
    /// [`SolveSession::solve_batch`]).
    pub fn solve_batch<R: AsRef<[f64]>>(&mut self, rhss: &[R]) -> Result<Vec<CgReport>> {
        rhss.iter()
            .enumerate()
            .map(|(i, rhs)| self.solve(rhs.as_ref()).map_err(|e| tag_batch_entry(i, e)))
            .collect()
    }

    /// The solution field of the most recent solve (zeros before the
    /// first); address-stable across solves.
    pub fn solution(&self) -> &[f64] {
        &self.x
    }

    /// Number of solves completed in this session.
    pub fn solves(&self) -> usize {
        self.solves
    }

    /// The operator's display label (canonical registry name).
    pub fn operator_label(&self) -> String {
        self.state.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cfg() -> RunConfig {
        RunConfig { nelt: 8, n: 4, niter: 20, ..Default::default() }
    }

    #[test]
    fn session_solve_matches_set_rhs_run() {
        let mut a = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = a.mesh().ndof_local();
        let rhs = crate::rng::Rng::new(11).normal_vec(ndof);

        let mut b = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        b.set_rhs(&rhs).unwrap();
        let mut x_run = vec![0.0; ndof];
        let want = b.run_into(Some(&mut x_run)).unwrap();

        let mut session = a.session();
        let mut x_session = vec![0.0; ndof];
        let rep = session.solve_into(&rhs, &mut x_session).unwrap();
        assert_eq!(rep.iterations, want.iterations);
        assert_eq!(rep.final_rnorm, want.final_residual);
        crate::proputil::assert_allclose(&x_session, &x_run, 1e-15, 1e-15);
        assert_eq!(session.solves(), 1);
    }

    #[test]
    fn solution_buffer_is_stable_across_solves() {
        // The no-allocation contract, probed by address: the session's
        // solution buffer must never reallocate between solves.
        let mut app = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = app.mesh().ndof_local();
        let rhs_a = crate::rng::Rng::new(1).normal_vec(ndof);
        let rhs_b = crate::rng::Rng::new(2).normal_vec(ndof);
        let mut session = app.session();
        let ptr0 = session.solution().as_ptr();
        session.solve(&rhs_a).unwrap();
        assert_eq!(session.solution().as_ptr(), ptr0);
        session.solve(&rhs_b).unwrap();
        assert_eq!(session.solution().as_ptr(), ptr0);
        assert_eq!(session.solves(), 2);
    }

    #[test]
    fn session_rejects_mis_sized_inputs() {
        let mut app = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = app.mesh().ndof_local();
        let mut session = app.session();
        assert!(session.solve(&vec![0.0; ndof + 1]).is_err());
        let rhs = vec![1.0; ndof];
        let mut short = vec![0.0; ndof - 1];
        assert!(session.solve_into(&rhs, &mut short).is_err());
    }

    #[test]
    fn mis_sized_rhs_is_config_error_naming_both_counts() {
        // The session boundary is what a network protocol fronts: the
        // rejection must be an `Error::Config` telling the client what it
        // sent and what the mesh wanted — for both session types.
        let mut app = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = app.mesh().ndof_local();
        let mut session = app.session();
        let err = session.solve(&vec![0.0; 7]).unwrap_err();
        match &err {
            Error::Config(msg) => {
                assert!(msg.contains('7') && msg.contains(&ndof.to_string()), "{msg}")
            }
            other => panic!("want Config, got {other:?}"),
        }
        drop(session);

        let mut owned = app.into_session();
        let err = owned.solve(&vec![0.0; 7]).unwrap_err();
        assert!(matches!(&err, Error::Config(m)
            if m.contains('7') && m.contains(&ndof.to_string())), "{err}");

        // Batch rejection names the offending entry.
        let good = vec![1.0; ndof];
        let err =
            owned.solve_batch(&[good.as_slice(), &[0.0; 3], good.as_slice()]).unwrap_err();
        assert!(matches!(&err, Error::Config(m) if m.contains("batch entry 1")), "{err}");
    }

    #[test]
    fn owned_session_matches_borrowing_session() {
        // `into_session` drops the build-time half; the solves it serves
        // must stay bitwise-identical to the borrowing session's.
        let mut a = Nekbone::builder(cfg()).operator("cpu-spec").build().unwrap();
        let b = Nekbone::builder(cfg()).operator("cpu-spec").build().unwrap();
        let ndof = a.mesh().ndof_local();
        let mut owned = b.into_session();
        assert_eq!(owned.ndof(), ndof);
        assert_eq!(owned.operator_label(), "cpu-spec");
        let mut session = a.session();
        for seed in [3u64, 4, 5] {
            let rhs = crate::rng::Rng::new(seed).normal_vec(ndof);
            let want = session.solve(&rhs).unwrap();
            let got = owned.solve(&rhs).unwrap();
            assert_eq!(got.iterations, want.iterations);
            assert_eq!(got.final_rnorm.to_bits(), want.final_rnorm.to_bits());
            assert_eq!(owned.solution(), session.solution());
        }
        assert_eq!(owned.solves(), 3);
    }

    #[test]
    fn owned_session_crosses_threads() {
        // The serve hand-off shape: build on this thread, solve on
        // another, answers unchanged.
        fn assert_send<T: Send>() {}
        assert_send::<OwnedSession>();
        assert_send::<Nekbone>();

        let mut a = Nekbone::builder(cfg()).operator("cpu-layered").build().unwrap();
        let ndof = a.mesh().ndof_local();
        let rhs = crate::rng::Rng::new(9).normal_vec(ndof);
        let want = a.session().solve(&rhs).unwrap();
        let mut owned = a.into_session();
        let rhs2 = rhs.clone();
        let (got, x) = std::thread::spawn(move || {
            let rep = owned.solve(&rhs2).unwrap();
            (rep, owned.solution().to_vec())
        })
        .join()
        .unwrap();
        assert_eq!(got.iterations, want.iterations);
        assert_eq!(got.final_rnorm.to_bits(), want.final_rnorm.to_bits());
        assert_eq!(x.len(), ndof);
    }
}
