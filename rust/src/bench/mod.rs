//! Benchmark harness (criterion is unavailable offline, so the repo carries
//! its own measurement core: warmup, repeated timed runs, median/MAD
//! statistics, and aligned table printing shared by all paper-figure
//! benches). The measured-roofline harness — machine ceilings, per-operator
//! arithmetic intensity, `BENCH_roofline.json` emission — lives in
//! [`roofline`].

pub mod roofline;

use std::time::Instant;

/// Statistics of repeated measurements (seconds).
#[derive(Clone, Debug)]
pub struct Samples {
    /// Raw samples, sorted ascending.
    pub sorted: Vec<f64>,
}

impl Samples {
    pub fn from_raw(mut raw: Vec<f64>) -> Self {
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Samples { sorted: raw }
    }

    /// Median sample.
    pub fn median(&self) -> f64 {
        let n = self.sorted.len();
        assert!(n > 0, "no samples");
        if n % 2 == 1 {
            self.sorted[n / 2]
        } else {
            0.5 * (self.sorted[n / 2 - 1] + self.sorted[n / 2])
        }
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut devs: Vec<f64> = self.sorted.iter().map(|s| (s - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Samples { sorted: devs }.median()
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Relative spread (MAD/median) — the paper reports <5% run-to-run.
    pub fn rel_spread(&self) -> f64 {
        self.mad() / self.median()
    }
}

/// Benchmark runner configuration, overridable from the environment so
/// `cargo bench` can be made quick (CI) or thorough:
/// `NEKBONE_BENCH_WARMUP`, `NEKBONE_BENCH_SAMPLES`.
#[derive(Clone, Copy, Debug)]
pub struct Runner {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Runner {
    fn default() -> Self {
        let env_usize = |k: &str, dflt: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(dflt)
        };
        Runner {
            warmup: env_usize("NEKBONE_BENCH_WARMUP", 1),
            samples: env_usize("NEKBONE_BENCH_SAMPLES", 3),
        }
    }
}

impl Runner {
    /// Time `f` (seconds per call) with warmup + repeats.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Samples {
        for _ in 0..self.warmup {
            f();
        }
        let mut raw = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            f();
            raw.push(t0.elapsed().as_secs_f64());
        }
        Samples::from_raw(raw)
    }
}

/// Fixed-width table printer for bench output (the "rows the paper
/// reports").
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for c in 0..ncol {
                if c > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:>width$}", cells[c], width = widths[c]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(Samples::from_raw(vec![3.0, 1.0, 2.0]).median(), 2.0);
        assert_eq!(Samples::from_raw(vec![4.0, 1.0, 2.0, 3.0]).median(), 2.5);
    }

    #[test]
    fn mad_constant_is_zero() {
        let s = Samples::from_raw(vec![2.0; 5]);
        assert_eq!(s.mad(), 0.0);
        assert_eq!(s.rel_spread(), 0.0);
    }

    #[test]
    fn runner_times_something() {
        let r = Runner { warmup: 1, samples: 3 };
        let mut count = 0;
        let s = r.run(|| {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(count, 4); // 1 warmup + 3 samples
        assert!(s.median() >= 0.0);
        assert!(s.min() <= s.max());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "gflops"]);
        t.row(&["layered".into(), "1.25".into()]);
        t.row(&["x".into(), "100.00".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
