//! Measured-roofline harness: machine ceilings + per-operator placement,
//! emitted as `BENCH_roofline.json`.
//!
//! The paper's central evidence (Fig. 4) is not "the kernel got faster"
//! but "the kernel reaches 77–92% of what the *measured* machine allows".
//! This module reproduces that methodology on the host:
//!
//! 1. **Bandwidth ceiling** — a STREAM-style triad (`a[i] = b[i] + s*c[i]`)
//!    over buffers far larger than cache; 24 bytes move per element per
//!    pass (two reads + one write).
//! 2. **Compute ceiling** — register-resident multiply-add chains across
//!    independent accumulators (2 flops each), no memory traffic.
//! 3. **Operator placement** — each operator's arithmetic intensity is
//!    `flops() / bytes_moved()` (both [`AxOperator`] hooks); its roof is
//!    `min(peak, intensity * bandwidth)` and the achieved GFLOP/s are
//!    reported as a percentage of that roof.
//!
//! The JSON schema (`nekbone-roofline/1`, documented in `ROADMAP.md`) is
//! append-friendly: stable keys `operator`, `degree`, `elements`,
//! `gflops`, `percent_of_roofline` per point, so successive PRs emit
//! comparable trajectories. Run it via `cargo bench --bench roofline` or
//! `nekbone roofline --bench-json <path>`.
//!
//! Relation to [`crate::roofline`]: that module implements the paper's
//! *solve-level* emulation (every load/store of a CG iteration replaced
//! by a copy of the same bytes, Eq. (2) intensity) and feeds the Fig. 4
//! comparison; this one measures *kernel-level* machine ceilings and uses
//! each operator's own traffic model. Keep ceiling-measurement fixes
//! (timers, `black_box` discipline) in sync between the two.

use crate::basis::Basis;
use crate::bench::{Runner, Samples, Table};
use crate::error::{Error, Result};
use crate::geometry::GeomFactors;
use crate::mesh::Mesh;
use crate::metrics::Stopwatch;
use crate::operators::{
    ax_flops, cg_bytes_moved, cg_flops, fused_ax_flops, AxOperator, OperatorCtx,
    OperatorRegistry,
};

/// Schema identifier written into (and asserted on) every emitted file.
pub const SCHEMA: &str = "nekbone-roofline/1";

/// Measured machine ceilings.
#[derive(Clone, Copy, Debug)]
pub struct MachineRoofs {
    /// Sustained STREAM-triad bandwidth, GB/s.
    pub bandwidth_gbs: f64,
    /// Sustained register-resident multiply-add rate, GFLOP/s.
    pub peak_gflops: f64,
}

/// STREAM-style triad bandwidth over `len` f64 elements per array:
/// `a[i] = b[i] + s * c[i]`, counted as 24 bytes per element per pass
/// (read `b`, read `c`, write `a`; write-allocate traffic is not
/// counted, matching STREAM's own accounting).
pub fn measure_stream_bandwidth(len: usize, reps: usize) -> f64 {
    let len = len.max(1);
    let reps = reps.max(1);
    // black_box the inputs: with compile-time-known b/c/scalar the triad
    // is provably a constant splat, and LLVM could drop both read streams
    // (turning the measurement into a fill). Opaque values force real
    // loads.
    let scalar = std::hint::black_box(3.0f64);
    let mut a = vec![0.0f64; len];
    let b = std::hint::black_box(vec![1.0f64; len]);
    let c = std::hint::black_box(vec![2.0f64; len]);
    let triad = |a: &mut [f64], b: &[f64], c: &[f64]| {
        for ((ai, bi), ci) in a.iter_mut().zip(b).zip(c) {
            *ai = bi + scalar * ci;
        }
    };
    // Warmup faults the pages in.
    triad(&mut a, &b, &c);
    let sw = Stopwatch::start();
    for _ in 0..reps {
        triad(&mut a, &b, &c);
        std::hint::black_box(&mut a);
    }
    let secs = sw.elapsed_s();
    let bytes = (3 * 8 * len * reps) as f64;
    bytes / secs / 1e9
}

/// Scalar lanes of the peak-FLOP measurement.
///
/// Must be large enough that, after vectorization, the number of
/// independent vector chains covers multiply-add latency × issue ports
/// (~4–5 cycles × 2 ports): with 32 scalar lanes an AVX2 target gets 8
/// independent 4-wide chains, enough to keep both FMA pipes full. Too few
/// chains measures *latency*, not throughput, and an optimized kernel
/// could then "exceed" the roof.
const PEAK_LANES: usize = 32;

/// FMA-contracted multiply-add chains, compiled with the same
/// `target_feature` set as the explicit-SIMD operators: the ceiling the
/// `cpu-simd*` kernels are held to must itself be measured with fused
/// multiply-adds, or a kernel issuing real `vfmadd` could exceed a
/// mul-then-add "peak" (reporting > 100% of roofline).
///
/// # Safety
///
/// Caller must have verified AVX2+FMA support at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn madd_chains_fma(acc: &mut [f64; PEAK_LANES], m: f64, a: f64, reps: usize) {
    for _ in 0..reps {
        for slot in acc.iter_mut() {
            *slot = slot.mul_add(m, a);
        }
    }
}

/// Peak-FLOP ceiling: `PEAK_LANES` (32) independent multiply-add chains
/// (`x = x * m + a`, 2 flops) that never touch memory. The iteration map
/// has fixed point `a / (1 - m)`, so the accumulators stay bounded and
/// finite for any rep count.
///
/// Dispatches exactly like the operators it bounds
/// ([`crate::operators::simd_arm`]): hosts where the `cpu-simd*` kernels
/// run fused multiply-adds get an FMA-contracted ceiling, everywhere else
/// the portable mul-then-add chain is the honest peak.
pub fn measure_peak_flops(reps: usize) -> f64 {
    let reps = reps.max(1);
    let m = std::hint::black_box(0.999_999_f64);
    let a = std::hint::black_box(1.0e-6_f64);
    let mut acc = [0.0f64; PEAK_LANES];
    for (l, slot) in acc.iter_mut().enumerate() {
        *slot = 0.5 + l as f64 * 0.125;
    }
    let fma = crate::operators::simd_arm() == crate::operators::SimdArm::Avx2;
    let sw = Stopwatch::start();
    if fma {
        // SAFETY: `simd_arm()` just verified AVX2+FMA support at runtime.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            madd_chains_fma(&mut acc, m, a, reps);
        };
    } else {
        for _ in 0..reps {
            for slot in acc.iter_mut() {
                *slot = *slot * m + a;
            }
        }
    }
    let secs = sw.elapsed_s();
    std::hint::black_box(acc);
    (2 * PEAK_LANES * reps) as f64 / secs / 1e9
}

/// Measure both ceilings. `quick` shrinks the working set and rep counts
/// to smoke-test scale (CI); the quick bandwidth number may be
/// cache-inflated and is not comparable to a full run.
pub fn measure_machine(quick: bool) -> MachineRoofs {
    let (len, bw_reps, flop_reps) =
        if quick { (1 << 16, 3, 1_000_000) } else { (4 << 20, 10, 40_000_000) };
    MachineRoofs {
        bandwidth_gbs: measure_stream_bandwidth(len, bw_reps),
        peak_gflops: measure_peak_flops(flop_reps),
    }
}

/// One operator/degree point on the measured roofline.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    /// Canonical operator-registry name.
    pub operator: String,
    /// GLL points per dimension (`n` = polynomial degree + 1).
    pub degree: usize,
    /// Local element count of the measured problem.
    pub elements: usize,
    /// Achieved GFLOP/s (best sample; flops from the operator's own
    /// [`flops`](crate::operators::AxOperator::flops) hook).
    pub gflops: f64,
    /// `100 * gflops / roof_gflops`.
    pub percent_of_roofline: f64,
    /// Arithmetic intensity, flop/byte (`flops() / bytes_moved()`).
    pub intensity: f64,
    /// The binding roof for this point: `min(peak, intensity * bw)`.
    pub roof_gflops: f64,
    /// Best per-apply seconds.
    pub seconds: f64,
}

/// A full harness run: the machine ceilings, host diagnostics (which
/// dispatch arm the kernels and ceilings actually ran — without these a
/// committed trajectory point cannot be compared across hosts), and
/// every measured point.
#[derive(Clone, Debug)]
pub struct RooflineReport {
    pub roofs: MachineRoofs,
    /// Worker threads the threaded operators ran with (resolved: 0 in
    /// the config means all cores, this is the actual count).
    pub threads: usize,
    /// Compile-time target arm: `"avx2"` when the crate was built with
    /// AVX2 in the baseline target features, else `"generic"`.
    pub target_cpu: String,
    /// Runtime SIMD dispatch arm ([`crate::operators::simd_arm`]) — the
    /// arm the `cpu-simd*` kernels and the FMA peak ceiling used.
    pub simd_arm: String,
    pub points: Vec<RooflinePoint>,
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct RooflineConfig {
    /// Operator-registry names to place on the roofline.
    pub operators: Vec<String>,
    /// Degrees (`n`, GLL points per dimension) to measure each at.
    pub degrees: Vec<usize>,
    /// Local element count of the measured problem (honored as given,
    /// quick mode included).
    pub elements: usize,
    /// Worker threads for threaded operators (0 = all cores).
    pub threads: usize,
    /// Artifact directory for AOT-compiled (`xla-*`) operators.
    pub artifacts_dir: String,
    /// Smoke-test scale (CI): minimal apply reps/samples and shrunken
    /// machine-ceiling measurements. Does not change the problem shape.
    pub quick: bool,
    /// Also measure the `cg-iteration*` point family: whole CG iterations
    /// (Ax + the solver's vector algebra) timed through full solves, with
    /// flops from [`cg_flops`] and bytes from [`cg_bytes_moved`], for the
    /// unfused/fused × unblocked/blocked grid. These points show the
    /// whole-solve intensity moving under `--block-dofs`, not just
    /// per-apply GFLOP/s; keys stay schema-identical, purely additive.
    pub cg_points: bool,
}

impl Default for RooflineConfig {
    /// The acceptance set: generic vs degree-specialized vs explicit-SIMD,
    /// unfused and fused, f64 and reduced-storage f32 twins, at the
    /// paper's degree sweep.
    fn default() -> Self {
        RooflineConfig {
            operators: vec![
                "cpu-layered".into(),
                "cpu-spec".into(),
                "cpu-simd".into(),
                "cpu-layered-fused".into(),
                "cpu-spec-fused".into(),
                "cpu-simd-fused".into(),
                "cpu-layered-f32".into(),
                "cpu-spec-f32".into(),
                "cpu-simd-f32".into(),
                "cpu-layered-fused-f32".into(),
                "cpu-spec-fused-f32".into(),
                "cpu-simd-fused-f32".into(),
                "cpu-asm".into(),
                "cpu-asm-fused".into(),
                "cpu-asm-f32".into(),
                "cpu-asm-fused-f32".into(),
            ],
            degrees: vec![5, 9, 11],
            elements: 64,
            threads: 0,
            artifacts_dir: "artifacts".into(),
            quick: false,
            cg_points: true,
        }
    }
}

/// [`run_with`] against the process-wide shared operator registry.
pub fn run(cfg: &RooflineConfig) -> Result<RooflineReport> {
    run_with(cfg, crate::operators::registry())
}

/// Run the harness: measure the machine ceilings once, then time every
/// (operator, degree) pair's `apply` and place it on the roofline. The
/// registry is a parameter so runtime-registered operators (the
/// registry's extension point) can be measured too.
///
/// Enforces the fused-flops contract for every operator it measures: a
/// fused operator must report [`fused_ax_flops`] and an unfused one
/// [`ax_flops`] — the count the paper's Eq. (1) assigns to the work the
/// kernel actually performs — and errors (no panic) on a mismatch.
pub fn run_with(cfg: &RooflineConfig, registry: &OperatorRegistry) -> Result<RooflineReport> {
    // Fail fast on unknown operator names before spending seconds on the
    // machine-ceiling measurements.
    for name in &cfg.operators {
        registry.resolve(name)?;
    }
    let roofs = measure_machine(cfg.quick);
    let elements = cfg.elements;
    // The strict Eq. (1) equality only binds names that belong to the
    // built-in family; a runtime-registered operator may model its flops
    // however it honestly can (it just can't report none at all).
    let builtins = crate::operators::registry();
    let mut points = Vec::new();
    for &n in &cfg.degrees {
        let mesh = Mesh::for_nelt(elements, n)?;
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let c = mesh.inv_multiplicity();
        let ndof = mesh.ndof_local();
        let u = crate::rng::Rng::new(0xBE2C).normal_vec(ndof);
        let mut w = vec![0.0; ndof];
        // Assembly fold plan so the `cpu-asm*` family measures its real
        // schedule (dssum + mask inside the sweep) — and reports the
        // assembled byte model — rather than the plain-layered fallback.
        let mask = mesh.boundary_mask();
        let gs = crate::gs::GatherScatter::new(&mesh);
        let plan = gs.assembly_plan(n * n * n, Some(&mask))?;
        let ctx = OperatorCtx {
            n,
            nelt: mesh.nelt(),
            chunk: mesh.nelt(),
            threads: cfg.threads,
            artifacts_dir: &cfg.artifacts_dir,
            d: &basis.d,
            g: &geom.g,
            c: &c,
            assemble: Some(&plan),
        };
        for name in &cfg.operators {
            let mut op = registry.build(name, &ctx)?;
            let flops = op.flops();
            if flops == 0 {
                return Err(Error::Config(format!(
                    "operator {name:?} reports no flops(); cannot place it on the \
                     roofline"
                )));
            }
            let want = if op.is_fused() {
                fused_ax_flops(n, mesh.nelt())
            } else {
                ax_flops(n, mesh.nelt())
            };
            if builtins.contains(&op.label()) && flops != want {
                return Err(Error::Config(format!(
                    "operator {name:?}: flops() = {flops} but the Eq. (1) count for \
                     its fusion class is {want}; fix the operator's flop model"
                )));
            }
            let bytes = op.bytes_moved();
            if bytes == 0 {
                return Err(Error::Config(format!(
                    "operator {name:?} reports no bytes_moved(); cannot place it on \
                     the roofline"
                )));
            }
            // Batch applies so one sample is long enough to time, then
            // take the best sample (the standard roofline estimator: least
            // interference, closest to the machine's capability).
            let reps = if cfg.quick {
                1
            } else {
                ((2e8 / flops as f64).ceil() as usize).clamp(1, 500)
            };
            let runner = if cfg.quick {
                Runner { warmup: 1, samples: 2 }
            } else {
                Runner { warmup: 2, samples: 5 }
            };
            let samples: Samples = runner.run(|| {
                for _ in 0..reps {
                    op.apply(&u, &mut w).expect("roofline apply");
                    std::hint::black_box(&mut w);
                }
            });
            let seconds = samples.min() / reps as f64;
            if seconds <= 0.0 {
                // A zero-duration sample would serialize as a silent bogus
                // trajectory point (inf → 0.0 in JSON); fail loudly instead.
                return Err(Error::Numerical(format!(
                    "operator {name:?} at n={n}: timed sample was 0s; raise reps"
                )));
            }
            let gflops = flops as f64 / seconds / 1e9;
            let intensity = flops as f64 / bytes as f64;
            let roof = roofs.peak_gflops.min(intensity * roofs.bandwidth_gbs);
            points.push(RooflinePoint {
                operator: op.label(),
                degree: n,
                elements: mesh.nelt(),
                gflops,
                percent_of_roofline: 100.0 * gflops / roof,
                intensity,
                roof_gflops: roof,
                seconds,
            });
        }
        if cfg.cg_points {
            // Whole-iteration points: time full CG solves (serial path,
            // reduce plan installed like the pipeline) and report
            // per-iteration GFLOP/s against the cg_flops / cg_bytes_moved
            // stream model. The blocked twins run the cache-blocked
            // pipeline — bitwise-identical trajectory, fewer vector
            // passes, so their intensity sits strictly higher.
            let mut rhs = crate::rng::Rng::new(0xC610).normal_vec(ndof);
            {
                let mut gs = crate::gs::GatherScatter::new(&mesh);
                gs.dssum(&mut rhs);
            }
            crate::solver::mask_apply(&mut rhs, &mask);
            let niter = if cfg.quick { 4 } else { 25 };
            let opts = crate::solver::CgOptions { niter, rtol: None, record_residuals: false };
            for (label, op_name, fused, blocked) in [
                ("cg-iteration", "cpu-layered", false, false),
                ("cg-iteration-blocked", "cpu-layered", false, true),
                ("cg-iteration-fused", "cpu-layered-fused", true, false),
                ("cg-iteration-fused-blocked", "cpu-layered-fused", true, true),
            ] {
                let mut op = registry.build(op_name, &ctx)?;
                let mut x = vec![0.0; ndof];
                let mut ws = crate::solver::CgWorkspace::new(ndof);
                ws.set_reduce_plan(n * n * n, (0..mesh.nelt() as u64).collect())?;
                if blocked {
                    ws.set_iteration_plan(crate::config::AUTO_BLOCK_DOFS.min(ndof).max(1))?;
                }
                let mut gs = crate::gs::GatherScatter::new(&mesh);
                let runner = if cfg.quick {
                    Runner { warmup: 1, samples: 2 }
                } else {
                    Runner { warmup: 1, samples: 3 }
                };
                let mut iterations = 1usize;
                let samples: Samples = runner.run(|| {
                    let rep = crate::solver::cg_solve_op(
                        op.as_mut(),
                        &mut gs,
                        &mut crate::solver::NullComm,
                        Some(&mask),
                        &c,
                        &rhs,
                        &mut x,
                        &opts,
                        &mut ws,
                    )
                    .expect("roofline cg solve");
                    iterations = rep.iterations.max(1);
                    std::hint::black_box(&mut x);
                });
                let seconds = samples.min() / iterations as f64;
                if seconds <= 0.0 {
                    return Err(Error::Numerical(format!(
                        "{label} at n={n}: timed sample was 0s; raise niter"
                    )));
                }
                // `cpu-layered*` leave assembly to the solver, so the
                // stored (not assembled) Ax byte model applies.
                let flops = cg_flops(n, mesh.nelt(), fused);
                let bytes = cg_bytes_moved(n, mesh.nelt(), fused, false, blocked);
                let gflops = flops as f64 / seconds / 1e9;
                let intensity = flops as f64 / bytes as f64;
                let roof = roofs.peak_gflops.min(intensity * roofs.bandwidth_gbs);
                points.push(RooflinePoint {
                    operator: label.into(),
                    degree: n,
                    elements: mesh.nelt(),
                    gflops,
                    percent_of_roofline: 100.0 * gflops / roof,
                    intensity,
                    roof_gflops: roof,
                    seconds,
                });
            }
        }
    }
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.threads
    };
    let target_cpu = if cfg!(target_feature = "avx2") { "avx2" } else { "generic" };
    Ok(RooflineReport {
        roofs,
        threads,
        target_cpu: target_cpu.into(),
        simd_arm: crate::operators::simd_arm().to_string(),
        points,
    })
}

/// Render the report as the aligned table the benches print.
pub fn render_table(report: &RooflineReport) -> String {
    let mut table = Table::new(&[
        "operator",
        "n",
        "elems",
        "flop/byte",
        "roof(GF/s)",
        "achieved(GF/s)",
        "% of roof",
    ]);
    for p in &report.points {
        table.row(&[
            p.operator.clone(),
            p.degree.to_string(),
            p.elements.to_string(),
            format!("{:.3}", p.intensity),
            format!("{:.3}", p.roof_gflops),
            format!("{:.3}", p.gflops),
            format!("{:.1}%", p.percent_of_roofline),
        ]);
    }
    table.render()
}

/// A JSON number that is always valid JSON (non-finite values, which JSON
/// cannot represent, become 0).
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9}")
    } else {
        "0.0".into()
    }
}

fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Serialize a report in the `nekbone-roofline/1` schema.
pub fn to_json(report: &RooflineReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": {},\n", jstr(SCHEMA)));
    out.push_str(&format!("  \"bandwidth_gbs\": {},\n", jnum(report.roofs.bandwidth_gbs)));
    out.push_str(&format!("  \"peak_gflops\": {},\n", jnum(report.roofs.peak_gflops)));
    out.push_str(&format!("  \"threads\": {},\n", report.threads));
    out.push_str(&format!("  \"target_cpu\": {},\n", jstr(&report.target_cpu)));
    out.push_str(&format!("  \"simd_arm\": {},\n", jstr(&report.simd_arm)));
    out.push_str("  \"points\": [\n");
    for (i, p) in report.points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"operator\": {}, \"degree\": {}, \"elements\": {}, \
             \"gflops\": {}, \"percent_of_roofline\": {}, \
             \"intensity_flop_per_byte\": {}, \"roof_gflops\": {}, \
             \"seconds\": {}}}{}\n",
            jstr(&p.operator),
            p.degree,
            p.elements,
            jnum(p.gflops),
            jnum(p.percent_of_roofline),
            jnum(p.intensity),
            jnum(p.roof_gflops),
            jnum(p.seconds),
            if i + 1 < report.points.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Validate a serialized report against the `nekbone-roofline/1` schema
/// (used by the bench after writing, and by CI's smoke job).
pub fn validate_json(text: &str) -> Result<()> {
    let doc = crate::json::parse(text)?;
    let bad = |msg: &str| Error::Config(format!("roofline json: {msg}"));
    if doc.get("schema").and_then(|v| v.as_str()) != Some(SCHEMA) {
        return Err(bad(&format!("\"schema\" must be {SCHEMA:?}")));
    }
    for key in ["bandwidth_gbs", "peak_gflops"] {
        doc.get(key).and_then(|v| v.as_f64()).ok_or_else(|| bad(&format!("missing {key}")))?;
    }
    // Host diagnostics: required since they make trajectory points
    // comparable across hosts (a generic-arm point is not an avx2
    // regression).
    doc.get("threads").and_then(|v| v.as_usize()).ok_or_else(|| bad("missing threads"))?;
    for key in ["target_cpu", "simd_arm"] {
        doc.get(key).and_then(|v| v.as_str()).ok_or_else(|| bad(&format!("missing {key}")))?;
    }
    let points =
        doc.get("points").and_then(|v| v.as_array()).ok_or_else(|| bad("missing points"))?;
    if points.is_empty() {
        return Err(bad("points must be non-empty"));
    }
    for p in points {
        p.get("operator").and_then(|v| v.as_str()).ok_or_else(|| bad("point operator"))?;
        p.get("degree").and_then(|v| v.as_usize()).ok_or_else(|| bad("point degree"))?;
        p.get("elements").and_then(|v| v.as_usize()).ok_or_else(|| bad("point elements"))?;
        p.get("gflops").and_then(|v| v.as_f64()).ok_or_else(|| bad("point gflops"))?;
        p.get("percent_of_roofline")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| bad("point percent_of_roofline"))?;
    }
    Ok(())
}

/// Write a report to `path` (schema-validated round trip).
pub fn write_json(report: &RooflineReport, path: &str) -> Result<()> {
    let text = to_json(report);
    validate_json(&text)?;
    std::fs::write(path, &text).map_err(|source| Error::Io { path: path.to_string(), source })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> RooflineConfig {
        RooflineConfig {
            degrees: vec![3, 5],
            elements: 2,
            quick: true,
            ..RooflineConfig::default()
        }
    }

    #[test]
    fn ceilings_positive_and_sane() {
        let roofs = measure_machine(true);
        assert!(roofs.bandwidth_gbs > 0.01, "bw {}", roofs.bandwidth_gbs);
        assert!(roofs.bandwidth_gbs < 100_000.0, "bw {}", roofs.bandwidth_gbs);
        assert!(roofs.peak_gflops > 0.01, "peak {}", roofs.peak_gflops);
        assert!(roofs.peak_gflops < 10_000.0, "peak {}", roofs.peak_gflops);
    }

    /// The cg-iteration family: 4 variants per degree when enabled.
    const CG_VARIANTS: usize = 4;

    #[test]
    fn harness_covers_every_operator_degree_pair() {
        let cfg = quick_cfg();
        let report = run(&cfg).unwrap();
        assert_eq!(
            report.points.len(),
            (cfg.operators.len() + CG_VARIANTS) * cfg.degrees.len()
        );
        for p in &report.points {
            assert!(
                p.gflops > 0.0 && p.gflops.is_finite(),
                "{}: gflops {}",
                p.operator,
                p.gflops
            );
            assert!(p.roof_gflops > 0.0 && p.roof_gflops.is_finite());
            assert!(p.percent_of_roofline > 0.0 && p.percent_of_roofline.is_finite());
            assert!(p.intensity > 0.0 && p.intensity.is_finite());
        }
        // Fused points carry the extra c stream: higher intensity
        // numerator and denominator, same degree ordering.
        let by = |name: &str, n: usize| {
            report
                .points
                .iter()
                .find(|p| p.operator == name && p.degree == n)
                .unwrap_or_else(|| panic!("missing point {name}/{n}"))
                .clone()
        };
        for &n in &cfg.degrees {
            let plain = by("cpu-layered", n);
            let fused = by("cpu-layered-fused", n);
            assert!(fused.intensity < plain.intensity * 1.2);
        }
        let table = render_table(&report);
        assert!(table.contains("cpu-spec"));
    }

    #[test]
    fn f32_points_sit_higher_on_the_roofline_than_their_f64_siblings() {
        // Reduced storage halves the six geometric-factor streams of the
        // per-point traffic with an unchanged flop count, so each f32
        // point's arithmetic intensity must exceed its f64 sibling's by
        // exactly the stream ratio. Stored accounting (sweep + standalone
        // dssum/mask re-stream): 80/56 unfused, 88/64 fused; assembled
        // accounting (`cpu-asm*`, no re-stream): 64/40 unfused, 72/48
        // fused.
        let report = run(&quick_cfg()).unwrap();
        let by = |name: &str, n: usize| {
            report
                .points
                .iter()
                .find(|p| p.operator == name && p.degree == n)
                .unwrap_or_else(|| panic!("missing point {name}/{n}"))
                .clone()
        };
        for &n in &[3usize, 5] {
            for (f32_name, f64_name, ratio) in [
                ("cpu-layered-f32", "cpu-layered", 80.0 / 56.0),
                ("cpu-spec-f32", "cpu-spec", 80.0 / 56.0),
                ("cpu-simd-f32", "cpu-simd", 80.0 / 56.0),
                ("cpu-layered-fused-f32", "cpu-layered-fused", 88.0 / 64.0),
                ("cpu-spec-fused-f32", "cpu-spec-fused", 88.0 / 64.0),
                ("cpu-simd-fused-f32", "cpu-simd-fused", 88.0 / 64.0),
                ("cpu-asm-f32", "cpu-asm", 64.0 / 40.0),
                ("cpu-asm-fused-f32", "cpu-asm-fused", 72.0 / 48.0),
            ] {
                let a = by(f32_name, n);
                let b = by(f64_name, n);
                assert!(
                    a.intensity > b.intensity,
                    "{f32_name}/{n}: {} must exceed {f64_name}'s {}",
                    a.intensity,
                    b.intensity
                );
                let got = a.intensity / b.intensity;
                assert!(
                    (got - ratio).abs() < 1e-9,
                    "{f32_name}/{n}: intensity ratio {got} vs stream ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn assembled_points_sit_strictly_above_their_stored_siblings() {
        // ISSUE 9 acceptance: folding dssum + mask into the sweep drops
        // the standalone pass's 16 bytes/point re-stream of `w`, so every
        // `cpu-asm*` point must report strictly higher intensity than its
        // `cpu-*` sibling — by exactly the stream ratio (the pinned
        // per-point byte models live in `operators::ax_bytes_moved_*`).
        let report = run(&quick_cfg()).unwrap();
        let by = |name: &str, n: usize| {
            report
                .points
                .iter()
                .find(|p| p.operator == name && p.degree == n)
                .unwrap_or_else(|| panic!("missing point {name}/{n}"))
                .clone()
        };
        for &n in &[3usize, 5] {
            for (asm_name, sib_name, ratio) in [
                ("cpu-asm", "cpu-layered", 80.0 / 64.0),
                ("cpu-asm-fused", "cpu-layered-fused", 88.0 / 72.0),
                ("cpu-asm-f32", "cpu-layered-f32", 56.0 / 40.0),
                ("cpu-asm-fused-f32", "cpu-layered-fused-f32", 64.0 / 48.0),
            ] {
                let a = by(asm_name, n);
                let s = by(sib_name, n);
                assert!(
                    a.intensity > s.intensity,
                    "{asm_name}/{n}: {} must exceed {sib_name}'s {}",
                    a.intensity,
                    s.intensity
                );
                let got = a.intensity / s.intensity;
                assert!(
                    (got - ratio).abs() < 1e-9,
                    "{asm_name}/{n}: intensity ratio {got} vs stream ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn cg_iteration_points_show_blocked_intensity_gain() {
        // ISSUE 10 acceptance: the cg-iteration family shows whole-solve
        // intensity moving under `--block-dofs`, not just per-apply
        // GFLOP/s. Blocking folds the solver's separate z / rtz / tail
        // passes into one cache-resident walk, dropping 24 bytes/dof from
        // the per-iteration stream while the flop count is untouched, so
        // each blocked point's intensity must exceed its unblocked twin's
        // by exactly the pinned byte-model ratio.
        let cfg = quick_cfg();
        assert!(cfg.cg_points, "cg points must default on");
        let report = run(&cfg).unwrap();
        let by = |name: &str, n: usize| {
            report
                .points
                .iter()
                .find(|p| p.operator == name && p.degree == n)
                .unwrap_or_else(|| panic!("missing point {name}/{n}"))
                .clone()
        };
        for &n in &cfg.degrees {
            for (blocked_name, flat_name, fused) in [
                ("cg-iteration-blocked", "cg-iteration", false),
                ("cg-iteration-fused-blocked", "cg-iteration-fused", true),
            ] {
                let b = by(blocked_name, n);
                let f = by(flat_name, n);
                assert!(
                    b.intensity > f.intensity,
                    "{blocked_name}/{n}: {} must exceed {flat_name}'s {}",
                    b.intensity,
                    f.intensity
                );
                let ratio = cg_bytes_moved(n, cfg.elements, fused, false, false) as f64
                    / cg_bytes_moved(n, cfg.elements, fused, false, true) as f64;
                let got = b.intensity / f.intensity;
                assert!(
                    (got - ratio).abs() < 1e-9,
                    "{blocked_name}/{n}: intensity ratio {got} vs stream ratio {ratio}"
                );
                for p in [&b, &f] {
                    assert!(p.gflops > 0.0 && p.gflops.is_finite());
                    assert!(p.seconds > 0.0 && p.seconds.is_finite());
                }
            }
        }
        // Opting out removes exactly the cg family and nothing else.
        let mut off = quick_cfg();
        off.cg_points = false;
        let plain = run(&off).unwrap();
        assert_eq!(
            plain.points.len(),
            off.operators.len() * off.degrees.len()
        );
        assert!(plain.points.iter().all(|p| !p.operator.starts_with("cg-iteration")));
    }

    #[test]
    fn json_round_trips_schema() {
        let report = run(&quick_cfg()).unwrap();
        let text = to_json(&report);
        validate_json(&text).unwrap();
        let doc = crate::json::parse(&text).unwrap();
        // Host diagnostics survive the round trip.
        assert!(doc.get("threads").unwrap().as_usize().unwrap() >= 1);
        let arm = doc.get("simd_arm").unwrap().as_str().unwrap().to_string();
        assert_eq!(arm, crate::operators::simd_arm().to_string());
        let target = doc.get("target_cpu").unwrap().as_str().unwrap();
        assert!(target == "avx2" || target == "generic", "{target}");
        let points = doc.get("points").unwrap().as_array().unwrap();
        assert_eq!(points.len(), report.points.len());
        assert_eq!(
            points[0].get("operator").unwrap().as_str().unwrap(),
            report.points[0].operator
        );
        assert_eq!(
            points[0].get("degree").unwrap().as_usize().unwrap(),
            report.points[0].degree
        );
    }

    #[test]
    fn validation_rejects_missing_keys() {
        assert!(validate_json("{}").is_err());
        assert!(validate_json("not json").is_err());
        const HOST: &str = "\"threads\": 2, \"target_cpu\": \"avx2\", \"simd_arm\": \"avx2\"";
        let no_host = format!(
            "{{\"schema\": \"{SCHEMA}\", \"bandwidth_gbs\": 1.0, \
             \"peak_gflops\": 1.0, \"points\": []}}"
        );
        assert!(validate_json(&no_host).is_err());
        let no_points = format!(
            "{{\"schema\": \"{SCHEMA}\", \"bandwidth_gbs\": 1.0, \
             \"peak_gflops\": 1.0, {HOST}, \"points\": []}}"
        );
        assert!(validate_json(&no_points).is_err());
        let bad_point = format!(
            "{{\"schema\": \"{SCHEMA}\", \"bandwidth_gbs\": 1.0, \
             \"peak_gflops\": 1.0, {HOST}, \"points\": [{{\"operator\": \"x\"}}]}}"
        );
        assert!(validate_json(&bad_point).is_err());
    }

    #[test]
    fn json_numbers_stay_finite() {
        assert_eq!(jnum(f64::NAN), "0.0");
        assert_eq!(jnum(f64::INFINITY), "0.0");
        assert_eq!(jnum(1.5), "1.500000000");
        assert_eq!(jstr("a\"b"), "\"a\\\"b\"");
    }
}
