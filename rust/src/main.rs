//! `nekbone` — the launcher binary.
//!
//! See `nekbone help` (or [`nekbone::cli::usage`]) for the interface.
//! Backends are resolved by name through the operator registry (the
//! `--backend` help list is generated from it); `nekbone info` lists
//! everything registered.

use nekbone::bench::Table;
use nekbone::cli::{parse_elems, usage, Args};
use nekbone::coordinator::{Nekbone, VectorBackend};
use nekbone::error::Result;
use nekbone::operators::registry;
use nekbone::rank::run_ranked;
use nekbone::roofline;
use nekbone::runtime::Manifest;
use nekbone::serve;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&raw) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dispatch(raw: &[String]) -> Result<()> {
    if raw.is_empty() || raw[0] == "help" || raw[0] == "--help" {
        print!("{}", usage());
        return Ok(());
    }
    let args = Args::parse(raw)?;
    match args.subcommand.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "roofline" => cmd_roofline(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "scenarios" => cmd_scenarios(&args),
        "info" => cmd_info(&args),
        other => {
            eprint!("unknown subcommand {other:?}\n\n{}", usage());
            std::process::exit(2);
        }
    }
}

/// Resolve `--backend` to its canonical operator name through the
/// registry — the one dispatch surface: aliases resolve, unknown names
/// error listing every registered operator.
fn operator_of(args: &Args) -> Result<String> {
    Ok(registry().resolve(args.get("backend").unwrap_or("xla-layered"))?.name.clone())
}

/// Ranked run honoring an explicitly chosen `--backend`; without one the
/// rank runtime keeps its CPU default (the multi-rank analog of the
/// paper's CPU/MPI baseline, and the only operator that needs no
/// artifacts).
fn ranked_report(args: &Args, cfg: &nekbone::config::RunConfig) -> Result<nekbone::coordinator::RunReport> {
    match args.get("backend") {
        Some(_) => nekbone::rank::run_ranked_with(cfg, &operator_of(args)?),
        None => run_ranked(cfg),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.run_config()?;
    let operator = operator_of(args)?;
    let vb = VectorBackend::parse(args.get("vector-backend").unwrap_or("rust"))?;

    if cfg.ranks > 1 {
        let report = ranked_report(args, &cfg)?;
        println!("{}", report.summary());
        return Ok(());
    }
    let mut app = Nekbone::builder(cfg)
        .operator(operator)
        .vector_backend(vb)
        .build()?;
    let report = app.run()?;
    println!("{}", report.summary());
    let cm = report.cost_model();
    println!(
        "  cost model: {} flops/iter, intensity {:.4} flop/byte, ax time {:.3}s ({:.2} GF/s kernel-level)",
        cm.flops_per_iter(),
        cm.intensity(),
        report.ax_seconds,
        report.ax_gflops(),
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = args.run_config()?;
    let operator = operator_of(args)?;
    let elems = parse_elems(args.get("elems").unwrap_or("64,128,256,512,1024"))?;
    let mut table = Table::new(&["backend", "nelt", "dof", "time(s)", "GFlop/s", "residual"]);
    for nelt in elems {
        let cfg = nekbone::config::RunConfig { nelt, ..base.clone() };
        let report = if cfg.ranks > 1 {
            ranked_report(args, &cfg)?
        } else {
            Nekbone::builder(cfg).operator(operator.as_str()).build()?.run()?
        };
        table.row(&[
            report.backend.clone(),
            report.nelt.to_string(),
            (report.nelt * report.n.pow(3)).to_string(),
            format!("{:.3}", report.seconds),
            format!("{:.3}", report.gflops()),
            format!("{:.3e}", report.final_residual),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_roofline(args: &Args) -> Result<()> {
    // `--bench-json PATH`: the measured kernel-roofline harness (the same
    // measurement as `cargo bench --bench roofline`), instead of the
    // solve-level Fig. 4 comparison below. Honors `--backend` (one
    // operator instead of the default four), `--n` (one degree instead of
    // 5/9/11), `--nelt`, and `--cpu-threads`; the other solve options
    // don't apply to a kernel-level measurement.
    if let Some(path) = args.get("bench-json") {
        let mut cfg = nekbone::bench::roofline::RooflineConfig {
            quick: args.flag("quick"),
            ..Default::default()
        };
        if args.get("backend").is_some() {
            cfg.operators = vec![operator_of(args)?];
        }
        if args.get("n").is_some() {
            let n = args.get_usize("n", 0)?;
            if n < 2 {
                return Err(nekbone::error::Error::Config(format!("--n must be >= 2, got {n}")));
            }
            cfg.degrees = vec![n];
        }
        cfg.elements = args.get_usize("nelt", cfg.elements)?;
        cfg.threads = args.get_usize("cpu-threads", cfg.threads)?;
        if let Some(dir) = args.get("artifacts") {
            cfg.artifacts_dir = dir.to_string();
        }
        let report = nekbone::bench::roofline::run(&cfg)?;
        println!(
            "# ceilings: {:.2} GB/s stream bandwidth, {:.2} GF/s peak multiply-add",
            report.roofs.bandwidth_gbs, report.roofs.peak_gflops
        );
        print!("{}", nekbone::bench::roofline::render_table(&report));
        nekbone::bench::roofline::write_json(&report, path)?;
        println!("# wrote {path} ({} points)", report.points.len());
        return Ok(());
    }
    let base = args.run_config()?;
    let operator = operator_of(args)?;
    let elems = parse_elems(args.get("elems").unwrap_or("256,512,1024,2048,4096"))?;
    let mut table = Table::new(&[
        "nelt",
        "dof",
        "bw(GB/s)",
        "roofline(GF/s)",
        "achieved(GF/s)",
        "fraction",
    ]);
    for nelt in elems {
        // The paper's methodology: communication off for both sides.
        let cfg = nekbone::config::RunConfig { nelt, no_comm: true, ..base.clone() };
        let n = cfg.n;
        let (bw, roof) = roofline::roofline_for(n, nelt, 5);
        let mut app = Nekbone::builder(cfg).operator(operator.as_str()).build()?;
        let report = app.run()?;
        let achieved = report.gflops();
        table.row(&[
            nelt.to_string(),
            (nelt * n.pow(3)).to_string(),
            format!("{:.2}", bw.bandwidth_gbs),
            format!("{roof:.3}"),
            format!("{achieved:.3}"),
            format!("{:.1}%", 100.0 * achieved / roof),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = serve::ServeConfig::from_args(args)?;
    let server = serve::Server::bind(&cfg)?;
    serve::install_sigint_handler();
    println!(
        "nekbone serve: listening on {} ({} shards, queue {}, batch {}, niter {})",
        server.local_addr()?,
        cfg.shards,
        cfg.queue,
        cfg.batch,
        cfg.niter
    );
    println!("  protocol: newline-delimited JSON; Ctrl-C or {{\"op\":\"shutdown\"}} drains");
    let report = server.run()?;
    println!("nekbone serve: drained after {} connections", report.connections);
    for s in &report.shards {
        println!(
            "  shard {}: {} reqs, {} batches, cache {}/{} hit/miss, peak depth {}",
            s.shard, s.requests, s.batches, s.cache_hits, s.cache_misses, s.max_depth
        );
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let cfg = serve::LoadgenConfig::from_args(args)?;
    let report = serve::run_loadgen(&cfg)?;
    print!("{}", serve::render_summary(&report));
    if let Some(path) = &cfg.bench_json {
        serve::write_json(&report, path)?;
        println!("# wrote {path} (schema nekbone-serve/1)");
    }
    if report.errors > 0 {
        return Err(nekbone::error::Error::Config(format!(
            "loadgen: {} request(s) failed",
            report.errors
        )));
    }
    Ok(())
}

fn cmd_scenarios(args: &Args) -> Result<()> {
    let cfg = nekbone::scenario::ScenarioConfig::from_args(args)?;
    let report = nekbone::scenario::run(&cfg)?;
    print!("{}", nekbone::scenario::render_table(&report));
    if report.skipped > 0 {
        println!(
            "# skipped {} infeasible (shape, ranks, elements) combination(s)",
            report.skipped
        );
    }
    if let Some(path) = &cfg.json {
        nekbone::scenario::write_json(&report, path)?;
        println!("# wrote {path} (schema {})", nekbone::scenario::SCHEMA);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    println!("nekbone-rs (reproduction of Karp et al. 2020)");
    let registry = registry();
    println!("registered operators:");
    for name in registry.known_names() {
        let spec = registry.resolve(&name)?;
        if spec.name == name {
            let kind = if spec.needs_artifacts { "xla artifacts" } else { "cpu" };
            println!("  {name:<24} [{kind}]");
        } else {
            println!("  {name:<24} [alias of {}]", spec.name);
        }
    }
    match Manifest::load(dir) {
        Ok(m) => {
            println!("artifacts dir: {dir} ({} entries)", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:<36} kind={:<8?} variant={:<16} n={:<3} chunk={}",
                    a.name, a.kind, a.variant, a.n, a.chunk
                );
            }
        }
        Err(e) => println!("artifacts dir {dir}: not loadable ({e}); run `make artifacts`"),
    }
    match nekbone::runtime::XlaRuntime::new(dir) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform_name()),
        Err(e) => println!("PJRT runtime unavailable: {e}"),
    }
    Ok(())
}
