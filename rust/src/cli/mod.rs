//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `nekbone <subcommand> [--key value | --flag]...`.

use std::collections::BTreeMap;

use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::operators::OperatorRegistry;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (without argv[0]).
    pub fn parse(raw: &[String]) -> Result<Self> {
        if raw.is_empty() {
            return Err(Error::Config("missing subcommand; try `nekbone help`".into()));
        }
        let subcommand = raw[0].clone();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 1;
        while i < raw.len() {
            let tok = &raw[i];
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --option, got {tok:?}")))?;
            if let Some((k, v)) = key.split_once('=') {
                opts.insert(k.to_string(), v.to_string());
                i += 1;
            } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                opts.insert(key.to_string(), raw[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Args { subcommand, opts, flags })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, dflt: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(dflt),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_u64(&self, name: &str, dflt: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(dflt),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64_opt(&self, name: &str) -> Result<Option<f64>> {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::Config(format!("--{name} expects a number, got {v:?}"))),
        }
    }

    /// Assemble a [`RunConfig`] from the common options.
    pub fn run_config(&self) -> Result<RunConfig> {
        let dflt = RunConfig::default();
        let cfg = RunConfig {
            nelt: self.get_usize("nelt", dflt.nelt)?,
            n: self.get_usize("n", dflt.n)?,
            niter: self.get_usize("niter", dflt.niter)?,
            chunk: self.get_usize("chunk", dflt.chunk)?,
            no_comm: self.flag("no-comm"),
            no_mask: self.flag("no-mask"),
            seed: self.get_u64("seed", dflt.seed)?,
            artifacts_dir: self.get("artifacts").unwrap_or(&dflt.artifacts_dir).to_string(),
            cpu_threads: self.get_usize("cpu-threads", dflt.cpu_threads)?,
            ranks: self.get_usize("ranks", dflt.ranks)?,
            rtol: self.get_f64_opt("rtol")?,
            record_residuals: self.flag("record-residuals"),
            precond: self.get("precond").unwrap_or(&dflt.precond).to_string(),
            cheb_order: self.get_usize("cheb-order", dflt.cheb_order)?,
            decomp: self.get("decomp").unwrap_or(&dflt.decomp).to_string(),
            block_dofs: self.get("block-dofs").unwrap_or(&dflt.block_dofs).to_string(),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Static head of the usage text: everything above the generated
/// `--backend` operator list.
const USAGE_HEAD: &str = "\
nekbone-rs - Nekbone tensor-product optimization reproduction (Karp et al. 2020)

USAGE: nekbone <subcommand> [options]

SUBCOMMANDS:
  run        run one Nekbone solve and print the report
  sweep      run a backend over a sweep of element counts (paper Figs. 2-3)
  roofline   measured-roofline comparison (paper Fig. 4)
  serve      serve solves over TCP (newline-delimited JSON protocol)
  loadgen    drive a running server; report in nekbone-serve/1 JSON
  scenarios  strong/weak-scaling campaign; nekbone-scaling/1 JSON
  info       list registered operators + manifest + platform information
  help       this text

COMMON OPTIONS (run/sweep/roofline):
  --nelt N           elements                      [64]
  --n N              GLL points per dim            [10]
  --niter N          CG iterations                 [100]
  --chunk N          elements per XLA launch       [64]
  --backend NAME     an operator-registry name     [xla-layered]
";

/// Static tail of the usage text: everything below the generated
/// `--backend` operator list.
const USAGE_TAIL: &str = "\
                     -fused backends compute the CG pap reduction inside
                     Ax (one fewer full-vector sweep per iteration);
                     cpu-spec* dispatch degree-specialized unrolled
                     kernels (n = 2..=12, layered fallback outside);
                     cpu-simd* add explicit AVX2+FMA vector kernels
                     (runtime-dispatched, scalar fallback elsewhere);
                     cpu-threaded* run the same simd dispatch on a
                     persistent worker pool
                     (`nekbone info` prints the live list)
  --vector-backend B rust | xla                    [rust]
  --ranks R          simulated MPI ranks [1]; with an explicit --backend
                     each rank runs that operator, else cpu-layered
  --decomp D         rank decomposition: slab | pencil | box [slab]
                     (z layers, z*y pencils, or z*y*x bricks; every shape
                     reproduces the serial answer bitwise)
  --artifacts DIR    artifact directory            [artifacts]
  --seed S           RHS seed                      [0x5EED]
  --rtol T           early-exit residual tolerance (default: none; run
                     the fixed niter like Nekbone). Honored identically
                     by serial and ranked runs (one shared solver)
  --record-residuals record |r| every iteration
  --block-dofs B     cache-blocked CG pipeline: auto | off | dofs per
                     segment [auto]. Blocked solves are bitwise identical
                     to unblocked; only CgReport.vector_sweeps drops
  --precond P        none | jacobi | cheb          [none]
  --cheb-order K     Chebyshev polynomial order for --precond cheb [4]
                     (each CG iteration costs K-1 extra Ax sweeps)
  --no-comm          skip gather-scatter (roofline methodology)
  --no-mask          skip the Dirichlet mask
  --cpu-threads T    threads for cpu-threaded (0 = all cores)
  --elems LIST       sweep: comma-separated element counts
  --bench-json PATH  roofline: run the measured kernel-roofline harness
                     (STREAM bandwidth + peak-FLOP ceilings, operators
                     placed by flops()/bytes_moved() intensity) and write
                     BENCH_roofline.json-schema output to PATH. Honors
                     --backend (one operator; default: cpu-layered,
                     cpu-spec, cpu-simd, their fused twins and the
                     reduced-storage -f32 twins of all six), --n (one
                     degree; default 5,9,11), --nelt, --cpu-threads and
                     --artifacts
  --quick            roofline: smoke-test scale for --bench-json
";

/// The generated `--backend` block: every canonical operator name with
/// its aliases inline, wrapped to the help text's option column. Built
/// from the process-wide [`crate::operators::registry`], so the list is
/// correct by construction — registering a builtin updates the help, and
/// no sync test has to police a hand-maintained copy.
fn backend_help_lines() -> String {
    let registry = crate::operators::registry();
    let entries: Vec<String> = registry
        .names()
        .iter()
        .map(|name| {
            let aliases = registry.aliases_of(name);
            if aliases.is_empty() {
                name.clone()
            } else {
                format!("{name} (alias {})", aliases.join(", "))
            }
        })
        .collect();
    const INDENT: &str = "                     "; // the option help column
    const WIDTH: usize = 58; // wrap the list short of 80 columns total
    let mut lines: Vec<String> = Vec::new();
    let mut line = String::from("built-ins: ");
    for (i, entry) in entries.iter().enumerate() {
        let piece = if i + 1 < entries.len() { format!("{entry} | ") } else { entry.clone() };
        if !line.is_empty() && !line.ends_with(": ") && line.len() + piece.len() > WIDTH {
            lines.push(line.trim_end().to_string());
            line = String::new();
        }
        line.push_str(&piece);
    }
    lines.push(line.trim_end().to_string());
    let mut out = String::new();
    for l in &lines {
        out.push_str(INDENT);
        out.push_str(l);
        out.push('\n');
    }
    out
}

/// Render one serve-layer option table from its [`crate::serve::OptSpec`]
/// rows — the same rows `ServeConfig::from_args` / `LoadgenConfig::from_args`
/// read their defaults from, so help and parser cannot drift.
fn opt_lines(opts: &[crate::serve::OptSpec]) -> String {
    let mut out = String::new();
    for o in opts {
        let head = if o.metavar.is_empty() {
            format!("  --{}", o.key)
        } else {
            format!("  --{} {}", o.key, o.metavar)
        };
        let dflt =
            if o.default.is_empty() { String::new() } else { format!(" [{}]", o.default) };
        out.push_str(&format!("{head:<21}{}{dflt}\n", o.help));
    }
    out
}

/// Top-level usage text. The `--backend` operator list is generated from
/// the process-wide operator registry and the serve/loadgen sections from
/// their `OptSpec` tables at call time, so the help can never drift from
/// what actually resolves or parses.
pub fn usage() -> String {
    format!(
        "{USAGE_HEAD}{}{USAGE_TAIL}\nSERVE OPTIONS (serve):\n{}\nLOADGEN OPTIONS (loadgen):\n{}\
         \nSCENARIO OPTIONS (scenarios):\n{}",
        backend_help_lines(),
        opt_lines(crate::serve::SERVE_OPTS),
        opt_lines(crate::serve::LOADGEN_OPTS),
        opt_lines(crate::scenario::SCENARIO_OPTS),
    )
}

/// Parse `--elems 64,128,256`-style lists.
pub fn parse_elems(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|_| Error::Config(format!("bad element count {t:?} in --elems")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args(&["run", "--nelt", "128", "--no-comm", "--n=8"]);
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get("nelt"), Some("128"));
        assert_eq!(a.get("n"), Some("8"));
        assert!(a.flag("no-comm"));
        assert!(!a.flag("no-mask"));
    }

    #[test]
    fn run_config_from_args() {
        let a = args(&["run", "--nelt", "256", "--niter", "10", "--no-mask"]);
        let cfg = a.run_config().unwrap();
        assert_eq!(cfg.nelt, 256);
        assert_eq!(cfg.niter, 10);
        assert!(cfg.no_mask);
        assert_eq!(cfg.n, 10); // default
        assert_eq!(cfg.rtol, None);
        assert!(!cfg.record_residuals);
    }

    #[test]
    fn solver_options_from_args() {
        let a = args(&["run", "--rtol", "1e-9", "--record-residuals"]);
        let cfg = a.run_config().unwrap();
        assert_eq!(cfg.rtol, Some(1e-9));
        assert!(cfg.record_residuals);
        // Bad / non-positive tolerances are rejected at parse/validate.
        assert!(args(&["run", "--rtol", "tiny"]).run_config().is_err());
        assert!(args(&["run", "--rtol", "-1e-9"]).run_config().is_err());
    }

    #[test]
    fn precond_options_from_args() {
        let a = args(&["run", "--precond", "cheb", "--cheb-order", "6"]);
        let cfg = a.run_config().unwrap();
        assert_eq!(cfg.precond, "cheb");
        assert_eq!(cfg.cheb_order, 6);
        let d = args(&["run"]).run_config().unwrap();
        assert_eq!(d.precond, "none");
        assert_eq!(d.cheb_order, 4);
        assert!(args(&["run", "--precond", "ilu"]).run_config().is_err());
        assert!(args(&["run", "--precond", "cheb", "--cheb-order", "0"])
            .run_config()
            .is_err());
    }

    #[test]
    fn decomp_option_from_args() {
        for shape in ["slab", "pencil", "box"] {
            let a = args(&["run", "--ranks", "2", "--decomp", shape]);
            assert_eq!(a.run_config().unwrap().decomp, shape);
        }
        assert_eq!(args(&["run"]).run_config().unwrap().decomp, "slab");
        assert!(args(&["run", "--decomp", "diag"]).run_config().is_err());
    }

    #[test]
    fn block_dofs_option_from_args() {
        assert_eq!(args(&["run"]).run_config().unwrap().block_dofs, "auto");
        for v in ["auto", "off", "512"] {
            let a = args(&["run", "--block-dofs", v]);
            assert_eq!(a.run_config().unwrap().block_dofs, v);
        }
        assert!(args(&["run", "--block-dofs", "0"]).run_config().is_err());
        assert!(args(&["run", "--block-dofs", "grid"]).run_config().is_err());
        // Above the global ndof (default 64_000) is a validate error too.
        assert!(args(&["run", "--block-dofs", "64001"]).run_config().is_err());
    }

    #[test]
    fn bad_integer_rejected() {
        let a = args(&["run", "--nelt", "many"]);
        assert!(a.run_config().is_err());
    }

    #[test]
    fn missing_subcommand_rejected() {
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn non_option_token_rejected() {
        assert!(Args::parse(&["run".into(), "stray".into()]).is_err());
    }

    #[test]
    fn usage_backend_list_is_generated_from_registry() {
        // The old hand-maintained list needed a sync test; this one only
        // checks the *rendering* (names survive wrapping, aliases shown
        // inline, lines stay within the help column) — completeness holds
        // by construction.
        let text = usage();
        let reg = OperatorRegistry::with_builtins();
        for name in reg.names() {
            assert!(text.contains(&name), "usage lost backend {name} in wrapping");
        }
        assert!(text.contains("(alias xla-openacc)"), "aliases must render inline:\n{text}");
        assert!(text.contains("(alias xla-fused)"), "aliases must render inline:\n{text}");
        for line in text.lines() {
            assert!(line.len() <= 80, "usage line too wide: {line:?}");
        }
    }

    #[test]
    fn usage_lists_every_serve_option_from_its_spec_table() {
        let text = usage();
        for (sub, opts) in [
            ("serve", crate::serve::SERVE_OPTS),
            ("loadgen", crate::serve::LOADGEN_OPTS),
            ("scenarios", crate::scenario::SCENARIO_OPTS),
        ] {
            assert!(text.contains(&format!("\n  {sub} ")), "SUBCOMMANDS must list {sub}");
            for o in opts {
                assert!(text.contains(&format!("--{}", o.key)), "usage lost --{}", o.key);
                assert!(text.contains(o.help), "usage lost the help for --{}", o.key);
            }
        }
    }

    #[test]
    fn elems_list() {
        assert_eq!(parse_elems("64, 128,256").unwrap(), vec![64, 128, 256]);
        assert!(parse_elems("64,x").is_err());
    }
}
