//! Deterministic randomized sweep of the explicit-SIMD family
//! (`cpu-simd`, `cpu-simd-fused`, and the simd-dispatched `cpu-threaded*`)
//! against the layered family, across every monomorphized degree, thread
//! count, and element count — plus the forced-fallback paths.
//!
//! Accuracy contract under test: on the scalar dispatch arm the SIMD
//! entry points are **bit-identical** to the layered/spec family; on the
//! AVX2 arm only FMA rounding may differ, bounded by a 1e-13 relative
//! band scaled with the field magnitude. Everything is seeded through
//! `rng::Rng`, so a failure reproduces exactly.

use nekbone::operators::{
    ax_layered, ax_layered_fused, ax_simd, ax_simd_fused, ax_simd_fused_with_arm,
    ax_simd_with_arm, simd_arm, OperatorCtx, OperatorRegistry, SimdArm,
};
use nekbone::proputil::assert_pap_close;
use nekbone::solver::glsc3;

mod util;
use crate::util::{assert_family_close, inputs};

fn ctx<'a>(
    n: usize,
    nelt: usize,
    threads: usize,
    d: &'a [f64],
    g: &'a [f64],
    c: &'a [f64],
) -> OperatorCtx<'a> {
    util::ctx(n, nelt, threads, "artifacts", d, g, c)
}

#[test]
fn simd_family_sweep_against_layered() {
    // N = 2..=12 (every monomorphized degree) × element counts × thread
    // counts: the registered simd operators and the simd-dispatched
    // threaded operators against the layered reference.
    let registry = OperatorRegistry::with_builtins();
    for n in 2..=12usize {
        for &nelt in &[1usize, 3, 5] {
            for &threads in &[1usize, 2, 3] {
                let seed = 0x51D0_0000 + (n as u64) * 64 + (nelt as u64) * 8 + threads as u64;
                let (u, d, g, c) = inputs(seed, n, nelt);
                let np = n * n * n;
                let what = format!("n={n} nelt={nelt} threads={threads}");

                let mut w_ref = vec![0.0; nelt * np];
                ax_layered(n, nelt, &u, &d, &g, &mut w_ref);
                // Single-thread simd reference for the bitwise pool checks.
                let mut w_simd = vec![0.0; nelt * np];
                ax_simd(n, nelt, &u, &d, &g, &mut w_simd);
                assert_family_close(&w_simd, &w_ref, &what);

                let cx = ctx(n, nelt, threads, &d, &g, &c);
                for name in ["cpu-simd", "cpu-threaded"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np]; // poisoned
                    op.apply(&u, &mut w).unwrap();
                    // Same kernel family, disjoint element ranges: every
                    // dispatch shape must be bit-identical to the
                    // single-thread simd apply.
                    assert_eq!(w, w_simd, "{name} {what}: w must match single-thread simd");
                }
                for name in ["cpu-simd-fused", "cpu-threaded-fused"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np];
                    op.apply(&u, &mut w).unwrap();
                    assert_eq!(w, w_simd, "{name} {what}: fused w must match unfused simd");
                    let pap = op.last_pap().expect("fused apply must produce pap");
                    let want = glsc3(&w, &c, &u);
                    assert_pap_close(pap, want, &w, &c, &u, 1e-12, &format!("{name} {what}"));
                }
            }
        }
    }
}

#[test]
fn forced_scalar_kernel_on_any_host_is_bit_identical_to_layered() {
    // The fallback-path test: force the scalar arm — on a SIMD-capable
    // host this bypasses the AVX2 dispatch — and require bit-identity
    // with the layered family at every monomorphized degree and one
    // fallback degree (n = 13, beyond the specialized table).
    for n in (2..=13usize).chain([16]) {
        let nelt = 2;
        let (u, d, g, c) = inputs(0xFA11 + n as u64, n, nelt);
        let np = n * n * n;
        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want);
        let mut got = vec![123.0; nelt * np];
        ax_simd_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g, &mut got);
        assert_eq!(got, want, "n={n}: forced scalar arm must equal layered bitwise");

        let mut w_l = vec![0.0; nelt * np];
        let pap_l = ax_layered_fused(n, nelt, &u, &d, &g, &c, &mut w_l);
        let mut w_s = vec![123.0; nelt * np];
        let pap_s = ax_simd_fused_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g, &c, &mut w_s);
        assert_eq!(w_s, w_l, "n={n}: forced scalar fused w");
        assert_eq!(pap_s.to_bits(), pap_l.to_bits(), "n={n}: forced scalar fused pap");
    }
}

#[test]
fn dispatch_arms_are_deterministic_and_degrade_safely() {
    let (n, nelt) = (9, 3);
    let (u, d, g, c) = inputs(0xDE7, n, nelt);
    let np = n * n * n;
    // Run-to-run determinism of whatever arm this host dispatches.
    let mut w1 = vec![0.0; nelt * np];
    let mut w2 = vec![0.0; nelt * np];
    let p1 = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut w1);
    let p2 = ax_simd_fused(n, nelt, &u, &d, &g, &c, &mut w2);
    assert_eq!(w1, w2, "dispatched arm must be deterministic");
    assert_eq!(p1.to_bits(), p2.to_bits());
    // Requesting AVX2 explicitly equals the dispatcher's own choice: on an
    // AVX2 host both run the vector kernel; on a scalar host the request
    // must degrade to the scalar arm instead of faulting.
    let mut w3 = vec![0.0; nelt * np];
    ax_simd_with_arm(SimdArm::Avx2, n, nelt, &u, &d, &g, &mut w3);
    match simd_arm() {
        SimdArm::Avx2 => assert_eq!(w3, w1, "avx2 request on an avx2 host"),
        SimdArm::Scalar => {
            let mut w_l = vec![0.0; nelt * np];
            ax_layered(n, nelt, &u, &d, &g, &mut w_l);
            assert_eq!(w3, w_l, "avx2 request on a scalar host must degrade to scalar");
        }
    }
}

#[test]
fn simd_operators_resolve_and_advertise_no_artifacts() {
    let registry = OperatorRegistry::with_builtins();
    for name in ["cpu-simd", "cpu-simd-fused"] {
        let spec = registry.resolve(name).unwrap();
        assert_eq!(spec.name, name);
        assert!(!spec.needs_artifacts, "{name} must run offline");
    }
    assert!(registry.create("cpu-simd-fused").unwrap().is_fused());
    assert!(!registry.create("cpu-simd").unwrap().is_fused());
}
