//! Table-driven negative-path coverage of the crate's `Error::Config`
//! surfaces: every rejection a user can trigger from the public API must
//! be a *structured* Config error whose message names the offending knob
//! and its limit — never a panic, never a silent fallback. Each table row
//! is one documented rejection; the suite fails if the message drifts
//! away from naming the problem.

mod util;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::error::Error;
use nekbone::operators::OperatorRegistry;

/// Assert `res` is an `Error::Config` whose message contains `needle`.
fn expect_config(res: Result<(), Error>, needle: &str, what: &str) {
    match res {
        Ok(()) => panic!("{what}: expected a Config error containing {needle:?}, got Ok"),
        Err(Error::Config(msg)) => assert!(
            msg.contains(needle),
            "{what}: Config message {msg:?} does not contain {needle:?}"
        ),
        Err(other) => panic!("{what}: expected Error::Config, got {other:?}"),
    }
}

#[test]
fn run_config_validation_names_each_bad_knob() {
    let table: &[(&str, fn(&mut RunConfig), &str)] = &[
        ("zero nelt", |c| c.nelt = 0, "nelt must be positive"),
        ("degree too low", |c| c.n = 1, "n must be >= 2"),
        ("zero niter", |c| c.niter = 0, "niter must be positive"),
        ("zero chunk", |c| c.chunk = 0, "chunk must be positive"),
        ("zero ranks", |c| c.ranks = 0, "ranks must be positive"),
        (
            "ranks above nelt",
            |c| {
                c.nelt = 4;
                c.ranks = 8;
            },
            "cannot exceed nelt",
        ),
        ("negative rtol", |c| c.rtol = Some(-1.0), "rtol must be positive"),
        ("nan rtol", |c| c.rtol = Some(f64::NAN), "rtol must be positive"),
        (
            "unknown precond",
            |c| c.precond = "ilu".into(),
            "precond must be none|jacobi|cheb",
        ),
        (
            "zero cheb order",
            |c| {
                c.precond = "cheb".into();
                c.cheb_order = 0;
            },
            "cheb-order must be >= 1",
        ),
        (
            "unknown decomp",
            |c| c.decomp = "spiral".into(),
            "decomp must be slab|pencil|box",
        ),
        ("zero block-dofs", |c| c.block_dofs = "0".into(), "block-dofs must be positive"),
        (
            "non-numeric block-dofs",
            |c| c.block_dofs = "grid".into(),
            "block-dofs must be auto|off|N",
        ),
        (
            "block-dofs above ndof",
            |c| {
                c.nelt = 2;
                c.n = 3;
                c.block_dofs = "55".into();
            },
            "cannot exceed ndof",
        ),
    ];
    for (what, mutate, needle) in table {
        let mut cfg = RunConfig::default();
        mutate(&mut cfg);
        expect_config(cfg.validate(), needle, what);
        // The builder front door must surface the same rejection — a bad
        // knob can never reach mesh construction.
        expect_config(
            Nekbone::builder(cfg).operator("cpu-layered").build().map(|_| ()),
            needle,
            &format!("builder: {what}"),
        );
    }
    assert!(RunConfig::default().validate().is_ok(), "the default config must be valid");
}

#[test]
fn fuzz_case_budget_parsing_is_loud_not_a_silent_fallback() {
    // The fuzz tier sizes its corpus from NEKBONE_FUZZ_CASES through this
    // parser; a CI typo ("24 ", "1e3", "") must be a structured Config
    // error naming the variable — never a silent fall-back to the default
    // budget, which would quietly shrink coverage.
    use nekbone::config::parse_cases_env;
    for bad in ["", "0", "-3", "many", "1e3", "24x"] {
        expect_config(
            parse_cases_env(bad).map(|_| ()),
            "NEKBONE_FUZZ_CASES",
            &format!("fuzz cases {bad:?}"),
        );
    }
    assert_eq!(parse_cases_env("24").unwrap(), 24);
    assert_eq!(parse_cases_env(" 7 ").unwrap(), 7, "surrounding whitespace is tolerated");
}

#[test]
fn iteration_plan_requires_a_reduce_plan_and_positive_blocks() {
    // The workspace-level contract behind --block-dofs: installing the
    // cache-blocking plan without a reduce plan (whose element blocks it
    // walks), or with a zero block size, is a structured rejection.
    use nekbone::solver::CgWorkspace;
    let mut ws = CgWorkspace::new(8);
    expect_config(ws.set_iteration_plan(4), "install a reduce plan first", "no reduce plan");
    ws.set_reduce_plan(2, vec![0, 1, 2, 3]).unwrap();
    expect_config(ws.set_iteration_plan(0), "block-dofs must be positive", "zero block");
    assert!(ws.set_iteration_plan(4).is_ok(), "a sized plan must install");
}

#[test]
fn operator_setup_and_apply_reject_missized_mesh_data() {
    let registry = OperatorRegistry::with_builtins();
    let (n, nelt) = (4usize, 3usize);
    let ndof = nelt * n * n * n;
    let (u, d, g, c) = util::inputs(0xBAD0, n, nelt);
    let table: &[(&str, &[f64], &[f64], &[f64], &str)] = &[
        ("short d", &d[..n * n - 1], &g, &c, "d must be n*n"),
        ("short g", &d, &g[..g.len() - 1], &c, "g must be nelt*6*n^3"),
    ];
    for (what, dd, gg, cc, needle) in table {
        let cx = util::ctx(n, nelt, 0, "artifacts", dd, gg, cc);
        expect_config(registry.build("cpu-layered", &cx).map(|_| ()), needle, what);
        // The same shape contract holds for the assembly-capable family.
        expect_config(registry.build("cpu-asm", &cx).map(|_| ()), needle, what);
    }
    // Fused operators additionally require the inner-product weights.
    let cx = util::ctx(n, nelt, 0, "artifacts", &d, &g, &c[..c.len() - 1]);
    expect_config(
        registry.build("cpu-layered-fused", &cx).map(|_| ()),
        "inner-product weights",
        "fused short c",
    );
    // Unfused operators must not demand c…
    let cx_no_c = util::ctx(n, nelt, 0, "artifacts", &d, &g, &c[..0]);
    assert!(registry.build("cpu-layered", &cx_no_c).is_ok(), "unfused must not require c");
    // …and apply checks the field lengths.
    let cx_ok = util::ctx(n, nelt, 0, "artifacts", &d, &g, &c);
    let mut op = registry.build("cpu-layered", &cx_ok).unwrap();
    let mut w = vec![0.0; ndof];
    expect_config(op.apply(&u[..ndof - 1], &mut w), "must be nelt*n^3", "short u");
    // A blank operator names itself when used before setup.
    let mut blank = registry.create("cpu-asm-fused").unwrap();
    expect_config(blank.apply(&u, &mut w), "used before setup", "apply before setup");
}

#[test]
fn mismatched_assembly_plan_is_rejected_at_setup() {
    // A fold plan sized for a different problem must be a structured
    // rejection naming both dof counts — not a silent fallback that would
    // let the solver skip a dssum the operator never performed.
    let registry = OperatorRegistry::with_builtins();
    let n = 4usize;
    let mesh = nekbone::mesh::Mesh::new(2, 2, 1, n).unwrap();
    let basis = nekbone::basis::Basis::new(n);
    let geom = nekbone::geometry::GeomFactors::affine(&mesh, &basis);
    let cw = mesh.inv_multiplicity();
    let other = nekbone::mesh::Mesh::new(2, 2, 2, 3).unwrap();
    let other_plan =
        nekbone::gs::GatherScatter::new(&other).assembly_plan(27, None).unwrap();
    let cx = nekbone::operators::OperatorCtx {
        n,
        nelt: mesh.nelt(),
        chunk: mesh.nelt(),
        threads: 0,
        artifacts_dir: "artifacts",
        d: &basis.d,
        g: &geom.g,
        c: &cw,
        assemble: Some(&other_plan),
    };
    for name in ["cpu-asm", "cpu-asm-fused", "cpu-asm-f32", "cpu-asm-fused-f32"] {
        expect_config(
            registry.build(name, &cx).map(|_| ()),
            "assembly plan covers",
            name,
        );
    }
}

#[test]
fn ranked_path_rejects_oversplit_axes_and_tag_overflow() {
    use nekbone::mesh::Mesh;
    use nekbone::rank::{run_ranked_with, DecompShape, Decomposition};
    // Direct decomposition table on a 2×2×2 element grid: each shape's
    // axis limits, each named in the error.
    let mesh = Mesh::for_nelt(8, 3).unwrap();
    let table: &[(&str, DecompShape, usize, &str)] = &[
        ("slab beyond z layers", DecompShape::Slab, 4, "slab decomposition of 4 ranks"),
        ("pencil beyond z*y", DecompShape::Pencil, 8, "pencil decomposition of 8 ranks"),
        ("box beyond all axes", DecompShape::Box, 16, "box decomposition of 16 ranks"),
    ];
    for (what, shape, ranks, needle) in table {
        expect_config(Decomposition::new(*shape, *ranks, &mesh).map(|_| ()), needle, what);
        expect_config(Decomposition::new(*shape, *ranks, &mesh).map(|_| ()), "infeasible", what);
    }
    expect_config(
        Decomposition::new(DecompShape::Slab, 0, &mesh).map(|_| ()),
        "at least one rank",
        "zero ranks",
    );
    // The ranked front door surfaces the same over-split rejection…
    let cfg = RunConfig {
        nelt: 8,
        n: 3,
        niter: 4,
        ranks: 4,
        decomp: "slab".into(),
        ..RunConfig::default()
    };
    expect_config(
        run_ranked_with(&cfg, "cpu-layered").map(|_| ()),
        "slab decomposition of 4 ranks",
        "ranked front door: over-split slab",
    );
    // …an unrepresentable niter tag (one exchange round per iteration
    // must fit the tag field)…
    let cfg = RunConfig {
        nelt: 8,
        n: 3,
        niter: 1usize << 32,
        ranks: 2,
        ..RunConfig::default()
    };
    expect_config(
        run_ranked_with(&cfg, "cpu-layered").map(|_| ()),
        "unrepresentable in the halo-exchange tag space",
        "ranked front door: niter tag overflow",
    );
    // …and the documented no-precondition contract.
    let cfg = RunConfig {
        nelt: 8,
        n: 3,
        niter: 4,
        ranks: 2,
        precond: "jacobi".into(),
        ..RunConfig::default()
    };
    expect_config(
        run_ranked_with(&cfg, "cpu-layered").map(|_| ()),
        "not supported on the ranked path",
        "ranked front door: precond",
    );
}

#[test]
fn serve_requests_reject_each_malformed_kind() {
    use nekbone::serve::protocol::parse_request;
    let table: &[(&str, &str, &str)] = &[
        ("missing op", r#"{"id": 1}"#, "request needs a string \"op\" field"),
        ("unknown op", r#"{"op": "reboot"}"#, "unknown op"),
        (
            "operator not a string",
            r#"{"op": "solve", "operator": 7, "n": 3, "nelt": 2, "rhs": []}"#,
            "operator must be a string",
        ),
        (
            "missing n",
            r#"{"op": "solve", "operator": "cpu-layered", "nelt": 2, "rhs": []}"#,
            "n must be an integer",
        ),
        (
            "missing nelt",
            r#"{"op": "solve", "operator": "cpu-layered", "n": 3, "rhs": []}"#,
            "nelt must be an integer",
        ),
        (
            "niter not an integer",
            r#"{"op": "solve", "operator": "cpu-layered", "n": 3, "nelt": 2, "niter": "many", "rhs": []}"#,
            "niter must be an integer",
        ),
        (
            "rhs not an array",
            r#"{"op": "solve", "operator": "cpu-layered", "n": 3, "nelt": 2, "rhs": 3}"#,
            "rhs must be an array",
        ),
        (
            "rhs holds a non-number",
            r#"{"op": "solve", "operator": "cpu-layered", "n": 3, "nelt": 2, "rhs": [1.0, "x"]}"#,
            "rhs[1] is not a number",
        ),
    ];
    for (what, line, needle) in table {
        expect_config(parse_request(line, 50).map(|_| ()), needle, what);
    }
    // Unparseable bytes are a Json error (the server still answers with a
    // bad-request response, but the variant carries the byte offset).
    assert!(
        matches!(parse_request("not json at all", 50), Err(Error::Json { .. })),
        "malformed JSON must be an Error::Json"
    );
    // And the happy path still parses.
    assert!(
        parse_request(
            r#"{"op": "solve", "operator": "cpu-layered", "n": 3, "nelt": 2, "rhs": [1.0, 2.0]}"#,
            50,
        )
        .is_ok(),
        "a well-formed solve request must parse"
    );
}

#[test]
fn session_boundaries_name_the_offending_size() {
    let cfg = RunConfig { nelt: 2, n: 3, niter: 3, ..RunConfig::default() };
    let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    let ndof = app.mesh().ndof_local();
    let short_rhs = vec![0.0; ndof - 1];
    expect_config(app.set_rhs(&short_rhs), "set_rhs: length mismatch", "set_rhs");
    let mut session = app.session();
    let long_rhs = vec![1.0; ndof + 1];
    expect_config(
        session.solve(&long_rhs).map(|_| ()),
        "session solve: rhs has",
        "session solve",
    );
    let rhs = vec![1.0; ndof];
    let mut x_bad = vec![0.0; ndof - 1];
    expect_config(
        session.solve_into(&rhs, &mut x_bad).map(|_| ()),
        "solve_into: x_out has",
        "solve_into",
    );
    // Batch rejections carry the entry index.
    let batch: Vec<Vec<f64>> = vec![rhs.clone(), rhs[..ndof - 1].to_vec()];
    expect_config(
        session.solve_batch(&batch).map(|_| ()),
        "batch entry 1: session solve: rhs has",
        "solve_batch",
    );
}

#[test]
fn preconditioner_assembly_rejects_bad_inputs() {
    use nekbone::solver::{Chebyshev, Jacobi};
    let n = 3usize;
    let mesh = nekbone::mesh::Mesh::for_nelt(2, n).unwrap();
    let basis = nekbone::basis::Basis::new(n);
    let geom = nekbone::geometry::GeomFactors::affine(&mesh, &basis);
    let mask = mesh.boundary_mask();
    let mut gs = nekbone::gs::GatherScatter::new(&mesh);
    expect_config(
        Chebyshev::assemble(n, mesh.nelt(), &basis.d, &geom.g, &mut gs, Some(&mask), 0)
            .map(|_| ()),
        "Chebyshev order must be >= 1",
        "cheb order 0",
    );
    expect_config(
        Jacobi::assemble(n, mesh.nelt(), &basis.d, &geom.g[..10], &mut gs, None).map(|_| ()),
        "Jacobi::assemble: size mismatch",
        "jacobi short g",
    );
}
