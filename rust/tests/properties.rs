//! Cross-module property tests (DESIGN.md section 9): invariants of the
//! assembled operator, the gather–scatter, the chunker/padding contract,
//! and spectral convergence of the discretization.

use nekbone::basis::Basis;
use nekbone::geometry::GeomFactors;
use nekbone::gs::GatherScatter;
use nekbone::mesh::Mesh;
use nekbone::operators::{ax_layered, OperatorRegistry};
use nekbone::proputil::{assert_allclose, assert_pap_close, forall, Cases};
use nekbone::solver::{glsc3, mask_apply};

mod util;

/// Apply the *assembled* operator: A = mask . Q Q^T . A_local.
fn assembled_ax(
    mesh: &Mesh,
    basis: &Basis,
    geom: &GeomFactors,
    gs: &mut GatherScatter,
    mask: &[f64],
    u: &[f64],
) -> Vec<f64> {
    let mut w = vec![0.0; u.len()];
    ax_layered(mesh.n, mesh.nelt(), u, &basis.d, &geom.g, &mut w);
    gs.dssum(&mut w);
    let mut w2 = w;
    mask_apply(&mut w2, mask);
    w2
}

/// A dssum-consistent, masked random field (a valid CG iterate).
fn consistent_field(mesh: &Mesh, gs: &mut GatherScatter, mask: &[f64], c: &mut Cases) -> Vec<f64> {
    let mut v = c.vec_normal(mesh.ndof_local());
    gs.dssum(&mut v);
    mask_apply(&mut v, mask);
    v
}

#[test]
fn assembled_operator_symmetric() {
    // <A u, v>_c = <u, A v>_c over consistent fields — the property CG
    // needs. Weighted by inverse multiplicity (= the global inner product).
    forall(0x57, 8, |cases| {
        let n = cases.size(3, 5);
        let (ex, ey, ez) = (cases.size(1, 2), cases.size(1, 2), cases.size(1, 2));
        let mesh = Mesh::new(ex, ey, ez, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let u = consistent_field(&mesh, &mut gs, &mask, cases);
        let v = consistent_field(&mesh, &mut gs, &mask, cases);
        let au = assembled_ax(&mesh, &basis, &geom, &mut gs, &mask, &u);
        let av = assembled_ax(&mesh, &basis, &geom, &mut gs, &mask, &v);
        let lhs = glsc3(&au, &cw, &v);
        let rhs = glsc3(&u, &cw, &av);
        let scale = lhs.abs().max(rhs.abs()).max(1e-12);
        assert!((lhs - rhs).abs() / scale < 1e-9, "lhs {lhs} rhs {rhs}");
    });
}

#[test]
fn assembled_operator_positive_semidefinite() {
    forall(0x58, 8, |cases| {
        let n = cases.size(3, 5);
        let mesh = Mesh::new(2, 2, 1, n).unwrap();
        let basis = Basis::new(n);
        let geom = GeomFactors::affine(&mesh, &basis);
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let cw = mesh.inv_multiplicity();
        let u = consistent_field(&mesh, &mut gs, &mask, cases);
        let au = assembled_ax(&mesh, &basis, &geom, &mut gs, &mask, &u);
        let quad = glsc3(&au, &cw, &u);
        assert!(quad >= -1e-10, "quadratic form {quad}");
    });
}

#[test]
fn chunker_padding_is_inert() {
    // Zero-padded elements (zero geometric factors) must contribute w = 0:
    // computing on [real | padding] equals computing on [real] alone.
    forall(0x59, 10, |cases| {
        let n = cases.size(2, 6);
        let np = n * n * n;
        let real = cases.size(1, 5);
        let pad = cases.size(1, 4);
        let d = nekbone::basis::derivative_matrix(n);
        let mut u = cases.vec_normal((real + pad) * np);
        let mut g = cases.vec_normal(real * 6 * np);
        g.extend(std::iter::repeat(0.0).take(pad * 6 * np));
        // Garbage in the padded u region must not matter.
        for v in u[real * np..].iter_mut() {
            *v = 1e6;
        }
        let mut w_all = vec![0.0; (real + pad) * np];
        ax_layered(n, real + pad, &u, &d, &g, &mut w_all);
        let mut w_real = vec![0.0; real * np];
        ax_layered(n, real, &u[..real * np], &d, &g[..real * 6 * np], &mut w_real);
        assert_allclose(&w_all[..real * np], &w_real, 1e-12, 1e-12);
        assert!(w_all[real * np..].iter().all(|&x| x == 0.0), "padding produced output");
    });
}

#[test]
fn dssum_of_consistent_field_scales_by_multiplicity() {
    forall(0x5A, 10, |cases| {
        let n = cases.size(2, 5);
        let mesh = Mesh::new(cases.size(1, 3), cases.size(1, 2), cases.size(1, 2), n).unwrap();
        let mut gs = GatherScatter::new(&mesh);
        let mask = mesh.boundary_mask();
        let v = consistent_field(&mesh, &mut gs, &mask, cases);
        // A consistent field's copies are equal, so dssum multiplies each
        // dof by its multiplicity.
        let mult = mesh.multiplicity();
        let mut w = v.clone();
        gs.dssum(&mut w);
        let want: Vec<f64> = v.iter().zip(&mult).map(|(a, m)| a * m).collect();
        assert_allclose(&w, &want, 1e-12, 1e-12);
    });
}

#[test]
fn solution_vanishes_on_boundary_and_matches_operator() {
    // Solve, then verify A x ≈ f on the masked subspace (true residual).
    use nekbone::config::RunConfig;
    use nekbone::coordinator::Nekbone;
    let cfg = RunConfig { nelt: 8, n: 5, niter: 400, ..Default::default() };
    let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    let mesh = app.mesh().clone();
    let mut x = vec![0.0; mesh.ndof_local()];
    let rep = app.run_into(Some(&mut x)).unwrap();
    assert!(rep.final_residual < 1e-8, "residual {}", rep.final_residual);
    let mask = mesh.boundary_mask();
    for (xi, mi) in x.iter().zip(&mask) {
        if *mi == 0.0 {
            assert_eq!(*xi, 0.0, "Dirichlet dof nonzero");
        }
    }
}

#[test]
fn spectral_convergence_of_interpolation_quadrature() {
    // The SEM machinery converges spectrally: integrating a smooth field
    // with the GLL quadrature through the geometric factors' weight part
    // gets exponentially accurate with n. We test via the mass-like sum
    // sum w |J| f(x) -> integral of f over the unit cube.
    let pi = std::f64::consts::PI;
    let f = move |x: f64, y: f64, z: f64| (pi * x).sin() * (pi * y).sin() * (pi * z).sin();
    // Exact: (∫_0^1 sin(πt) dt)^3 = (2/π)^3.
    let exact = (2.0 / pi).powi(3);
    let mut errs = Vec::new();
    for n in [3, 5, 7, 9] {
        let mesh = Mesh::new(2, 2, 2, n).unwrap();
        let basis = Basis::new(n);
        let (xs, ys, zs) = mesh.coordinates(&basis.points);
        let mut quad = 0.0;
        let npts = n * n * n;
        for e in 0..mesh.nelt() {
            let (lo, hi) = mesh.element_bounds(e);
            let detj = (hi[0] - lo[0]) * (hi[1] - lo[1]) * (hi[2] - lo[2]) / 8.0;
            for k in 0..n {
                for j in 0..n {
                    for i in 0..n {
                        let idx = e * npts + (k * n + j) * n + i;
                        let w = basis.weights[i] * basis.weights[j] * basis.weights[k];
                        quad += w * detj * f(xs[idx], ys[idx], zs[idx]);
                    }
                }
            }
        }
        errs.push((quad - exact).abs());
    }
    // Each degree bump shrinks the error by at least 10x until round-off.
    for w in errs.windows(2) {
        assert!(
            w[1] < w[0] / 10.0 || w[1] < 1e-12,
            "no spectral decay: {errs:?}"
        );
    }
}

#[test]
fn fused_pap_matches_unfused_glsc3_across_shapes() {
    // The fused-operator contract: after apply(u, w), last_pap() equals
    // glsc3(w, c, u) of the unfused path, for every artifact-free fused
    // backend (enumerated from the registry, never hand-listed), across
    // random shapes/thread counts.
    let registry = OperatorRegistry::with_builtins();
    let fused_names: Vec<String> = registry
        .names()
        .into_iter()
        .filter(|name| {
            let spec = registry.resolve(name).unwrap();
            !spec.needs_artifacts && spec.create().is_fused()
        })
        .collect();
    assert!(fused_names.len() >= 10, "registry lost fused CPU operators: {fused_names:?}");
    forall(0xFA7, 12, |cases| {
        let n = cases.size(2, 7);
        let nelt = cases.size(1, 6);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = nekbone::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        let threads = cases.size(1, 4);
        let ctx = util::ctx(n, nelt, threads, "artifacts", &d, &g, &c);
        // Unfused references: the layered kernel + a separate glsc3 sweep.
        // The `-f32` family solves the once-rounded system, so its
        // reference is the same kernel over pre-rounded factors — the
        // tolerance stays the tight f64 band either way.
        let mut w_ref = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut w_ref);
        let want_pap = glsc3(&w_ref, &c, &u);
        let g_rounded: Vec<f64> = g.iter().map(|&x| (x as f32) as f64).collect();
        let mut w_ref32 = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g_rounded, &mut w_ref32);
        let want_pap32 = glsc3(&w_ref32, &c, &u);
        for name in &fused_names {
            let (w_b, pap_b) = if name.ends_with("-f32") {
                (&w_ref32, want_pap32)
            } else {
                (&w_ref, want_pap)
            };
            let mut op = registry.build(name, &ctx).unwrap();
            let mut w = vec![0.0; nelt * np];
            op.apply(&u, &mut w).unwrap();
            assert_allclose(&w, w_b, 1e-11, 1e-11);
            let pap = op.last_pap().expect("fused operator must report pap");
            // Term-scaled tolerance: robust when the signed sum cancels,
            // still tight enough to catch a real defect (the
            // simd-dispatched operators legitimately differ from the
            // layered reference by FMA rounding).
            assert_pap_close(pap, pap_b, &w, &c, &u, 1e-11, name);
        }
    });
}

#[test]
fn fused_cg_reproduces_unfused_trajectory() {
    // A CG solve through cpu-layered-fused must walk the same iterate
    // trajectory as the unfused operator: same iteration count, solution
    // allclose — and save exactly niter full glsc3 sweeps along the way.
    use nekbone::solver::{cg_solve_op, CgOptions, CgWorkspace, NullComm};
    let n = 5;
    let mesh = Mesh::new(2, 2, 2, n).unwrap();
    let basis = Basis::new(n);
    let geom = GeomFactors::affine(&mesh, &basis);
    let mask = mesh.boundary_mask();
    let cw = mesh.inv_multiplicity();
    let ndof = mesh.ndof_local();
    let mut rng = nekbone::rng::Rng::new(0xF00D);
    let mut f = rng.normal_vec(ndof);
    {
        let mut gs = GatherScatter::new(&mesh);
        gs.dssum(&mut f);
    }
    mask_apply(&mut f, &mask);
    let opts = CgOptions { niter: 30, rtol: None, record_residuals: false };
    let registry = OperatorRegistry::with_builtins();
    let ctx = util::ctx(n, mesh.nelt(), 0, "artifacts", &basis.d, &geom.g, &cw);
    let mut solve = |name: &str| {
        let mut op = registry.build(name, &ctx).unwrap();
        let mut gs = GatherScatter::new(&mesh);
        let mut x = vec![0.0; ndof];
        let mut ws = CgWorkspace::new(ndof);
        let rep = cg_solve_op(
            op.as_mut(),
            &mut gs,
            &mut NullComm,
            Some(&mask),
            &cw,
            &f,
            &mut x,
            &opts,
            &mut ws,
        )
        .unwrap();
        (rep, x)
    };
    let (rep_u, x_u) = solve("cpu-layered");
    let (rep_f, x_f) = solve("cpu-layered-fused");
    assert_eq!(rep_f.iterations, rep_u.iterations, "same trajectory length");
    assert_allclose(&x_f, &x_u, 1e-9, 1e-11);
    assert_eq!(
        rep_u.glsc3_sweeps - rep_f.glsc3_sweeps,
        opts.niter,
        "fused CG must perform exactly niter fewer glsc3 sweeps \
         (unfused {} vs fused {})",
        rep_u.glsc3_sweeps,
        rep_f.glsc3_sweeps
    );
}

#[test]
fn jacobi_pcg_converges_no_slower() {
    // The paper's future work (section VII): preconditioned CG. On the
    // masked SEM system Jacobi must reach a tolerance in no more
    // iterations than plain CG, with both converging to the same solution.
    use nekbone::solver::{cg_solve_pc, CgOptions, CgWorkspace, Jacobi, NullComm};
    let n = 5;
    let mesh = Mesh::new(2, 2, 2, n).unwrap();
    let basis = Basis::new(n);
    let geom = GeomFactors::affine(&mesh, &basis);
    let mask = mesh.boundary_mask();
    let cw = mesh.inv_multiplicity();
    let ndof = mesh.ndof_local();
    let mut rng = nekbone::rng::Rng::new(0x9C6);
    let mut f = rng.normal_vec(ndof);
    {
        let mut gs = GatherScatter::new(&mesh);
        gs.dssum(&mut f);
    }
    for (fi, mi) in f.iter_mut().zip(&mask) {
        *fi *= mi;
    }

    let run = |precond: bool| {
        let mut gs = GatherScatter::new(&mesh);
        let jac = Jacobi::assemble(n, mesh.nelt(), &basis.d, &geom.g, &mut gs, Some(&mask))
            .unwrap();
        let mut ax = |p: &[f64], w: &mut [f64]| -> nekbone::Result<()> {
            ax_layered(n, mesh.nelt(), p, &basis.d, &geom.g, w);
            Ok(())
        };
        let mut x = vec![0.0; ndof];
        let mut ws = CgWorkspace::new(ndof);
        let opts = CgOptions { niter: 500, rtol: Some(1e-10), record_residuals: true };
        let rep = cg_solve_pc(
            &mut ax,
            &mut gs,
            &mut NullComm,
            Some(&mask),
            &cw,
            &f,
            &mut x,
            &opts,
            &mut ws,
            precond.then_some(&jac),
        )
        .unwrap();
        (rep.iterations, x)
    };
    let (iters_plain, x_plain) = run(false);
    let (iters_pcg, x_pcg) = run(true);
    assert!(
        iters_pcg <= iters_plain,
        "Jacobi PCG took {iters_pcg} vs plain {iters_plain}"
    );
    assert_allclose(&x_pcg, &x_plain, 1e-6, 1e-8);
}

#[test]
fn chebyshev_pcg_cuts_iterations_below_jacobi() {
    // Chebyshev-accelerated Jacobi contracts the whole Jacobi-
    // preconditioned band at once: to the same tolerance it must need
    // strictly fewer CG iterations than plain Jacobi (each bought with
    // `order - 1` extra Ax sweeps), while converging to the same field.
    use nekbone::solver::{
        cg_solve_precond, CgOptions, CgWorkspace, Chebyshev, Jacobi, NullComm, Precond,
    };
    let n = 5;
    let mesh = Mesh::new(2, 2, 2, n).unwrap();
    let basis = Basis::new(n);
    let geom = GeomFactors::affine(&mesh, &basis);
    let mask = mesh.boundary_mask();
    let cw = mesh.inv_multiplicity();
    let ndof = mesh.ndof_local();
    let mut f = nekbone::rng::Rng::new(0x9C7).normal_vec(ndof);
    {
        let mut gs = GatherScatter::new(&mesh);
        gs.dssum(&mut f);
    }
    for (fi, mi) in f.iter_mut().zip(&mask) {
        *fi *= mi;
    }

    let run = |pc: &dyn Fn(&mut GatherScatter) -> Precond| {
        let mut gs = GatherScatter::new(&mesh);
        let precond = pc(&mut gs);
        let mut ax = |p: &[f64], w: &mut [f64]| -> nekbone::Result<()> {
            ax_layered(n, mesh.nelt(), p, &basis.d, &geom.g, w);
            Ok(())
        };
        let mut x = vec![0.0; ndof];
        let mut ws = CgWorkspace::new(ndof);
        let opts = CgOptions { niter: 500, rtol: Some(1e-10), record_residuals: true };
        let rep = cg_solve_precond(
            &mut ax,
            &mut gs,
            &mut NullComm,
            Some(&mask),
            &cw,
            &f,
            &mut x,
            &opts,
            &mut ws,
            Some(&precond),
        )
        .unwrap();
        (rep.iterations, x)
    };
    let (iters_jac, x_jac) = run(&|gs| {
        Precond::Jacobi(
            Jacobi::assemble(n, mesh.nelt(), &basis.d, &geom.g, gs, Some(&mask)).unwrap(),
        )
    });
    let (iters_cheb, x_cheb) = run(&|gs| {
        Precond::Chebyshev(
            Chebyshev::assemble(n, mesh.nelt(), &basis.d, &geom.g, gs, Some(&mask), 4)
                .unwrap(),
        )
    });
    assert!(
        iters_cheb < iters_jac,
        "Chebyshev(4) took {iters_cheb} iterations vs Jacobi's {iters_jac}"
    );
    assert_allclose(&x_cheb, &x_jac, 1e-6, 1e-8);
}

#[test]
fn spec_operators_match_layered_across_all_degrees() {
    // The degree-specialized kernels (`cpu-spec`, `cpu-spec-fused`) must
    // reproduce the generic layered schedule at every monomorphized degree
    // (n = 2..=12) on random meshes — bit-identical output and pap, which
    // is the contract the worker pool's degree dispatch relies on.
    let registry = OperatorRegistry::with_builtins();
    for n in 2..=12usize {
        assert!(nekbone::operators::is_specialized(n));
        let mut cases = Cases::new(0x57EC + n as u64);
        let nelt = cases.size(1, 4);
        let np = n * n * n;
        let u = cases.vec_normal(nelt * np);
        let d = nekbone::basis::derivative_matrix(n);
        let g = cases.vec_normal(nelt * 6 * np);
        let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
        let ctx = util::ctx(n, nelt, 0, "artifacts", &d, &g, &c);
        let mut w_ref = vec![0.0; nelt * np];
        registry.build("cpu-layered", &ctx).unwrap().apply(&u, &mut w_ref).unwrap();
        let mut spec = registry.build("cpu-spec", &ctx).unwrap();
        let mut w = vec![123.0; nelt * np]; // poisoned
        spec.apply(&u, &mut w).unwrap();
        assert_eq!(w, w_ref, "n={n}: cpu-spec must be bit-identical to cpu-layered");

        let mut lf = registry.build("cpu-layered-fused", &ctx).unwrap();
        let mut w_lf = vec![0.0; nelt * np];
        lf.apply(&u, &mut w_lf).unwrap();
        let mut sf = registry.build("cpu-spec-fused", &ctx).unwrap();
        let mut w_sf = vec![123.0; nelt * np];
        sf.apply(&u, &mut w_sf).unwrap();
        assert_eq!(w_sf, w_lf, "n={n}: fused spec w");
        let (pap_s, pap_l) = (sf.last_pap().unwrap(), lf.last_pap().unwrap());
        assert_eq!(pap_s.to_bits(), pap_l.to_bits(), "n={n}: {pap_s} vs {pap_l}");
    }
}

#[test]
fn spec_out_of_range_degree_falls_back_instead_of_erroring() {
    // n = 13 has no monomorphized kernel instance: the cpu-spec operators
    // must still build and apply (falling back to the layered kernel, as
    // documented), not error out.
    let n = 13;
    assert!(!nekbone::operators::is_specialized(n));
    let registry = OperatorRegistry::with_builtins();
    let mut cases = Cases::new(0xFB13);
    let nelt = 2;
    let np = n * n * n;
    let u = cases.vec_normal(nelt * np);
    let d = nekbone::basis::derivative_matrix(n);
    let g = cases.vec_normal(nelt * 6 * np);
    let c = cases.vec_uniform(nelt * np, 0.1, 1.0);
    let ctx = util::ctx(n, nelt, 0, "artifacts", &d, &g, &c);
    let mut w_ref = vec![0.0; nelt * np];
    ax_layered(n, nelt, &u, &d, &g, &mut w_ref);
    let mut spec = registry.build("cpu-spec", &ctx).expect("out-of-range n must still build");
    let mut w = vec![0.0; nelt * np];
    spec.apply(&u, &mut w).expect("out-of-range n must still apply");
    assert_eq!(w, w_ref, "fallback must be the layered kernel");

    let mut sf = registry.build("cpu-spec-fused", &ctx).expect("fused fallback builds");
    let mut w_sf = vec![0.0; nelt * np];
    sf.apply(&u, &mut w_sf).unwrap();
    assert_eq!(w_sf, w_ref);
    let want_pap = glsc3(&w_ref, &c, &u);
    assert_allclose(&[sf.last_pap().unwrap()], &[want_pap], 1e-11, 1e-11);
}
