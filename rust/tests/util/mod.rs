//! Shared operator-agreement helpers for the integration-test suites.
//!
//! Every file under `tests/` is its own crate, so each comparison suite
//! (`conformance`, `simd`, `mixed_precision`, `properties`,
//! `fuzz_differential`) includes this module via `mod util;` — the input
//! recipe, the `OperatorCtx` construction, and the tolerance ladder live
//! in exactly one place:
//!
//! * **bitwise** — the Exact tier, and any two operators sharing one
//!   schedule;
//! * **[`FMA_BAND`]** — reassociation-only differences (AVX2 FMA
//!   contraction, thread partitioning) between f64 schedules;
//! * **[`REDUCED_BAND`]** — f32-stored geometric factors against an f64
//!   reference: the factors round once at setup, the arithmetic still
//!   accumulates in f64.
//!
//! [`joint_band`] maps a *pair* of declared
//! [`PrecisionTier`]s onto that ladder and [`joint_cg_tol`] does the same
//! for whole CG trajectories — the comparators the differential fuzz
//! tier drives for every operator pair.
#![allow(dead_code)] // each suite uses its own subset

use nekbone::operators::{simd_arm, OperatorCtx, PrecisionTier, SimdArm};
use nekbone::rng::Rng;

/// Per-point band for reassociation-only differences (FMA contraction,
/// thread partitioning) between f64 schedules.
pub const FMA_BAND: f64 = 1e-11;

/// Per-point band for f32-stored geometric factors against an f64
/// reference: rounding the six factors once perturbs each of the ~12n
/// products feeding a point by at most one ulp(f32) relatively, so `1e-5`
/// leaves ~10× headroom at n = 12 while still catching any
/// double-rounding or f32 *accumulation* bug by orders of magnitude.
pub const REDUCED_BAND: f64 = 1e-5;

/// Deterministic operator inputs for one `(n, nelt)` case: normal `u` and
/// `g`, the exact GLL derivative matrix, and strictly positive `c` (the
/// inner-product weights are positive in a real solve).
pub fn inputs(seed: u64, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let np = n * n * n;
    let u = rng.normal_vec(nelt * np);
    let d = nekbone::basis::derivative_matrix(n);
    let g = rng.normal_vec(nelt * 6 * np);
    let c: Vec<f64> = (0..nelt * np).map(|_| rng.range(0.1, 1.0)).collect();
    (u, d, g, c)
}

/// The one place the integration suites build an [`OperatorCtx`] over
/// synthetic inputs. Synthetic `g` has no mesh behind it, so there is no
/// assembly plan (`assemble: None`) — `cpu-asm*` run their plan-less
/// layered fallback and compare like any other operator.
pub fn ctx<'a>(
    n: usize,
    nelt: usize,
    threads: usize,
    artifacts_dir: &'a str,
    d: &'a [f64],
    g: &'a [f64],
    c: &'a [f64],
) -> OperatorCtx<'a> {
    OperatorCtx { n, nelt, chunk: nelt, threads, artifacts_dir, d, g, c, assemble: None }
}

/// Bitwise equality with a per-point failure message.
pub fn assert_bitwise(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}[{i}]: got {g}, want {w} (bitwise)"
        );
    }
}

/// Banded comparison: per point `band * (|want| + max|want|)` — the
/// magnitude-scaled absolute term keeps cancellation points honest.
pub fn assert_within_band(got: &[f64], want: &[f64], band: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = band * (w.abs() + scale);
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, want {w} exceeds the band {tol:e}"
        );
    }
}

/// The agreement band implied by a *pair* of declared precision tiers
/// (`None` = bitwise). Two `Exact` operators share one schedule; an f32
/// operator against an f64 one differs by the factor rounding; two f32
/// operators share the same once-rounded system, so — like any remaining
/// pair — only reassociation separates them.
pub fn joint_band(a: PrecisionTier, b: PrecisionTier) -> Option<f64> {
    use PrecisionTier::*;
    match (a, b) {
        (Exact, Exact) => None,
        (ReducedStorage, ReducedStorage) => Some(FMA_BAND),
        (ReducedStorage, _) | (_, ReducedStorage) => Some(REDUCED_BAND),
        _ => Some(FMA_BAND),
    }
}

/// Compare two operator outputs at a joint tier band from [`joint_band`].
pub fn assert_agree_at(got: &[f64], want: &[f64], band: Option<f64>, what: &str) {
    match band {
        None => assert_bitwise(got, want, what),
        Some(b) => assert_within_band(got, want, b, what),
    }
}

/// Relative tolerance for comparing two full CG trajectories (residual
/// norms, solution fields): within one storage class the trajectories
/// track to ~1e-9 over tens of iterations, so `1e-8` leaves headroom;
/// across the f32/f64 seam the two solves target *different nearby
/// systems* and only storage-band agreement survives the iteration.
pub fn joint_cg_tol(a: PrecisionTier, b: PrecisionTier) -> f64 {
    if (a == PrecisionTier::ReducedStorage) == (b == PrecisionTier::ReducedStorage) {
        1e-8
    } else {
        1e-3
    }
}

/// Arm-aware family comparison (the SIMD suite's contract): the scalar
/// dispatch arm must be bit-identical, the AVX2 arm may differ by FMA
/// contraction — a `1e-13` band, tighter than [`FMA_BAND`] because a
/// single apply involves contraction but never partitioning.
pub fn assert_family_close(got: &[f64], want: &[f64], what: &str) {
    match simd_arm() {
        SimdArm::Scalar => assert_bitwise(got, want, what),
        SimdArm::Avx2 => assert_within_band(got, want, 1e-13, what),
    }
}
