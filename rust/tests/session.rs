//! SolveSession coverage: repeated solves reuse all state (no operator
//! re-setup, no workspace churn), batches match independent solves, and
//! the report content is stable across reuse.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::operators::{ax_layered, AxOperator, OperatorCtx, OperatorRegistry};

/// Test-only operator wrapping the layered kernel, counting `setup` and
/// `apply` calls so tests can assert state reuse across a session.
struct CountingOp {
    setups: Arc<AtomicUsize>,
    applies: Arc<AtomicUsize>,
    st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
}

impl AxOperator for CountingOp {
    fn label(&self) -> String {
        "test-counting".into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> nekbone::Result<()> {
        self.setups.fetch_add(1, Ordering::SeqCst);
        self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> nekbone::Result<()> {
        self.applies.fetch_add(1, Ordering::SeqCst);
        let (n, nelt, d, g) = self.st.as_ref().expect("setup ran");
        ax_layered(*n, *nelt, u, d, g, w);
        Ok(())
    }

    fn flops(&self) -> u64 {
        0
    }
}

fn counting_app(cfg: RunConfig) -> (Nekbone, Arc<AtomicUsize>, Arc<AtomicUsize>) {
    let setups = Arc::new(AtomicUsize::new(0));
    let applies = Arc::new(AtomicUsize::new(0));
    let (s, a) = (Arc::clone(&setups), Arc::clone(&applies));
    let mut registry = OperatorRegistry::with_builtins();
    registry
        .register("test-counting", false, move || {
            Box::new(CountingOp {
                setups: Arc::clone(&s),
                applies: Arc::clone(&a),
                st: None,
            })
        })
        .unwrap();
    let app = Nekbone::builder(cfg)
        .registry(registry)
        .operator("test-counting")
        .build()
        .unwrap();
    (app, setups, applies)
}

fn cfg() -> RunConfig {
    RunConfig { nelt: 8, n: 4, niter: 12, ..Default::default() }
}

#[test]
fn repeated_session_solves_do_not_rebuild_state() {
    // The reuse contract: one operator setup for the whole session, one
    // apply per CG iteration, nothing rebuilt between solves.
    let (mut app, setups, applies) = counting_app(cfg());
    assert_eq!(setups.load(Ordering::SeqCst), 1, "builder sets up exactly once");
    let ndof = app.mesh().ndof_local();
    let rhss: Vec<Vec<f64>> = (0..3)
        .map(|i| nekbone::rng::Rng::new(nekbone::rng::rhs_seed(7, i as u64)).normal_vec(ndof))
        .collect();

    let mut session = app.session();
    let reports = session.solve_batch(&rhss).unwrap();
    assert_eq!(reports.len(), 3);
    assert_eq!(
        setups.load(Ordering::SeqCst),
        1,
        "session solves must reuse the operator, not re-set it up"
    );
    let total_iters: usize = reports.iter().map(|r| r.iterations).sum();
    assert_eq!(
        applies.load(Ordering::SeqCst),
        total_iters,
        "exactly one operator application per CG iteration"
    );
    // Identical sweep accounting for every entry: the reused workspace
    // changes nothing about the solver's work.
    for r in &reports[1..] {
        assert_eq!(r.glsc3_sweeps, reports[0].glsc3_sweeps);
    }
}

#[test]
fn repeated_identical_solves_are_identical() {
    // Same rhs through one session twice: bitwise-identical report (the
    // workspace carries no state between solves).
    let (mut app, _setups, _applies) = counting_app(cfg());
    let ndof = app.mesh().ndof_local();
    let rhs = nekbone::rng::Rng::new(41).normal_vec(ndof);
    let mut session = app.session();
    let a = session.solve(&rhs).unwrap();
    let first: Vec<f64> = session.solution().to_vec();
    let b = session.solve(&rhs).unwrap();
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.final_rnorm.to_bits(), b.final_rnorm.to_bits());
    assert_eq!(a.rtz1.to_bits(), b.rtz1.to_bits());
    assert_eq!(a.glsc3_sweeps, b.glsc3_sweeps);
    assert_eq!(first, session.solution());
}

#[test]
fn batch_matches_independent_solves_unfused() {
    // solve_batch == N independent fresh applications, entry by entry
    // (the fused-operator variant of this is in e2e.rs).
    let rhs_count = 3;
    let (mut app, ..) = counting_app(cfg());
    let ndof = app.mesh().ndof_local();
    let rhss: Vec<Vec<f64>> = (0..rhs_count)
        .map(|i| nekbone::rng::Rng::new(nekbone::rng::rhs_seed(90, i as u64)).normal_vec(ndof))
        .collect();
    let mut session = app.session();
    let reports = session.solve_batch(&rhss).unwrap();

    for (i, (rhs, rep)) in rhss.iter().zip(&reports).enumerate() {
        let (mut fresh, ..) = counting_app(cfg());
        fresh.set_rhs(rhs).unwrap();
        let want = fresh.run().unwrap();
        assert_eq!(rep.iterations, want.iterations, "entry {i}");
        assert_eq!(
            rep.final_rnorm.to_bits(),
            want.final_residual.to_bits(),
            "entry {i}: {} vs {}",
            rep.final_rnorm,
            want.final_residual
        );
    }
}

#[test]
fn fused_last_pap_not_stale_across_batch_entries() {
    // Two very different right-hand sides through a fused-operator
    // session: if the second entry consumed the first entry's fused pap
    // (stale state), its trajectory would diverge from an independent
    // solve. Uses the single-thread fused operator for bitwise
    // comparability.
    let base = cfg();
    let mut app = Nekbone::builder(base.clone())
        .operator("cpu-layered-fused")
        .build()
        .unwrap();
    let ndof = app.mesh().ndof_local();
    let rhs_a = nekbone::rng::Rng::new(5).normal_vec(ndof);
    let rhs_b: Vec<f64> = nekbone::rng::Rng::new(6)
        .normal_vec(ndof)
        .iter()
        .map(|v| v * 1e3)
        .collect();

    let mut session = app.session();
    let reports = session.solve_batch(&[rhs_a, rhs_b.clone()]).unwrap();

    let mut fresh = Nekbone::builder(base).operator("cpu-layered-fused").build().unwrap();
    fresh.set_rhs(&rhs_b).unwrap();
    let want = fresh.run().unwrap();
    assert_eq!(reports[1].iterations, want.iterations);
    assert_eq!(
        reports[1].final_rnorm.to_bits(),
        want.final_residual.to_bits(),
        "second batch entry diverged: {} vs {} (stale fused pap?)",
        reports[1].final_rnorm,
        want.final_residual
    );
}

#[test]
fn session_honors_config_rtol() {
    // Session solves run the same solver with the same options as
    // Nekbone::run — including early exit.
    let with_history = RunConfig { record_residuals: true, ..cfg() };
    let mut app = Nekbone::builder(with_history).operator("cpu-layered").build().unwrap();
    let ndof = app.mesh().ndof_local();
    let rhs = nekbone::rng::Rng::new(77).normal_vec(ndof);
    let mut session = app.session();
    let rep = session.solve(&rhs).unwrap();
    assert_eq!(rep.rnorms.len(), rep.iterations);
    let tol = (rep.rnorms[4] * rep.rnorms[5]).sqrt();

    let tol_cfg = RunConfig { rtol: Some(tol), ..cfg() };
    let mut tapp = Nekbone::builder(tol_cfg).operator("cpu-layered").build().unwrap();
    let mut tsession = tapp.session();
    let trep = tsession.solve(&rhs).unwrap();
    assert!(trep.iterations < 12, "rtol must exit early: {}", trep.iterations);
    assert!(trep.final_rnorm <= tol);
}
