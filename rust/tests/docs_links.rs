//! Doc-link check: every relative markdown link in the repo's curated
//! docs must resolve to an existing file or directory. CI runs the same
//! check as a standalone job; this test keeps it enforced by plain
//! `cargo test` too.

use std::path::{Path, PathBuf};

/// Extract `](target)` link targets from markdown text (inline links
/// only — that is the only style these docs use).
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            if let Some(end) = text[i + 2..].find(')') {
                out.push(text[i + 2..i + 2 + end].to_string());
                i += 2 + end;
                continue;
            }
        }
        i += 1;
    }
    out
}

fn is_external(target: &str) -> bool {
    target.starts_with("http://")
        || target.starts_with("https://")
        || target.starts_with("mailto:")
        || target.starts_with('#')
}

#[test]
fn markdown_links_resolve() {
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let files = ["docs/ARCHITECTURE.md", "rust/README.md", "ROADMAP.md"];
    let mut checked = 0;
    for rel in files {
        let path = repo.join(rel);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
        let dir = path.parent().unwrap_or(Path::new("."));
        for target in link_targets(&text) {
            if is_external(&target) || target.is_empty() {
                continue;
            }
            // Strip an in-file anchor (`file.md#section`).
            let file_part = target.split('#').next().unwrap();
            if file_part.is_empty() {
                continue;
            }
            let resolved = dir.join(file_part);
            assert!(
                resolved.exists(),
                "{rel}: broken link {target:?} (resolved to {})",
                resolved.display()
            );
            checked += 1;
        }
    }
    assert!(checked > 10, "expected the docs to contain relative links, found {checked}");
}

#[test]
fn architecture_doc_is_linked_from_readme() {
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    assert!(repo.join("docs/ARCHITECTURE.md").exists());
    let readme = std::fs::read_to_string(repo.join("rust/README.md")).unwrap();
    assert!(
        readme.contains("docs/ARCHITECTURE.md"),
        "rust/README.md must link the architecture doc"
    );
}

#[test]
fn link_extraction_handles_edge_cases() {
    let text = "a [x](one.md) b [y](https://e.com) c [z](dir/two.md#sec) trailing ](";
    let links = link_targets(text);
    assert_eq!(links, vec!["one.md", "https://e.com", "dir/two.md#sec"]);
    assert!(is_external("https://e.com"));
    assert!(is_external("#anchor"));
    assert!(!is_external("one.md"));
}
